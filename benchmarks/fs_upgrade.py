"""Online-upgrade benchmark (paper §4.8 — future work there, implemented
here): measures service pause seen by a concurrent workload while the
mounted file system is hot-swapped, plus upgrade-path microtimings.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List

from repro.core.upgrade import upgrade
from repro.fs.mounts import make_mount
from repro.fs.xv6 import Xv6FileSystem, Xv6Options


def run(n_upgrades: int = 5, workload_seconds: float = 2.0) -> Dict:
    mf = make_mount("bento", n_blocks=16384)
    v = mf.view
    v.makedirs("/w")
    stop = threading.Event()
    op_times: List[float] = []
    errors: List[str] = []

    def workload():
        i = 0
        while not stop.is_set():
            t0 = time.perf_counter()
            try:
                v.write_file(f"/w/f{i % 32:03d}", b"z" * 4096)
                v.read_file(f"/w/f{i % 32:03d}")
            except Exception as e:  # noqa: BLE001 — any error fails the claim
                errors.append(str(e))
            op_times.append(time.perf_counter() - t0)
            i += 1

    t = threading.Thread(target=workload, daemon=True)
    t.start()
    time.sleep(workload_seconds / 2)
    stats = []
    for _ in range(n_upgrades):
        s = upgrade(mf.mount, Xv6FileSystem(Xv6Options()))
        stats.append(s)
        time.sleep(workload_seconds / (2 * n_upgrades))
    stop.set()
    t.join(timeout=5)
    mf.close()
    total = [s["total_s"] for s in stats]
    return {
        "bench": "online_upgrade",
        "n_upgrades": n_upgrades,
        "ops_during": len(op_times),
        "failed_ops": len(errors),
        "upgrade_total_ms_mean": 1e3 * sum(total) / len(total),
        "upgrade_total_ms_max": 1e3 * max(total),
        "workload_op_ms_p99": 1e3 * sorted(op_times)[int(0.99 * len(op_times))]
        if op_times else None,
    }
