"""Online-upgrade benchmark (paper §4.8 + §6): measures the service pause
seen by concurrent workloads while the mounted file system is hot-swapped.

Two modes:

* ``run()`` — the original single-workload pause measurement: same-module
  upgrades under one background thread.

* ``run_under_load()`` — the paper's headline demo, measured: N submitter
  threads hammer the mount through the multi-submitter queue while the
  provenance layer (``repro.fs.prov``) is hot-swapped ON (plain → prov)
  and back OFF (prov → plain). Reports:

    - the swap pauses (``upgrade`` timing stats — the paper's 15 ms claim,
      here interpreter-scaled),
    - the longest completion gap any submitter observed (the pause as the
      application feels it),
    - plain-window vs prov-window throughput (the provenance overhead
      budget),
    - provenance-record and completion-integrity tripwires, asserted — a
      lost completion, a mis-ordered batch or an empty log fails the run
      (CI executes ``--under-load --quick``).

CLI:  PYTHONPATH=src python -m benchmarks.fs_upgrade --under-load [--quick]
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List

from repro.core.interface import PrevResult, SQE_LINK, SubmissionEntry
from repro.core.upgrade import unwrap_layer, upgrade, wrap_layer
from repro.fs.mounts import make_mount
from repro.fs.prov import ProvFilesystem
from repro.fs.xv6 import Xv6FileSystem, Xv6Options


def run(n_upgrades: int = 5, workload_seconds: float = 2.0) -> Dict:
    mf = make_mount("bento", n_blocks=16384)
    v = mf.view
    v.makedirs("/w")
    stop = threading.Event()
    op_times: List[float] = []
    errors: List[str] = []

    def workload():
        i = 0
        while not stop.is_set():
            t0 = time.perf_counter()
            try:
                v.write_file(f"/w/f{i % 32:03d}", b"z" * 4096)
                v.read_file(f"/w/f{i % 32:03d}")
            except Exception as e:  # noqa: BLE001 — any error fails the claim
                errors.append(str(e))
            op_times.append(time.perf_counter() - t0)
            i += 1

    t = threading.Thread(target=workload, daemon=True)
    t.start()
    time.sleep(workload_seconds / 2)
    stats = []
    for _ in range(n_upgrades):
        s = upgrade(mf.mount, Xv6FileSystem(Xv6Options()))
        stats.append(s)
        time.sleep(workload_seconds / (2 * n_upgrades))
    stop.set()
    t.join(timeout=5)
    mf.close()
    total = [s["total_s"] for s in stats]
    return {
        "bench": "online_upgrade",
        "n_upgrades": n_upgrades,
        "ops_during": len(op_times),
        "failed_ops": len(errors),
        "upgrade_total_ms_mean": 1e3 * sum(total) / len(total),
        "upgrade_total_ms_max": 1e3 * max(total),
        "workload_op_ms_p99": 1e3 * sorted(op_times)[int(0.99 * len(op_times))]
        if op_times else None,
    }


# --- the §6 demo, measured: hot-swap provenance under N submitters ----------------


class _Submitter:
    """One thread's scripted workload through ``mount.submit``: rounds of
    a chained create→write(PrevResult) pair plus reads, every completion
    checked against its submission (user_data order + expected results).
    Completion timestamps feed the observed-pause metric."""

    def __init__(self, mount, dino: int, t: int, payload: bytes):
        self.m = mount
        self.dino = dino
        self.t = t
        self.payload = payload
        self.rounds: List[Dict] = []   # {name, t_end, gen_before, gen_after}
        self.errors: List[str] = []

    def run(self, stop: threading.Event) -> None:
        r = 0
        while not stop.is_set():
            name = f"t{self.t}_r{r:05d}"
            entries = [
                SubmissionEntry("create", (self.dino, name),
                                user_data=(r, "c"), flags=SQE_LINK),
                SubmissionEntry("write", (PrevResult("ino"), 0, self.payload),
                                user_data=(r, "w")),
                SubmissionEntry("getattr", (self.dino,), user_data=(r, "g")),
            ]
            gen_before = getattr(self.m, "generation", 0)
            try:
                comps = self.m.submit(entries)
            except Exception as e:  # noqa: BLE001 — surfaced by the caller
                self.errors.append(f"t{self.t} r{r}: {type(e).__name__}: {e}")
                return
            gen_after = getattr(self.m, "generation", 0)
            if [c.user_data for c in comps] != [e.user_data for e in entries]:
                self.errors.append(f"t{self.t} r{r}: completions lost/"
                                   f"reordered: {[c.user_data for c in comps]}")
            elif not all(c.ok for c in comps) \
                    or comps[1].result != len(self.payload):
                self.errors.append(
                    f"t{self.t} r{r}: bad completion "
                    f"{[(c.user_data, c.errno, c.result) for c in comps]}")
            self.rounds.append({"name": name, "t_end": time.perf_counter(),
                                "gen_before": gen_before,
                                "gen_after": gen_after})
            r += 1


def _max_completion_gap(subs: List[_Submitter]) -> float:
    gap = 0.0
    for s in subs:
        ts = [r["t_end"] for r in s.rounds]
        gap = max([gap] + [b - a for a, b in zip(ts, ts[1:])])
    return gap


def run_under_load(n_submitters: int = 4, phase_seconds: float = 0.6,
                   pause_budget_s: float = 5.0,
                   overhead_budget: float = 0.15) -> Dict:
    """Swap plain → prov → plain while ``n_submitters`` threads hammer the
    mount through the multi-submitter queue. Asserts its own tripwires:
    zero failed/lost/reordered completions, every generation-certain
    prov-window round in the log (and no plain-window round), pauses and
    prov overhead within budget."""
    assert n_submitters >= 4, "the claim is about CONCURRENT submitters"
    mf = make_mount("bento", n_blocks=16384)
    m, v = mf.mount, mf.view
    payload = b"p" * 1024
    subs = []
    for t in range(n_submitters):
        v.makedirs(f"/w{t}")
        subs.append(_Submitter(m, v.stat(f"/w{t}").ino, t, payload))
    stop = threading.Event()
    threads = [threading.Thread(target=s.run, args=(stop,), daemon=True)
               for s in subs]
    t_start = time.perf_counter()
    for th in threads:
        th.start()

    time.sleep(phase_seconds)                    # plain window
    t_wrap = time.perf_counter()
    wrap_stats = wrap_layer(m, ProvFilesystem)
    prov_gen = m.generation
    time.sleep(phase_seconds)                    # prov window
    t_unwrap = time.perf_counter()
    # read the log while the layer is still mounted (records keep landing
    # until the unwrap's freeze, so the authoritative read happens below,
    # after the run, by re-wrapping onto the durable log)
    unwrap_stats = unwrap_layer(m)
    time.sleep(phase_seconds)                    # plain again
    stop.set()
    for th in threads:
        th.join(timeout=30)
    assert not any(th.is_alive() for th in threads), "submitter deadlocked"

    errors = [e for s in subs for e in s.errors]
    assert not errors, errors[:5]

    # authoritative log read: re-wrap adopts the durable on-device log
    wrap_layer(m, ProvFilesystem)
    logged = {r["name"] for r in v.read_provenance()
              if r["op"] == "create"}
    unwrap_layer(m)

    # differential: rounds certainly inside the prov window are logged,
    # rounds certainly outside are not (a round whose generation changed
    # mid-flight is boundary-ambiguous and only the window rule applies)
    n_prov_certain = n_plain_certain = 0
    for s in subs:
        in_log = [r["name"] in logged for r in s.rounds]
        # the logged rounds form one contiguous window per submitter
        first = in_log.index(True) if True in in_log else 0
        last = len(in_log) - 1 - in_log[::-1].index(True) \
            if True in in_log else -1
        assert all(in_log[first:last + 1]) if last >= 0 else True, \
            f"t{s.t}: provenance window not contiguous"
        for r, lg in zip(s.rounds, in_log):
            if r["gen_before"] == r["gen_after"] == prov_gen:
                n_prov_certain += 1
                assert lg, f"{r['name']} completed under prov, not logged"
            elif r["gen_after"] < prov_gen or r["gen_before"] > prov_gen:
                n_plain_certain += 1
                assert not lg, f"{r['name']} completed plain, yet logged"
    assert n_prov_certain > 0, "no round certainly ran under the prov layer"
    assert n_plain_certain > 0, "no round certainly ran plain"

    # throughput per window (ops = rounds × 3 entries)
    def _window_rate(t0, t1):
        n = sum(1 for s in subs for r in s.rounds if t0 <= r["t_end"] < t1)
        return 3 * n / max(t1 - t0, 1e-9)

    plain_rate = _window_rate(t_start, t_wrap)
    prov_rate = _window_rate(t_wrap + wrap_stats["total_s"], t_unwrap)
    overhead_ratio = prov_rate / max(plain_rate, 1e-9)

    gap = _max_completion_gap(subs)
    pauses_ms = {"wrap_ms": 1e3 * wrap_stats["total_s"],
                 "unwrap_ms": 1e3 * unwrap_stats["total_s"],
                 "max_completion_gap_ms": 1e3 * gap}
    assert wrap_stats["total_s"] < pause_budget_s \
        and unwrap_stats["total_s"] < pause_budget_s, \
        f"swap pause exceeded budget: {pauses_ms}"
    assert overhead_ratio >= overhead_budget, \
        (f"prov layer too slow: {prov_rate:.0f} vs {plain_rate:.0f} ops/s "
         f"({overhead_ratio:.2f}x < {overhead_budget}x budget)")

    total_rounds = sum(len(s.rounds) for s in subs)
    mf.close()
    return {
        "bench": "upgrade_under_load", "submitters": n_submitters,
        "rounds": total_rounds, "failed": 0,
        "prov_certain_rounds": n_prov_certain,
        "plain_certain_rounds": n_plain_certain,
        "records": len(logged),
        "plain_ops_per_s": plain_rate, "prov_ops_per_s": prov_rate,
        "prov_overhead_ratio": overhead_ratio,
        **pauses_ms,
    }


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--under-load", action="store_true",
                    help="hot-swap the provenance layer under N submitter "
                         "threads (the paper's §6 demo, measured + asserted)")
    ap.add_argument("--submitters", type=int, default=4)
    ap.add_argument("--quick", action="store_true",
                    help="shorter phases (CI smoke)")
    args = ap.parse_args()
    if args.under_load:
        r = run_under_load(n_submitters=args.submitters,
                           phase_seconds=0.35 if args.quick else 0.8)
        print(f"upgrade_under_load: {r['submitters']} submitters, "
              f"{r['rounds']} rounds ({r['records']} prov records), "
              f"0 failed/lost/reordered")
        print(f"  swap pause: wrap {r['wrap_ms']:.2f} ms, unwrap "
              f"{r['unwrap_ms']:.2f} ms (paper's demo: ~15 ms); max "
              f"completion gap {r['max_completion_gap_ms']:.2f} ms")
        print(f"  throughput: plain {r['plain_ops_per_s']:.0f} ops/s, prov "
              f"{r['prov_ops_per_s']:.0f} ops/s "
              f"({r['prov_overhead_ratio']:.2f}x)")
    else:
        print(run())


if __name__ == "__main__":
    main()
