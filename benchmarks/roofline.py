"""Roofline table builder — reads the dry-run JSONs and emits §Roofline.

Per (arch x shape x mesh) cell:
  compute_s    = per-chip HLO dot FLOPs / 197e12   (bf16 peak, v5e)
  memory_s     = per-chip dot operand+output bytes / 819e9 (HBM traffic
                 upper bound: no fusion credit — see method notes)
  collective_s = per-chip ring-model wire bytes / 50e9 (1 ICI link)
  dominant     = argmax term;  roofline_fraction = compute_s / dominant_s
  model_ratio  = analytic MODEL_FLOPS / HLO dot FLOPs (useful-compute share)

MODEL_FLOPS: 6*N_active*tokens (train) / 2*N_active*tokens (prefill/decode)
plus the architecture's attention/state-scan term (family formulas below).
"""

from __future__ import annotations

import glob
import json
import os
import time
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

# repro.configs (the model registry) is imported lazily inside build_table:
# the --blockhash mode measures the filesystem hash kernel and must run
# standalone, without the model stack importing at all.


def analytic_model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Useful-model FLOPs for the whole step (all chips), family-aware."""
    N = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    mult = 6.0 if shape.kind == "train" else 2.0  # fwd+bwd vs fwd
    fwd_attn_mult = 3.0 if shape.kind == "train" else 1.0

    if shape.kind == "decode":
        tokens = B  # one new token per sequence
        flops = mult * N * tokens
        # attention against the cache
        if cfg.num_heads > 0:
            eff = min(S, cfg.sliding_window) if cfg.sliding_window else S
            n_attn = _n_attn_layers(cfg)
            flops += 4.0 * B * cfg.num_heads * cfg.head_dim * eff * n_attn
        if cfg.family in ("ssm", "hybrid"):
            flops += _state_flops_per_token(cfg) * B
        return flops

    tokens = B * S
    flops = mult * N * tokens
    if cfg.num_heads > 0 and cfg.family != "ssm":
        eff = min(S, cfg.sliding_window) if cfg.sliding_window else S
        n_attn = _n_attn_layers(cfg)
        flops += fwd_attn_mult * 2.0 * B * S * eff * cfg.num_heads * cfg.head_dim * n_attn
        if cfg.family == "vlm":
            n_cross = cfg.num_layers // cfg.cross_attn_every
            flops += fwd_attn_mult * 4.0 * B * S * cfg.num_image_tokens * \
                cfg.num_heads * cfg.head_dim * n_cross
        if cfg.family == "audio":
            Te = cfg.encoder_seq
            flops += fwd_attn_mult * 4.0 * B * Te * Te * cfg.num_heads * \
                cfg.head_dim * cfg.encoder_layers
            flops += fwd_attn_mult * 4.0 * B * S * Te * cfg.num_heads * \
                cfg.head_dim * cfg.num_layers
    if cfg.family in ("ssm", "hybrid"):
        flops += fwd_attn_mult * _state_flops_per_token(cfg) * tokens
    return flops


def _n_attn_layers(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        e = cfg.shared_attn_every
        return sum(1 for i in range(cfg.num_layers) if i % e == e - 1) if e else 0
    if cfg.family == "vlm":
        return cfg.num_layers - cfg.num_layers // cfg.cross_attn_every
    return cfg.num_layers


def _state_flops_per_token(cfg: ModelConfig) -> float:
    if cfg.family == "ssm":  # wkv6: ~4 mults per (k,v) state cell
        H = cfg.d_model // cfg.wkv_head_dim
        return 4.0 * H * cfg.wkv_head_dim * cfg.wkv_head_dim * cfg.num_layers
    if cfg.family == "hybrid":  # mamba2 ssd
        d_inner = cfg.ssm_expand * cfg.d_model
        H = d_inner // cfg.ssm_head_dim
        return 4.0 * H * cfg.ssm_head_dim * cfg.ssm_state * cfg.num_layers
    return 0.0


def improvement_note(dom: str, row: Dict) -> str:
    if dom == "collective_s":
        return ("collective-bound: resharding/gather traffic dominates — "
                "fewer/overlapped gathers (cast-then-gather, seqpar rules, "
                "shard_map decode/MoE) moves this down")
    if dom == "memory_s":
        return ("memory-bound: unfused attention/scan intermediates dominate "
                "HBM traffic — the Pallas fused kernels eliminate the "
                "materialized scores/decay tensors on TPU")
    return ("compute-bound: near the MXU roofline; remaining headroom is "
            "remat recompute and causal-block waste")


def load_cells(result_dir: str) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def build_table(result_dir: str) -> List[Dict]:
    from repro.configs import SHAPES_BY_NAME, registry

    out = []
    for cell in load_cells(result_dir):
        if cell.get("skipped"):
            out.append({"arch": cell["arch"], "shape": cell["shape"],
                        "mesh": cell["mesh"], "skipped": cell["reason"]})
            continue
        if not cell.get("ok"):
            out.append({"arch": cell["arch"], "shape": cell["shape"],
                        "mesh": cell["mesh"], "error": cell.get("error")})
            continue
        cfg = registry.get(cell["arch"]).model
        shape = SHAPES_BY_NAME[cell["shape"]]
        n_chips = cell["n_chips"]
        terms = cell["roofline_terms_s"]
        dom = max(terms, key=terms.get)
        model_flops = analytic_model_flops(cfg, shape)
        hlo_flops_all = cell["hlo"]["dot_flops"] * n_chips
        row = {
            "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
            "ruleset": cell.get("ruleset", "baseline"),
            "chips": n_chips,
            "compute_s": terms["compute_s"],
            "memory_s": terms["memory_s"],
            "collective_s": terms["collective_s"],
            "dominant": dom.replace("_s", ""),
            "roofline_fraction": terms["compute_s"] / max(terms[dom], 1e-12),
            "model_flops": model_flops,
            "model_ratio": model_flops / max(hlo_flops_all, 1.0),
            "live_gib_per_dev": cell["per_device_bytes"]["live_peak_est"] / 2**30,
            "note": improvement_note(dom, cell),
        }
        out.append(row)
    return out


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | chips | compute_s | memory_s | "
           "collective_s | dominant | RL-frac | model/HLO | GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | "
                         f"SKIP: {r['skipped'][:60]} | | | | | | |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | "
                         f"ERROR | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} | "
            f"{r['compute_s']:.3f} | {r['memory_s']:.3f} | "
            f"{r['collective_s']:.3f} | {r['dominant']} | "
            f"{r['roofline_fraction']:.2f} | {r['model_ratio']:.2f} | "
            f"{r['live_gib_per_dev']:.1f} |")
    return hdr + "\n".join(lines)


def main(result_dir: str = "results/dryrun_baseline",
         out_json: str = "results/roofline_table.json") -> None:
    rows = build_table(result_dir)
    os.makedirs(os.path.dirname(out_json), exist_ok=True)
    with open(out_json, "w") as f:
        json.dump(rows, f, indent=1)
    print(markdown_table(rows))


# --- the filesystem hash kernel's roofline (--blockhash) --------------------------
# kernels/blockhash is the BlockStore data plane's hot path: one batched
# launch hashes every block a flushed write batch produced. The kernel is
# memory-bound by construction (one pass over the block, one u32 out), so
# its roofline term is HBM traffic / bandwidth; the table reports measured
# throughput against that bound per batch width — the knee shows the batch
# size where launch overhead stops dominating (why BlockStore batches
# hashes instead of hashing per block).


def blockhash_table(batches=(1, 4, 16, 64, 256), block_bytes: int = 4096,
                    reps: int = 5) -> List[Dict]:
    import numpy as np

    from repro.kernels.blockhash.ops import checksum_batch

    rng = np.random.default_rng(0)
    rows = []
    for n in batches:
        blocks = [rng.integers(0, 256, block_bytes, dtype=np.uint8).tobytes()
                  for _ in range(n)]
        checksum_batch(blocks)  # warm-up: jit/trace outside the clock
        t0 = time.perf_counter()
        for _ in range(reps):
            checksum_batch(blocks)
        wall = (time.perf_counter() - t0) / reps
        moved = n * (block_bytes + 4)  # block in, u32 digest out
        memory_s = moved / HBM_BW
        rows.append({
            "bench": "blockhash", "batch": n, "block_bytes": block_bytes,
            "wall_s": wall, "blocks_per_s": n / wall,
            "gb_per_s": moved / wall / 1e9,
            "memory_s": memory_s,
            "roofline_fraction": memory_s / wall,
        })
    return rows


def blockhash_main(out_json: str = "results/blockhash_roofline.json") -> None:
    rows = blockhash_table()
    os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
    with open(out_json, "w") as f:
        json.dump(rows, f, indent=1)
    print("| batch | blocks/s | GB/s | memory_s | RL-frac |")
    print("|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['batch']} | {r['blocks_per_s']:.0f} | "
              f"{r['gb_per_s']:.3f} | {r['memory_s']:.2e} | "
              f"{r['roofline_fraction']:.2e} |")


if __name__ == "__main__":
    import sys
    if "--blockhash" in sys.argv[1:]:
        blockhash_main(*[a for a in sys.argv[1:] if a != "--blockhash"])
    else:
        main(*sys.argv[1:])
