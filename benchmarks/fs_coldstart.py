"""Cold-start provisioning benchmark: CoW overlay tenants vs full copies.

The lazy-materialization claim, measured: provisioning N tenants over ONE
shared base image must be O(metadata) — a small writable upper (mkfs) plus
a lazy view of the base that fetches blocks only on first read — while the
naive alternative copies the ENTIRE image through the block interface per
tenant (what `dd`-style container provisioning does). Both paths produce a
fully usable mount, verified per tenant (base content readable, private
writes isolated).

Self-asserting (the acceptance bar, not a human eyeballing numbers):

* provisioning 64 overlay tenants is >= 10x faster than 64 full copies;
* the blocks a tenant materializes at provision time are a small fraction
  of the base image (the O(metadata) claim — data blocks stay unfetched);
* provider round-trips per tenant stay O(1)-ish thanks to the batched
  ``read_many`` fetch path (one round-trip per miss RUN, not per block).

CLI:  PYTHONPATH=src python -m benchmarks.fs_coldstart [--quick]
      [--tenants 64] [--kind xv6|ext4like]
"""

from __future__ import annotations

import time
from typing import Dict

from repro.core.registry import mount as bento_mount
from repro.core.services import kernel_binding
from repro.fs.blockdev import MemBlockDevice
from repro.fs.ext4like import Ext4LikeFileSystem
from repro.fs.mounts import MountedFs, build_base_image, overlay_tenant
from repro.fs.posix import PosixView
from repro.fs.xv6 import Xv6FileSystem, Xv6Options


def provision_copy(image: MemBlockDevice, fs_kind: str) -> MountedFs:
    """The naive baseline: byte-for-byte copy of the WHOLE image through
    the block interface (read_block/write_block per block — the honest
    cost; a memcpy would be cheating the comparison), then mount it."""
    dev = MemBlockDevice(image.n_blocks)
    for b in range(image.n_blocks):
        dev.write_block(b, image.read_block(b))
    ks = kernel_binding(dev)
    cls = Ext4LikeFileSystem if fs_kind == "ext4like" else Xv6FileSystem
    fs = cls(Xv6Options(group_commit=True, batched_install=True))
    m = bento_mount("copy-tenant", ks, module=fs)
    return MountedFs("full-copy", m, PosixView(m), ks, dev)


def _lazy_dev(mf: MountedFs):
    return mf.mount.module.opts.base_dev


def run(n_tenants: int = 64, fs_kind: str = "xv6", *,
        speedup_floor: float = 10.0,
        materialize_ceiling: float = 0.10) -> Dict:
    image = build_base_image(fs_kind)
    image_bytes0 = image._data.tobytes()

    # --- overlay tenants: O(metadata) provisioning --------------------------------
    t0 = time.perf_counter()
    tenants = [overlay_tenant(image, fs_kind) for _ in range(n_tenants)]
    lazy_s = time.perf_counter() - t0
    # fetch counters BEFORE any tenant workload: what provisioning alone
    # materialized (mount-time metadata — superblock, root, dir walk)
    fetched = [_lazy_dev(t).provider_blocks_fetched for t in tenants]
    trips = [_lazy_dev(t).provider_round_trips for t in tenants]

    # --- full-copy tenants: the naive baseline ------------------------------------
    t0 = time.perf_counter()
    copies = [provision_copy(image, fs_kind) for _ in range(n_tenants)]
    copy_s = time.perf_counter() - t0

    # both paths must yield USABLE, ISOLATED mounts (no benchmarking a
    # mount that can't serve) — checked outside the timed windows
    for group in (tenants, copies):
        for t, mf in enumerate(group):
            assert mf.view.read_file("/etc/hostname") == b"golden\n"
            mf.view.write_file("/private", b"tenant %d" % t)
        assert group[0].view.read_file("/private") == b"tenant 0", \
            "tenant writes leaked across mounts"
    assert image._data.tobytes() == image_bytes0, \
        "a tenant write reached the shared base image"

    speedup = copy_s / max(lazy_s, 1e-9)
    frac = max(fetched) / image.n_blocks
    result = {
        "bench": "fs_coldstart", "fs_kind": fs_kind, "tenants": n_tenants,
        "base_blocks": image.n_blocks,
        "lazy_s": lazy_s, "copy_s": copy_s, "speedup": speedup,
        "lazy_ms_per_tenant": 1e3 * lazy_s / n_tenants,
        "copy_ms_per_tenant": 1e3 * copy_s / n_tenants,
        "materialized_blocks_max": max(fetched),
        "materialized_fraction": frac,
        "provider_round_trips_max": max(trips),
    }

    # the acceptance bar, asserted
    assert speedup >= speedup_floor, (
        f"overlay provisioning only {speedup:.1f}x faster than full copy "
        f"({1e3 * lazy_s:.0f} ms vs {1e3 * copy_s:.0f} ms for "
        f"{n_tenants} tenants) — floor is {speedup_floor}x")
    assert frac <= materialize_ceiling, (
        f"provisioning materialized {max(fetched)} of {image.n_blocks} "
        f"base blocks ({frac:.0%}) — not O(metadata)")
    assert max(trips) <= 64, (
        f"provider interface crossings not O(metadata): {max(trips)} "
        f"round-trips at provision time")

    for mf in tenants + copies:
        mf.close()
    return result


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tenants", type=int, default=64)
    ap.add_argument("--kind", default="xv6", choices=["xv6", "ext4like"])
    ap.add_argument("--quick", action="store_true",
                    help="16 tenants (CI smoke; same asserted floors)")
    args = ap.parse_args()
    n = 16 if args.quick else args.tenants
    r = run(n, args.kind)
    print(f"fs_coldstart {r['fs_kind']}: {r['tenants']} tenants over one "
          f"{r['base_blocks']}-block base image")
    print(f"  overlay: {1e3 * r['lazy_s']:8.1f} ms total "
          f"({r['lazy_ms_per_tenant']:6.2f} ms/tenant, "
          f"{r['materialized_blocks_max']} blocks materialized, "
          f"{r['provider_round_trips_max']} provider round-trips max)")
    print(f"  full copy: {1e3 * r['copy_s']:6.1f} ms total "
          f"({r['copy_ms_per_tenant']:6.2f} ms/tenant, "
          f"{r['base_blocks']} blocks copied each)")
    print(f"  speedup: {r['speedup']:.1f}x (floor 10x), materialized "
          f"fraction {r['materialized_fraction']:.1%} (ceiling 10%) — OK")


if __name__ == "__main__":
    main()
