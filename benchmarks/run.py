"""Benchmark orchestrator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--skip-fs] [--skip-roofline]

Prints ``name,value,unit`` CSV rows and writes results/*.json artifacts:
  fig2_3_read / fig4_write / tab4_create / tab5_delete  (FS micro matrix)
  tab6_macro (varmail / fileserver / untar)
  upgrade (online-upgrade pause under load — §4.8, beyond-paper)
  roofline (from the dry-run matrix, if present)
"""

from __future__ import annotations

import argparse
import json
import os
import sys

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _emit(rows, key_fields, value_field, unit):
    for r in rows:
        if value_field not in r:
            continue
        name = "/".join(str(r[k]) for k in key_fields if k in r)
        print(f"{name},{r[value_field]:.2f},{unit}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-fs", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--kinds", default="bento,vfs,fuse,ext4like")
    args = ap.parse_args()
    os.makedirs(RESULTS, exist_ok=True)
    artifacts = {}

    if not args.skip_fs:
        from benchmarks import fs_macro, fs_micro, fs_upgrade

        kinds = tuple(args.kinds.split(","))
        print("# --- FS micro (paper Fig 2-4, Tab 4-5) ---")
        micro = fs_micro.run_all(kinds=kinds, quick=args.quick)
        artifacts["fs_micro"] = micro
        _emit([r for r in micro if r["bench"] == "read" and r["size_kb"] == 4],
              ("bench", "fs", "mode", "threads"), "ops_per_s", "ops/s")
        _emit([r for r in micro if r["bench"] == "read" and r["size_kb"] > 4],
              ("bench", "fs", "size_kb", "mode", "threads"), "mb_per_s", "MB/s")
        _emit([r for r in micro if r["bench"] == "write"],
              ("bench", "fs", "size_kb", "mode", "threads"), "mb_per_s", "MB/s")
        _emit([r for r in micro if r["bench"] in ("create", "delete")],
              ("bench", "fs", "threads"), "ops_per_s", "ops/s")

        print("# --- FS macro (paper Tab 6) ---")
        macro = fs_macro.run_all(kinds=kinds, quick=args.quick)
        artifacts["fs_macro"] = macro
        _emit([r for r in macro if "ops_per_s" in r],
              ("bench", "fs"), "ops_per_s", "ops/s")
        _emit([r for r in macro if "seconds" in r],
              ("bench", "fs"), "seconds", "s")

        print("# --- online upgrade under load (§4.8) ---")
        up = fs_upgrade.run(n_upgrades=3 if args.quick else 5)
        artifacts["upgrade"] = up
        print(f"upgrade/pause_mean,{up['upgrade_total_ms_mean']:.3f},ms")
        print(f"upgrade/pause_max,{up['upgrade_total_ms_max']:.3f},ms")
        print(f"upgrade/failed_ops,{up['failed_ops']},count")

    if not args.skip_roofline:
        dr_dir = os.path.join(RESULTS, "dryrun_baseline")
        if os.path.isdir(dr_dir) and os.listdir(dr_dir):
            from benchmarks import roofline

            print("# --- roofline (from dry-run matrix) ---")
            rows = roofline.build_table(dr_dir)
            artifacts["roofline"] = rows
            for r in rows:
                if "compute_s" in r:
                    print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
                          f"{r['roofline_fraction']:.3f},fraction")
            hc_dir = os.path.join(RESULTS, "hillclimb")
            if os.path.isdir(hc_dir) and os.listdir(hc_dir):
                hc = roofline.build_table(hc_dir)
                artifacts["roofline_optimized"] = hc
                for r in hc:
                    if "compute_s" in r:
                        print(f"roofline-opt/{r['arch']}/{r['shape']}/"
                              f"{r['mesh']}/{r['ruleset']},"
                              f"{r['roofline_fraction']:.3f},fraction")
        else:
            print("# roofline: no dry-run results found "
                  "(run src/repro/launch/dryrun.py first)", file=sys.stderr)

    with open(os.path.join(RESULTS, "bench_artifacts.json"), "w") as f:
        json.dump(artifacts, f, indent=1, default=float)
    print("# artifacts -> results/bench_artifacts.json")


if __name__ == "__main__":
    main()
