"""FS microbenchmarks — paper Figures 2-4 + Tables 4-5.

read  : 4K ops/s + 32K/128K/1M MB/s, sequential+random, 1 and 32 threads
write : 32K/128K/1M MB/s, seq 1-thread + random 1/32 threads
create: ops/s, 1/32 threads         delete: ops/s, 1/32 threads

Mount matrix: bento / vfs / fuse / ext4like (repro.fs.mounts). Op counts are
bounded (not wall-clock bounded like filebench) so the suite stays CPU-
friendly; FUSE rows run a reduced op count and report the same ops/s metric.
"""

from __future__ import annotations

import concurrent.futures as cf
import time
from typing import Dict, List

import numpy as np

from repro.fs.mounts import ALL_KINDS, make_mount

FILE_MB = 4
N_THREADS = 32


def _mk_file(view, path: str, mb: int) -> None:
    blob = np.random.default_rng(7).integers(0, 256, mb << 20, dtype=np.uint8)
    view.write_file(path, blob.tobytes())
    view.fsync(path)


def _run_threads(n_threads: int, per_thread_ops: int, fn) -> float:
    """Returns wall seconds for n_threads x per_thread_ops calls of fn(i)."""
    t0 = time.perf_counter()
    if n_threads == 1:
        for i in range(per_thread_ops):
            fn(i)
    else:
        with cf.ThreadPoolExecutor(n_threads) as ex:
            futs = [ex.submit(lambda t=t: [fn(t * per_thread_ops + i)
                                           for i in range(per_thread_ops)])
                    for t in range(n_threads)]
            for f in futs:
                f.result()
    return time.perf_counter() - t0


def bench_read(kind: str, *, ops_scale: float = 1.0) -> List[Dict]:
    rows = []
    mf = make_mount(kind, n_blocks=16384)
    v = mf.view
    _mk_file(v, "/readfile", FILE_MB)
    file_bytes = FILE_MB << 20
    rng = np.random.default_rng(3)
    for size_kb in (4, 32, 128, 1024):
        size = size_kb << 10
        n_off = file_bytes // size
        for mode in ("seq", "rand"):
            for threads in (1, N_THREADS):
                total_ops = max(8, int(2048 * ops_scale))
                per_thread = max(1, total_ops // threads)

                def op(i, mode=mode, size=size, n_off=n_off):
                    idx = (i % n_off) if mode == "seq" else int(rng.integers(n_off))
                    v.read_file("/readfile", off=idx * size, size=size)

                wall = _run_threads(threads, per_thread, op)
                ops = threads * per_thread
                rows.append({
                    "bench": "read", "fs": kind, "size_kb": size_kb,
                    "mode": mode, "threads": threads,
                    "ops_per_s": ops / wall,
                    "mb_per_s": ops * size / wall / 2**20,
                })
    mf.close()
    return rows


def bench_write(kind: str, *, ops_scale: float = 1.0) -> List[Dict]:
    rows = []
    mf = make_mount(kind, n_blocks=16384)
    v = mf.view
    _mk_file(v, "/writefile", FILE_MB)
    file_bytes = FILE_MB << 20
    rng = np.random.default_rng(4)
    blob = np.random.default_rng(9).integers(0, 256, 1 << 20, dtype=np.uint8).tobytes()
    for size_kb in (32, 128, 1024):
        size = size_kb << 10
        n_off = file_bytes // size
        cases = [("seq", 1), ("rand", 1), ("rand", N_THREADS)]
        for mode, threads in cases:
            total_ops = max(4, int(64 * ops_scale))
            per_thread = max(1, total_ops // threads)

            def op(i, mode=mode, size=size, n_off=n_off):
                idx = (i % n_off) if mode == "seq" else int(rng.integers(n_off))
                v.write_file("/writefile", blob[:size], off=idx * size,
                             create=False)

            wall = _run_threads(threads, per_thread, op)
            ops = threads * per_thread
            rows.append({
                "bench": "write", "fs": kind, "size_kb": size_kb,
                "mode": mode, "threads": threads,
                "ops_per_s": ops / wall,
                "mb_per_s": ops * size / wall / 2**20,
            })
    mf.close()
    return rows


def bench_create(kind: str, *, ops_scale: float = 1.0) -> List[Dict]:
    rows = []
    for threads in (1, N_THREADS):
        mf = make_mount(kind, n_blocks=16384)
        v = mf.view
        v.makedirs("/c")
        total = max(16, int(256 * ops_scale))
        per_thread = max(1, total // threads)
        payload = b"x" * 1024

        def op(i):
            v.write_file(f"/c/f{i:06d}", payload)
            v.fsync(f"/c/f{i:06d}")

        wall = _run_threads(threads, per_thread, op)
        rows.append({"bench": "create", "fs": kind, "threads": threads,
                     "ops_per_s": threads * per_thread / wall})
        mf.close()
    return rows


def bench_delete(kind: str, *, ops_scale: float = 1.0) -> List[Dict]:
    rows = []
    for threads in (1, N_THREADS):
        mf = make_mount(kind, n_blocks=16384)
        v = mf.view
        v.makedirs("/d")
        total = max(16, int(256 * ops_scale))
        per_thread = max(1, total // threads)
        n = threads * per_thread
        for i in range(n):
            v.write_file(f"/d/f{i:06d}", b"y" * 1024)
        v.fsync("/d")

        def op(i):
            v.unlink(f"/d/f{i:06d}")

        wall = _run_threads(threads, per_thread, op)
        rows.append({"bench": "delete", "fs": kind, "threads": threads,
                     "ops_per_s": n / wall})
        mf.close()
    return rows


def run_all(kinds=ALL_KINDS, quick: bool = False) -> List[Dict]:
    rows: List[Dict] = []
    for kind in kinds:
        scale = (0.05 if kind == "fuse" else 1.0) * (0.25 if quick else 1.0)
        rows += bench_read(kind, ops_scale=scale)
        rows += bench_write(kind, ops_scale=scale)
        rows += bench_create(kind, ops_scale=scale)
        rows += bench_delete(kind, ops_scale=scale)
    return rows
