"""FS microbenchmarks — paper Figures 2-4 + Tables 4-5, plus the
BentoQueue batched-vs-scalar mode (beyond-paper).

read  : 4K ops/s + 32K/128K/1M MB/s, sequential+random, 1 and 32 threads
write : 32K/128K/1M MB/s, seq 1-thread + random 1/32 threads
create: ops/s, 1/32 threads         delete: ops/s, 1/32 threads
batched: N-op submission batches through ``Mount.submit`` vs scalar
         dispatch — 4 KiB reads, flushed writes, batched create/delete
         (``create_many``/``unlink_many``) and chained create+write+fsync
         (SQE_LINK). Reports ops/s for both sides, the speedup, gate-
         crossings per batch (must be 1) and checksum_batch launches per
         flushed batch (must be 1; run with REPRO_FORCE_PALLAS_CHECKSUM=1
         to make each launch a real Pallas kernel call). ``--seed`` pins
         the payload rng for reproducible runs; the counter tripwires
         assert, so a silent scalar fallback fails the run (CI smoke).
threads: ``--threads N`` (with ``--batched``) adds the multi-submitter
         mode: N worker threads, each staging batches into its THREAD-
         LOCAL SubmitterQueue, against the same N threads hammering the
         scalar path. The mount's drainer carries every queue pending at
         drain time across the boundary in one gate crossing (io_uring
         SQPOLL-style) and fuses every submitter's read-only runs into
         ONE vectorized cache pass, so the tripwires here are
         *aggregate*: gate crossings ≪ submissions (the drain really
         coalesces concurrent submitters), ≥ 3.0x aggregate throughput
         over the N scalar threads, and — for the chained phase — exactly one journal chain
         reservation per create→write pair regardless of how submissions
         interleaved (chains never split across a drain or merge across
         submitters).

Mount matrix: bento / vfs / fuse / ext4like (repro.fs.mounts). Op counts are
bounded (not wall-clock bounded like filebench) so the suite stays CPU-
friendly; FUSE rows run a reduced op count and report the same ops/s metric.

CLI:  PYTHONPATH=src python -m benchmarks.fs_micro --batched [--kind bento]
      PYTHONPATH=src python -m benchmarks.fs_micro --batched --threads 4
"""

from __future__ import annotations

import concurrent.futures as cf
import gc
import threading
import time
from typing import Dict, List

import numpy as np

from repro.fs.mounts import ALL_KINDS, make_mount

FILE_MB = 4
N_THREADS = 32


def _mk_file(view, path: str, mb: int, seed: int = 7) -> None:
    blob = np.random.default_rng(seed).integers(0, 256, mb << 20, dtype=np.uint8)
    view.write_file(path, blob.tobytes())
    view.fsync(path)


def _run_threads(n_threads: int, per_thread_ops: int, fn) -> float:
    """Returns wall seconds for n_threads x per_thread_ops calls of fn(i)."""
    t0 = time.perf_counter()
    if n_threads == 1:
        for i in range(per_thread_ops):
            fn(i)
    else:
        with cf.ThreadPoolExecutor(n_threads) as ex:
            futs = [ex.submit(lambda t=t: [fn(t * per_thread_ops + i)
                                           for i in range(per_thread_ops)])
                    for t in range(n_threads)]
            for f in futs:
                f.result()
    return time.perf_counter() - t0


def bench_read(kind: str, *, ops_scale: float = 1.0) -> List[Dict]:
    rows = []
    mf = make_mount(kind, n_blocks=16384)
    v = mf.view
    _mk_file(v, "/readfile", FILE_MB)
    file_bytes = FILE_MB << 20
    rng = np.random.default_rng(3)
    for size_kb in (4, 32, 128, 1024):
        size = size_kb << 10
        n_off = file_bytes // size
        for mode in ("seq", "rand"):
            for threads in (1, N_THREADS):
                total_ops = max(8, int(2048 * ops_scale))
                per_thread = max(1, total_ops // threads)

                def op(i, mode=mode, size=size, n_off=n_off):
                    idx = (i % n_off) if mode == "seq" else int(rng.integers(n_off))
                    v.read_file("/readfile", off=idx * size, size=size)

                wall = _run_threads(threads, per_thread, op)
                ops = threads * per_thread
                rows.append({
                    "bench": "read", "fs": kind, "size_kb": size_kb,
                    "mode": mode, "threads": threads,
                    "ops_per_s": ops / wall,
                    "mb_per_s": ops * size / wall / 2**20,
                })
    mf.close()
    return rows


def bench_write(kind: str, *, ops_scale: float = 1.0) -> List[Dict]:
    rows = []
    mf = make_mount(kind, n_blocks=16384)
    v = mf.view
    _mk_file(v, "/writefile", FILE_MB)
    file_bytes = FILE_MB << 20
    rng = np.random.default_rng(4)
    blob = np.random.default_rng(9).integers(0, 256, 1 << 20, dtype=np.uint8).tobytes()
    for size_kb in (32, 128, 1024):
        size = size_kb << 10
        n_off = file_bytes // size
        cases = [("seq", 1), ("rand", 1), ("rand", N_THREADS)]
        for mode, threads in cases:
            total_ops = max(4, int(64 * ops_scale))
            per_thread = max(1, total_ops // threads)

            def op(i, mode=mode, size=size, n_off=n_off):
                idx = (i % n_off) if mode == "seq" else int(rng.integers(n_off))
                v.write_file("/writefile", blob[:size], off=idx * size,
                             create=False)

            wall = _run_threads(threads, per_thread, op)
            ops = threads * per_thread
            rows.append({
                "bench": "write", "fs": kind, "size_kb": size_kb,
                "mode": mode, "threads": threads,
                "ops_per_s": ops / wall,
                "mb_per_s": ops * size / wall / 2**20,
            })
    mf.close()
    return rows


def bench_create(kind: str, *, ops_scale: float = 1.0) -> List[Dict]:
    rows = []
    for threads in (1, N_THREADS):
        mf = make_mount(kind, n_blocks=16384)
        v = mf.view
        v.makedirs("/c")
        total = max(16, int(256 * ops_scale))
        per_thread = max(1, total // threads)
        payload = b"x" * 1024

        def op(i):
            v.write_file(f"/c/f{i:06d}", payload)
            v.fsync(f"/c/f{i:06d}")

        wall = _run_threads(threads, per_thread, op)
        rows.append({"bench": "create", "fs": kind, "threads": threads,
                     "ops_per_s": threads * per_thread / wall})
        mf.close()
    return rows


def bench_delete(kind: str, *, ops_scale: float = 1.0) -> List[Dict]:
    rows = []
    for threads in (1, N_THREADS):
        mf = make_mount(kind, n_blocks=16384)
        v = mf.view
        v.makedirs("/d")
        total = max(16, int(256 * ops_scale))
        per_thread = max(1, total // threads)
        n = threads * per_thread
        for i in range(n):
            v.write_file(f"/d/f{i:06d}", b"y" * 1024)
        v.fsync("/d")

        def op(i):
            v.unlink(f"/d/f{i:06d}")

        wall = _run_threads(threads, per_thread, op)
        rows.append({"bench": "delete", "fs": kind, "threads": threads,
                     "ops_per_s": n / wall})
        mf.close()
    return rows


def bench_batched(kind: str = "bento", *, batch: int = 128,
                  total_ops: int = 8192, write_batch: int = 16,
                  n_write_batches: int = 32, meta_ops: int = 512,
                  meta_batch: int = 64, seed: int = 7) -> List[Dict]:
    """Batched submission vs scalar dispatch (the BentoQueue tentpole).

    4KiB-read microbenchmark: ``total_ops`` sequential 4 KiB reads of a
    warm file, first one scalar call at a time, then in ``batch``-entry
    submissions (one gate-crossing each). Then a batched-write mode:
    ``write_batch`` 4 KiB writes + one flush per submission — the flush
    commits the whole batch as ONE journal transaction, i.e. one
    checksum_batch launch per batch. Then the metadata modes: batched
    create/delete (``create_many``/``unlink_many``, one submission and one
    directory scan per ``meta_batch`` names) and chained
    create+write+fsync (SQE_LINK triples, one flush commit per batch) —
    each against its scalar-loop twin.
    """
    rows: List[Dict] = []
    mf = make_mount(kind, n_blocks=16384)
    v = mf.view
    _mk_file(v, "/readfile", FILE_MB, seed=seed)
    size = 4096
    n_off = (FILE_MB << 20) // size
    gate = getattr(mf.mount, "gate", None)

    # --- scalar 4KiB reads ---------------------------------------------------
    t0 = time.perf_counter()
    for i in range(total_ops):
        v.read_file("/readfile", off=(i % n_off) * size, size=size)
    scalar_s = time.perf_counter() - t0
    scalar_ops = total_ops / scalar_s

    # --- batched 4KiB reads --------------------------------------------------
    g0 = gate.crossings if gate else 0
    n_batches = total_ops // batch
    t0 = time.perf_counter()
    for b in range(n_batches):
        specs = [("/readfile", ((b * batch + i) % n_off) * size, size)
                 for i in range(batch)]
        v.read_many(specs)
    batched_s = time.perf_counter() - t0
    batched_ops = (n_batches * batch) / batched_s
    crossings_per_batch = ((gate.crossings - g0) / n_batches) if gate else None

    rows.append({
        "bench": "batched_read", "fs": kind, "size_kb": 4, "batch": batch,
        "scalar_ops_per_s": scalar_ops, "batched_ops_per_s": batched_ops,
        "speedup": batched_ops / scalar_ops,
        "gate_crossings_per_batch": crossings_per_batch,
    })

    # --- batched writes: one flush (= one journal commit = one checksum
    # launch) per submission batch -------------------------------------------
    ks = mf.services
    blob = b"w" * size
    if ks is not None:
        c0 = ks.counters["checksum_batch_calls"]
        t0 = time.perf_counter()
        for b in range(n_write_batches):
            items = [("/readfile", ((b * write_batch + i) % n_off) * size, blob)
                     for i in range(write_batch)]
            v.write_many(items, create=False, fsync=True)
        batched_w_s = time.perf_counter() - t0
        launches = ks.counters["checksum_batch_calls"] - c0
        rows.append({
            "bench": "batched_write", "fs": kind, "size_kb": 4,
            "batch": write_batch,
            "batched_ops_per_s": n_write_batches * write_batch / batched_w_s,
            "checksum_batch_per_flush": launches / n_write_batches,
        })

    # --- batched create/delete: create_many / unlink_many vs scalar loops ----
    v.makedirs("/cs")
    v.makedirs("/cb")
    t0 = time.perf_counter()
    for i in range(meta_ops):
        v.create(f"/cs/f{i:06d}")
    v.fsync("/cs")
    scalar_c_s = time.perf_counter() - t0
    n_meta_batches = max(1, meta_ops // meta_batch)
    g0 = gate.crossings if gate else 0
    t0 = time.perf_counter()
    for b in range(n_meta_batches):
        v.create_many([f"/cb/f{b * meta_batch + i:06d}"
                       for i in range(meta_batch)])
    v.fsync("/cb")
    batched_c_s = time.perf_counter() - t0
    # one create_many submission per batch + the trailing fsync crossing
    create_crossings = ((gate.crossings - g0 - 1) / n_meta_batches
                        if gate else None)
    rows.append({
        "bench": "batched_create", "fs": kind, "batch": meta_batch,
        "scalar_ops_per_s": meta_ops / scalar_c_s,
        "batched_ops_per_s": n_meta_batches * meta_batch / batched_c_s,
        "speedup": (n_meta_batches * meta_batch / batched_c_s)
        / (meta_ops / scalar_c_s),
        "gate_crossings_per_batch": create_crossings,
    })

    t0 = time.perf_counter()
    for i in range(meta_ops):
        v.unlink(f"/cs/f{i:06d}")
    scalar_d_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for b in range(n_meta_batches):
        v.unlink_many([f"/cb/f{b * meta_batch + i:06d}"
                       for i in range(meta_batch)])
    batched_d_s = time.perf_counter() - t0
    rows.append({
        "bench": "batched_delete", "fs": kind, "batch": meta_batch,
        "scalar_ops_per_s": meta_ops / scalar_d_s,
        "batched_ops_per_s": n_meta_batches * meta_batch / batched_d_s,
        "speedup": (n_meta_batches * meta_batch / batched_d_s)
        / (meta_ops / scalar_d_s),
    })

    # --- chained create+write+fsync: SQE_LINK pairs + one flush commit per
    # batch. Chain batches are sized to fit ONE journal transaction (every
    # file's create+write lands in the same group commit — that is the
    # crash-atomicity unit: ~1 data + shared meta blocks per file must stay
    # under the journal's 0.75*capacity commit threshold), so the flush is
    # the only checksum launch.
    chain_batch = min(32, meta_batch)
    n_chain_batches = max(1, meta_ops // chain_batch)
    v.makedirs("/ks")
    v.makedirs("/kb")
    payload = b"p" * 1024
    t0 = time.perf_counter()
    for i in range(meta_ops):
        path = f"/ks/f{i:06d}"
        v.create(path)
        v.write_file(path, payload, create=False)
        v.fsync(path)
    scalar_k_s = time.perf_counter() - t0
    ks = mf.services
    c0 = ks.counters["checksum_batch_calls"] if ks else 0
    journal = getattr(getattr(mf.mount, "module", None), "journal", None)
    ch0 = journal.chains if journal else 0
    t0 = time.perf_counter()
    for b in range(n_chain_batches):
        v.create_and_write_many(
            [(f"/kb/f{b * chain_batch + i:06d}", payload)
             for i in range(chain_batch)], fsync=True)
    chained_s = time.perf_counter() - t0
    launches_per_batch = ((ks.counters["checksum_batch_calls"] - c0)
                          / n_chain_batches if ks else None)
    # chain-aware journal reservation: every create→write pair takes ONE
    # chain-transaction reservation; the flushed-batch counters above must
    # hold with it enabled (a reservation that forced mid-batch commits
    # would show up as extra checksum launches and fail the tripwire)
    chains_per_batch = ((journal.chains - ch0) / n_chain_batches
                        if journal else None)
    rows.append({
        "bench": "chained_cwf", "fs": kind, "batch": chain_batch,
        "scalar_ops_per_s": meta_ops / scalar_k_s,
        "batched_ops_per_s": n_chain_batches * chain_batch / chained_s,
        "speedup": (n_chain_batches * chain_batch / chained_s)
        / (meta_ops / scalar_k_s),
        "checksum_batch_per_flush": launches_per_batch,
        "chain_reservations_per_batch": chains_per_batch,
    })
    mf.close()
    return rows


def bench_threaded(kind: str = "bento", *, threads: int = 4, batch: int = 128,
                   batches_per_thread: int = 16, chain_items: int = 96,
                   seed: int = 7) -> List[Dict]:
    """Multi-submitter BentoQueues vs the same threads on the scalar path.

    Phase 1 (scalar-shared): ``threads`` workers issue per-op scalar
    ``read_file`` calls against one shared mount — every op its own gate
    crossing. Phase 2 (threaded SQs): the same workers issue ``read_many``
    batches; each worker's submissions stage into its thread-local
    SubmitterQueue and whichever thread holds the drainer role carries
    everything pending across the boundary in ONE crossing. Phase 3
    (threaded chains): each worker commits create→write(PrevResult)→flush
    chains in its own directory via ``create_and_write_many`` — correct
    results under concurrency prove chains never split across a drain,
    and the journal's chain-reservation counter proves they never merge.

    Self-asserting tripwires (CI runs this via --threads):
      * every completion ok, every read byte-identical to the file;
      * aggregate batched throughput ≥ 3.0x the scalar-shared phase;
      * gate crossings ≪ submissions (drains really coalesce; asserted
        at ≤ 80% — uncontended they would be equal);
      * chain reservations == total create→write pairs exactly.

    Both timed phases run ``reps`` INTERLEAVED trials (scalar/SQ pairs)
    and keep the best wall per phase — the standard microbenchmark noise
    filter, plus interleaving so an ambient load spike degrades trials of
    both phases instead of sinking one side of the ratio — with the GC
    paused during timing: identical treatment on both sides.
    """
    rows: List[Dict] = []
    mf = make_mount(kind, n_blocks=16384)
    v = mf.view
    m = mf.mount
    if not hasattr(m, "start_sqpoll"):
        mf.close()
        raise SystemExit(
            f"--threads needs a gated mount with the multi-submitter "
            f"queue (bento/ext4like), not {kind!r}")
    _mk_file(v, "/readfile", FILE_MB, seed=seed)
    size = 4096
    n_off = (FILE_MB << 20) // size
    expect = {i: v.read_file("/readfile", off=(i % n_off) * size, size=size)
              for i in (0, 1, n_off - 1)}
    reps = 5  # best-of-5: the tripwire ratio must not trip on tail noise
    total_ops = threads * batches_per_thread * batch
    start = threading.Barrier(threads)  # cyclic: reused across reps

    # --- phase 1: N threads sharing the scalar path --------------------------
    def scalar_worker(t):
        start.wait()
        for b in range(batches_per_thread):
            for i in range(batch):
                off = ((t * batches_per_thread * batch + b * batch + i)
                       % n_off) * size
                v.read_file("/readfile", off=off, size=size)

    errors: List[str] = []

    # phase 2 worker: N threads, thread-local SQs, dedicated SQPOLL drainer.
    # The TIMED worker only issues the batches — per-op verification runs
    # in the untimed pass below (inside the timed loop it would tax the SQ
    # side of the ratio with checking work the scalar worker never does).
    def sq_worker(t):
        start.wait()
        for b in range(batches_per_thread):
            base = t * batches_per_thread * batch + b * batch
            v.read_many([("/readfile", ((base + i) % n_off) * size, size)
                         for i in range(batch)])

    def sq_verify_worker(t):
        start.wait()
        for b in range(batches_per_thread):
            base = t * batches_per_thread * batch + b * batch
            specs = [("/readfile", ((base + i) % n_off) * size, size)
                     for i in range(batch)]
            got = v.read_many(specs)
            for (_, off, _), data in zip(specs, got):
                i = off // size
                if i in expect and data != expect[i]:
                    errors.append(f"thread {t}: bad read at off {off}")

    gc_was_on = gc.isenabled()
    gc.disable()
    try:
        # INTERLEAVED trials: scalar/SQ/scalar/SQ..., best wall of each.
        # Back-to-back phase blocks let one ambient load spike (this often
        # runs on a one-core CI box) sink only one side of the ratio; with
        # A/B interleaving the spike degrades trials of BOTH phases and
        # best-of-reps discards them together.
        # idle_us=0: under the GIL the drain's own execution time IS the
        # gather window — submitters pile on while the drainer runs, so a
        # sleep on top only adds latency.
        wall_scalar = wall_sq = float("inf")
        crossings = submissions = drains = 0
        for _ in range(reps):
            wall_scalar = min(wall_scalar,
                              _run_workers(threads, scalar_worker))
            m.start_sqpoll(idle_us=0, adaptive=False)
            g0, s0, d0 = m.gate.crossings, m.mq_submissions, m.mq_drains
            wall_sq = min(wall_sq, _run_workers(threads, sq_worker))
            crossings += m.gate.crossings - g0
            submissions += m.mq_submissions - s0
            drains += m.mq_drains - d0
            m.stop_sqpoll()  # scalar trials measure the unpolled path
        scalar_ops = total_ops / wall_scalar
        sq_ops = total_ops / wall_sq
        # untimed correctness pass: same batches, every read checked
        m.start_sqpoll(idle_us=0, adaptive=False)
        _run_workers(threads, sq_verify_worker)
        m.stop_sqpoll()
    finally:
        if gc_was_on:
            gc.enable()
    assert not errors, errors[:5]
    rows.append({
        "bench": "threaded_read", "fs": kind, "threads": threads,
        "batch": batch, "scalar_ops_per_s": scalar_ops,
        "batched_ops_per_s": sq_ops, "speedup": sq_ops / scalar_ops,
        "submissions": submissions, "drains": drains,
        "gate_crossings": crossings,
    })

    # --- phase 3: concurrent chains (create→write→flush per item) -------------
    journal = getattr(getattr(m, "module", None), "journal", None)
    ch0 = journal.chains if journal else 0
    per_thread_items = max(1, chain_items // threads)
    payload = b"p" * 1024
    start = threading.Barrier(threads)
    chain_errors: List[str] = []

    def chain_worker(t):
        v.makedirs(f"/t{t}")
        start.wait()
        try:
            out = v.create_and_write_many(
                [(f"/t{t}/f{i:04d}", payload)
                 for i in range(per_thread_items)], fsync=True)
            if out != [len(payload)] * per_thread_items:
                chain_errors.append(f"thread {t}: {out[:3]}...")
        except Exception as e:  # noqa: BLE001 — surfaced by the assert
            chain_errors.append(f"thread {t}: {type(e).__name__}: {e}")

    m.start_sqpoll(idle_us=0, adaptive=False)  # chains ride the poller too
    wall_chain = _run_workers(threads, chain_worker)
    m.stop_sqpoll()
    assert not chain_errors, chain_errors[:5]
    n_chain = threads * per_thread_items
    chains_taken = (journal.chains - ch0) if journal else None
    rows.append({
        "bench": "threaded_chained_cwf", "fs": kind, "threads": threads,
        "batch": per_thread_items,
        "batched_ops_per_s": n_chain / wall_chain,
        "chain_reservations": chains_taken, "chain_items": n_chain,
    })
    # verify: every file present with its payload
    for t in range(threads):
        names = v.listdir(f"/t{t}")
        assert len(names) == per_thread_items, (t, len(names))
    mf.close()

    # --- tripwires -------------------------------------------------------------
    r = rows[0]
    assert r["speedup"] >= 3.0, \
        (f"threaded SQs only {r['speedup']:.2f}x over {threads} scalar "
         f"threads (target 3.0x)")
    assert r["submissions"] >= threads * batches_per_thread  # all submitted
    assert r["drains"] <= r["submissions"], "drains cannot exceed submissions"
    assert r["gate_crossings"] <= 0.8 * r["submissions"], \
        (f"{r['gate_crossings']} crossings for {r['submissions']} "
         f"submissions — the drain never coalesced concurrent submitters")
    rc = rows[1]
    assert rc["chain_reservations"] is None \
        or rc["chain_reservations"] == rc["chain_items"], \
        (f"{rc['chain_reservations']} chain reservations for "
         f"{rc['chain_items']} create→write pairs — a chain merged or split")
    return rows


def bench_dedup(kind: str = "dedup-bento", *, n_files: int = 24,
                blocks_per_file: int = 8, n_torn: int = 6,
                seed: int = 7) -> List[Dict]:
    """Content-addressed BlockStore mode (dedup mounts) — self-asserting.

    Phase 1 (space): a dup-heavy corpus — ``n_files`` files of
    ``blocks_per_file`` 4 KiB blocks each, drawn from a unique-block pool
    a quarter the corpus size — written through ``write_many`` batches.
    Tripwires: exactly ONE blockhash launch per flushed batch (the
    batched data plane never degrades to per-block hashing) and ≥ 2x
    logical-over-physical space saving measured by the statfs free-block
    delta (dedup really shares).

    Phase 2 (verified reads): tear ``n_torn`` tracked device blocks
    behind the cache's back, drop them from the cache, and bulk-read the
    whole corpus with ``strict=False``. Tripwires: EIO for EXACTLY the
    files touching torn blocks (100% detection, zero false positives),
    byte-identical data everywhere else, and a corruption counter equal
    to the number of torn blocks."""
    from repro.core.interface import FsError

    rows: List[Dict] = []
    mf = make_mount(kind, n_blocks=16384)
    v, ks, fs = mf.view, mf.services, mf.mount.module
    rng = np.random.default_rng(seed)
    pool_n = max(2, (n_files * blocks_per_file) // 4)
    pool = [rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
            for _ in range(pool_n)]
    files = {
        f"/d{f:03d}": b"".join(pool[int(rng.integers(pool_n))]
                               for _ in range(blocks_per_file))
        for f in range(n_files)}
    paths = sorted(files)

    # --- phase 1: dup-heavy corpus through flushed write_many batches --------
    free0 = v.statfs()["free_blocks_est"]
    h0 = v.statfs()["dedup_hash_launches"]
    per_batch = 8
    n_batches = 0
    t0 = time.perf_counter()
    for i in range(0, len(paths), per_batch):
        chunk = paths[i:i + per_batch]
        v.write_many([(p, 0, files[p]) for p in chunk], create=True,
                     fsync=True)
        n_batches += 1
    wall = time.perf_counter() - t0
    sf = v.statfs()
    logical = n_files * blocks_per_file
    physical = free0 - sf["free_blocks_est"]
    launches = sf["dedup_hash_launches"] - h0
    ratio = logical / max(1, physical)
    rows.append({
        "bench": "dedup_write", "fs": kind, "files": n_files,
        "logical_blocks": logical, "physical_blocks": physical,
        "space_saving": ratio, "dedup_hits": sf["dedup_hits"],
        "cow_breaks": sf["dedup_cow_breaks"],
        "hash_launches_per_batch": launches / n_batches,
        "ops_per_s": logical / wall,
    })
    assert launches == n_batches, \
        (f"{launches} blockhash launches for {n_batches} flushed batches "
         f"(expected exactly one per batch)")
    assert ratio >= 2.0, \
        (f"space saving {ratio:.2f}x on a 4:1 dup-heavy corpus "
         f"(target >= 2x): {physical} physical for {logical} logical")

    # --- phase 2: torn device blocks must all be caught by verified reads ----
    store = fs._blockstore
    hashed = sorted(store.hashval)
    picks = np.linspace(0, len(hashed) - 1, min(n_torn, len(hashed)))
    torn = sorted({hashed[int(i)] for i in picks})
    block_files: Dict[int, set] = {}
    for p in paths:
        di = fs._iget(v._walk(p))
        cache: Dict = {}
        for bn in range((di.size + 4095) // 4096):
            block_files.setdefault(fs._bmap_ro(di, bn, cache), set()).add(p)
    expect_bad = {p for b in torn for p in block_files.get(b, ())}
    for b in torn:
        raw = bytearray(mf.dev.read_block(b))
        raw[:16] = b"torn-by-bench!!!"
        mf.dev.write_block(b, bytes(raw))
    ks.sb_invalidate_blocks(fs.sb_cap, torn)  # next read refetches
    c0 = v.statfs()["dedup_corruptions_detected"]
    got = v.read_many([(p, 0, len(files[p])) for p in paths], strict=False)
    bad = {p for p, r in zip(paths, got) if isinstance(r, FsError)}
    detected = v.statfs()["dedup_corruptions_detected"] - c0
    rows.append({
        "bench": "dedup_verify", "fs": kind, "torn_blocks": len(torn),
        "detected_blocks": detected, "files_eio": len(bad),
        "detection_rate": detected / len(torn),
    })
    assert bad == expect_bad, \
        (f"verified reads flagged {sorted(bad)} but torn blocks belong to "
         f"{sorted(expect_bad)}")
    assert detected == len(torn), \
        f"{detected}/{len(torn)} torn blocks detected (need 100%)"
    for p, r in zip(paths, got):
        if p not in bad:
            assert r == files[p], f"clean file {p} returned wrong bytes"
    mf.close()
    return rows


def _run_workers(n: int, worker) -> float:
    threads = [threading.Thread(target=worker, args=(t,)) for t in range(n)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.perf_counter() - t0


def run_all(kinds=ALL_KINDS, quick: bool = False) -> List[Dict]:
    rows: List[Dict] = []
    for kind in kinds:
        scale = (0.05 if kind == "fuse" else 1.0) * (0.25 if quick else 1.0)
        rows += bench_read(kind, ops_scale=scale)
        rows += bench_write(kind, ops_scale=scale)
        rows += bench_create(kind, ops_scale=scale)
        rows += bench_delete(kind, ops_scale=scale)
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batched", action="store_true",
                    help="run the batched-vs-scalar BentoQueue mode")
    ap.add_argument("--kind", default="bento",
                    help="mount kind for --batched (default: bento)")
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--total-ops", type=int, default=8192)
    ap.add_argument("--threads", type=int, default=0,
                    help="with --batched: also run the multi-submitter "
                         "mode with N worker threads on thread-local "
                         "SubmitterQueues vs N scalar threads")
    ap.add_argument("--seed", type=int, default=7,
                    help="rng seed for benchmark payloads (reproducibility)")
    ap.add_argument("--dedup", action="store_true",
                    help="with --batched: also run the content-addressed "
                         "BlockStore mode (space saving, one blockhash "
                         "launch per batch, torn-write detection) on both "
                         "dedup mount kinds")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.batched:
        if args.batch <= 0 or args.total_ops < args.batch:
            ap.error("--batch must be positive and <= --total-ops")
        total_ops = args.total_ops // 4 if args.quick else args.total_ops
        batch = min(args.batch, total_ops)  # --quick shrinks ops, not args
        meta_ops = 128 if args.quick else 512
        rows = bench_batched(args.kind, batch=batch, total_ops=total_ops,
                             meta_ops=meta_ops,
                             meta_batch=min(64, meta_ops), seed=args.seed)
        for r in rows:
            line = f"{r['bench']}/{r['fs']}/batch{r['batch']}:"
            if "scalar_ops_per_s" in r:
                line += (f" scalar {r['scalar_ops_per_s']:.0f} ops/s,"
                         f" batched {r['batched_ops_per_s']:.0f} ops/s,"
                         f" speedup {r['speedup']:.2f}x")
            else:
                line += f" {r['batched_ops_per_s']:.0f} ops/s"
            if r.get("gate_crossings_per_batch") is not None:
                line += (f", gate crossings/batch "
                         f"{r['gate_crossings_per_batch']:.2f}")
            if r.get("checksum_batch_per_flush") is not None:
                line += (f", checksum_batch launches/flush "
                         f"{r['checksum_batch_per_flush']:.2f}")
            if r.get("chain_reservations_per_batch") is not None:
                line += (f", chain txn reservations/batch "
                         f"{r['chain_reservations_per_batch']:.1f}")
            print(line)
        # perf-path bitrot tripwires (CI runs this with --quick): a silent
        # fall-back to scalar dispatch shows up as extra gate crossings or
        # extra checksum launches and must fail loudly, not just slow down.
        for r in rows:
            c = r.get("gate_crossings_per_batch")
            assert c is None or c == 1.0, \
                f"{r['bench']}: {c} gate crossings/batch (expected 1)"
            c = r.get("checksum_batch_per_flush")
            assert c is None or c == 1.0, \
                f"{r['bench']}: {c} checksum_batch launches/flush (expected 1)"
            c = r.get("chain_reservations_per_batch")
            assert c is None or c == float(r["batch"]), \
                (f"{r['bench']}: {c} chain reservations/batch "
                 f"(expected {r['batch']} — one per create→write pair)")
        slow = [r for r in rows if r.get("speedup", 99) < 1.5]
        for r in slow:
            print(f"WARNING: {r['bench']} speedup {r['speedup']:.2f}x "
                  f"below the 1.5x target")
        if args.dedup:
            from repro.fs.mounts import DEDUP_KINDS
            n_files = 16 if args.quick else 24
            for dkind in DEDUP_KINDS:
                drows = bench_dedup(dkind, n_files=n_files, seed=args.seed)
                for r in drows:
                    if r["bench"] == "dedup_write":
                        print(f"{r['bench']}/{r['fs']}: "
                              f"{r['logical_blocks']} logical -> "
                              f"{r['physical_blocks']} physical blocks "
                              f"({r['space_saving']:.2f}x saved), "
                              f"{r['dedup_hits']} hits, "
                              f"{r['hash_launches_per_batch']:.2f} "
                              f"blockhash launches/batch")
                    else:
                        print(f"{r['bench']}/{r['fs']}: "
                              f"{r['detected_blocks']}/{r['torn_blocks']} "
                              f"torn blocks detected "
                              f"({r['detection_rate']:.0%}), "
                              f"{r['files_eio']} files EIO")
            # bench_dedup asserts its own tripwires (one launch per batch,
            # >=2x space saving, 100% torn-write detection, no false EIO)
        if args.threads > 0:
            trows = bench_threaded(
                args.kind, threads=args.threads,
                batches_per_thread=12 if args.quick else 16,
                chain_items=48 if args.quick else 96, seed=args.seed)
            for r in trows:
                line = (f"{r['bench']}/{r['fs']}/threads{r['threads']}"
                        f"/batch{r['batch']}:")
                if "scalar_ops_per_s" in r:
                    line += (f" scalar {r['scalar_ops_per_s']:.0f} ops/s,"
                             f" threaded-SQ {r['batched_ops_per_s']:.0f} "
                             f"ops/s, speedup {r['speedup']:.2f}x")
                else:
                    line += f" {r['batched_ops_per_s']:.0f} ops/s"
                if r.get("submissions") is not None:
                    line += (f", {r['submissions']} submissions in "
                             f"{r['drains']} drains "
                             f"({r['gate_crossings']} gate crossings)")
                if r.get("chain_reservations") is not None:
                    line += (f", {r['chain_reservations']} chain txns for "
                             f"{r['chain_items']} items")
                print(line)
            # bench_threaded asserts its own tripwires (crossings ≪
            # submissions, ≥1.5x aggregate, one chain txn per pair)
    else:
        for r in run_all(quick=args.quick):
            print(r)


if __name__ == "__main__":
    main()
