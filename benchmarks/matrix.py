"""Variance-aware benchmark matrix — the persisted perf trajectory.

Sweeps {mount kind} x {dispatch mode: scalar / batched / chained /
sqpoll, plus single-threaded v2 checkpoint save+restore cycles on the
kinds a trainer checkpoints to} x {thread count: 1/4/8} with SHUFFLED
SHORT-RUN REPETITION (the btrfs-ublk
benchmark_matrix idiom): instead of timing each cell once in a fixed
order — where thermal drift, page-cache state and background noise bias
whole cells — every (cell, repetition) pair becomes one short run, the
runs are shuffled with a seeded rng, and each run gets a FRESH mount.
Noise then time-averages across cells instead of accumulating into one,
and the per-cell spread (std/cv over repetitions) is reported next to the
mean, so a later PR claiming "X is now faster" has both a baseline and an
error bar to beat.

Output: ``BENCH_<pr>.json`` — ``{"meta", "runs", "summary"}`` where
``runs`` holds one record per short run (execution order preserved) and
``summary`` one aggregate per cell. CI and later perf PRs diff summaries;
the runs stay for re-analysis.

CLI:  PYTHONPATH=src python -m benchmarks.matrix --out BENCH_10.json
      [--reps 5] [--quick] [--fuse] [--seed 7]
      [--baseline BENCH_9.json]

When the baseline file exists, the ckpt (save+restore cycle) cells are
diffed against its error bars: each cell's new mean must clear the
baseline's mean + one std, so a perf claim has to beat the noise band,
not just the point estimate. ``--baseline ''`` skips the gate.
"""

from __future__ import annotations

import argparse
import json
import random
import statistics
import threading
import time
from typing import Dict, List

from repro.fs.mounts import make_mount

WARM_BLOCKS = 64          # 256 KiB warm file per mount
SIZE = 4096

# kind label -> make_mount arguments (the prov layer is a flag, not a kind)
KIND_ARGS = {
    "bento": ("bento", False),
    "vfs": ("vfs", False),
    "ext4like": ("ext4like", False),
    "prov-bento": ("bento", True),
    "dedup-bento": ("dedup-bento", False),
    "dedup-ext4like": ("dedup-ext4like", False),
    "overlay-bento": ("overlay-bento", False),
    "overlay-ext4like": ("overlay-ext4like", False),
    "fuse": ("fuse", False),
}
DEFAULT_KINDS = ("bento", "vfs", "ext4like", "prov-bento",
                 "dedup-bento", "dedup-ext4like", "overlay-bento")
MODES = ("scalar", "batched", "chained", "sqpoll", "ckpt")
THREADS = (1, 4, 8)
# sqpoll cells need the gated multi-submitter mount; the VFS-direct
# baseline and the FUSE bridge have no SubmitterQueue to poll
NO_SQPOLL_KINDS = ("vfs", "fuse")
# checkpoint save+restore cycles (v2 sharded store, re-save swap + load):
# single-threaded, on the kinds a trainer actually checkpoints to
CKPT_KINDS = ("bento", "ext4like", "dedup-bento")


def _workers(n: int, worker) -> float:
    """Wall seconds for n barrier-synchronized workers of worker(t)."""
    if n == 1:
        t0 = time.perf_counter()
        worker(0)
        return time.perf_counter() - t0
    barrier = threading.Barrier(n + 1)
    done: List[BaseException] = []

    def run(t):
        barrier.wait()
        try:
            worker(t)
        except BaseException as e:  # noqa: BLE001 — re-raised below
            done.append(e)

    threads = [threading.Thread(target=run, args=(t,)) for t in range(n)]
    for th in threads:
        th.start()
    barrier.wait()
    t0 = time.perf_counter()
    for th in threads:
        th.join()
    if done:
        raise done[0]
    return time.perf_counter() - t0


def run_one(kind: str, mode: str, threads: int, *, ops: int,
            seed: int) -> Dict:
    """One short run: fresh mount, warm file, timed workload, ops/s."""
    base_kind, prov = KIND_ARGS[kind]
    mf = make_mount(base_kind, n_blocks=16384, prov=prov)
    v = mf.view
    try:
        blob = bytes([seed & 0xFF]) * SIZE
        v.write_file("/warm", blob * WARM_BLOCKS)
        v.fsync("/warm")
        n_off = WARM_BLOCKS
        if mode == "scalar":
            def worker(t):
                for i in range(ops):
                    v.read_file("/warm", off=((t * ops + i) % n_off) * SIZE,
                                size=SIZE)

            wall = _workers(threads, worker)
            n_ops = threads * ops
        elif mode in ("batched", "sqpoll"):
            batch = 64
            n_batches = max(1, ops // batch)

            def worker(t):
                for b in range(n_batches):
                    base = t * ops + b * batch
                    v.read_many([("/warm", ((base + i) % n_off) * SIZE, SIZE)
                                 for i in range(batch)])

            if mode == "sqpoll":
                # dedicated poller drains every submitter's queue in one
                # crossing and fuses the read runs into one cache pass;
                # idle_us=0 — execution itself is the gather window
                mf.mount.start_sqpoll(idle_us=0, adaptive=False)
                try:
                    wall = _workers(threads, worker)
                finally:
                    mf.mount.stop_sqpoll()
            else:
                wall = _workers(threads, worker)
            n_ops = threads * n_batches * batch
        elif mode == "chained":  # create→write(PrevResult)→fsync triples
            files = max(4, ops // 16)
            payload = b"p" * 1024

            def worker(t):
                v.makedirs(f"/t{t}")
                v.create_and_write_many(
                    [(f"/t{t}/f{i:04d}", payload) for i in range(files)],
                    fsync=True)

            wall = _workers(threads, worker)
            n_ops = threads * files
        elif mode == "ckpt":
            # v2 sharded checkpoint cycles: each round re-saves over the
            # live checkpoint (generation bump + tmp/rename swap) and
            # restores it back — the durable save/restore path a trainer
            # pays every ckpt_every steps. One op = one shard file
            # written or read.
            import numpy as np

            from repro import checkpoint as ckpt_store
            from repro.distributed.resharding import ShardGrid

            rng = np.random.default_rng(seed)
            tree = {"w": rng.normal(size=(64, 32)).astype(np.float32),
                    "b": rng.normal(size=(256,)).astype(np.float32),
                    "s": np.float32(seed)}
            grids = {"w": ShardGrid.from_spec((64, 32), ("d", "m"),
                                              {"d": 2, "m": 2}),
                     "b": None, "s": None}
            cks = mf.services.checksum
            cycles = max(1, ops // 64)
            shard_files = 0
            t0 = time.perf_counter()
            for c in range(cycles):
                man = ckpt_store.save(v, "/ck/step_1", tree, step=1,
                                      checksum=cks, shardings=grids)
                shard_files = sum(len(r["shards"]) for r in man["leaves"])
                back, _ = ckpt_store.load(v, "/ck/step_1", tree,
                                          checksum=cks)
                assert float(np.asarray(back["s"])) == float(tree["s"])
            wall = time.perf_counter() - t0
            n_ops = cycles * shard_files * 2
        return {"kind": kind, "mode": mode, "threads": threads,
                "ops": n_ops, "wall_s": wall, "ops_per_s": n_ops / wall}
    finally:
        mf.close()


def run_matrix(kinds=DEFAULT_KINDS, *, reps: int = 5, ops: int = 512,
               seed: int = 7) -> Dict:
    cells = [(k, m, t) for k in kinds for m in MODES for t in THREADS
             # scalar-shared at 4 threads exists for every kind; the fuse
             # daemon serializes anyway, so skip its 4-thread rows
             if not (k == "fuse" and t > 1)
             and not (m == "sqpoll" and k in NO_SQPOLL_KINDS)
             and not (m == "ckpt" and (k not in CKPT_KINDS or t != 1))]
    schedule = [(c, r) for c in cells for r in range(reps)]
    random.Random(seed).shuffle(schedule)  # the variance-awareness
    runs: List[Dict] = []
    for i, ((kind, mode, threads), rep) in enumerate(schedule):
        cell_ops = ops // 8 if kind == "fuse" else ops
        row = run_one(kind, mode, threads, ops=cell_ops, seed=seed + rep)
        row.update({"rep": rep, "order": i})
        runs.append(row)
        print(f"[{i + 1:3d}/{len(schedule)}] {kind}/{mode}/t{threads} "
              f"rep{rep}: {row['ops_per_s']:.0f} ops/s")
    summary = []
    for kind, mode, threads in cells:
        vals = sorted(r["ops_per_s"] for r in runs
                      if (r["kind"], r["mode"], r["threads"])
                      == (kind, mode, threads))
        mean = statistics.fmean(vals)
        std = statistics.stdev(vals) if len(vals) > 1 else 0.0
        summary.append({
            "kind": kind, "mode": mode, "threads": threads, "reps": len(vals),
            "ops_per_s_mean": mean, "ops_per_s_std": std,
            "cv": std / mean if mean else 0.0,
            "ops_per_s_min": vals[0], "ops_per_s_max": vals[-1],
        })
    return {
        "meta": {"bench": "matrix", "reps": reps, "ops": ops, "seed": seed,
                 "kinds": list(kinds), "modes": list(MODES),
                 "ckpt_kinds": list(CKPT_KINDS),
                 "threads": list(THREADS), "shuffled": True,
                 "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S")},
        "runs": runs,
        "summary": summary,
    }


def diff_ckpt_cells(table: Dict, baseline_path: str) -> bool:
    """Gate the ckpt cells on the baseline's error bars: every
    (kind, threads) ckpt cell present in both tables must have a new
    mean above the baseline's mean + one std. Returns False (after
    printing the losers) when any cell misses."""
    import os

    if not baseline_path or not os.path.exists(baseline_path):
        print(f"  (no baseline {baseline_path!r} — ckpt diff skipped)")
        return True
    with open(baseline_path) as f:
        base = json.load(f)
    bars = {(s["kind"], s["threads"]):
            (s["ops_per_s_mean"], s["ops_per_s_std"])
            for s in base["summary"] if s["mode"] == "ckpt"}
    ok = True
    for s in table["summary"]:
        if s["mode"] != "ckpt" or (s["kind"], s["threads"]) not in bars:
            continue
        mean, std = bars[(s["kind"], s["threads"])]
        bar = mean + std
        verdict = "OK" if s["ops_per_s_mean"] > bar else "MISS"
        ok = ok and verdict == "OK"
        print(f"  ckpt {s['kind']:>14}: {s['ops_per_s_mean']:7.0f} ops/s "
              f"vs baseline {mean:.0f} + {std:.0f} = {bar:.0f} — {verdict}")
    return ok


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_10.json")
    ap.add_argument("--baseline", default="BENCH_9.json",
                    help="prior matrix to diff ckpt cells against "
                         "('' disables the gate)")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--ops", type=int, default=512,
                    help="per-thread op budget of one short run")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--quick", action="store_true",
                    help="3 reps x 256 ops (CI budget)")
    ap.add_argument("--fuse", action="store_true",
                    help="include the FUSE daemon kind (a subprocess per "
                         "run — much slower)")
    args = ap.parse_args()
    reps = 3 if args.quick else args.reps
    ops = 256 if args.quick else args.ops
    kinds = DEFAULT_KINDS + (("fuse",) if args.fuse else ())
    table = run_matrix(kinds, reps=reps, ops=ops, seed=args.seed)
    with open(args.out, "w") as f:
        json.dump(table, f, indent=1)
    print(f"\n{args.out}: {len(table['runs'])} runs, "
          f"{len(table['summary'])} cells")
    for s in table["summary"]:
        print(f"  {s['kind']:>14}/{s['mode']:<7} t{s['threads']}: "
              f"{s['ops_per_s_mean']:9.0f} ops/s "
              f"± {s['ops_per_s_std']:7.0f} (cv {s['cv']:.2f})")
    if not diff_ckpt_cells(table, args.baseline):
        raise SystemExit(
            "ckpt cells regressed against the baseline error bars")


if __name__ == "__main__":
    main()
