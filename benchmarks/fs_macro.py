"""FS macrobenchmarks — paper Table 6: varmail, fileserver, untar-linux.

varmail    : mail-server loop — create/append/fsync/read/delete + a fsync'd
             operation log (ops/s; fsync-dominated like the paper's).
fileserver : file-serving mix — create/write/append/read/stat/delete over a
             working set, few fsyncs (ops/s).
untar      : create a synthetic source tree (dirs + files with realistic
             size mix), measured as total seconds — lower is better.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.fs.mounts import ALL_KINDS, make_mount


def varmail(kind: str, loops: int = 120) -> Dict:
    mf = make_mount(kind, n_blocks=16384)
    v = mf.view
    v.makedirs("/mail")
    v.create("/mail/op.log")
    msg = b"m" * 8192
    ops = 0
    t0 = time.perf_counter()
    for i in range(loops):
        name = f"/mail/msg{i % 64:04d}"
        v.write_file(name, msg)
        v.append("/mail/op.log", b"delivered %d\n" % i)
        v.fsync("/mail/op.log")
        v.read_file(name)
        if i % 4 == 3:
            v.unlink(name)
        ops += 4
    wall = time.perf_counter() - t0
    mf.close()
    return {"bench": "varmail", "fs": kind, "ops_per_s": ops / wall}


def fileserver(kind: str, loops: int = 120) -> Dict:
    mf = make_mount(kind, n_blocks=32768)
    v = mf.view
    v.makedirs("/srv")
    blob = b"f" * 65536
    ops = 0
    rng = np.random.default_rng(11)
    t0 = time.perf_counter()
    for i in range(loops):
        name = f"/srv/file{int(rng.integers(50)):04d}"
        v.write_file(name, blob)
        v.append(name, b"tail" * 256)
        v.read_file(name)
        v.stat(name)
        if i % 5 == 4:
            v.unlink(name)
        ops += 5
        if i % 16 == 15:
            v.fsync(name if v.exists(name) else "/srv")
            ops += 1
    wall = time.perf_counter() - t0
    mf.close()
    return {"bench": "fileserver", "fs": kind, "ops_per_s": ops / wall}


def untar(kind: str, n_dirs: int = 12, files_per_dir: int = 10) -> Dict:
    """Synthetic kernel-source-like tree: many small files, few big."""
    mf = make_mount(kind, n_blocks=32768)
    v = mf.view
    rng = np.random.default_rng(13)
    sizes = [1024, 2048, 4096, 8192, 16384, 65536]
    t0 = time.perf_counter()
    for d in range(n_dirs):
        v.makedirs(f"/src/dir{d:03d}")
        for f in range(files_per_dir):
            size = sizes[int(rng.integers(len(sizes)))]
            v.write_file(f"/src/dir{d:03d}/file{f:03d}.c", b"c" * size)
    v.fsync("/src")
    wall = time.perf_counter() - t0
    mf.close()
    return {"bench": "untar", "fs": kind, "seconds": wall}


def run_all(kinds=ALL_KINDS, quick: bool = False) -> List[Dict]:
    rows = []
    for kind in kinds:
        scale = 0.15 if kind == "fuse" else 1.0
        if quick:
            scale *= 0.3
        loops = max(10, int(120 * scale))
        rows.append(varmail(kind, loops))
        rows.append(fileserver(kind, loops))
        rows.append(untar(kind, n_dirs=max(3, int(12 * scale))))
    return rows
