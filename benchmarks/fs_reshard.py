"""Topology-elastic checkpoint benchmark: reshard-on-restore through the
batched FS path, plus the multi-tenant provisioning stories on top of it.

Three self-asserting phases (the acceptance bar, not a human eyeballing
numbers):

* **elastic** — a seeded model saved shard-per-file on mesh A (2x2) is
  restored onto the SAME, a HALVED (1x2) and a DOUBLED (4x2) mesh:
  every leaf must come back byte-identical to the whole-tensor reference
  with the target topology's sharding, and every leaf whose target shards
  are proper subsets of the tensor must assemble with peak materialized
  bytes strictly BELOW full-tensor size (the streamed ``read_many``
  reshard path — a restore that gathers full leaves fails here).
* **overlap** — the same elastic reshard through the FUSE daemon (a real
  address-space crossing per fetch), restored serial (pipeline depth 0:
  the legacy verify-then-fill two-pass) vs overlapped (depth 2: folded
  verification + prefetch-while-assemble). Best-of-N wall clock; the
  overlapped engine must beat serial >= 1.3x on the halved+doubled
  reshard cells combined, per-leaf metered peak must stay strictly below
  full-tensor bytes for properly sharded targets AND within depth x the
  serial engine's peak for every streamed leaf.
* **tenants** — N overlay tenants over ONE golden base image carrying the
  checkpoint each restore it through their CoW mount: byte-identical per
  tenant, the shared image untouched, and the blocks materialized per
  tenant a bounded fraction of the image (restore reads ride the lazy
  batched fetch path).
* **dedup** — N identical checkpoints saved to distinct roots of a
  dedup mount must physically cost ~one checkpoint: the content-addressed
  blockstore absorbs the clones (logical - physical = saved blocks).

CLI:  PYTHONPATH=src python -m benchmarks.fs_reshard [--quick]
      [--tenants 8] [--skip-elastic]
"""

from __future__ import annotations

import os

# 8 fake host devices for the elastic phase — must land before jax loads
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time
from typing import Dict

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import checkpoint as ckpt
from repro.distributed.resharding import ShardGrid
from repro.fs.mounts import build_base_image, make_mount, overlay_tenant
from repro.launch.mesh import make_elastic_mesh

SPECS = {
    "w1": P("data", "model"),
    "w2": P("model", "data"),
    "e": P("model", None),
    "b": P("data"),
    "r": P(),
    "s": P(),
}


def _host_tree(scale: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(7)
    return {
        "w1": rng.normal(size=(64 * scale, 32 * scale)).astype(np.float32),
        "w2": rng.normal(size=(32 * scale, 16 * scale)).astype(np.float32),
        "e": rng.normal(size=(16 * scale, 8 * scale)).astype(np.float32),
        "b": rng.normal(size=(64 * scale,)).astype(np.float32),
        "r": rng.normal(size=(8, 8)).astype(np.float32),
        "s": np.float32(1.25),
    }


def run_elastic(scale: int = 4) -> Dict:
    """Save on (2,2), restore onto same/halved/doubled — asserted."""
    if len(jax.devices()) < 8:
        raise RuntimeError("elastic phase needs 8 host devices "
                           "(XLA_FLAGS was set too late)")
    host = _host_tree(scale)
    mesh_a = make_elastic_mesh(2, 2)
    sh_a = {k: NamedSharding(mesh_a, SPECS[k]) for k in host}
    tree = {k: jax.device_put(jnp.asarray(v), sh_a[k])
            for k, v in host.items()}
    total_bytes = sum(v.nbytes for v in host.values())

    mf = make_mount("bento", n_blocks=65536)
    cks = mf.services.checksum
    t0 = time.perf_counter()
    man = ckpt.save(mf.view, "/ck/step_1", tree, step=1, checksum=cks,
                    shardings=sh_a)
    save_s = time.perf_counter() - t0
    assert man["version"] == 2
    n_shard_files = sum(len(r["shards"]) for r in man["leaves"])
    assert n_shard_files > len(man["leaves"]), "nothing actually sharded"

    like = {k: jnp.zeros(v.shape, v.dtype) for k, v in host.items()}
    topos = {"same": (2, 2), "halved": (1, 2), "doubled": (4, 2)}
    out = {"bench": "fs_reshard", "phase": "elastic",
           "leaf_bytes_total": total_bytes, "shard_files": n_shard_files,
           "save_s": save_s, "restores": {}}
    for name, (d, m) in topos.items():
        mesh_b = make_elastic_mesh(d, m)
        sh_b = {k: NamedSharding(mesh_b, SPECS[k]) for k in host}
        stats: Dict = {}
        t0 = time.perf_counter()
        back, _ = ckpt.load(mf.view, "/ck/step_1", like, checksum=cks,
                            sharding_tree=sh_b, stats=stats)
        restore_s = time.perf_counter() - t0
        for k, ref in host.items():  # byte-identical + right topology
            got = np.asarray(jax.device_get(back[k]))
            assert got.dtype == ref.dtype and got.shape == ref.shape
            assert (got == ref).all(), f"{name}: leaf {k} corrupted"
            assert back[k].sharding.devices_indices_map(ref.shape) == \
                sh_b[k].devices_indices_map(ref.shape), (name, k)
        # bounded peak: every properly-sharded streamed leaf assembles
        # strictly below full-tensor bytes; replicated targets (or axes
        # collapsed to 1 on the halved mesh) legitimately materialize
        # the whole leaf and are exempt by construction
        strict = [s for s in stats["leaves"]
                  if s["streamed"] and
                  s["max_target_bytes"] < s["full_bytes"]]
        for s in strict:
            assert s["peak_bytes"] < s["full_bytes"], (
                f"{name}: leaf {s['leaf']} peaked at {s['peak_bytes']} "
                f">= full {s['full_bytes']} — restore gathered the tensor")
        assert len(strict) >= 2, (name, stats["leaves"])
        worst = max(s["peak_bytes"] / s["full_bytes"] for s in strict)
        out["restores"][name] = {
            "mesh": [d, m], "restore_s": restore_s,
            "streamed_leaves": sum(1 for s in stats["leaves"]
                                   if s["streamed"]),
            "strict_leaves": len(strict), "worst_peak_fraction": worst,
        }
    mf.close()
    return out


def run_overlap(scale: int = 32, depth: int = 2, reps: int = 4,
                min_speedup: float = 1.3) -> Dict:
    """Overlapped (prefetch-while-assemble) vs serial restore through the
    FUSE daemon — the store where fetch latency is a real address-space
    crossing, i.e. the regime the restore pipeline exists for."""
    import zlib

    if len(jax.devices()) < 8:
        raise RuntimeError("overlap phase needs 8 host devices "
                           "(XLA_FLAGS was set too late)")

    def cks(raw):  # the userspace binding's checksum (services daemon-side)
        return zlib.crc32(bytes(raw)) & 0xFFFFFFFF

    host = _host_tree(scale)
    like = {k: jnp.zeros(v.shape, v.dtype) for k, v in host.items()}

    mf = make_mount("fuse", n_blocks=65536)
    try:
        return _run_overlap_cells(mf, cks, host, like, depth, reps,
                                  min_speedup)
    finally:
        mf.close()  # a failed assert must not leak the daemon


def _run_overlap_cells(mf, cks, host, like, depth, reps,
                       min_speedup) -> Dict:
    out = {"bench": "fs_reshard", "phase": "overlap", "depth": depth,
           "leaf_bytes_total": sum(v.nbytes for v in host.values()),
           "cells": {}}
    sh_a = {k: NamedSharding(make_elastic_mesh(2, 2), SPECS[k])
            for k in host}
    tree = {k: jax.device_put(jnp.asarray(v), sh_a[k])
            for k, v in host.items()}
    ckpt.save(mf.view, "/ck/step_1", tree, step=1, checksum=cks,
              shardings=sh_a)
    serial_total = piped_total = 0.0
    for name, (d, m) in (("halved", (1, 2)), ("doubled", (4, 2))):
        mesh_b = make_elastic_mesh(d, m)
        sh_b = {k: NamedSharding(mesh_b, SPECS[k]) for k in host}
        # untimed warm-up: first restore onto a fresh target mesh pays
        # one-off device_put/layout costs that belong to neither engine
        ckpt.load(mf.view, "/ck/step_1", like, checksum=cks,
                  sharding_tree=sh_b, pipeline_depth=depth)
        best = {}
        for dep in (0, depth):
            best[dep] = (1e9, None)
            for _ in range(reps):
                stats: Dict = {}
                t0 = time.perf_counter()
                back, _ = ckpt.load(mf.view, "/ck/step_1", like,
                                    checksum=cks, sharding_tree=sh_b,
                                    stats=stats, pipeline_depth=dep)
                dt = time.perf_counter() - t0
                if dt < best[dep][0]:
                    best[dep] = (dt, stats)
            for k, ref in host.items():  # both engines: byte-identical
                assert (np.asarray(jax.device_get(back[k])) == ref).all(), \
                    f"overlap/{name} depth {dep}: leaf {k} corrupted"
        serial_s, serial_stats = best[0]
        piped_s, piped_stats = best[depth]
        serial_total += serial_s
        piped_total += piped_s
        # peak discipline: strictly sub-full for properly sharded
        # targets, and within depth x the serial engine's metered peak
        serial_peak = {s["leaf"]: s["peak_bytes"]
                       for s in serial_stats["leaves"]}
        strict = 0
        for s in piped_stats["leaves"]:
            if not s["streamed"]:
                continue
            assert s["peak_bytes"] <= depth * serial_peak[s["leaf"]], (
                f"overlap/{name}: leaf {s['leaf']} peak {s['peak_bytes']} "
                f"exceeds depth x serial peak "
                f"{depth * serial_peak[s['leaf']]}")
            if s["max_target_bytes"] < s["full_bytes"]:
                assert s["peak_bytes"] < s["full_bytes"], (
                    f"overlap/{name}: leaf {s['leaf']} gathered the "
                    f"tensor ({s['peak_bytes']} >= {s['full_bytes']})")
                strict += 1
        assert strict >= 2, (name, piped_stats["leaves"])
        out["cells"][name] = {
            "mesh": [d, m], "serial_s": serial_s, "pipelined_s": piped_s,
            "speedup": serial_s / piped_s,
            "overlap_ratio": piped_stats["pipeline"]["overlap_ratio"],
        }
    out["speedup_combined"] = serial_total / piped_total
    assert out["speedup_combined"] >= min_speedup, (
        f"overlapped restore only {out['speedup_combined']:.2f}x serial "
        f"across halved+doubled cells (bar: {min_speedup}x) — the "
        f"pipeline is not hiding fetch latency")
    return out


def _virtual_ckpt_save(view, root: str, host: Dict[str, np.ndarray]):
    """Deviceless v2 save (virtual 2x2 grid on the biggest leaf) — the
    tenant/dedup phases shard without touching jax device state."""
    grids = {k: (ShardGrid.from_spec(v.shape, ("d", "m"),
                                     {"d": 2, "m": 2})
                 if len(v.shape) == 2 and min(v.shape) >= 2 else None)
             for k, v in host.items()}
    return ckpt.save(view, root, host, step=1, shardings=grids)


def run_tenants(n_tenants: int = 8, scale: int = 2, *,
                materialize_ceiling: float = 0.25) -> Dict:
    """N tenants restore the SAME checkpoint from one shared base image
    through CoW overlay mounts — the fleet-redeploy story."""
    host = _host_tree(scale)

    def populate(view):
        _virtual_ckpt_save(view, "/ckpt/step_1", host)

    image = build_base_image("xv6", n_blocks=8192, populate=populate)
    image_bytes0 = image._data.tobytes()
    t0 = time.perf_counter()
    tenants = [overlay_tenant(image, "xv6") for _ in range(n_tenants)]
    provision_s = time.perf_counter() - t0
    like = {k: np.zeros(v.shape, v.dtype) for k, v in host.items()}
    t0 = time.perf_counter()
    fetched = []
    for t, mf in enumerate(tenants):
        assert ckpt.latest_step(mf.view, "/ckpt") == 1
        back, man = ckpt.load(mf.view, "/ckpt/step_1", like)
        assert man["version"] == 2
        for k, ref in host.items():
            got = np.asarray(jax.device_get(back[k]))
            assert (got == ref).all(), f"tenant {t}: leaf {k} corrupted"
        mf.view.write_file("/private", b"tenant %d" % t)  # isolation probe
        lazy = mf.mount.module.opts.base_dev
        fetched.append(lazy.provider_blocks_fetched)
    restore_s = time.perf_counter() - t0
    assert tenants[0].view.read_file("/private") == b"tenant 0", \
        "tenant writes leaked across mounts"
    assert image._data.tobytes() == image_bytes0, \
        "a tenant restore wrote to the shared base image"
    frac = max(fetched) / image.n_blocks
    assert frac <= materialize_ceiling, (
        f"restore materialized {max(fetched)} of {image.n_blocks} base "
        f"blocks ({frac:.0%}) — the lazy fetch path regressed")
    for mf in tenants:
        mf.close()
    return {"bench": "fs_reshard", "phase": "tenants",
            "tenants": n_tenants, "provision_s": provision_s,
            "restore_s": restore_s,
            "restore_ms_per_tenant": 1e3 * restore_s / n_tenants,
            "materialized_fraction": frac}


def run_dedup(n_copies: int = 6, scale: int = 2, *,
              marginal_ceiling: float = 0.30) -> Dict:
    """N identical checkpoints on a dedup mount physically cost ~one."""
    host = _host_tree(scale)
    mf = make_mount("dedup-bento", n_blocks=32768)
    free0 = mf.view.statfs()["free_blocks_est"]
    _virtual_ckpt_save(mf.view, "/t0/ckpt", host)
    first_cost = free0 - mf.view.statfs()["free_blocks_est"]
    for t in range(1, n_copies):
        _virtual_ckpt_save(mf.view, f"/t{t}/ckpt", host)
    st = mf.view.statfs()
    total_cost = free0 - st["free_blocks_est"]
    marginal = (total_cost - first_cost) / max(1, n_copies - 1)
    saved = st["free_blocks_logical_est"] - st["free_blocks_est"]
    assert marginal <= marginal_ceiling * first_cost, (
        f"clone checkpoints cost {marginal:.1f} blocks each vs "
        f"{first_cost} for the first — dedup is not absorbing them")
    assert saved >= (n_copies - 1) * first_cost * 0.5, (
        f"only {saved} blocks saved across {n_copies} identical "
        f"checkpoints of {first_cost} blocks")
    like = {k: np.zeros(v.shape, v.dtype) for k, v in host.items()}
    back, _ = ckpt.load(mf.view, f"/t{n_copies - 1}/ckpt", like)
    for k, ref in host.items():
        assert (np.asarray(jax.device_get(back[k])) == ref).all(), \
            f"dedup'd checkpoint corrupted leaf {k}"
    mf.close()
    return {"bench": "fs_reshard", "phase": "dedup", "copies": n_copies,
            "first_cost_blocks": first_cost,
            "marginal_blocks_per_copy": marginal, "saved_blocks": saved}


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small tensors, fewer tenants (CI smoke; same "
                         "asserted bars)")
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--skip-elastic", action="store_true",
                    help="skip the 8-device elastic phase (jax already "
                         "initialized with fewer devices)")
    args = ap.parse_args()
    scale = 2 if args.quick else 4
    n_tenants = 4 if args.quick else args.tenants

    if not args.skip_elastic:
        r = run_elastic(scale=scale)
        print(f"fs_reshard elastic: {r['leaf_bytes_total']} leaf bytes as "
              f"{r['shard_files']} shard files, save {1e3 * r['save_s']:.1f} ms")
        for name, rr in r["restores"].items():
            print(f"  restore {name:8s} mesh {tuple(rr['mesh'])}: "
                  f"{1e3 * rr['restore_s']:7.1f} ms, "
                  f"{rr['streamed_leaves']} streamed leaves, worst peak "
                  f"{rr['worst_peak_fraction']:.2f}x of full (< 1.0) — OK")
        r = run_overlap(scale=16 if args.quick else 32)
        for name, rr in r["cells"].items():
            print(f"fs_reshard overlap {name:8s}: serial "
                  f"{1e3 * rr['serial_s']:7.1f} ms -> depth-{r['depth']} "
                  f"{1e3 * rr['pipelined_s']:7.1f} ms "
                  f"({rr['speedup']:.2f}x)")
        print(f"fs_reshard overlap: combined {r['speedup_combined']:.2f}x "
              f"serial across halved+doubled cells (>= 1.3x) — OK")
    r = run_tenants(n_tenants, scale=2 if args.quick else 3)
    print(f"fs_reshard tenants: {r['tenants']} overlay tenants restored one "
          f"shared checkpoint ({r['restore_ms_per_tenant']:.1f} ms/tenant, "
          f"materialized {r['materialized_fraction']:.1%} of the base "
          f"image) — OK")
    r = run_dedup(4 if args.quick else 6, scale=2 if args.quick else 3)
    print(f"fs_reshard dedup: {r['copies']} identical checkpoints, first "
          f"{r['first_cost_blocks']} blocks, marginal "
          f"{r['marginal_blocks_per_copy']:.1f} blocks/copy, "
          f"{r['saved_blocks']} blocks deduplicated — OK")


if __name__ == "__main__":
    main()
