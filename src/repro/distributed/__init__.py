from repro.distributed.resharding import (
    ReadOp, ShardGrid, normalize_index, plan_reshard, plan_target_shard,
)
from repro.distributed.sharding import RULESETS, ShardingCtx, resolve_spec

__all__ = ["RULESETS", "ShardingCtx", "resolve_spec", "ShardGrid",
           "ReadOp", "normalize_index", "plan_reshard", "plan_target_shard"]
