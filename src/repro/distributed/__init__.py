from repro.distributed.sharding import RULESETS, ShardingCtx, resolve_spec

__all__ = ["RULESETS", "ShardingCtx", "resolve_spec"]
