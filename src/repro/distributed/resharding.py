"""Topology-elastic reshard planning — pure index math, no devices.

A checkpoint saved on mesh A stores each leaf as a GRID of shard files;
restoring onto mesh B (halved, doubled, reshaped) must hand every target
device exactly its slice without ever materializing the full tensor. The
planner here is the deviceless core of that path: ``ShardGrid`` describes
how a leaf was cut (the manifest persists it), and ``plan_target_shard``
intersects source cells with one target index to emit ReadOps — which
source shard files to read and which sub-slices to copy where. The
checkpoint store executes plans over streamed ``read_many`` batches;
everything in this module is testable with plain numpy.

Index convention: every index is a tuple of per-dimension ``(lo, hi)``
half-open int pairs — scalars use the empty tuple. jax's ``slice``-based
index maps normalize through :func:`normalize_index` (slices are not even
hashable, so the normalized form doubles as a grouping key).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

Slice1D = Tuple[int, int]                 # [lo, hi)
Index = Tuple[Slice1D, ...]               # one per dim; () for scalars


def normalize_index(index, shape: Sequence[int]) -> Index:
    """jax device-index-map entry (tuple of slices) -> ((lo,hi), ...).

    ``slice(None)`` / missing bounds mean the full dimension (replicated
    dims in a PartitionSpec show up this way)."""
    out = []
    for sl, dim in zip(tuple(index), tuple(shape)):
        lo = 0 if sl.start is None else int(sl.start)
        hi = dim if sl.stop is None else int(sl.stop)
        out.append((lo, hi))
    return tuple(out)


def _chunk(dim: int, cuts: int, c: int) -> Slice1D:
    """Cell ``c`` of ``dim`` split ``cuts`` ways — jax's ceil-div tiling
    (the last cells may be short or empty on uneven dims)."""
    step = -(-dim // cuts) if cuts else dim
    lo = min(c * step, dim)
    return (lo, min(lo + step, dim))


@dataclasses.dataclass(frozen=True)
class ShardGrid:
    """How one leaf is cut into shard files.

    ``spec`` is the normalized PartitionSpec: one tuple of mesh-axis names
    per dimension (empty = replicated/uncut). ``axes`` carries the sizes
    of every axis the spec references, so the grid is self-contained —
    restoring needs no source Mesh object, just the manifest."""

    shape: Tuple[int, ...]
    spec: Tuple[Tuple[str, ...], ...]
    axes: Tuple[Tuple[str, int], ...]

    @staticmethod
    def trivial(shape: Sequence[int]) -> "ShardGrid":
        shape = tuple(int(d) for d in shape)
        return ShardGrid(shape, tuple(() for _ in shape), ())

    @staticmethod
    def from_spec(shape: Sequence[int], spec, axis_sizes: Dict[str, int]
                  ) -> "ShardGrid":
        """Build from a PartitionSpec-like (entries: None | str | tuple of
        str, trailing Nones implied) + mesh axis sizes."""
        shape = tuple(int(d) for d in shape)
        entries = list(tuple(spec))
        entries += [None] * (len(shape) - len(entries))
        norm = []
        used = []
        for e in entries[:len(shape)]:
            if e is None:
                norm.append(())
            else:
                names = (e,) if isinstance(e, str) else tuple(e)
                norm.append(names)
                used.extend(names)
        axes = tuple(sorted((a, int(axis_sizes[a])) for a in set(used)))
        return ShardGrid(shape, tuple(norm), axes)

    @staticmethod
    def from_sharding(shape: Sequence[int], sharding) -> "ShardGrid":
        """Build from a jax NamedSharding (save-time entry point)."""
        mesh = sharding.mesh
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        return ShardGrid.from_spec(shape, tuple(sharding.spec), sizes)

    # -- grid geometry -------------------------------------------------

    # cached: the grid is frozen, and save/restore walk these per shard
    @functools.cached_property
    def axis_sizes(self) -> Dict[str, int]:
        return dict(self.axes)

    @functools.cached_property
    def grid(self) -> Tuple[int, ...]:
        """Cuts per dimension (product of the spec'd axis sizes)."""
        sizes = self.axis_sizes
        out = []
        for names in self.spec:
            n = 1
            for a in names:
                n *= sizes[a]
            out.append(n)
        return tuple(out)

    @property
    def n_shards(self) -> int:
        n = 1
        for c in self.grid:
            n *= c
        return n

    def coords(self, j: int) -> Tuple[int, ...]:
        """Shard ``j`` (row-major over the grid) -> per-dim cell coords."""
        out = []
        for cuts in reversed(self.grid):
            out.append(j % cuts)
            j //= cuts
        return tuple(reversed(out))

    def index(self, j: int) -> Index:
        return tuple(_chunk(d, cuts, c) for d, cuts, c in
                     zip(self.shape, self.grid, self.coords(j)))

    def indices(self) -> List[Index]:
        return [self.index(j) for j in range(self.n_shards)]

    # -- manifest round-trip -------------------------------------------

    def to_manifest(self) -> Dict:
        return {"spec": [list(names) for names in self.spec],
                "axes": {a: n for a, n in self.axes}}

    @staticmethod
    def from_manifest(shape: Sequence[int], rec: Dict) -> "ShardGrid":
        return ShardGrid(
            tuple(int(d) for d in shape),
            tuple(tuple(names) for names in rec.get("spec", [])) or
            tuple(() for _ in shape),
            tuple(sorted((a, int(n)) for a, n in
                         rec.get("axes", {}).items())))


@dataclasses.dataclass(frozen=True)
class ReadOp:
    """Copy ``src_slice`` of source shard ``src`` into ``dst_slice`` of
    the target shard's local buffer (both slices are shard-local)."""

    src: int
    src_slice: Index
    dst_slice: Index

    def volume(self) -> int:
        n = 1
        for lo, hi in self.dst_slice:
            n *= hi - lo
        return n


def plan_target_shard(src_indices: Sequence[Index], dst_index: Index
                      ) -> List[ReadOp]:
    """Intersect every source cell with one target index.

    Returns ops in source order; for scalars (empty indices) every source
    cell overlaps, so callers pass a single-cell source grid."""
    ops = []
    for j, src_index in enumerate(src_indices):
        src_loc, dst_loc, empty = [], [], False
        for (slo, shi), (dlo, dhi) in zip(src_index, dst_index):
            lo, hi = max(slo, dlo), min(shi, dhi)
            if lo >= hi:
                empty = True
                break
            src_loc.append((lo - slo, hi - slo))
            dst_loc.append((lo - dlo, hi - dlo))
        if not empty:
            ops.append(ReadOp(j, tuple(src_loc), tuple(dst_loc)))
    return ops


def plan_reshard(src_indices: Sequence[Index], dst_grid: ShardGrid
                 ) -> List[List[ReadOp]]:
    """One read plan per target shard of ``dst_grid``."""
    return [plan_target_shard(src_indices, dst_grid.index(t))
            for t in range(dst_grid.n_shards)]


def shift_ops(ops: Sequence[ReadOp], dst_index: Index) -> List[ReadOp]:
    """Rebase cell-local ``dst_slice``s to global coordinates.

    ``plan_target_shard`` emits destinations relative to the target cell;
    when a restore assembles several cells into ONE host buffer (uneven —
    non-divisible — target grids, where no per-device placement exists),
    each cell's ops shift by the cell's lower corner. Empty ops shift to
    empty ops; short last cells shift like any other."""
    return [ReadOp(op.src, op.src_slice,
                   tuple((lo + base, hi + base)
                         for (lo, hi), (base, _) in
                         zip(op.dst_slice, dst_index)))
            for op in ops]


def op_bytes(op: ReadOp, itemsize: int) -> int:
    """Destination bytes one op materializes (== the sum of its file
    runs' byte lengths: every source element lands exactly once)."""
    return op.volume() * itemsize


def chunk_ops(ops: Sequence[ReadOp], itemsize: int, budget: int,
              max_ops: int = 0) -> List[List[ReadOp]]:
    """Greedy byte-budgeted chunking of a read plan — the unit of overlap
    for the pipelined restore engine. Consecutive ops pack into one chunk
    while their combined destination bytes stay within ``budget`` (and,
    if ``max_ops`` > 0, their count within it); an op bigger than the
    whole budget travels alone, so a chunk's in-flight raw bytes exceed
    the budget only when a SINGLE op already does. Order is preserved:
    concatenating the chunks yields ``ops``."""
    chunks: List[List[ReadOp]] = []
    cur: List[ReadOp] = []
    pend = 0
    for op in ops:
        n = op_bytes(op, itemsize)
        if cur and (pend + n > budget or (max_ops and len(cur) >= max_ops)):
            chunks.append(cur)
            cur, pend = [], 0
        cur.append(op)
        pend += n
    if cur:
        chunks.append(cur)
    return chunks


def plan_volume(ops: Sequence[ReadOp]) -> int:
    return sum(op.volume() for op in ops)


def index_volume(index: Index) -> int:
    n = 1
    for lo, hi in index:
        n *= hi - lo
    return n
