"""Logical-axis sharding rules (MaxText-style).

Model code annotates tensors with *logical* axis names; a ruleset maps each
logical axis to an ordered preference list of mesh axes. Resolution is
divisibility-aware and never assigns one mesh axis twice within a spec, so a
single model codebase supports many sharding strategies — the §Perf hillclimb
edits rulesets, not models.

Logical axes used across the codebase:

  batch        global batch                     -> data (+pod)
  seq          sequence (activations)           -> None (baseline) / model (SP)
  embed        d_model features                 -> None (baseline)
  heads        query heads                      -> model
  kv_heads     kv heads                         -> model (when divisible)
  head_dim     per-head features                -> None
  mlp          feed-forward hidden              -> model
  vocab        vocabulary                       -> model
  experts      MoE expert count                 -> model (expert parallelism)
  expert_mlp   per-expert hidden                -> None
  capacity     MoE per-expert capacity          -> None
  cache_seq    KV-cache sequence                -> model (decode baseline)\n  cache_batch  KV-cache batch                   -> data
  layers       stacked-scan leading axis        -> None (never sharded)
  fsdp         weight dim chosen for ZeRO shard -> data (+pod)
  conv_k       conv kernel taps                 -> None
  state        SSM state                        -> None
  img_seq      image/encoder token axis         -> None
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Candidates = Tuple[Tuple[str, ...], ...]  # ordered preference: each entry is a
# tuple of mesh axes to use *jointly* for the dim (e.g. ("pod","data")).


def _ruleset(d: Dict[str, Sequence[Sequence[str]]]) -> Dict[str, Candidates]:
    return {k: tuple(tuple(e) for e in v) for k, v in d.items()}


# Baseline: FSDP(data) x TP(model); pod = outer data parallelism.
BASELINE = _ruleset({
    "batch": [("pod", "data"), ("data",)],
    "seq": [],        # residual stream / remat storage (seqpar shards this)
    "seq_inner": [],  # inside attention/MLP blocks: always full-seq
    # (Megatron-SP: all-gather at block entry, reduce-scatter at exit, so
    # weight-gradient contractions stay local over the model axis)
    "embed": [],
    "heads": [("model",)],
    "kv_heads": [("model",)],
    "head_dim": [],
    "mlp": [("model",)],
    "vocab": [("model",)],
    "experts": [("model",)],
    "expert_mlp": [],
    "capacity": [],
    "cache_seq": [("model",)],
    "cache_heads": [("model",)],
    "cache_batch": [("pod", "data"), ("data",)],
    "layers": [],
    "fsdp": [("pod", "data"), ("data",)],
    "conv_k": [],
    "state": [],
    "img_seq": [],
})

# Sequence-parallel variant: activations' seq axis sharded over model between
# blocks (used by hillclimbed configs; attention/mlp re-gather internally).
SEQPAR = dict(BASELINE)
SEQPAR.update(_ruleset({"seq": [("model",)], "seq_inner": [("model",)]}))
# NB: a Megatron-SP variant (seq_inner full inside blocks) was tried and
# REFUTED on this workload: XLA re-gathers activations per projection,
# 5.7x worse collective traffic — see EXPERIMENTS §Perf iteration log.

# Decode-optimized: single-token activations are tiny, so they are
# REPLICATED over the data axis (weights stay 2D-sharded and matmuls
# partial-reduce small outputs instead of all-gathering 100MB+ weight
# slices every token); the KV cache stays batch-sharded over data and
# seq-sharded over model, combined via shard_map LSE flash-decoding.
DECODE_FLASH = dict(BASELINE)
DECODE_FLASH.update(_ruleset({
    "batch": [],  # replicate decode activations over batch...
    "embed": [("data",)],  # ...but shard the residual stream's features over
    # data, so 2D-sharded weights never need gathering: every matmul
    # partial-reduces a (B,1,dim) activation instead of a weight slice.
    "cache_seq": [("model",)],
    "kv_heads": [],
    "cache_heads": [],
}))

RULESETS: Dict[str, Dict[str, Candidates]] = {
    "baseline": BASELINE,
    "seqpar": SEQPAR,
    "decode_flash": DECODE_FLASH,
    "moe_a2a": BASELINE,  # same layout; the MoE layer switches to shard_map EP
}


def resolve_spec(
    logical: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Dict[str, Candidates],
) -> P:
    """Map logical axes -> PartitionSpec, first-fit with divisibility checks."""
    assert len(logical) == len(shape), (logical, shape)
    used: set = set()
    out = []
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for name, dim in zip(logical, shape):
        chosen = None
        if name is not None:
            for cand in rules.get(name, ()):  # each cand: tuple of mesh axes
                if any(a in used or a not in axis_sizes for a in cand):
                    continue
                total = 1
                for a in cand:
                    total *= axis_sizes[a]
                if total > 1 and dim % total == 0:
                    chosen = cand
                    used.update(cand)
                    break
        out.append(chosen if chosen is None else (chosen[0] if len(chosen) == 1 else chosen))
    # Trim trailing Nones for tidier specs.
    while out and out[-1] is None:
        out.pop()
    return P(*out)


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    """Threaded through model code; applies activation constraints.

    mesh=None means single-host testing: constraints become no-ops.
    """

    mesh: Optional[Mesh]
    rules: Dict[str, Candidates]

    @staticmethod
    def null() -> "ShardingCtx":
        return ShardingCtx(mesh=None, rules=BASELINE)

    @staticmethod
    def for_mesh(mesh: Optional[Mesh], ruleset: str = "baseline") -> "ShardingCtx":
        return ShardingCtx(mesh=mesh, rules=RULESETS[ruleset])

    def spec(self, logical: Sequence[Optional[str]], shape: Sequence[int]) -> P:
        assert self.mesh is not None
        return resolve_spec(logical, shape, self.mesh, self.rules)

    def sharding(self, logical, shape) -> NamedSharding:
        assert self.mesh is not None
        return NamedSharding(self.mesh, self.spec(logical, shape))

    def constrain(self, x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.sharding(logical, x.shape))
