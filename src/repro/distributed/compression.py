"""Gradient compression with error feedback.

Two compressors, both stateless-math + persistent residual ("error
feedback" — the quantization error re-enters the next step, preserving
convergence):

  * int8 symmetric quantization (4x vs f32 / 2x vs bf16 wire),
  * top-k magnitude sparsification (k-fraction of values + indices).

Integration points:
  * the cross-pod gradient exchange in pipeline mode (``ppermute`` moves
    int8 payloads natively),
  * the manual shard_map data-parallel step in examples/tests
    (``compressed_psum_int8``): quantize -> int8 all-to-all-free psum in
    int32 lanes pre-scaled to avoid overflow -> dequantize.

The pjit/GSPMD path keeps XLA-generated reduces (compression there requires
intercepting XLA collectives; documented limitation in DESIGN.md).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


# --- int8 error-feedback ----------------------------------------------------------


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return q.astype(dtype) * scale.astype(dtype)


def ef_compress_int8(x: jax.Array, residual: jax.Array):
    """Returns (q, scale, new_residual). x and residual same shape f32."""
    xc = x + residual
    q, scale = quantize_int8(xc)
    deq = dequantize_int8(q, scale)
    return q, scale, xc - deq


# --- top-k error-feedback ------------------------------------------------------------


def ef_compress_topk(x: jax.Array, residual: jax.Array, k_frac: float = 0.01):
    xc = (x + residual).ravel()
    k = max(1, int(xc.size * k_frac))
    vals, idx = jax.lax.top_k(jnp.abs(xc), k)
    picked = xc[idx]
    sparse = jnp.zeros_like(xc).at[idx].set(picked)
    new_residual = (xc - sparse).reshape(x.shape)
    return (picked, idx), new_residual


def decompress_topk(payload, shape) -> jax.Array:
    vals, idx = payload
    out = jnp.zeros(int(jnp.prod(jnp.array(shape))), vals.dtype)
    return out.at[idx].set(vals).reshape(shape)


# --- collective integration -------------------------------------------------------------


def compressed_psum_int8(x: jax.Array, axis_name: str) -> jax.Array:
    """Inside shard_map: int8-quantized mean-reduce over ``axis_name`` with a
    pre-agreed global scale, so the int8 payload itself crosses the wire
    (true 4x saving vs f32); scale = pmax(|x|)/127."""
    gmax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    scale = jnp.maximum(gmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    n = jax.lax.psum(1, axis_name)
    s = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return (s.astype(jnp.float32) * scale / n).astype(x.dtype)


# --- tree-level API ---------------------------------------------------------------------


def init_residuals(tree) -> Any:
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def compress_tree_int8(grads, residuals):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.flatten(residuals)[0]
    qs, scales, new_r = [], [], []
    for g, r in zip(flat_g, flat_r):
        q, s, nr = ef_compress_int8(g.astype(jnp.float32), r)
        qs.append(q)
        scales.append(s)
        new_r.append(nr)
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, scales),
            jax.tree.unflatten(treedef, new_r))


def decompress_tree_int8(qs, scales, dtype=jnp.float32):
    return jax.tree.map(lambda q, s: dequantize_int8(q, s, dtype), qs, scales)
