"""Pipeline parallelism over the ``pod`` axis (GPipe-style).

``pipeline_apply`` runs a layer stack split into S stages over the mesh
axis: each stage holds L/S layers; microbatches stream through via
``ppermute`` (activation hand-off to the next stage) with the standard
(S-1)-step fill/drain schedule. ``ppermute`` is differentiable, so
``jax.grad`` through the pipelined forward yields the correct pipelined
backward (reverse hand-offs) for free.

Gradient compression hooks in naturally here: the inter-stage activations
(and their cotangents) are the cross-pod traffic, and int8 error-feedback
payloads (repro.distributed.compression) can wrap the ppermute boundary.

Schedule cost model (for §Roofline): bubble fraction = (S-1)/(M+S-1) for M
microbatches; inter-pod wire per step = 2 x M x |activation| (fwd + bwd),
vs pure-DP's 2 x |params| gradient all-reduce — pipeline wins when
M x activations << params, i.e. exactly the 100B+ regime.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as PS


def pipeline_apply(stage_fn: Callable, params_stages, x_microbatches, mesh,
                   axis: str = "pod"):
    """Run a pipelined forward.

    stage_fn(stage_params, x) -> x            (applies one stage's layers)
    params_stages: pytree with leading dim S (stage-sharded over ``axis``)
    x_microbatches: (M, mb, ...) microbatch-major inputs, replicated over
        ``axis`` (each stage consumes them only at stage 0).

    Returns (M, mb, ...) outputs as produced by the LAST stage (replicated
    back via ppermute ring closure).
    """
    S = mesh.devices.shape[list(mesh.axis_names).index(axis)]
    M = x_microbatches.shape[0]
    n_ticks = M + S - 1

    def local(params_local, xs):
        # params_local: stage slice (1, ...) -> squeeze
        p = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]
        state = jnp.zeros(mb_shape, xs.dtype)  # in-flight activation
        outs = jnp.zeros((M,) + mb_shape, xs.dtype)

        def tick(carry, t):
            state, outs = carry
            # stage 0 ingests microbatch t (when in range)
            take = jnp.clip(t, 0, M - 1)
            injected = jnp.where((stage == 0) & (t < M),
                                 xs[take], state)
            y = stage_fn(p, injected)
            # last stage emits finished microbatch t-(S-1)
            done_idx = t - (S - 1)
            emit = (stage == S - 1) & (done_idx >= 0)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(done_idx, 0, M - 1), 0),
                lambda o: o, outs)
            # hand off to next stage (ring; last->first carries garbage,
            # overwritten by injection)
            perm = [(i, (i + 1) % S) for i in range(S)]
            state = jax.lax.ppermute(y, axis, perm)
            return (state, outs), None

        (state, outs), _ = jax.lax.scan(tick, (state, outs),
                                        jnp.arange(n_ticks))
        # replicate final outputs from the last stage to all stages so the
        # caller sees them everywhere (psum of one-hot contribution)
        contrib = jnp.where(stage == S - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(contrib, axis)

    in_param_specs = jax.tree.map(lambda _: PS(axis), params_stages)
    return shard_map(
        local, mesh=mesh,
        in_specs=(in_param_specs, PS()),
        out_specs=PS(),
        check_rep=False,
    )(params_stages, x_microbatches)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
