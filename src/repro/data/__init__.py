from repro.data.pipeline import (FsShardReader, Prefetcher, SyntheticLM,
                                 write_shards)

__all__ = ["FsShardReader", "Prefetcher", "SyntheticLM", "write_shards"]
