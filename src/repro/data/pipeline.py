"""Data pipeline: deterministic synthetic LM batches + FS-backed token
shards (read through the Bento file system — the storage stack is a live
substrate, not a demo), with background prefetch and straggler re-dispatch.
"""

from __future__ import annotations

import io
import queue
import threading
import time
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec


class SyntheticLM:
    """Deterministic tokens: batch for step N is a pure function of
    (seed, N) — resume after restart replays identically (tested)."""

    def __init__(self, cfg: ModelConfig, global_batch: int, seq_len: int,
                 seed: int = 0):
        self.cfg = cfg
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.seed = seed

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        toks = rng.integers(0, self.cfg.vocab_size,
                            (self.global_batch, self.seq_len + 1), dtype=np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.family == "vlm":
            out["image_embeds"] = rng.standard_normal(
                (self.global_batch, self.cfg.num_image_tokens, self.cfg.d_model)
            ).astype(np.float32) * 0.02
        if self.cfg.family == "audio":
            out["frame_embeds"] = rng.standard_normal(
                (self.global_batch, self.cfg.encoder_seq, self.cfg.d_model)
            ).astype(np.float32) * 0.02
        return out


# --- FS-backed shards ---------------------------------------------------------------


def write_shards(view, dataset: SyntheticLM, n_shards: int, root="/data") -> None:
    """Materialize token shards into a Bento fs (one file per shard)."""
    view.makedirs(root)
    for i in range(n_shards):
        b = dataset.batch(i)
        buf = io.BytesIO()
        np.savez(buf, **b)
        view.write_file(f"{root}/shard_{i:05d}.npz", buf.getvalue())
    view.fsync(f"{root}/shard_{n_shards-1:05d}.npz")


class FsShardReader:
    """Reads shards through the Bento FS; failed/slow reads are re-dispatched
    (straggler mitigation at the data tier)."""

    def __init__(self, view, root="/data", timeout_s: float = 5.0,
                 max_retries: int = 3):
        self.view = view
        self.root = root
        self.timeout_s = timeout_s
        self.max_retries = max_retries
        self.shards = sorted(view.listdir(root))
        self.retries = 0

    def read(self, idx: int) -> Dict[str, np.ndarray]:
        name = self.shards[idx % len(self.shards)]
        last_err: Optional[Exception] = None
        for _attempt in range(self.max_retries):
            try:
                raw = self._read_deadline(f"{self.root}/{name}")
                with np.load(io.BytesIO(raw)) as z:
                    return {k: z[k] for k in z.files}
            except Exception as e:  # noqa: BLE001 — retry path
                last_err = e
                self.retries += 1
        raise RuntimeError(f"shard {name} unreadable after retries: {last_err}")

    def _read_deadline(self, path: str) -> bytes:
        box: List = []

        def work():
            box.append(self.view.read_file(path))

        t = threading.Thread(target=work, daemon=True)
        t.start()
        t.join(self.timeout_s)
        if not box:
            raise TimeoutError(f"straggling read: {path}")
        return box[0]


class Prefetcher:
    """Background-thread prefetch queue over any step->batch function."""

    def __init__(self, fetch, start_step: int = 0, depth: int = 2):
        self.fetch = fetch
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        s = self.step
        while not self._stop:
            try:
                item = (s, self.fetch(s))
            except Exception as e:  # noqa: BLE001
                item = (s, e)
            self.q.put(item)
            s += 1

    def next(self):
        s, item = self.q.get()
        if isinstance(item, Exception):
            raise item
        return s, item

    def close(self):
        self._stop = True
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
