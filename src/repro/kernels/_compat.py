"""Pallas-TPU API compat: ONE feature-detect for the whole kernel pack.

jax has renamed the TPU compiler-params class across releases
(``pltpu.TPUCompilerParams`` on the 0.4.x line — the image pins 0.4.37 —
``pltpu.CompilerParams`` on newer lines). Every kernel imports the probe
from here instead of re-detecting locally, and the probe fails at IMPORT
time with an actionable message if the API moves again — a silent
``getattr(..., None)`` chain in four kernels is exactly how the last
rename slipped through. ``tests/test_kernels.py`` smoke-constructs the
detected class with the kwargs the kernels actually pass, so a field
rename breaks loudly there too.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams", None)

if CompilerParams is None:  # pragma: no cover - only on a future jax bump
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams — the pallas compiler-params API moved again; "
        "update repro/kernels/_compat.py (one probe, all kernels follow)")
