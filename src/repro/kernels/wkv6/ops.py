"""jit'd wrapper for the WKV6 kernel (TPU pallas / CPU interpret / jnp ref)."""

from __future__ import annotations

import jax

from repro.kernels.wkv6 import kernel as K
from repro.kernels.wkv6 import ref


def wkv6(r, k, v, w, u, state, *, chunk: int = 32, use_kernel=None,
         interpret=None):
    on_tpu = jax.default_backend() == "tpu"
    if use_kernel is None:
        use_kernel = on_tpu  # XLA ref path on CPU (dry-run), kernel on TPU
    if use_kernel:
        if interpret is None:
            interpret = not on_tpu
        return K.wkv6_chunked(r, k, v, w, u, state, chunk=chunk,
                              interpret=interpret)
    return ref.wkv6(r, k, v, w, u, state, chunk=chunk)


def wkv6_kernel(r, k, v, w, u, state, *, chunk: int = 32, interpret=True):
    return K.wkv6_chunked(r, k, v, w, u, state, chunk=chunk, interpret=interpret)
