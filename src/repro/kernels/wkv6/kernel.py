"""WKV6 chunked linear-attention Pallas kernel.

Grid (B, H, S/chunk) with the chunk axis sequential ("arbitrary") so the
per-(b,h) running state S in R^{K x V} lives in VMEM scratch across chunk
steps — the cross-chunk recurrence never touches HBM. Within a chunk the
exact per-channel decay tensor A (chunk, chunk, K) is materialized in VMEM
(chunk=32, K=64 -> 256 KiB f32), all exponents clipped <= 0 so the math is
overflow-safe (see models/rwkv.py for the derivation).

This is the TPU adaptation of the fla/CUDA chunked WKV kernels: instead of
warp-level shuffles per 16-token sub-tile, one VMEM-resident chunk per grid
step with VPU elementwise decay math and MXU matmuls for the (C,C) @ (C,V)
contraction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
            s_scr, *, chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    f32 = jnp.float32
    rr = r_ref[0, :, 0, :].astype(f32)  # (C,K)
    kk = k_ref[0, :, 0, :].astype(f32)
    vv = v_ref[0, :, 0, :].astype(f32)  # (C,V)
    ww = w_ref[0, :, 0, :].astype(f32)
    u = u_ref[0].astype(f32)  # (K,)

    logw = -jnp.exp(ww)
    Li = jnp.cumsum(logw, axis=0)  # (C,K) inclusive
    Le = Li - logw  # exclusive
    # A[t,s,k] = exp(Le[t]-Li[s]) for s < t
    A = jnp.exp(jnp.clip(Le[:, None, :] - Li[None, :, :], -60.0, 0.0))
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1) < \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    A = jnp.where(mask[:, :, None], A, 0.0)
    tmp = jnp.sum(rr[:, None, :] * A * kk[None, :, :], axis=-1)  # (C,C)
    y = jax.lax.dot_general(tmp, vv, (((1,), (0,)), ((), ())),
                            preferred_element_type=f32)
    # diagonal bonus
    y += jnp.sum(rr * u[None, :] * kk, axis=-1, keepdims=True) * vv
    # incoming state
    S_in = s_scr[...]
    y += jax.lax.dot_general(rr * jnp.exp(Le), S_in, (((1,), (0,)), ((), ())),
                             preferred_element_type=f32)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    # state update
    decay_all = jnp.exp(Li[-1])  # (K,)
    kd = kk * jnp.exp(Li[-1][None, :] - Li)  # (C,K)
    s_scr[...] = decay_all[:, None] * S_in + jax.lax.dot_general(
        kd, vv, (((0,), (0,)), ((), ())), preferred_element_type=f32)

    @pl.when(ic == n_chunks - 1)
    def _fin():
        sout_ref[0, 0] = s_scr[...]


def wkv6_chunked(r, k, v, w, u, state, *, chunk=32, interpret=False):
    """Shapes as in ref.wkv6. Returns (y f32, state_out f32)."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    assert S % chunk == 0
    n = S // chunk
    grid = (B, H, n)
    kern = functools.partial(_kernel, chunk=chunk, n_chunks=n)
    y, sout = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, K), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, chunk, 1, K), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, chunk, 1, V), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, chunk, 1, K), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, K), lambda b, h, ic: (h, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, V), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, V), jnp.float32),
            jax.ShapeDtypeStruct((B, H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u, state)
    return y, sout
