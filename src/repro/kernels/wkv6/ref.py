"""Pure-jnp oracle for the WKV6 chunked-scan kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6(r, k, v, w, u, state, *, chunk: int = 32):
    """r,k,w: (B,S,H,K); v: (B,S,H,V); u: (H,K); state: (B,H,K,V) f32.

    w is the pre-decay parameter: decay = exp(-exp(w)).
    Returns (y (B,S,H,V) f32, state_out (B,H,K,V) f32).
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    f32 = jnp.float32
    n = S // chunk
    assert S % chunk == 0

    def resh(x):
        return jnp.moveaxis(x.reshape(B, n, chunk, H, x.shape[-1]), 1, 0)

    rc, kc, vc, wc = map(resh, (r, k, v, w))
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), -1)

    def one(S_in, inp):
        rr, kk, vv, ww = [x.astype(f32) for x in inp]
        logw = -jnp.exp(ww)
        Li = jnp.cumsum(logw, axis=1)
        Le = Li - logw
        A = jnp.exp(jnp.clip(Le[:, :, None] - Li[:, None, :], -60.0, 0.0))
        A = jnp.where(mask[None, :, :, None, None], A, 0.0)
        tmp = jnp.einsum("bthk,btshk,bshk->btsh", rr, A, kk)
        y = jnp.einsum("btsh,bshv->bthv", tmp, vv)
        y += jnp.einsum("bthk,hk,bthk,bthv->bthv", rr, u.astype(f32), kk, vv)
        y += jnp.einsum("bthk,bthk,bhkv->bthv", rr, jnp.exp(Le), S_in)
        decay_all = jnp.exp(Li[:, -1])
        kd = kk * jnp.exp(Li[:, -1, None] - Li)
        S_out = decay_all[..., None] * S_in + jnp.einsum("bshk,bshv->bhkv", kd, vv)
        return S_out, y

    state, ys = jax.lax.scan(one, state.astype(f32), (rc, kc, vc, wc))
    return jnp.moveaxis(ys, 0, 1).reshape(B, S, H, V), state
