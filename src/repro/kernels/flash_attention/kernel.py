"""Flash attention forward Pallas kernel (TPU).

Tiling: grid (batch, q_head, Sq/block_q, Skv/block_kv), kv innermost with
"arbitrary" semantics so the (m, l, acc) VMEM scratch carries across kv
steps — the online-softmax recurrence. Block shapes are MXU-aligned
(block_q x D and block_kv x D tiles; D rides the 128-lane dim). Fully
masked causal/SWA blocks are skipped with ``pl.when`` (no MXU work issued),
so kernel FLOPs match the causal-optimal count — replacing the XLA
chunked-softmax path's ~2x causal waste on TPU.

GQA is handled by indexing the kv head as q_head // group_size.
VMEM footprint per step: q/k/v tiles (block_q + 2*block_kv) x D x 2B plus
f32 scratch (block_q x (D + 2)) — ~0.6 MiB at (256, 256, 128), far under
the ~16 MiB v5e VMEM budget, leaving room for double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                scale: float, causal: bool, window: int, softcap: float,
                block_q: int, block_kv: int, n_kv: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    kv_start = ik * block_kv

    live = True
    if causal:
        live = kv_start <= q_start + block_q - 1
    if window > 0:
        live = live & (kv_start + block_kv - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bkv, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kpos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = jnp.ones((block_q, block_kv), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > (qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_cur

    @pl.when(ik == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal=True, window=0, softcap=0.0,
                        block_q=256, block_kv=256, interpret=False):
    """q: (B,Sq,Hq,D); k,v: (B,Skv,Hkv,D). Returns (B,Sq,Hq,D)."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    assert Sq % block_q == 0 and Skv % block_kv == 0, (Sq, Skv, block_q, block_kv)
    n_q, n_kv = Sq // block_q, Skv // block_kv
    grid = (B, Hq, n_q, n_kv)
    scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_kv=block_kv, n_kv=n_kv)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, block_kv, 1, D),
                         lambda b, h, iq, ik, G=G: (b, ik, h // G, 0)),
            pl.BlockSpec((1, block_kv, 1, D),
                         lambda b, h, iq, ik, G=G: (b, ik, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
