"""Pure-jnp oracle for the flash attention kernel (GQA, causal, SWA,
softcap). Numerically the ground truth the kernel is tested against."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q: (B,Sq,Hq,D); k,v: (B,Skv,Hkv,D); Hq % Hkv == 0. Returns (B,Sq,Hq,D)."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(D))
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    m = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        m &= kpos <= qpos
    if window > 0:
        m &= kpos > (qpos - window)
    s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)
