"""jit'd wrapper for the flash attention kernel.

Dispatch: Pallas-compiled on TPU, Pallas-interpret for correctness tests on
CPU, pure-jnp reference for XLA lowerings (the dry-run path) — the same
one-API-two-bindings philosophy as the Bento services layer.

Backward pass: custom_vjp with recompute — the bwd rule re-runs the jnp
reference under jax.vjp (flash-style recompute; a dedicated bwd kernel is a
further optimization documented in EXPERIMENTS §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal=True, window=0, softcap=0.0,
                    interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    return K.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                 softcap=softcap, interpret=interpret)


def _fwd(q, k, v, causal, window, softcap, interpret):
    out = flash_attention(q, k, v, causal, window, softcap, interpret)
    return out, (q, k, v)


def _bwd(causal, window, softcap, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention(q_, k_, v_, causal=causal,
                                         window=window, softcap=softcap),
        q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
