"""Host-facing checksum API used by the kernel-services binding."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.blockhash import kernel as K
from repro.kernels.blockhash import ref


@functools.lru_cache(maxsize=8)
def _pows(n: int) -> np.ndarray:
    return ref.powers(n)


@functools.lru_cache(maxsize=8)
def _jitted(wpb: int, interpret: bool):
    pows = jnp.asarray(_pows(wpb))

    @jax.jit
    def f(words):
        return K.blockhash_batch(words, pows, interpret=interpret)

    return f


def checksum(data: bytes, *, interpret=None) -> int:
    """Checksum one block (journal commit-record entries)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    pad = (-len(data)) % 4
    arr = np.frombuffer(data + b"\0" * pad, dtype=np.uint32)[None, :]
    out = _jitted(arr.shape[1], interpret)(jnp.asarray(arr))
    return int(out[0])


def checksum_batch(blocks, *, interpret=None) -> list:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    arrs = []
    for data in blocks:
        pad = (-len(data)) % 4
        arrs.append(np.frombuffer(data + b"\0" * pad, dtype=np.uint32))
    words = np.stack(arrs)
    out = _jitted(words.shape[1], interpret)(jnp.asarray(words))
    return [int(x) for x in out]
