"""Pure-jnp oracle for the journal block-checksum kernel.

Polynomial hash over u32 words: h = sum_i word_i * P^(n-1-i)  (mod 2^32),
P = 0x01000193 (FNV prime). Chosen over CRC32C because CRC's bit-serial
table chaining is TPU-hostile, while a polynomial hash is a vectorizable
dot product (HW-adaptation note in DESIGN.md); collision/torn-write
detection strength is equivalent for journal-commit purposes.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

PRIME = np.uint32(0x01000193)


def powers(n: int) -> np.ndarray:
    """[P^(n-1), ..., P^1, P^0] mod 2^32."""
    out = np.empty(n, dtype=np.uint32)
    acc = np.uint32(1)
    for i in range(n - 1, -1, -1):
        out[i] = acc
        acc = np.uint32((int(acc) * int(PRIME)) & 0xFFFFFFFF)
    return out


def blockhash(words: jnp.ndarray, pows: jnp.ndarray) -> jnp.ndarray:
    """words, pows: (n,) uint32 -> scalar uint32."""
    return jnp.sum(words.astype(jnp.uint32) * pows.astype(jnp.uint32),
                   dtype=jnp.uint32)


def blockhash_np(data: bytes) -> int:
    pad = (-len(data)) % 4
    arr = np.frombuffer(data + b"\0" * pad, dtype=np.uint32)
    p = powers(len(arr))
    return int(np.sum(arr.astype(np.uint64) * p.astype(np.uint64)) & 0xFFFFFFFF)
