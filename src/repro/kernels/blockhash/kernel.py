"""Journal block-checksum Pallas kernel.

One grid step per 4 KiB block: the (1024,) u32 word vector and the
precomputed power vector sit in VMEM (8 KiB), the hash is a u32
multiply-accumulate on the VPU (integer mul wraps mod 2^32 natively).
Batched: hashes many blocks per call — the journal commit path checksums a
whole transaction in one kernel launch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels._compat import CompilerParams as _CompilerParams


def _kernel(words_ref, pows_ref, out_ref):
    w = words_ref[0, :]
    p = pows_ref[:]
    out_ref[0] = jnp.sum(w * p, dtype=jnp.uint32)


def blockhash_batch(words: jax.Array, pows: jax.Array, *, interpret=False):
    """words: (nblocks, wpb) u32; pows: (wpb,) u32 -> (nblocks,) u32."""
    n, wpb = words.shape
    return pl.pallas_call(
        _kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, wpb), lambda i: (i, 0)),
            pl.BlockSpec((wpb,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(words, pows)
