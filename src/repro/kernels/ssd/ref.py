"""Pure-jnp oracle for the Mamba2 SSD chunked-scan kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd(x, dt, B, C, A_log, D, state, *, chunk: int = 128):
    """x: (b,S,H,P); dt: (b,S,H); B,C: (b,S,N); state: (b,H,P,N) f32.

    Returns (y (b,S,H,P) f32, state_out f32).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    f32 = jnp.float32
    n = S // chunk
    assert S % chunk == 0
    A = -jnp.exp(A_log.astype(f32))

    def resh(z):
        return jnp.moveaxis(z.reshape(b, n, chunk, *z.shape[2:]), 1, 0)

    xc, dtc, Bc, Cc = map(resh, (x, dt, B, C))
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    def one(h_in, inp):
        xx, dd, BB, CC = (z.astype(f32) for z in inp)
        la = dd * A[None, None, :]
        Li = jnp.cumsum(la, axis=1)
        cb = jnp.einsum("btn,bsn->bts", CC, BB)
        G = jnp.exp(jnp.clip(Li[:, :, None, :] - Li[:, None, :, :], -60.0, 0.0))
        M = cb[..., None] * G * dd[:, None, :, :]
        M = jnp.where(mask[None, :, :, None], M, 0.0)
        y = jnp.einsum("btsh,bshp->bthp", M, xx)
        y += jnp.einsum("btn,bhpn,bth->bthp", CC, h_in, jnp.exp(Li))
        decay_all = jnp.exp(Li[:, -1])
        wgt = jnp.exp(Li[:, -1, None] - Li) * dd
        h_out = decay_all[:, :, None, None] * h_in + jnp.einsum(
            "bth,bthp,btn->bhpn", wgt, xx, BB)
        return h_out, y

    state, ys = jax.lax.scan(one, state.astype(f32), (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, S, H, P)
    y = y + x.astype(f32) * D.astype(f32)[None, None, :, None]
    return y, state
