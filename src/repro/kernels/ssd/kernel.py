"""Mamba2 SSD (state-space duality) chunked Pallas kernel.

Grid (b, H, S/chunk), chunk axis sequential with the (P, N) state in VMEM
scratch. Per chunk the decay matrix M[t,s] = (C_t.B_s) exp(Li[t]-Li[s]) dt_s
(s<=t) is a plain (chunk x chunk) MXU operand per head — the SSD insight
that the scan can be expressed as matmuls maps directly onto the MXU, with
the cross-chunk recurrence carried in registers/VMEM rather than CUDA's
shared-memory warp accumulators (HW adaptation noted in DESIGN.md).

VMEM per step @ chunk=128, P=64, N=64: x/B/C tiles + M (128x128 f32) +
state (64x64 f32) ~= 0.4 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _kernel(x_ref, dt_ref, b_ref, c_ref, alog_ref, d_ref, s0_ref,
            y_ref, sout_ref, s_scr, *, chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    f32 = jnp.float32
    xx = x_ref[0, :, 0, :].astype(f32)  # (C,P)
    dd = dt_ref[0, :, 0].astype(f32)  # (C,)
    BB = b_ref[0].astype(f32)  # (C,N)
    CC = c_ref[0].astype(f32)  # (C,N)
    A = -jnp.exp(alog_ref[0].astype(f32))  # scalar
    Dv = d_ref[0].astype(f32)

    la = dd * A  # (C,)
    Li = jnp.cumsum(la)
    cb = jax.lax.dot_general(CC, BB, (((1,), (1,)), ((), ())),
                             preferred_element_type=f32)  # (C,C)
    G = jnp.exp(jnp.clip(Li[:, None] - Li[None, :], -60.0, 0.0))
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1) <= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    M = jnp.where(mask, cb * G * dd[None, :], 0.0)
    y = jax.lax.dot_general(M, xx, (((1,), (0,)), ((), ())),
                            preferred_element_type=f32)  # (C,P)
    # incoming state: y += exp(Li)[:,None] * (CC @ state^T)
    h_in = s_scr[...]  # (P,N)
    y += jnp.exp(Li)[:, None] * jax.lax.dot_general(
        CC, h_in, (((1,), (1,)), ((), ())), preferred_element_type=f32)
    y += xx * Dv
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    # state update: h_out = exp(Li[-1]) h_in + (w*x)^T @ B
    wgt = jnp.exp(Li[-1] - Li) * dd  # (C,)
    upd = jax.lax.dot_general(wgt[:, None] * xx, BB, (((0,), (0,)), ((), ())),
                              preferred_element_type=f32)  # (P,N)
    s_scr[...] = jnp.exp(Li[-1]) * h_in + upd

    @pl.when(ic == n_chunks - 1)
    def _fin():
        sout_ref[0, 0] = s_scr[...]


def ssd_chunked(x, dt, B, C, A_log, D, state, *, chunk=128, interpret=False):
    """Shapes as in ref.ssd. Returns (y f32, state_out f32)."""
    b, S, H, P = x.shape
    N = B.shape[-1]
    assert S % chunk == 0
    n = S // chunk
    grid = (b, H, n)
    kern = functools.partial(_kernel, chunk=chunk, n_chunks=n)
    y, sout = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bb, h, ic: (bb, ic, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bb, h, ic: (bb, ic, h)),
            pl.BlockSpec((1, chunk, N), lambda bb, h, ic: (bb, ic, 0)),
            pl.BlockSpec((1, chunk, N), lambda bb, h, ic: (bb, ic, 0)),
            pl.BlockSpec((1,), lambda bb, h, ic: (h,)),
            pl.BlockSpec((1,), lambda bb, h, ic: (h,)),
            pl.BlockSpec((1, 1, P, N), lambda bb, h, ic: (bb, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bb, h, ic: (bb, ic, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bb, h, ic: (bb, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, S, H, P), jnp.float32),
            jax.ShapeDtypeStruct((b, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, B, C, A_log, D, state)
    return y, sout
