"""jit'd wrapper for the SSD kernel (TPU pallas / CPU interpret / jnp ref)."""

from __future__ import annotations

import jax

from repro.kernels.ssd import kernel as K
from repro.kernels.ssd import ref


def ssd(x, dt, B, C, A_log, D, state, *, chunk: int = 128, use_kernel=None,
        interpret=None):
    on_tpu = jax.default_backend() == "tpu"
    if use_kernel is None:
        use_kernel = on_tpu
    if use_kernel:
        if interpret is None:
            interpret = not on_tpu
        return K.ssd_chunked(x, dt, B, C, A_log, D, state, chunk=chunk,
                             interpret=interpret)
    return ref.ssd(x, dt, B, C, A_log, D, state, chunk=chunk)


def ssd_kernel(x, dt, B, C, A_log, D, state, *, chunk: int = 128,
               interpret=True):
    return K.ssd_chunked(x, dt, B, C, A_log, D, state, chunk=chunk,
                         interpret=interpret)
