"""Online upgrade (paper §4.8) — the high-velocity feature.

Protocol:
  1. ``freeze`` the mount's op gate and drain in-flight operations
     (no ownership can be stranded: the boundary never transferred it),
  2. ``extract_state()`` from the old module (schema-checked),
  3. optional ``migrate`` hook maps old-version state to the new version,
  4. instantiate + ``init`` the new module, ``restore_state``,
  5. atomically swap the function table, ``thaw``.

Applications see only a pause (measured in benchmarks/fs_upgrade.py).
The same quiesce→extract→restore protocol implements checkpoint/restart and
elastic rescale for trainer modules (repro.train.trainer).
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

from repro.core.interface import BentoFilesystem, BentoModule
from repro.core.registry import Mount, _FS_OPS


class UpgradeError(Exception):
    pass


def _extracted_state(old: BentoModule, new: BentoModule,
                     migrate: Optional[Callable],
                     strict_schema: bool) -> Dict[str, Any]:
    """Extract + migrate + schema-check: the ONE state-transfer front door.

    Both the mount upgrade path and generic module transfer (trainer
    substrates) go through here, so a new version whose schema expects keys
    the old version never emitted fails loudly in either path instead of
    silently restoring partial state."""
    state = old.extract_state()
    if migrate is not None:
        state = migrate(state, old.VERSION, new.VERSION)
    if strict_schema:
        missing = set(new.state_schema()) - set(state)
        if missing:
            raise UpgradeError(
                f"state transfer incomplete: {sorted(missing)} missing "
                f"(old v{old.VERSION} -> new v{new.VERSION})")
    return state


def upgrade(mount: Mount, new_module: BentoFilesystem,
            migrate: Optional[Callable[[Dict, int, int], Dict]] = None,
            strict_schema: bool = True) -> Dict[str, float]:
    """Swap the mounted module for ``new_module`` without unmounting.

    Returns timing stats {quiesce_s, transfer_s, total_s}.
    """
    old = mount.module
    t0 = time.perf_counter()
    mount.gate.freeze()
    t_quiesce = time.perf_counter() - t0
    try:
        state = _extracted_state(old, new_module, migrate, strict_schema)
        t1 = time.perf_counter()
        sb = mount.services.superblock()
        new_module.init(sb, mount.services)
        new_module.restore_state(state, old.VERSION)
        # Atomic table swap: dispatch uses the table, never the module object.
        mount.module = new_module
        mount.table = {op: getattr(new_module, op) for op in _FS_OPS}
        mount.generation += 1
        old.destroy()
        t_transfer = time.perf_counter() - t1
    finally:
        mount.gate.thaw()
    return {"quiesce_s": t_quiesce, "transfer_s": t_transfer,
            "total_s": time.perf_counter() - t0}


# --- generic module upgrade (trainer substrates) --------------------------------------


def transfer_state(old: BentoModule, new: BentoModule,
                   migrate: Optional[Callable] = None,
                   strict_schema: bool = True) -> None:
    """Quiesce-free state transfer between module instances (trainer
    substrates, checkpoint/restart). Applies the same strict_schema check
    as the mount upgrade path: a trainer upgrade can no more silently drop
    state keys than a file-system upgrade can."""
    state = _extracted_state(old, new, migrate, strict_schema)
    new.restore_state(state, old.VERSION)
