"""Online upgrade (paper §4.8) — the high-velocity feature.

Protocol:
  1. ``freeze`` the mount's op gate and drain in-flight operations
     (no ownership can be stranded: the boundary never transferred it),
  2. ``extract_state()`` from the old module (schema-checked),
  3. optional ``migrate`` hook maps old-version state to the new version,
  4. instantiate + ``init`` the new module, ``restore_state``,
  5. atomically swap the function table, ``thaw``.

Applications see only a pause (measured in benchmarks/fs_upgrade.py).
The same quiesce→extract→restore protocol implements checkpoint/restart and
elastic rescale for trainer modules (repro.train.trainer).
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

from repro.core.interface import BentoFilesystem, BentoModule
from repro.core.registry import Mount, _FS_OPS


class UpgradeError(Exception):
    pass


def _extracted_state(old: BentoModule, new: BentoModule,
                     migrate: Optional[Callable],
                     strict_schema: bool) -> Dict[str, Any]:
    """Extract + migrate + schema-check: the ONE state-transfer front door.

    Both the mount upgrade path and generic module transfer (trainer
    substrates) go through here, so a new version whose schema expects keys
    the old version never emitted fails loudly in either path instead of
    silently restoring partial state."""
    state = old.extract_state()
    if migrate is not None:
        state = migrate(state, old.VERSION, new.VERSION)
    if strict_schema:
        # layer-aware: keys the new module can synthesize itself (a
        # stackable layer's private state, bootstrapped on a plain->layered
        # upgrade) are not required of the OLD module's extract
        optional = set(getattr(new, "optional_state_keys", lambda: ())())
        missing = set(new.state_schema()) - set(state) - optional
        if missing:
            raise UpgradeError(
                f"state transfer incomplete: {sorted(missing)} missing "
                f"(old v{old.VERSION} -> new v{new.VERSION})")
    return state


def upgrade(mount: Mount, new_module: BentoFilesystem,
            migrate: Optional[Callable[[Dict, int, int], Dict]] = None,
            strict_schema: bool = True) -> Dict[str, float]:
    """Swap the mounted module for ``new_module`` without unmounting.

    Returns timing stats {quiesce_s, transfer_s, total_s}.
    """
    old = mount.module
    t0 = time.perf_counter()
    mount.gate.freeze()
    t_quiesce = time.perf_counter() - t0
    try:
        state = _extracted_state(old, new_module, migrate, strict_schema)
        t1 = time.perf_counter()
        sb = mount.services.superblock()
        new_module.init(sb, mount.services)
        new_module.restore_state(state, old.VERSION)
        # Atomic table swap: dispatch uses the table, never the module object.
        mount.module = new_module
        mount.table = {op: getattr(new_module, op) for op in _FS_OPS}
        mount.generation += 1
        old.destroy()
        t_transfer = time.perf_counter() - t1
    finally:
        mount.gate.thaw()
    return {"quiesce_s": t_quiesce, "transfer_s": t_transfer,
            "total_s": time.perf_counter() - t0}


# --- stackable layers: wrap/unwrap a live mount (the paper's §6 demo) -----------------
#
# The provenance demo is "add a feature to a RUNNING file system": wrap the
# mounted module in a stackable layer (repro.fs.prov) with no remount, then
# strip it again. Both directions are ordinary upgrades — the layer's
# restore_state forwards the inner module's keys to a fresh inner instance
# (open handles stay valid: inos are device state; the dentry cache lives in
# PosixView above the swap; the journal position rides the "journal" state
# key) and bootstraps its own private state, declared optional via
# ``optional_state_keys`` so the plain module's extract passes the schema
# check.


def _fresh_like(module: BentoModule) -> BentoModule:
    """A fresh instance of ``module``'s class, preserving its policy options
    (the fs classes take them as the sole constructor arg)."""
    cls = type(module)
    opts = getattr(module, "opts", None)
    if opts is not None:
        try:
            return cls(opts)
        except TypeError:
            pass
    return cls()


def wrap_layer(mount: Mount, make_layer: Callable[[BentoFilesystem],
                                                  BentoFilesystem],
               migrate: Optional[Callable] = None) -> Dict[str, float]:
    """Hot-swap the mounted module for ``make_layer(fresh_inner)`` — e.g.
    ``wrap_layer(mount, ProvFilesystem)`` adds provenance tracking to a
    live mount. Returns the upgrade timing stats (the measured pause).
    One layer deep only: wrapping an already-layered mount is refused
    (``_fresh_like`` would rebuild the layer around its options object,
    not its module — unwrap first)."""
    if getattr(mount.module, "inner", None) is not None:
        raise UpgradeError(
            f"mount already carries a stackable layer "
            f"({type(mount.module).__name__}) — unwrap it first")
    return upgrade(mount, make_layer(_fresh_like(mount.module)),
                   migrate=migrate)


def unwrap_layer(mount: Mount,
                 migrate: Optional[Callable] = None) -> Dict[str, float]:
    """The reverse demo: strip the mounted stackable layer, downgrading to
    a fresh instance of its inner module's class. The layer's private state
    keys ride along in the extracted dict and are simply ignored by the
    plain module's restore; its on-device artifacts (the provenance log)
    stay durable for the next wrap."""
    layer = mount.module
    if getattr(layer, "inner", None) is None:
        raise UpgradeError(
            f"mounted module {type(layer).__name__} is not a stackable "
            "layer — nothing to unwrap")
    return upgrade(mount, _fresh_like(layer.inner), migrate=migrate)


# --- generic module upgrade (trainer substrates) --------------------------------------


def transfer_state(old: BentoModule, new: BentoModule,
                   migrate: Optional[Callable] = None,
                   strict_schema: bool = True) -> None:
    """Quiesce-free state transfer between module instances (trainer
    substrates, checkpoint/restart). Applies the same strict_schema check
    as the mount upgrade path: a trainer upgrade can no more silently drop
    state keys than a file-system upgrade can."""
    state = _extracted_state(old, new, migrate, strict_schema)
    new.restore_state(state, old.VERSION)
