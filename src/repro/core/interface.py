"""The Bento module boundary (paper §4.3).

Two interfaces cross the boundary:

* ``BentoModule`` — the generic versioned-extension contract every substrate
  implements (file systems, model modules, optimizers, data pipelines):
  ``extract_state`` / ``restore_state`` make online upgrade, checkpoint,
  elastic rescale and failure recovery one protocol (§4.8).

* ``BentoFilesystem`` — the file-operations API, a port of the FUSE
  low-level API augmented with the SuperBlock capability (§4.3): inode-
  granular operations, plain values in/out, no kernel structures exposed.
  Ownership of arguments never transfers: ``bytes`` in/out are immutable
  (a shared borrow), capabilities are held, never owned.
"""

from __future__ import annotations

import abc
import dataclasses
import enum
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.core.capability import SuperBlockCap


class Errno(enum.IntEnum):
    ENOENT = 2
    EIO = 5
    EEXIST = 17
    ENOTDIR = 20
    EISDIR = 21
    EINVAL = 22
    EFBIG = 27
    ENOSPC = 28
    ENOTEMPTY = 39
    ESTALE = 116


class FsError(Exception):
    def __init__(self, errno: Errno, msg: str = ""):
        super().__init__(f"{errno.name}: {msg}")
        self.errno = errno


class FileKind(enum.IntEnum):
    FILE = 1
    DIR = 2


@dataclasses.dataclass
class Attr:
    """Plain-value attribute record — no shared kernel structures (§4.3)."""

    ino: int
    kind: FileKind
    size: int
    nlink: int
    mtime: float = 0.0

    @property
    def is_dir(self) -> bool:
        return self.kind == FileKind.DIR


ROOT_INO = 1


class BentoModule(abc.ABC):
    """Versioned extension: the §4.8 state-transfer contract."""

    NAME: str = "module"
    VERSION: int = 1

    def extract_state(self) -> Dict[str, Any]:
        """Serialize transferable in-memory state before an upgrade.

        Called only after the runtime has quiesced the module (no in-flight
        operations, no outstanding mutable borrows)."""
        return {}

    def restore_state(self, state: Dict[str, Any], from_version: int) -> None:
        """Install state extracted from ``from_version`` of this module."""
        del state, from_version

    def state_schema(self) -> Tuple[str, ...]:
        """Keys this version emits/accepts — checked at upgrade time."""
        return ()


class BentoFilesystem(BentoModule):
    """File-operations API (FUSE low-level port + SuperBlock capability)."""

    # --- lifecycle -------------------------------------------------------------
    @abc.abstractmethod
    def init(self, sb: SuperBlockCap, services: "KernelServices") -> None:
        """Mount-time: the runtime lends the superblock capability and the
        kernel-services API. The fs must not stash raw kernel objects."""

    def destroy(self) -> None:
        pass

    # --- inode ops ---------------------------------------------------------------
    @abc.abstractmethod
    def getattr(self, ino: int) -> Attr: ...

    @abc.abstractmethod
    def lookup(self, parent: int, name: str) -> Attr: ...

    @abc.abstractmethod
    def create(self, parent: int, name: str) -> Attr: ...

    @abc.abstractmethod
    def mkdir(self, parent: int, name: str) -> Attr: ...

    @abc.abstractmethod
    def unlink(self, parent: int, name: str) -> None: ...

    @abc.abstractmethod
    def rmdir(self, parent: int, name: str) -> None: ...

    @abc.abstractmethod
    def rename(self, parent: int, name: str, newparent: int, newname: str) -> None: ...

    @abc.abstractmethod
    def readdir(self, ino: int) -> List[Tuple[str, int, FileKind]]: ...

    # --- data ops -------------------------------------------------------------------
    @abc.abstractmethod
    def read(self, ino: int, off: int, size: int) -> bytes: ...

    @abc.abstractmethod
    def write(self, ino: int, off: int, data: bytes) -> int: ...

    @abc.abstractmethod
    def truncate(self, ino: int, size: int) -> None: ...

    @abc.abstractmethod
    def fsync(self, ino: int) -> None: ...

    def flush(self) -> None:
        """Write back everything (unmount / upgrade barrier)."""

    @abc.abstractmethod
    def statfs(self) -> Dict[str, int]: ...


# Filled in by repro.core.services at import time (cycle-free forward ref).
KernelServices = Any
