"""The Bento module boundary (paper §4.3), batched.

Two interfaces cross the boundary:

* ``BentoModule`` — the generic versioned-extension contract every substrate
  implements (file systems, model modules, optimizers, data pipelines):
  ``extract_state`` / ``restore_state`` make online upgrade, checkpoint,
  elastic rescale and failure recovery one protocol (§4.8).

* ``BentoFilesystem`` — the file-operations API, a port of the FUSE
  low-level API augmented with the SuperBlock capability (§4.3): inode-
  granular operations, plain values in/out, no kernel structures exposed.
  Ownership of arguments never transfers: ``bytes`` in/out are immutable
  (a shared borrow), capabilities are held, never owned.

The native shape of the boundary is a *batch*, io_uring style. Callers
build a list of ``SubmissionEntry(op, args, user_data)`` records and hand
them across the boundary once; they get back one ``CompletionEntry`` per
submission, in submission order. Two rules make the batch a faithful
extension of the paper's single-op design rather than a new protocol:

* plain values only — entries and completions carry ints/bytes/strs, the
  same no-kernel-structures rule as scalar ops (§4.3);
* errors never cross as exceptions — a failing entry (fs error or
  malformed entry) completes with an ``errno`` and does not poison its
  neighbours, exactly like a CQE's ``res`` field. ``FsError`` still
  exists for the scalar convenience methods. Only genuine implementation
  exceptions (bugs) propagate, as they do through scalar dispatch.

``BentoFilesystem.submit_batch`` is the override hook: the default loops
scalar ops with per-entry errno capture, so every module is batchable;
modules that can do better (vectorized reads that hit the buffer cache
once, one journal transaction per batch, one Pallas checksum launch per
commit) override it — see ``repro.fs.xv6``.
"""

from __future__ import annotations

import abc
import dataclasses
import enum
import inspect
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.core.capability import SuperBlockCap


class Errno(enum.IntEnum):
    ENOENT = 2
    EIO = 5
    EEXIST = 17
    ENOTDIR = 20
    EISDIR = 21
    EINVAL = 22
    EFBIG = 27
    ENOSPC = 28
    ENOTEMPTY = 39
    ESTALE = 116


class FsError(Exception):
    def __init__(self, errno: Errno, msg: str = ""):
        super().__init__(f"{errno.name}: {msg}")
        self.errno = errno


class FileKind(enum.IntEnum):
    FILE = 1
    DIR = 2


@dataclasses.dataclass
class Attr:
    """Plain-value attribute record — no shared kernel structures (§4.3)."""

    ino: int
    kind: FileKind
    size: int
    nlink: int
    mtime: float = 0.0

    @property
    def is_dir(self) -> bool:
        return self.kind == FileKind.DIR


ROOT_INO = 1


# --- batched boundary records (io_uring-shaped, §4.3 plain values) ---------------

# Ops that may appear in a submission batch. ``init``/``destroy`` are
# lifecycle-only and ``submit_batch`` itself may not nest.
BATCHABLE_OPS = frozenset({
    "getattr", "lookup", "create", "mkdir", "unlink", "rmdir", "rename",
    "readdir", "read", "write", "truncate", "fsync", "flush", "statfs",
})


@dataclasses.dataclass(slots=True)
class SubmissionEntry:
    """One SQE: which op, its plain-value args, and an opaque cookie the
    caller uses to match the completion (never interpreted by the fs).

    Treat as immutable once submitted (not ``frozen=True`` only because a
    frozen __init__ costs ~3x on the hot path — batches are built in
    bulk)."""

    op: str
    args: Tuple[Any, ...] = ()
    kwargs: Optional[Dict[str, Any]] = None  # None == {} (skips an alloc)
    user_data: Any = None


@dataclasses.dataclass(slots=True)
class CompletionEntry:
    """One CQE: the submission's cookie plus result XOR errno."""

    user_data: Any
    result: Any = None
    errno: Optional[Errno] = None

    @property
    def ok(self) -> bool:
        return self.errno is None

    def unwrap(self):
        """Scalar-shim helper: re-raise the errno the way the scalar API
        would have (the only place batch errors become exceptions again)."""
        if self.errno is not None:
            raise FsError(self.errno, f"batched {self.user_data!r}")
        return self.result


class BentoModule(abc.ABC):
    """Versioned extension: the §4.8 state-transfer contract."""

    NAME: str = "module"
    VERSION: int = 1

    def extract_state(self) -> Dict[str, Any]:
        """Serialize transferable in-memory state before an upgrade.

        Called only after the runtime has quiesced the module (no in-flight
        operations, no outstanding mutable borrows)."""
        return {}

    def restore_state(self, state: Dict[str, Any], from_version: int) -> None:
        """Install state extracted from ``from_version`` of this module."""
        del state, from_version

    def state_schema(self) -> Tuple[str, ...]:
        """Keys this version emits/accepts — checked at upgrade time."""
        return ()


class BentoFilesystem(BentoModule):
    """File-operations API (FUSE low-level port + SuperBlock capability)."""

    # --- lifecycle -------------------------------------------------------------
    @abc.abstractmethod
    def init(self, sb: SuperBlockCap, services: "KernelServices") -> None:
        """Mount-time: the runtime lends the superblock capability and the
        kernel-services API. The fs must not stash raw kernel objects."""

    def destroy(self) -> None:
        pass

    # --- inode ops ---------------------------------------------------------------
    @abc.abstractmethod
    def getattr(self, ino: int) -> Attr: ...

    @abc.abstractmethod
    def lookup(self, parent: int, name: str) -> Attr: ...

    @abc.abstractmethod
    def create(self, parent: int, name: str) -> Attr: ...

    @abc.abstractmethod
    def mkdir(self, parent: int, name: str) -> Attr: ...

    @abc.abstractmethod
    def unlink(self, parent: int, name: str) -> None: ...

    @abc.abstractmethod
    def rmdir(self, parent: int, name: str) -> None: ...

    @abc.abstractmethod
    def rename(self, parent: int, name: str, newparent: int, newname: str) -> None: ...

    @abc.abstractmethod
    def readdir(self, ino: int) -> List[Tuple[str, int, FileKind]]: ...

    # --- data ops -------------------------------------------------------------------
    @abc.abstractmethod
    def read(self, ino: int, off: int, size: int) -> bytes: ...

    @abc.abstractmethod
    def write(self, ino: int, off: int, data: bytes) -> int: ...

    @abc.abstractmethod
    def truncate(self, ino: int, size: int) -> None: ...

    @abc.abstractmethod
    def fsync(self, ino: int) -> None: ...

    def flush(self) -> None:
        """Write back everything (unmount / upgrade barrier)."""

    @abc.abstractmethod
    def statfs(self) -> Dict[str, int]: ...

    # --- batched boundary ------------------------------------------------------
    _SIG_CACHE: Dict[Tuple[type, str], inspect.Signature] = {}

    # basic value shapes checked pre-call for the data ops, so a malformed
    # entry completes EINVAL while a TypeError from inside a correctly-
    # called op (an implementation bug) propagates loudly, like scalar
    # dispatch
    _VALUE_CHECKS = {
        "write": lambda ba: (isinstance(ba.arguments.get("data"),
                                        (bytes, bytearray))
                             and isinstance(ba.arguments.get("off"), int)),
        "read": lambda ba: (isinstance(ba.arguments.get("off"), int)
                            and isinstance(ba.arguments.get("size"), int)),
    }

    def _entry_fits(self, op: str, args, kwargs) -> bool:
        """Does (args, kwargs) form a well-shaped call of ``op``? Checked
        BEFORE dispatch: arity/keywords via the cached signature, plus the
        per-op basic value shapes above."""
        key = (type(self), op)
        sig = self._SIG_CACHE.get(key)
        if sig is None:
            sig = self._SIG_CACHE[key] = inspect.signature(getattr(self, op))
        try:
            ba = sig.bind(*args, **(kwargs or {}))
        except TypeError:
            return False
        check = self._VALUE_CHECKS.get(op)
        return check is None or check(ba)

    def _dispatch_one(self, entry: SubmissionEntry) -> CompletionEntry:
        """Run one entry with per-entry errno capture: malformed entries
        and FsErrors become errnos; implementation exceptions propagate."""
        if (entry.op not in BATCHABLE_OPS
                or not self._entry_fits(entry.op, entry.args, entry.kwargs)):
            return CompletionEntry(entry.user_data, errno=Errno.EINVAL)
        try:
            fn = getattr(self, entry.op)
            return CompletionEntry(entry.user_data,
                                   result=fn(*entry.args,
                                             **(entry.kwargs or {})))
        except FsError as e:
            return CompletionEntry(entry.user_data, errno=e.errno)

    def submit_batch(self, entries: Iterable[SubmissionEntry]
                     ) -> List[CompletionEntry]:
        """Process a submission batch; completions in submission order.

        Default: scalar dispatch with per-entry errno isolation, so every
        module speaks the batched boundary. Override for vectorized fast
        paths (amortize locks, cache passes, journal commits, checksum
        launches across the batch) — completion order must be preserved.
        """
        return [self._dispatch_one(e) for e in entries]


# Filled in by repro.core.services at import time (cycle-free forward ref).
KernelServices = Any
