"""The Bento module boundary (paper §4.3), batched.

Two interfaces cross the boundary:

* ``BentoModule`` — the generic versioned-extension contract every substrate
  implements (file systems, model modules, optimizers, data pipelines):
  ``extract_state`` / ``restore_state`` make online upgrade, checkpoint,
  elastic rescale and failure recovery one protocol (§4.8).

* ``BentoFilesystem`` — the file-operations API, a port of the FUSE
  low-level API augmented with the SuperBlock capability (§4.3): inode-
  granular operations, plain values in/out, no kernel structures exposed.
  Ownership of arguments never transfers: ``bytes`` in/out are immutable
  (a shared borrow), capabilities are held, never owned.

The native shape of the boundary is a *batch*, io_uring style. Callers
build a list of ``SubmissionEntry(op, args, user_data)`` records and hand
them across the boundary once; they get back one ``CompletionEntry`` per
submission, in submission order. Two rules make the batch a faithful
extension of the paper's single-op design rather than a new protocol:

* plain values only — entries and completions carry ints/bytes/strs, the
  same no-kernel-structures rule as scalar ops (§4.3);
* errors never cross as exceptions — a failing entry (fs error or
  malformed entry) completes with an ``errno`` and does not poison its
  neighbours, exactly like a CQE's ``res`` field. ``FsError`` still
  exists for the scalar convenience methods. Only genuine implementation
  exceptions (bugs) propagate, as they do through scalar dispatch.

``BentoFilesystem.submit_batch`` is the override hook: the default loops
scalar ops with per-entry errno capture, so every module is batchable;
modules that can do better (vectorized reads that hit the buffer cache
once, one journal transaction per batch, one Pallas checksum launch per
commit) override it — see ``repro.fs.xv6``.

Entries may also be *chained*, io_uring ``IOSQE_LINK`` style: an entry
whose ``flags`` carry ``SQE_LINK`` links the NEXT entry into its chain, so
entry N+1 runs only if entry N completed without an errno. The first
failure in a chain cancels every remaining member, which complete with
``Errno.ECANCELED`` (never silently dropped — one completion per
submission always holds). Chain semantics live ABOVE ``submit_batch``, in
``execute_batch``: dispatch layers (``Mount.submit``, the VFS-direct
baseline, the FUSE daemon) route batches through it, modules never see the
flags. A chained entry may use ``PrevResult`` placeholders in its args to
consume an earlier chain member's result (e.g. the ino of a just-created
file: create → write(PrevResult("ino"), ...) → fsync), the io_uring
fixed-file trick generalized to plain values. Because a whole submission
executes under ONE gate crossing (see ``repro.core.registry``), an online
upgrade's table swap can never land between two members of a chain: chains
are atomic with respect to module generations, like batches (§4.8).

Chains are also atomic with respect to CRASHES: ``execute_batch`` wraps
every chain group in the module's ``chain_begin``/``chain_end`` hooks, and
journaled modules use them to reserve the whole chain as ONE journal
transaction (sized from the submission entries; a chain that can never fit
completes ENOSPC-first/ECANCELED-rest before staging anything) — see
``repro.fs.journal`` for the transaction semantics and
``repro.fs.crashsim`` for the exhaustive crash-point proof. ``SQE_DRAIN``
marks a barrier entry that runs only after every prior entry in the batch
completed, documenting ordering for mixed chain/unchained batches.

Concurrent submitters compose through ``execute_multi_batch``: many
per-thread submissions drain under one gate crossing (io_uring
SQPOLL-style — see ``repro.core.registry``), with chains grouped per
submitter and unchained runs coalesced across submitters. When the module
exposes lock-domain hooks (``group_footprint``/``domain_scope`` — the
sharded replacement for the big fs lock, see ``repro.fs.xv6``) and the
drain is handed a worker ``pool``, non-overlapping dispatch groups
execute concurrently instead of serially, multi-queue block-driver style.
"""

from __future__ import annotations

import abc
import concurrent.futures
import dataclasses
import enum
import inspect
import time
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.core.capability import SuperBlockCap


class Errno(enum.IntEnum):
    EPERM = 1   # mutating fs-internal reserved names (the dedup index)
    ENOENT = 2
    EIO = 5
    EEXIST = 17
    EXDEV = 18  # overlay: rename would cross the base/upper "device" line
    ENOTDIR = 20
    EISDIR = 21
    EINVAL = 22
    EFBIG = 27
    ENOSPC = 28
    ENOTEMPTY = 39
    ETIME = 62  # linked timeout fired: its chain's deadline passed
    ESTALE = 116
    ECANCELED = 125  # chained entry cancelled: an earlier link failed


class FsError(Exception):
    def __init__(self, errno: Errno, msg: str = ""):
        super().__init__(f"{errno.name}: {msg}")
        self.errno = errno


class FileKind(enum.IntEnum):
    FILE = 1
    DIR = 2


@dataclasses.dataclass
class Attr:
    """Plain-value attribute record — no shared kernel structures (§4.3)."""

    ino: int
    kind: FileKind
    size: int
    nlink: int
    mtime: float = 0.0

    @property
    def is_dir(self) -> bool:
        return self.kind == FileKind.DIR


ROOT_INO = 1


# --- batched boundary records (io_uring-shaped, §4.3 plain values) ---------------

# The file-operations table, in canonical order — the ONE list every
# dispatch surface derives from (``Mount``'s function table, the
# VFS-direct baseline, the FUSE client/daemon). ``init``/``destroy`` are
# lifecycle-only and ``submit_batch`` itself may not nest.
FS_OPS = ("getattr", "lookup", "create", "mkdir", "unlink", "rmdir", "rename",
          "readdir", "read", "write", "truncate", "fsync", "flush", "statfs",
          "read_provenance")

# Ops that may appear in a submission batch.
BATCHABLE_OPS = frozenset(FS_OPS)


# SubmissionEntry.flags bits (io_uring IOSQE_* analogues).
SQE_LINK = 0x1   # link the NEXT entry into this entry's chain
SQE_LINK_TIMEOUT = 0x4  # this entry is a deadline guard for its chain
#   (io_uring IOSQE_IO_LINK + link-timeout SQE): args=(monotonic_deadline,)
#   where the deadline is a ``time.monotonic()`` timestamp. The entry is
#   never dispatched to the module; conventionally its op is
#   "link_timeout" (rejected EINVAL by modules, so a stray flagless copy
#   fails loudly). If the deadline has already passed when the chain
#   DRAINS, the whole chain is refused before anything is staged: the
#   timeout entry completes ``Errno.ETIME`` and every other member
#   ``ECANCELED``. If the deadline passes between members (this executor
#   is synchronous, so that is the only other observation point), the
#   remaining members are cancelled the same way. Otherwise the chain
#   runs to completion and the timeout entry completes with result 0,
#   io_uring's "timeout cancelled because the link finished first". A
#   guard is invisible to ``PrevResult`` data flow: ``back`` counts REAL
#   members only, so an op right after the guard still reads the op
#   right before it with the default back=1. The
#   flag is only meaningful inside a chain — a bare flagged entry reaches
#   the module as an ordinary op and EINVALs on the conventional opname.
SQE_DRAIN = 0x2  # barrier: run only after ALL prior entries in the batch
#   completed (io_uring IOSQE_IO_DRAIN). In this synchronous executor every
#   entry already completes before the next starts; the observable effect is
#   that a drain entry starts a NEW dispatch group, so a module's vectorized
#   coalescing (same-op runs, write merging) never crosses the barrier. This
#   is how a mixed batch documents ordering: "everything before the drain —
#   including any chain, whatever its fate — is complete before this runs."
#   A drain flag on a LATER chain member is redundant and ignored: chains
#   are already ordered and are never severed by a barrier.


@dataclasses.dataclass(frozen=True)
class PrevResult:
    """Placeholder argument inside a *chained* entry: replaced at execution
    time by the result of the chain member ``back`` entries earlier (1 =
    the immediately preceding entry). ``attr`` optionally projects a named
    attribute of that result (e.g. ``PrevResult("ino")`` after a create).

    Only ``execute_batch`` resolves these, and only inside a chain; a
    placeholder that reaches dispatch unresolved (unchained entry, or
    ``back`` pointing before the chain start) completes with ``EINVAL``.
    The referenced member always succeeded — a failed link would already
    have cancelled this entry."""

    attr: Optional[str] = None
    back: int = 1


@dataclasses.dataclass(slots=True)
class SubmissionEntry:
    """One SQE: which op, its plain-value args, an opaque cookie the
    caller uses to match the completion (never interpreted by the fs), and
    link flags (``SQE_LINK`` chains the next entry — see ``execute_batch``).

    Treat as immutable once submitted (not ``frozen=True`` only because a
    frozen __init__ costs ~3x on the hot path — batches are built in
    bulk)."""

    op: str
    args: Tuple[Any, ...] = ()
    kwargs: Optional[Dict[str, Any]] = None  # None == {} (skips an alloc)
    user_data: Any = None
    flags: int = 0
    # who staged this entry — stamped by the submission queue (SQPOLL
    # drain) from the registered submitter identity, so provenance records
    # and dedup index stats attribute work to the real submitter instead
    # of guessing from the dispatching thread. None: direct/anonymous
    # submission.
    submitter: Optional[str] = None


@dataclasses.dataclass(slots=True)
class CompletionEntry:
    """One CQE: the submission's cookie plus result XOR errno."""

    user_data: Any
    result: Any = None
    errno: Optional[Errno] = None

    @property
    def ok(self) -> bool:
        return self.errno is None

    def unwrap(self):
        """Scalar-shim helper: re-raise the errno the way the scalar API
        would have (the only place batch errors become exceptions again)."""
        if self.errno is not None:
            raise FsError(self.errno, f"batched {self.user_data!r}")
        return self.result


def split_chains(entries: List["SubmissionEntry"]
                 ) -> List[Tuple[bool, List["SubmissionEntry"]]]:
    """Group a batch into ``(is_chain, members)`` runs. A chain is a
    maximal run of SQE_LINK entries plus the first entry after them (the
    chain's tail); a trailing SQE_LINK at batch end simply ends the chain
    there, like an io_uring link that reaches the submit boundary. An
    SQE_DRAIN entry always STARTS a group (the barrier: every prior group
    completes first); a drain inside a chain never severs it."""
    groups: List[Tuple[bool, List[SubmissionEntry]]] = []
    i, n = 0, len(entries)
    while i < n:
        j = i
        if entries[i].flags & SQE_LINK:
            while j < n and entries[j].flags & SQE_LINK:
                j += 1
            j = min(j + 1, n)  # include the tail entry
            groups.append((True, entries[i:j]))
        else:
            while j < n and not (entries[j].flags & SQE_LINK) \
                    and not (j > i and entries[j].flags & SQE_DRAIN):
                j += 1
            groups.append((False, entries[i:j]))
        i = j
    return groups


def _resolve_placeholders(entry: "SubmissionEntry",
                          done: List["CompletionEntry"]):
    """Substitute PrevResult args from the chain's completions so far.
    Returns a substituted entry, or a CompletionEntry(EINVAL) when a
    placeholder is unresolvable (bad ``back`` / missing attribute)."""
    def sub(v):
        if not isinstance(v, PrevResult):
            return v
        if v.back < 1 or v.back > len(done):
            raise LookupError(f"PrevResult back={v.back} escapes the chain")
        r = done[-v.back].result
        return getattr(r, v.attr) if v.attr else r

    try:
        args = tuple(sub(a) for a in entry.args)
        kwargs = ({k: sub(v) for k, v in entry.kwargs.items()}
                  if entry.kwargs else None)
    except (LookupError, AttributeError):
        return CompletionEntry(entry.user_data, errno=Errno.EINVAL)
    if args == entry.args and kwargs == entry.kwargs:
        return entry
    return SubmissionEntry(entry.op, args, kwargs, entry.user_data,
                           entry.flags, entry.submitter)


def _run_chain(submit_batch, group, chain_begin, chain_end
               ) -> List["CompletionEntry"]:
    """Execute ONE chain group member-by-member under the module's chain
    reservation scope — the single implementation of the SQE_LINK rules
    (including SQE_LINK_TIMEOUT deadline guards) shared by
    ``execute_batch`` and ``execute_multi_batch``."""
    has_timeout = any(e.flags & SQE_LINK_TIMEOUT for e in group)
    deadline = None
    if has_timeout:
        ds = [e.args[0] for e in group
              if e.flags & SQE_LINK_TIMEOUT and e.args
              and isinstance(e.args[0], (int, float))
              and not isinstance(e.args[0], bool)]
        deadline = min(ds) if ds else None
        if deadline is not None and time.monotonic() >= deadline:
            # expired before the drain reached this chain: refuse it whole
            # with nothing staged (no chain_begin, no journal reservation)
            return [CompletionEntry(e.user_data, errno=(
                        Errno.ETIME if e.flags & SQE_LINK_TIMEOUT
                        else Errno.ECANCELED)) for e in group]
    members = ([e for e in group if not (e.flags & SQE_LINK_TIMEOUT)]
               if has_timeout else group)
    if chain_begin is not None:
        err = chain_begin(members)
        if err is not None:  # chain can never fit: nothing was staged
            return ([CompletionEntry(group[0].user_data, errno=err)]
                    + [CompletionEntry(e.user_data, errno=Errno.ECANCELED)
                       for e in group[1:]])
    done: List[CompletionEntry] = []
    # guards are timers, not data producers: PrevResult resolves against
    # the completions of REAL members only, so ``back=1`` after a guard
    # still names the op before it (io_uring's timeout SQE is likewise
    # invisible to the data flow of its link chain)
    data_done: List[CompletionEntry] = []
    expired = False
    try:
        for e in group:
            is_guard = bool(e.flags & SQE_LINK_TIMEOUT)
            # every entry (guards included) observes the clock at its
            # position: a guard reached after the deadline passed reports
            # ETIME itself rather than letting a later member's ECANCELED
            # contradict a "timer cancelled" completion
            if not expired and deadline is not None \
                    and time.monotonic() >= deadline:
                expired = True
            if is_guard:
                if expired:
                    done.append(CompletionEntry(e.user_data,
                                                errno=Errno.ETIME))
                elif done and not done[-1].ok:
                    done.append(CompletionEntry(e.user_data,
                                                errno=Errno.ECANCELED))
                elif not (e.args and isinstance(e.args[0], (int, float))
                          and not isinstance(e.args[0], bool)):
                    done.append(CompletionEntry(e.user_data,
                                                errno=Errno.EINVAL))
                else:  # the chain beat its deadline: timeout cancelled
                    done.append(CompletionEntry(e.user_data, result=0))
                continue
            if expired or (done and not done[-1].ok):
                done.append(CompletionEntry(e.user_data,
                                            errno=Errno.ECANCELED))
                continue
            resolved = _resolve_placeholders(e, data_done)
            if isinstance(resolved, CompletionEntry):
                done.append(resolved)
            else:
                done.append(submit_batch([resolved])[0])
            data_done.append(done[-1])
    finally:
        if chain_end is not None:
            chain_end()
    return done


def execute_batch(submit_batch, entries) -> List["CompletionEntry"]:
    """Chain-aware batch executor — the one implementation of SQE_LINK
    (and SQE_DRAIN barriers).

    Unchained runs go to ``submit_batch`` whole, keeping the module's
    vectorized fast paths (a drain entry starts a fresh run, so coalescing
    never crosses the barrier); chained runs execute member-by-member
    (each member may depend on the previous one's result via
    ``PrevResult``), and the first failing member cancels the rest of its
    chain with ``ECANCELED``. Callers hold whatever gate/lock makes the
    whole batch atomic — this function never re-enters dispatch.

    Chains are *reserved* as one journal transaction: when the module
    behind ``submit_batch`` exposes the ``chain_begin``/``chain_end``
    hooks (see ``BentoFilesystem``), every chain group runs inside that
    scope, so all members' journal writes land in a single commit — a
    crash at any device write leaves the whole chain installed or none of
    it. A chain whose estimated footprint can never fit the journal is
    refused up front: its FIRST member completes with the errno
    ``chain_begin`` returned (``ENOSPC``) *before any block is staged* and
    the rest complete ``ECANCELED`` — a raw ``JournalFull`` never escapes
    the boundary. All three dispatch layers (``Mount.submit``, the
    VFS-direct baseline, the FUSE daemon) share this path."""
    if not isinstance(entries, list):
        entries = list(entries)
    if not any(e.flags & (SQE_LINK | SQE_DRAIN) for e in entries):
        return submit_batch(entries)  # fast path: no chains/barriers staged
    owner = getattr(submit_batch, "__self__", None)
    chain_begin = getattr(owner, "chain_begin", None)
    chain_end = getattr(owner, "chain_end", None)
    comps: List[CompletionEntry] = []
    for is_chain, group in split_chains(entries):
        if is_chain:
            comps.extend(_run_chain(submit_batch, group, chain_begin,
                                    chain_end))
        else:
            comps.extend(submit_batch(group))
    return comps


def execute_multi_batch(submit_batch, segments, pool=None
                        ) -> List[List["CompletionEntry"]]:
    """Multi-submitter batch executor: each *segment* is one submitter's
    submission, and the whole call runs under ONE gate crossing held by
    the caller (the drain of the SQPOLL-style multi-queue design — see
    ``repro.core.registry``).

    Two rules extend the single-batch semantics to concurrent submitters:

    * chains are grouped PER SEGMENT — a trailing ``SQE_LINK`` in one
      submitter's segment ends its chain at the segment boundary, exactly
      like an io_uring link reaching the submit boundary; it can never
      link into another submitter's first entry;
    * adjacent *unchained* runs from different segments coalesce into one
      ``submit_batch`` call, so the module's vectorized fast paths (bulk
      cache passes, one directory scan per parent, write merging)
      amortize ACROSS submitters — the throughput half of the design. A
      segment-internal ``SQE_DRAIN`` barrier still starts a fresh run, so
      per-submitter ordering documentation survives the merge.

    Entries execute in segment-major order (each segment's internal order
    preserved); concurrent submissions have no mutual ordering contract.
    Returns one completion list per segment, each in submission order.

    With a worker ``pool`` (any ``concurrent.futures`` executor) and a
    module exposing the lock-domain hooks (``group_footprint`` /
    ``domain_scope`` — see ``repro.fs.xv6``), the drain schedules
    NON-OVERLAPPING dispatch groups onto the pool concurrently instead of
    draining serially, multi-queue block-driver style: each group's
    footprint (the set of lock domains its entries touch, computed by the
    same estimator machinery ``chain_begin`` sizes transactions with) is
    consulted, a group waits for every earlier group it could overlap
    (same submitter, shared domain, or an unanalyzable ``None`` footprint
    — which overlaps everything), and each group runs under
    ``domain_scope(footprint)`` so the module's sharded domain locks
    stand in for the big fs lock. Journal commit remains the only global
    serialization point. Per-segment completion order, chain atomicity
    and errno discipline are identical to the serial drain; unchained
    runs do NOT coalesce across submitters in parallel mode (they may
    land on different workers). Falls back to the serial drain when the
    hooks are absent or no footprint is analyzable."""
    segments = [s if isinstance(s, list) else list(s) for s in segments]
    if len(segments) == 1:
        return [execute_batch(submit_batch, segments[0])]
    owner = getattr(submit_batch, "__self__", None)
    chain_begin = getattr(owner, "chain_begin", None)
    chain_end = getattr(owner, "chain_end", None)
    flat: List[Tuple[int, bool, List[SubmissionEntry]]] = []
    for si, entries in enumerate(segments):
        for is_chain, group in split_chains(entries):
            flat.append((si, is_chain, group))
    if pool is not None:
        par = _execute_multi_parallel(submit_batch, owner, chain_begin,
                                      chain_end, segments, flat, pool)
        if par is not None:
            return par
    out: List[List[CompletionEntry]] = [[] for _ in segments]
    i, n = 0, len(flat)
    while i < n:
        si, is_chain, group = flat[i]
        if is_chain:
            out[si].extend(_run_chain(submit_batch, group, chain_begin,
                                      chain_end))
            i += 1
            continue
        # coalesce adjacent unchained groups (across submitters) into one
        # dispatch; a group opening with a DRAIN barrier starts its own
        run = [(si, group)]
        j = i + 1
        while j < n and not flat[j][1] \
                and not (flat[j][2][0].flags & SQE_DRAIN):
            run.append((flat[j][0], flat[j][2]))
            j += 1
        comps = submit_batch([e for _, g in run for e in g])
        k = 0
        for rsi, g in run:
            out[rsi].extend(comps[k:k + len(g)])
            k += len(g)
        i = j
    return out


def _execute_multi_parallel(submit_batch, owner, chain_begin, chain_end,
                            segments, flat, pool
                            ) -> Optional[List[List["CompletionEntry"]]]:
    """Footprint-scheduled parallel drain over a worker pool.

    Returns ``None`` when the module lacks the lock-domain hooks or no
    group has an analyzable footprint — the caller then falls back to the
    serial drain, which is byte-identical to the pre-sharding behaviour.

    Scheduling is a dependency DAG over the flattened dispatch groups:
    group *j* waits on every earlier group *i* that (a) belongs to the
    same segment (per-submitter order is a contract), or (b) has an
    overlapping footprint — with ``None`` (unanalyzable) treated as
    overlapping everything, so such groups act as barriers and run under
    the table's global exclusive bracket. The DRAINER thread runs this
    loop and never executes module code itself; workers never touch the
    op gate (the drainer's single crossing brackets the whole drain) and
    never wait on futures, so the pool cannot deadlock on itself. The
    first implementation exception stops new scheduling, lets in-flight
    groups finish, and re-raises — poisoning the drain exactly like the
    serial path."""
    group_footprint = getattr(owner, "group_footprint", None)
    domain_scope = getattr(owner, "domain_scope", None)
    if group_footprint is None or domain_scope is None:
        return None
    fps = [group_footprint(group) for _, _, group in flat]
    if all(fp is None for fp in fps):
        return None  # every group would serialize anyway: serial drain wins
    n = len(flat)
    ndeps = [0] * n
    dependents: List[List[int]] = [[] for _ in range(n)]
    for j in range(n):
        sj, fj = flat[j][0], fps[j]
        for i in range(j):
            if flat[i][0] == sj or fps[i] is None or fj is None \
                    or (fps[i] & fj):
                ndeps[j] += 1
                dependents[i].append(j)
    results: List[Optional[List[CompletionEntry]]] = [None] * n

    def run_unit(u: int) -> List[CompletionEntry]:
        _, is_chain, group = flat[u]
        with domain_scope(fps[u]):
            if is_chain:
                return _run_chain(submit_batch, group, chain_begin,
                                  chain_end)
            return submit_batch(group)

    ready = [u for u in range(n) if ndeps[u] == 0]
    in_flight: Dict[Any, int] = {}
    first_exc: Optional[BaseException] = None
    while in_flight or (ready and first_exc is None):
        if first_exc is None:
            for u in ready:
                in_flight[pool.submit(run_unit, u)] = u
            ready = []
        done, _ = concurrent.futures.wait(
            in_flight, return_when=concurrent.futures.FIRST_COMPLETED)
        for f in done:
            u = in_flight.pop(f)
            try:
                results[u] = f.result()
            except BaseException as e:  # a module bug, not an fs errno
                if first_exc is None:
                    first_exc = e
                continue
            for v in dependents[u]:
                ndeps[v] -= 1
                if ndeps[v] == 0:
                    ready.append(v)
    if first_exc is not None:
        raise first_exc
    out: List[List[CompletionEntry]] = [[] for _ in segments]
    for u in range(n):
        out[flat[u][0]].extend(results[u])
    return out


class BentoModule(abc.ABC):
    """Versioned extension: the §4.8 state-transfer contract."""

    NAME: str = "module"
    VERSION: int = 1

    def extract_state(self) -> Dict[str, Any]:
        """Serialize transferable in-memory state before an upgrade.

        Called only after the runtime has quiesced the module (no in-flight
        operations, no outstanding mutable borrows)."""
        return {}

    def restore_state(self, state: Dict[str, Any], from_version: int) -> None:
        """Install state extracted from ``from_version`` of this module."""
        del state, from_version

    def state_schema(self) -> Tuple[str, ...]:
        """Keys this version emits/accepts — checked at upgrade time."""
        return ()

    def optional_state_keys(self) -> Tuple[str, ...]:
        """Subset of ``state_schema`` this version can synthesize when the
        outgoing module never emitted it — the layer-aware half of the
        schema check. A stackable layer (``repro.fs.prov``) lists its own
        keys here so a PLAIN module can be upgraded into the layered one
        without a migrate hook: the layer bootstraps its private state and
        forwards everything else to its inner module. Keys NOT listed stay
        strictly required, so a genuinely incomplete transfer still fails
        loudly."""
        return ()


class BentoFilesystem(BentoModule):
    """File-operations API (FUSE low-level port + SuperBlock capability)."""

    # --- lifecycle -------------------------------------------------------------
    @abc.abstractmethod
    def init(self, sb: SuperBlockCap, services: "KernelServices") -> None:
        """Mount-time: the runtime lends the superblock capability and the
        kernel-services API. The fs must not stash raw kernel objects."""

    def destroy(self) -> None:
        pass

    # --- inode ops ---------------------------------------------------------------
    @abc.abstractmethod
    def getattr(self, ino: int) -> Attr: ...

    @abc.abstractmethod
    def lookup(self, parent: int, name: str) -> Attr: ...

    @abc.abstractmethod
    def create(self, parent: int, name: str) -> Attr: ...

    @abc.abstractmethod
    def mkdir(self, parent: int, name: str) -> Attr: ...

    @abc.abstractmethod
    def unlink(self, parent: int, name: str) -> None: ...

    @abc.abstractmethod
    def rmdir(self, parent: int, name: str) -> None: ...

    @abc.abstractmethod
    def rename(self, parent: int, name: str, newparent: int, newname: str) -> None: ...

    @abc.abstractmethod
    def readdir(self, ino: int) -> List[Tuple[str, int, FileKind]]: ...

    # --- data ops -------------------------------------------------------------------
    @abc.abstractmethod
    def read(self, ino: int, off: int, size: int) -> bytes: ...

    @abc.abstractmethod
    def write(self, ino: int, off: int, data: bytes) -> int: ...

    @abc.abstractmethod
    def truncate(self, ino: int, size: int) -> None: ...

    @abc.abstractmethod
    def fsync(self, ino: int) -> None: ...

    def flush(self) -> None:
        """Write back everything (unmount / upgrade barrier)."""

    @abc.abstractmethod
    def statfs(self) -> Dict[str, int]: ...

    # --- stackable layers (provenance query op) ---------------------------------
    # A stackable module (see ``repro.fs.prov``) wraps another
    # BentoFilesystem and sets ``inner``; dispatch layers never care, but
    # the upgrade path uses it to wrap/unwrap layers onto a live mount.
    inner: Optional["BentoFilesystem"] = None

    def read_provenance(self, since: int = 0, offset: int = 0,
                        limit: Optional[int] = None) -> List[Dict[str, Any]]:
        """Query the provenance log (paper §6): plain-value records, each
        carrying at least ``seq``/``op``/``ino``/``parent``/``name``/``ts``,
        for records with ``seq >= since``. ``offset``/``limit`` paginate
        within that selection (submission payloads stay bounded however
        large the log grows). Part of the file-operations API so it crosses
        every dispatch layer (scalar, batched, FUSE) like any other op;
        modules without a provenance layer refuse it with ``EINVAL``, the
        way an unknown ioctl would be."""
        del since, offset, limit
        raise FsError(Errno.EINVAL, "no provenance layer mounted")

    # --- batched boundary ------------------------------------------------------
    _SIG_CACHE: Dict[Tuple[type, str], tuple] = {}

    # basic value shapes checked pre-call for the data ops, so a malformed
    # entry completes EINVAL while a TypeError from inside a correctly-
    # called op (an implementation bug) propagates loudly, like scalar
    # dispatch. ``bound`` is a plain {param: value} mapping.
    _VALUE_CHECKS = {
        "write": lambda bound: (isinstance(bound.get("data"),
                                           (bytes, bytearray))
                                and isinstance(bound.get("off"), int)),
        "read": lambda bound: (isinstance(bound.get("off"), int)
                               and isinstance(bound.get("size"), int)),
    }

    def _entry_fits(self, op: str, args, kwargs) -> bool:
        """Does (args, kwargs) form a well-shaped call of ``op``? Checked
        BEFORE dispatch: arity/keywords via a precomputed shape of the
        signature (``inspect.signature`` binding per entry was the single
        hottest line of batched dispatch), plus the per-op basic value
        shapes above. An unresolved ``PrevResult`` placeholder (legal
        only inside a chain, where ``execute_batch`` substitutes it
        before dispatch) never fits."""
        if any(isinstance(a, PrevResult) for a in args) or \
                (kwargs and any(isinstance(v, PrevResult)
                                for v in kwargs.values())):
            return False
        key = (type(self), op)
        meta = self._SIG_CACHE.get(key)
        if meta is None:
            sig = inspect.signature(getattr(self, op))
            simple = all(p.kind in (inspect.Parameter.POSITIONAL_ONLY,
                                    inspect.Parameter.POSITIONAL_OR_KEYWORD)
                         for p in sig.parameters.values())
            if simple:
                names = tuple(sig.parameters)
                required = sum(1 for p in sig.parameters.values()
                               if p.default is inspect.Parameter.empty)
                meta = (names, required)
            else:  # kw-only / varargs: keep real binding semantics
                meta = (None, sig)
            self._SIG_CACHE[key] = meta
        names, required = meta
        if names is None:
            try:
                bound = required.bind(*args, **(kwargs or {})).arguments
            except TypeError:
                return False
        else:
            if len(args) > len(names):
                return False
            bound = dict(zip(names, args))
            if kwargs:
                for k, v in kwargs.items():
                    if k not in names or k in bound:
                        return False
                    bound[k] = v
            if sum(1 for n in names[:required] if n in bound) < required:
                return False
        check = self._VALUE_CHECKS.get(op)
        return check is None or check(bound)

    def _dispatch_one(self, entry: SubmissionEntry) -> CompletionEntry:
        """Run one entry with per-entry errno capture: malformed entries
        and FsErrors become errnos; implementation exceptions propagate."""
        if (entry.op not in BATCHABLE_OPS
                or not self._entry_fits(entry.op, entry.args, entry.kwargs)):
            return CompletionEntry(entry.user_data, errno=Errno.EINVAL)
        try:
            fn = getattr(self, entry.op)
            return CompletionEntry(entry.user_data,
                                   result=fn(*entry.args,
                                             **(entry.kwargs or {})))
        except FsError as e:
            return CompletionEntry(entry.user_data, errno=e.errno)

    def submit_batch(self, entries: Iterable[SubmissionEntry]
                     ) -> List[CompletionEntry]:
        """Process a submission batch; completions in submission order.

        Default: scalar dispatch with per-entry errno isolation, so every
        module speaks the batched boundary. Override for vectorized fast
        paths (amortize locks, cache passes, journal commits, checksum
        launches across the batch) — completion order must be preserved.
        """
        return [self._dispatch_one(e) for e in entries]

    # --- chain reservation hooks -------------------------------------------------
    def chain_begin(self, entries: List[SubmissionEntry],
                    extra_blocks: int = 0) -> Optional[Errno]:
        """Called by ``execute_batch`` before a chain group executes; the
        module reserves whatever makes the WHOLE chain one atomicity unit
        (journaled modules size one journal transaction from the entries —
        see ``repro.fs.xv6``). ``extra_blocks`` is the stacked-layer hook:
        a wrapper that stages additional blocks inside the same
        transaction (provenance records) adds its footprint here. Return
        an ``Errno`` (``ENOSPC``) to refuse the chain before anything is
        staged: the first member completes with it, the rest
        ``ECANCELED``. Default: no reservation needed."""
        del entries, extra_blocks
        return None

    def estimate_append_blocks(self, nbytes: int) -> int:
        """Journal-blocks upper bound for appending ``nbytes`` to an
        existing file — part of the stackable-layer contract (a wrapper
        sizes the records it will stage through this module's write path).
        Journaled modules override with their real write-path overhead
        (see ``repro.fs.xv6``); the default is a generous generic bound."""
        return nbytes // 4096 + 4

    def chain_end(self) -> None:
        """Close the scope ``chain_begin`` opened (always called, even when
        a member failed mid-chain). Default: nothing to release."""


# Filled in by repro.core.services at import time (cycle-free forward ref).
KernelServices = Any
