"""Module registration and mount table (paper §4.2, §5.2).

File systems register a *factory*; mounting instantiates the module, mints
its capabilities, and captures a function table (the function-pointer
struct of §5.2). Dispatch goes through the table + an operation gate so the
online-upgrade path (core.upgrade) can quiesce in-flight operations and
atomically swap the table — applications keep their mount handle across the
swap.
"""

from __future__ import annotations

import threading
from typing import Any, Callable, Dict, Optional

from repro.core.interface import BentoFilesystem, Errno, FsError

_FS_REGISTRY: Dict[str, Callable[[], BentoFilesystem]] = {}


def register_bento(name: str, factory: Callable[[], BentoFilesystem]) -> None:
    _FS_REGISTRY[name] = factory


def registered() -> Dict[str, Callable[[], BentoFilesystem]]:
    return dict(_FS_REGISTRY)


class OpGate:
    """Reader-writer gate: operations enter as readers; quiesce takes the
    writer side and drains in-flight ops (paper §4.8 upgrade barrier)."""

    def __init__(self):
        self._lock = threading.Condition()
        self._active = 0
        self._frozen = False

    def enter(self) -> None:
        with self._lock:
            while self._frozen:
                self._lock.wait()
            self._active += 1

    def exit(self) -> None:
        with self._lock:
            self._active -= 1
            if self._active == 0:
                self._lock.notify_all()

    def freeze(self) -> None:
        with self._lock:
            self._frozen = True
            while self._active > 0:
                self._lock.wait()

    def thaw(self) -> None:
        with self._lock:
            self._frozen = False
            self._lock.notify_all()


_FS_OPS = ("getattr", "lookup", "create", "mkdir", "unlink", "rmdir", "rename",
           "readdir", "read", "write", "truncate", "fsync", "flush", "statfs")


class Mount:
    """A mounted Bento file system: function table + op gate + capabilities."""

    def __init__(self, name: str, module: BentoFilesystem, services):
        self.name = name
        self.services = services
        self.gate = OpGate()
        self._lock = threading.Lock()
        self.module: Optional[BentoFilesystem] = None
        self.table: Dict[str, Callable] = {}
        self.generation = 0
        self._install(module)

    def _install(self, module: BentoFilesystem) -> None:
        sb = self.services.superblock()
        module.init(sb, self.services)
        self.module = module
        # Capture the function table — dispatch never touches the module
        # object directly after this point (mirrors the VFS fn-pointer struct).
        self.table = {op: getattr(module, op) for op in _FS_OPS}
        self.generation += 1

    # --- dispatch -------------------------------------------------------------------
    def call(self, op: str, *args, **kw):
        fn = self.table.get(op)
        if fn is None:
            raise FsError(Errno.EINVAL, f"no such op {op}")
        self.gate.enter()
        try:
            return fn(*args, **kw)
        finally:
            self.gate.exit()

    def __getattr__(self, op: str):
        if op in _FS_OPS:
            return lambda *a, **k: self.call(op, *a, **k)
        raise AttributeError(op)

    def unmount(self) -> None:
        self.gate.freeze()
        try:
            self.module.flush()
            self.module.destroy()
            self.services.unmount_checks()
        finally:
            self.gate.thaw()


def mount(name: str, services, module: Optional[BentoFilesystem] = None) -> Mount:
    if module is None:
        factory = _FS_REGISTRY.get(name)
        if factory is None:
            raise KeyError(f"no registered bento fs {name!r}")
        module = factory()
    return Mount(name, module, services)
