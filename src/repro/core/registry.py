"""Module registration, mount table, and the batched dispatch gate
(paper §4.2, §5.2).

File systems register a *factory*; mounting instantiates the module, mints
its capabilities, and captures a function table (the function-pointer
struct of §5.2). Dispatch goes through the table + an operation gate so the
online-upgrade path (core.upgrade) can quiesce in-flight operations and
atomically swap the table — applications keep their mount handle across the
swap.

Two dispatch surfaces cross the gate:

* ``Mount.call(op, ...)`` — the scalar path: one gate-crossing, one table
  lookup, one module call per operation (the paper's §4.3 shape).
* ``Mount.submit(entries)`` — the batched path: the gate is entered ONCE
  for the whole batch, then the module's ``submit_batch`` runs every entry.
  Upgrade quiesce therefore drains whole batches atomically: a table swap
  can never land between two entries of one batch, so a batch's
  completions all come from the same module generation (§4.8 guarantee,
  extended to batches). ``BentoQueue`` is the io_uring-style SQ/CQ
  convenience wrapper over ``Mount.submit``.

The gate tracks per-thread depth: a module op that re-enters dispatch on
the same thread (nested ``call``/``submit``) joins its outer crossing
instead of deadlocking against a concurrent ``freeze``.
"""

from __future__ import annotations

import collections
import threading
from typing import Any, Callable, Deque, Dict, Iterable, List, Optional

from repro.core.interface import (BentoFilesystem, CompletionEntry, Errno,
                                  FsError, SQE_LINK, SubmissionEntry,
                                  execute_batch)

_FS_REGISTRY: Dict[str, Callable[[], BentoFilesystem]] = {}


def register_bento(name: str, factory: Callable[[], BentoFilesystem]) -> None:
    _FS_REGISTRY[name] = factory


def registered() -> Dict[str, Callable[[], BentoFilesystem]]:
    return dict(_FS_REGISTRY)


class OpGate:
    """Reader-writer gate: operations enter as readers; quiesce takes the
    writer side and drains in-flight ops (paper §4.8 upgrade barrier).

    Re-entrant per thread: a thread already inside the gate (an op that
    dispatches a nested op) bumps a thread-local depth instead of waiting —
    otherwise a nested ``enter`` during ``freeze`` would deadlock: freeze
    waits for the outer op to exit while the inner enter waits for thaw.
    ``crossings`` counts outermost entries only, so a submitted batch is
    exactly one crossing (the batching win, measured in benchmarks).
    """

    def __init__(self):
        self._lock = threading.Condition()
        self._active = 0
        self._frozen = False
        self._depth = threading.local()
        self.crossings = 0

    def enter(self) -> None:
        depth = getattr(self._depth, "v", 0)
        if depth > 0:  # nested on this thread: already counted as active
            self._depth.v = depth + 1
            return
        with self._lock:
            while self._frozen:
                self._lock.wait()
            self._active += 1
            self.crossings += 1
        self._depth.v = 1

    def exit(self) -> None:
        depth = getattr(self._depth, "v", 1)
        if depth > 1:
            self._depth.v = depth - 1
            return
        self._depth.v = 0
        with self._lock:
            self._active -= 1
            if self._active == 0:
                self._lock.notify_all()

    def freeze(self) -> None:
        with self._lock:
            self._frozen = True
            while self._active > 0:
                self._lock.wait()

    def thaw(self) -> None:
        with self._lock:
            self._frozen = False
            self._lock.notify_all()


_FS_OPS = ("getattr", "lookup", "create", "mkdir", "unlink", "rmdir", "rename",
           "readdir", "read", "write", "truncate", "fsync", "flush", "statfs",
           "submit_batch")


class Mount:
    """A mounted Bento file system: function table + op gate + capabilities."""

    def __init__(self, name: str, module: BentoFilesystem, services):
        self.name = name
        self.services = services
        self.gate = OpGate()
        self._lock = threading.Lock()
        self.module: Optional[BentoFilesystem] = None
        self.table: Dict[str, Callable] = {}
        self.generation = 0
        self._install(module)

    def _install(self, module: BentoFilesystem) -> None:
        sb = self.services.superblock()
        module.init(sb, self.services)
        self.module = module
        # Capture the function table — dispatch never touches the module
        # object directly after this point (mirrors the VFS fn-pointer struct).
        self.table = {op: getattr(module, op) for op in _FS_OPS}
        self.generation += 1

    # --- dispatch -------------------------------------------------------------------
    def call(self, op: str, *args, **kw):
        fn = self.table.get(op)
        if fn is None:
            raise FsError(Errno.EINVAL, f"no such op {op}")
        self.gate.enter()
        try:
            return fn(*args, **kw)
        finally:
            self.gate.exit()

    def submit(self, entries: Iterable[SubmissionEntry]) -> List[CompletionEntry]:
        """Batched dispatch: ONE gate-crossing for the whole batch.

        The table is read once after entering the gate, so every entry of
        the batch executes against the same module generation even if an
        upgrade is waiting to swap it (it drains this batch first). Chained
        entries (SQE_LINK) are grouped and executed by ``execute_batch``
        inside the same single crossing, so a table swap can never land
        between two members of a chain either — a chain's completions all
        come from one module generation.
        """
        if not isinstance(entries, list):
            entries = list(entries)
        self.gate.enter()
        try:
            return execute_batch(self.table["submit_batch"], entries)
        finally:
            self.gate.exit()

    def __getattr__(self, op: str):
        if op in _FS_OPS:
            return lambda *a, **k: self.call(op, *a, **k)
        raise AttributeError(op)

    def unmount(self) -> None:
        self.gate.freeze()
        try:
            self.module.flush()
            self.module.destroy()
            self.services.unmount_checks()
        finally:
            self.gate.thaw()


class BentoQueue:
    """io_uring-style submission/completion queue over a mount handle.

    ``prep`` stages entries in the submission queue; ``submit`` crosses the
    boundary once for everything staged (auto-submitting when the queue
    reaches ``depth``); completions accumulate in the completion queue and
    drain via ``drain`` in submission order. Not thread-safe: like an
    io_uring, one queue belongs to one submitter (make one per thread —
    the mount underneath is the shared, thread-safe object).
    """

    def __init__(self, mount, depth: int = 256):
        if depth <= 0:
            raise ValueError("queue depth must be positive")
        self.mount = mount
        self.depth = depth
        self._sq: List[SubmissionEntry] = []
        self._cq: Deque[CompletionEntry] = collections.deque()

    def prep(self, op: str, *args, user_data: Any = None, flags: int = 0,
             **kwargs) -> None:
        """Stage one submission; auto-submits a full queue. Pass
        ``flags=SQE_LINK`` to chain the NEXT prepped entry onto this one;
        auto-submit is deferred while a chain is open (a link must never be
        severed by a batch boundary — an explicit ``submit`` mid-chain,
        like io_uring's, ends the chain at the boundary instead)."""
        self._sq.append(SubmissionEntry(op, args, kwargs or None, user_data,
                                        flags))
        if len(self._sq) >= self.depth and not (flags & SQE_LINK):
            self.submit()

    def submit(self) -> int:
        """Submit everything staged (one gate-crossing); returns the number
        of completions now waiting."""
        if self._sq:
            batch, self._sq = self._sq, []
            self._cq.extend(self.mount.submit(batch))
        return len(self._cq)

    def drain(self) -> List[CompletionEntry]:
        """Take all waiting completions (submission order)."""
        out = list(self._cq)
        self._cq.clear()
        return out

    def __len__(self) -> int:
        return len(self._sq)


def mount(name: str, services, module: Optional[BentoFilesystem] = None) -> Mount:
    if module is None:
        factory = _FS_REGISTRY.get(name)
        if factory is None:
            raise KeyError(f"no registered bento fs {name!r}")
        module = factory()
    return Mount(name, module, services)
