"""Module registration, mount table, and the batched dispatch gate
(paper §4.2, §5.2).

File systems register a *factory*; mounting instantiates the module, mints
its capabilities, and captures a function table (the function-pointer
struct of §5.2). Dispatch goes through the table + an operation gate so the
online-upgrade path (core.upgrade) can quiesce in-flight operations and
atomically swap the table — applications keep their mount handle across the
swap.

Two dispatch surfaces cross the gate:

* ``Mount.call(op, ...)`` — the scalar path: one gate-crossing, one table
  lookup, one module call per operation (the paper's §4.3 shape).
* ``Mount.submit(entries)`` — the batched path: the gate is entered ONCE
  for the whole batch, then the module's ``submit_batch`` runs every entry.
  Upgrade quiesce therefore drains whole batches atomically: a table swap
  can never land between two entries of one batch, so a batch's
  completions all come from the same module generation (§4.8 guarantee,
  extended to batches). ``BentoQueue`` is the io_uring-style SQ/CQ
  convenience wrapper over ``Mount.submit``.

``Mount.submit`` is *multi-submitter* (io_uring SQPOLL-style): each call
is one submission, and instead of every thread racing for its own gate
crossing, submissions queue on the mount and the first thread to claim the
drainer role carries EVERYTHING pending across the boundary in one
crossing (``execute_multi_batch``): chains stay within their submission,
unchained runs coalesce across submitters, completions route back to each
submitter with per-entry errnos. Uncontended, this degenerates to exactly
the old behaviour (one crossing per submission); under N contending
threads, crossings collapse toward one per drain (``mq_drains`` vs
``mq_submissions`` — the benchmark tripwire). ``SubmitterQueue`` is the
per-thread SQ handle (``Mount.submitter_queue()``).

The gate tracks per-thread depth: a module op that re-enters dispatch on
the same thread (nested ``call``/``submit``) joins its outer crossing
instead of deadlocking against a concurrent ``freeze``.

Domain-lock protocol (parallel drain)
-------------------------------------
A drain normally executes its dispatch groups serially under the module's
big fs lock. ``Mount.enable_parallel_drain(workers)`` (or
``start_sqpoll(parallel=N)``) attaches a small worker pool, and the drain
instead hands NON-OVERLAPPING groups to the pool concurrently
(``execute_multi_batch(..., pool=...)``): the module's
``group_footprint`` hook maps each group's submission entries to the set
of lock domains it touches (per-inode stripes plus ALLOC / BLOCKSTORE /
PROV specials — the multi-queue analogue of per-hctx locks), groups wait
only for earlier groups they overlap, and each runs under the module's
``domain_scope`` so sharded domain locks replace the single ``_oplock``
acquisition. The protocol's invariants, enforced by the fs side (see
``repro.fs.xv6``):

* every MUTATING footprint contains ALLOC, so at most one group stages
  journal blocks at a time — ``Journal`` commit stays the only global
  serialization point and member-abort rollback can never clobber a
  concurrent chain's staging;
* a ``None`` footprint (kwargs, ``PrevResult`` args, ops the estimator
  does not model) overlaps everything: the group becomes a barrier and
  runs under the table's global exclusive bracket — exactly the old
  big-lock behaviour;
* workers never touch the op gate: the drainer's single crossing
  brackets the whole drain, so upgrade quiesce still drains whole rounds
  atomically. Worker threads that re-enter dispatch from module code are
  recognized (``_drain_tids``) and join the crossing directly, like the
  drainer itself.
"""

from __future__ import annotations

import collections
import threading
from typing import Any, Callable, Deque, Dict, Iterable, List, Optional

from repro.core.interface import (BentoFilesystem, CompletionEntry, Errno,
                                  FS_OPS, FsError, SQE_LINK, SubmissionEntry,
                                  execute_batch, execute_multi_batch)

_FS_REGISTRY: Dict[str, Callable[[], BentoFilesystem]] = {}


def register_bento(name: str, factory: Callable[[], BentoFilesystem]) -> None:
    _FS_REGISTRY[name] = factory


def registered() -> Dict[str, Callable[[], BentoFilesystem]]:
    return dict(_FS_REGISTRY)


class OpGate:
    """Reader-writer gate: operations enter as readers; quiesce takes the
    writer side and drains in-flight ops (paper §4.8 upgrade barrier).

    Re-entrant per thread: a thread already inside the gate (an op that
    dispatches a nested op) bumps a thread-local depth instead of waiting —
    otherwise a nested ``enter`` during ``freeze`` would deadlock: freeze
    waits for the outer op to exit while the inner enter waits for thaw.
    ``crossings`` counts outermost entries only, so a submitted batch is
    exactly one crossing (the batching win, measured in benchmarks).
    """

    def __init__(self):
        self._lock = threading.Condition()
        self._active = 0
        self._frozen = False
        self._depth = threading.local()
        self.crossings = 0

    def enter(self) -> None:
        depth = getattr(self._depth, "v", 0)
        if depth > 0:  # nested on this thread: already counted as active
            self._depth.v = depth + 1
            return
        with self._lock:
            while self._frozen:
                self._lock.wait()
            self._active += 1
            self.crossings += 1
        self._depth.v = 1

    def exit(self) -> None:
        depth = getattr(self._depth, "v", 1)
        if depth > 1:
            self._depth.v = depth - 1
            return
        self._depth.v = 0
        with self._lock:
            self._active -= 1
            if self._active == 0:
                self._lock.notify_all()

    def freeze(self) -> None:
        with self._lock:
            self._frozen = True
            while self._active > 0:
                self._lock.wait()

    def thaw(self) -> None:
        with self._lock:
            self._frozen = False
            self._lock.notify_all()


_FS_OPS = FS_OPS + ("submit_batch",)  # the table also carries the batch door


class _PendingSubmission:
    """One submitter's staged entries waiting for a drain, plus the slot
    its completions (or the drain's implementation exception) come back
    through."""

    __slots__ = ("entries", "comps", "error")

    def __init__(self, entries: List[SubmissionEntry]):
        self.entries = entries
        self.comps: Optional[List[CompletionEntry]] = None
        self.error: Optional[BaseException] = None


class Mount:
    """A mounted Bento file system: function table + op gate + capabilities."""

    def __init__(self, name: str, module: BentoFilesystem, services):
        self.name = name
        self.services = services
        self.gate = OpGate()
        self._lock = threading.Lock()
        self.module: Optional[BentoFilesystem] = None
        self.table: Dict[str, Callable] = {}
        self.generation = 0
        # multi-submitter queue state (SQPOLL-style drain-on-submit).
        # Two condition variables over ONE lock: submitters park on
        # _mq_cv (completions / drainer-role changes), the SQPOLL poller
        # parks on _mq_work_cv (new-work signal) — so a submission's
        # notify wakes exactly the poller instead of broadcasting to
        # every waiting submitter (a thundering herd per submission)
        _mq_lock = threading.Lock()
        self._mq_cv = threading.Condition(_mq_lock)
        self._mq_work_cv = threading.Condition(_mq_lock)
        self._mq_pending: List[_PendingSubmission] = []
        self._mq_draining = False
        self._mq_drainer_tid: Optional[int] = None
        self._sqpoll: Optional[threading.Thread] = None
        self._sqpoll_run = False
        self._sqpoll_idle_s = 0.0
        self._sqpoll_idle_base_s = 0.0
        self._sqpoll_adaptive = False
        self._tls = threading.local()
        # parallel drain (sharded lock domains — see module docstring)
        self._drain_pool = None
        self._drain_tids: set = set()
        self.mq_submissions = 0  # submit() calls routed through the queue
        self.mq_drains = 0       # gate crossings that drained pending SQs
        self.mq_gather_skips = 0  # gather windows skipped: backlog present
        self._install(module)

    def _install(self, module: BentoFilesystem) -> None:
        sb = self.services.superblock()
        module.init(sb, self.services)
        self.module = module
        # Capture the function table — dispatch never touches the module
        # object directly after this point (mirrors the VFS fn-pointer struct).
        self.table = {op: getattr(module, op) for op in _FS_OPS}
        self.generation += 1

    # --- dispatch -------------------------------------------------------------------
    def call(self, op: str, *args, **kw):
        fn = self.table.get(op)
        if fn is None:
            raise FsError(Errno.EINVAL, f"no such op {op}")
        if self._drain_tids and threading.get_ident() in self._drain_tids:
            # parallel-drain worker re-entering dispatch: the drainer's
            # crossing brackets this thread (see submit()); entering the
            # gate here could deadlock against a pending freeze
            return fn(*args, **kw)
        self.gate.enter()
        try:
            return fn(*args, **kw)
        finally:
            self.gate.exit()

    def submit(self, entries: Iterable[SubmissionEntry]) -> List[CompletionEntry]:
        """Batched dispatch, multi-submitter: each call is ONE submission.

        The calling thread appends its submission to the mount's pending
        queue; the first thread to find the drainer role free takes it and
        drains EVERYTHING pending — its own submission plus any that other
        threads staged meanwhile — in one gate crossing via
        ``execute_multi_batch`` (``mq_drains`` counts those crossings,
        ``mq_submissions`` the calls; uncontended they are equal, under
        contention drains ≪ submissions). Threads whose submissions ride
        someone else's drain just wait for their completions.

        The table is read once inside the crossing, so every entry of a
        drain executes against the same module generation even if an
        upgrade is waiting to swap it (it drains these batches first).
        Chains (SQE_LINK) are grouped per submission — never spanning
        submitters, never split across a drain — so a table swap can never
        land between two members of a chain either: a chain's completions
        all come from one module generation.
        """
        if not isinstance(entries, list):
            entries = list(entries)
        tid = threading.get_ident()
        if self._mq_drainer_tid == tid:
            # nested dispatch from inside a module op on the drainer
            # thread: join the outer crossing (the gate is reentrant) —
            # queueing on ourselves would deadlock
            self.gate.enter()
            try:
                return execute_batch(self.table["submit_batch"], entries)
            finally:
                self.gate.exit()
        if self._drain_tids and tid in self._drain_tids:
            # nested dispatch from a parallel-drain worker, which executes
            # module code on the drainer's behalf: the drainer's crossing
            # already brackets this thread's work, and its gate depth here
            # is 0 — entering would deadlock against a freeze waiting for
            # the drainer (which waits for this worker). Run direct.
            return execute_batch(self.table["submit_batch"], entries)
        sub = _PendingSubmission(entries)
        with self._mq_cv:
            self._mq_pending.append(sub)
            self.mq_submissions += 1
            if self._sqpoll is not None:
                self._mq_work_cv.notify()  # wake the poller (it waits; the
                #   opportunistic drainer polls the queue and needs none)
            while sub.comps is None and sub.error is None \
                    and self._mq_draining:
                self._mq_cv.wait()
            if sub.comps is not None or sub.error is not None:
                if sub.error is not None:
                    raise sub.error
                return sub.comps
            # drainer role is free and our submission is still pending
            # (also the recovery path: a drainer that died re-raising a
            # module bug leaves the role free, and a waiter picks it up)
            self._mq_draining = True
            self._mq_drainer_tid = threading.get_ident()
        try:
            self._drain_pending()
        finally:
            with self._mq_cv:
                self._mq_draining = False
                self._mq_drainer_tid = None
                self._mq_cv.notify_all()
        if sub.error is not None:
            raise sub.error
        return sub.comps

    def _drain_pending(self) -> int:
        """Drainer role: swallow everything pending in one gate crossing,
        repeating until the queue is empty (submissions that arrive while
        a drain executes ride the NEXT crossing, not their own). Returns
        the number of submissions carried — the SQPOLL poller feeds it to
        ``_adapt_idle``."""
        carried = 0
        while True:
            with self._mq_cv:
                batch, self._mq_pending = self._mq_pending, []
            if not batch:
                return carried
            carried += len(batch)
            self.mq_drains += 1
            self.gate.enter()
            try:
                segs = execute_multi_batch(self.table["submit_batch"],
                                           [s.entries for s in batch],
                                           pool=self._drain_pool)
            except BaseException as e:
                # an implementation exception (a bug — fs errors cross as
                # errnos) poisons the whole drain: deliver it to every
                # waiter and re-raise in the drainer, like scalar dispatch
                with self._mq_cv:
                    for s in batch:
                        s.error = e
                    self._mq_cv.notify_all()
                raise
            finally:
                self.gate.exit()
            with self._mq_cv:
                for s, comps in zip(batch, segs):
                    s.comps = comps
                self._mq_cv.notify_all()

    def enable_parallel_drain(self, workers: int = 4) -> None:
        """Attach a small worker pool to the drain: dispatch groups with
        non-overlapping lock-domain footprints execute concurrently
        (``execute_multi_batch(..., pool=...)`` — see the module
        docstring for the protocol). Idempotent; ``workers <= 0`` detaches
        and shuts the pool down, restoring the serial drain. Worker
        threads register their tids so nested dispatch from module code
        running on a worker joins the drainer's crossing instead of
        queueing on itself."""
        if workers <= 0:
            pool, self._drain_pool = self._drain_pool, None
            if pool is not None:
                pool.shutdown(wait=True)
                # dead workers' tids could be recycled for unrelated
                # threads, which would then bypass the gate — forget them
                self._drain_tids.clear()
            return
        if self._drain_pool is not None:
            return
        import concurrent.futures as _cf

        def _register_worker():
            self._drain_tids.add(threading.get_ident())

        self._drain_pool = _cf.ThreadPoolExecutor(
            max_workers=workers, thread_name_prefix=f"drain-{self.name}",
            initializer=_register_worker)

    def submitter_queue(self, depth: int = 256,
                        submitter: Optional[str] = None) -> "SubmitterQueue":
        """The calling thread's SubmitterQueue over this mount, created on
        first use — the per-thread SQ of the multi-submitter design.
        ``submitter`` names the identity stamped onto staged entries
        (first call wins; default ``tid:<owner>``)."""
        q = getattr(self._tls, "sq", None)
        if q is None:
            q = self._tls.sq = SubmitterQueue(self, depth,
                                              submitter=submitter)
        return q

    # --- dedicated SQPOLL drainer (io_uring IORING_SETUP_SQPOLL analogue) ------
    def start_sqpoll(self, idle_us: int = 500, adaptive: bool = True,
                     parallel: int = 0) -> None:
        """Hand the drainer role to a dedicated thread: submitters only
        append and wait, the poller drains everything pending in one gate
        crossing per round. ``idle_us`` is the ``sq_thread_idle``
        analogue — a short gather window after work first appears, letting
        concurrent submitters pile on before the crossing (worth real
        coalescing under an interpreter whose threads otherwise hand off
        in 5 ms slices). Opportunistic drain-on-submit resumes after
        ``stop_sqpoll``; uncontended callers should prefer that default —
        the poller adds the gather window to every submission's latency.

        ``adaptive`` shrinks that latency tax when traffic turns out to be
        uncontended: a drain that carried ≤ 1 submission paid the gather
        window for nothing, so the window HALVES (down to zero); a full
        drain (≥ 2 submissions actually coalesced) restores the configured
        window — see ``_adapt_idle``.

        ``parallel`` > 0 additionally attaches a worker pool of that size
        (``enable_parallel_drain``) so each round's non-overlapping
        dispatch groups execute concurrently."""
        if parallel > 0:
            self.enable_parallel_drain(parallel)
        with self._mq_cv:
            if self._sqpoll is not None:
                return
            # an opportunistic drainer may be mid-flight: wait for it to
            # release the role (its finally notifies) — installing the
            # poller over a live drainer would leave two drainers racing
            while self._mq_draining:
                self._mq_cv.wait()
            self._sqpoll_run = True
            self._sqpoll_adaptive = adaptive
            self._sqpoll_idle_base_s = max(idle_us, 0) / 1e6
            self._sqpoll_idle_s = self._sqpoll_idle_base_s
            self._mq_draining = True  # the poller owns the role for good
            self._sqpoll = threading.Thread(
                target=self._sqpoll_loop, name=f"sqpoll-{self.name}",
                daemon=True)
            self._sqpoll.start()

    def stop_sqpoll(self) -> None:
        """Retire the poller (drains whatever is pending first) and return
        to opportunistic drain-on-submit."""
        with self._mq_cv:
            if self._sqpoll is None:
                return
            self._sqpoll_run = False
            poller = self._sqpoll
            self._mq_work_cv.notify_all()  # the poller parks on work-cv
        poller.join()  # its finally released the role

    def _adapt_idle(self, carried: int) -> None:
        """Adaptive ``sq_thread_idle``: drains that carry ≤ 1 submission
        prove nobody piled on during the gather window, so latency-
        sensitive lone submitters stop paying it — the window halves each
        such drain (snapping to 0 below 1 µs). The first drain that really
        coalesces (≥ 2 submissions) restores the configured window, so
        bursty traffic gets its coalescing back immediately. A window
        decayed to 0 never busy-spins: an idle poller parks on the
        condition variable, not the gather sleep. Pure state transition on
        (window, carried) — deterministic to unit-test."""
        if not self._sqpoll_adaptive or self._sqpoll_idle_base_s <= 0:
            return
        if carried <= 1:
            self._sqpoll_idle_s /= 2
            if self._sqpoll_idle_s < 1e-6:
                self._sqpoll_idle_s = 0.0
        else:
            self._sqpoll_idle_s = self._sqpoll_idle_base_s

    def _sqpoll_loop(self) -> None:
        me = threading.current_thread()
        self._mq_drainer_tid = threading.get_ident()
        import time as _t
        try:
            while True:
                with self._mq_cv:
                    # Starvation fix: submissions that arrived DURING the
                    # previous drain are a backlog, not fresh traffic —
                    # they already waited a whole drain, and sleeping the
                    # gather window again before serving them starves
                    # them for (window + drain) per round. Only sleep
                    # when work appeared while we were genuinely idle
                    # (parked on the cv), i.e. when the wait loop ran.
                    backlog = bool(self._mq_pending)
                    while not self._mq_pending and self._sqpoll_run:
                        backlog = False
                        self._mq_work_cv.wait(timeout=0.05)
                    if not self._sqpoll_run and not self._mq_pending:
                        return
                if self._sqpoll_idle_s > 0:
                    if backlog:
                        self.mq_gather_skips += 1
                    else:
                        _t.sleep(self._sqpoll_idle_s)  # gather (GIL off)
                carried = self._drain_pending()
                if carried:
                    self._adapt_idle(carried)
        finally:
            # normal retirement AND death-by-module-bug both release the
            # drainer role here, or every later submit would wait forever
            # on a poller that no longer exists; opportunistic
            # drain-on-submit resumes (the bug itself was already
            # delivered to that round's waiters by _drain_pending)
            with self._mq_cv:
                if self._sqpoll is me:
                    self._sqpoll = None
                    self._sqpoll_run = False
                    self._mq_draining = False
                    self._mq_drainer_tid = None
                    self._mq_cv.notify_all()

    def __getattr__(self, op: str):
        if op in _FS_OPS:
            return lambda *a, **k: self.call(op, *a, **k)
        raise AttributeError(op)

    def unmount(self) -> None:
        self.enable_parallel_drain(0)  # retire drain workers first
        self.gate.freeze()
        try:
            self.module.flush()
            self.module.destroy()
            self.services.unmount_checks()
        finally:
            self.gate.thaw()


class BentoQueue:
    """io_uring-style submission/completion queue over a mount handle.

    ``prep`` stages entries in the submission queue; ``submit`` crosses the
    boundary once for everything staged (auto-submitting when the queue
    reaches ``depth``); completions accumulate in the completion queue and
    drain via ``drain`` in submission order. Not thread-safe: like an
    io_uring, one queue belongs to one submitter (make one per thread —
    the mount underneath is the shared, thread-safe object).
    """

    def __init__(self, mount, depth: int = 256,
                 submitter: Optional[str] = None):
        if depth <= 0:
            raise ValueError("queue depth must be positive")
        self.mount = mount
        self.depth = depth
        # the identity stamped onto every staged entry (None: anonymous) —
        # provenance records and dedup stats attribute work to it instead
        # of guessing from whichever thread happens to hold the drainer
        # role when the entry executes
        self.submitter = submitter
        self._sq: List[SubmissionEntry] = []
        self._cq: Deque[CompletionEntry] = collections.deque()

    def prep(self, op: str, *args, user_data: Any = None, flags: int = 0,
             **kwargs) -> None:
        """Stage one submission; auto-submits a full queue. Pass
        ``flags=SQE_LINK`` to chain the NEXT prepped entry onto this one;
        auto-submit is deferred while a chain is open (a link must never be
        severed by a batch boundary — an explicit ``submit`` mid-chain,
        like io_uring's, ends the chain at the boundary instead)."""
        self.prep_entry(SubmissionEntry(op, args, kwargs or None, user_data,
                                        flags))

    def prep_entry(self, entry: SubmissionEntry) -> None:
        """Stage a pre-built entry (callers that assemble entries
        directly, e.g. the PosixView batched forms); same auto-submit and
        chain-deferral rules as ``prep``."""
        if self.submitter is not None and entry.submitter is None:
            entry.submitter = self.submitter
        self._sq.append(entry)
        if len(self._sq) >= self.depth and not (entry.flags & SQE_LINK):
            self.submit()

    def stage(self, entries: Iterable[SubmissionEntry]) -> None:
        """Stage many pre-built entries WITHOUT auto-submitting: the
        caller owns the submit boundary (a batch that must cross the
        boundary whole stages here and calls ``submit`` once)."""
        if self.submitter is None:
            self._sq.extend(entries)
            return
        for e in entries:
            if e.submitter is None:
                e.submitter = self.submitter
            self._sq.append(e)

    def submit(self) -> int:
        """Submit everything staged (one gate-crossing); returns the number
        of completions now waiting."""
        if self._sq:
            batch, self._sq = self._sq, []
            self._cq.extend(self.mount.submit(batch))
        return len(self._cq)

    def drain(self) -> List[CompletionEntry]:
        """Take all waiting completions (submission order)."""
        out = list(self._cq)
        self._cq.clear()
        return out

    def __len__(self) -> int:
        return len(self._sq)


class SubmitterQueue(BentoQueue):
    """A per-thread submission queue, io_uring SQPOLL-style: ``submit()``
    publishes the staged entries as ONE submission to the mount's shared
    drain, where whichever thread holds the drainer role carries them
    across the boundary — under contention many submitters' queues cross
    in one gate crossing (see ``Mount.submit``).

    Thread-affine by construction: obtain one per thread via
    ``Mount.submitter_queue()`` (or construct directly); never share an
    instance across threads — the mount underneath is the shared,
    thread-safe object. ``submits``/``entries_submitted`` count what this
    submitter pushed, pairing with the mount's ``mq_drains`` to show the
    coalescing ratio."""

    def __init__(self, mount, depth: int = 256,
                 submitter: Optional[str] = None):
        self.owner_tid = threading.get_ident()
        # default identity: the OWNING thread, fixed at construction — the
        # real submitter even when another thread's drain executes the work
        super().__init__(mount, depth,
                         submitter or f"tid:{self.owner_tid}")
        self.submits = 0
        self.entries_submitted = 0

    def submit(self) -> int:
        if self._sq:
            self.submits += 1
            self.entries_submitted += len(self._sq)
        return super().submit()


def mount(name: str, services, module: Optional[BentoFilesystem] = None) -> Mount:
    if module is None:
        factory = _FS_REGISTRY.get(name)
        if factory is None:
            raise KeyError(f"no registered bento fs {name!r}")
        module = factory()
    return Mount(name, module, services)
