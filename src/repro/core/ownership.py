"""Ownership / borrow model across the extension boundary (paper §4.4).

Contract: ownership of an object never crosses the interface; callers lend
(mutably XOR shared) and the callee may only touch the object inside the
borrow window. Rust proves this at compile time; here the runtime tracks
borrows and raises on violations, and hypothesis property tests fuzz the
contract (tests/test_core_contracts.py).

jax.Arrays are immutable, so sharing them across the boundary is always a
free "shared borrow" — the model/optimizer side of the framework satisfies
the ownership model by construction. The guards below exist for *host-side*
mutable objects: buffer-cache blocks, journal state, caches.
"""

from __future__ import annotations

import threading
from typing import Any, Optional


class BorrowError(Exception):
    pass


class Owned:
    """An object owned by one side of the boundary; lendable, never given."""

    __slots__ = ("_value", "_shared", "_mut", "_lock", "name")

    def __init__(self, value: Any, name: str = "object"):
        self._value = value
        self._shared = 0
        self._mut = False
        self._lock = threading.Lock()
        self.name = name

    # --- lending --------------------------------------------------------------
    def borrow(self) -> "Borrow":
        with self._lock:
            if self._mut:
                raise BorrowError(f"{self.name}: shared borrow while mutably lent")
            self._shared += 1
        return Borrow(self, mutable=False)

    def borrow_mut(self) -> "Borrow":
        with self._lock:
            if self._mut or self._shared:
                raise BorrowError(
                    f"{self.name}: mutable borrow requires exclusivity "
                    f"(shared={self._shared}, mut={self._mut})")
            self._mut = True
        return Borrow(self, mutable=True)

    def _release(self, mutable: bool) -> None:
        with self._lock:
            if mutable:
                self._mut = False
            else:
                self._shared -= 1

    @property
    def is_lent(self) -> bool:
        with self._lock:
            return self._mut or self._shared > 0

    def take(self) -> Any:
        """Owner-side: reclaim the value; fails while lent (paper §3.2.1 —
        the upgrade path must wait for all borrows to return)."""
        with self._lock:
            if self._mut or self._shared:
                raise BorrowError(f"{self.name}: cannot take while lent")
            return self._value


class Borrow:
    """A borrow window; use as a context manager. Access outside the window
    (use-after-return — the C analogue of a dangling pointer) raises."""

    __slots__ = ("_owner", "_mutable", "_open")

    def __init__(self, owner: Owned, mutable: bool):
        self._owner = owner
        self._mutable = mutable
        self._open = True

    @property
    def mutable(self) -> bool:
        return self._mutable

    def get(self) -> Any:
        if not self._open:
            raise BorrowError(f"{self._owner.name}: access after borrow ended")
        return self._owner._value

    def set(self, value: Any) -> None:
        if not self._open:
            raise BorrowError(f"{self._owner.name}: access after borrow ended")
        if not self._mutable:
            raise BorrowError(f"{self._owner.name}: write through shared borrow")
        self._owner._value = value

    def end(self) -> None:
        if self._open:
            self._open = False
            self._owner._release(self._mutable)

    def __enter__(self) -> "Borrow":
        return self

    def __exit__(self, *exc) -> None:
        self.end()

    def __del__(self):  # leak detector: a GC'd open borrow is a missing brelse
        if getattr(self, "_open", False):
            self.end()
