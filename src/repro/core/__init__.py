# Bento core: the paper's primary contribution, adapted to a JAX runtime.
#
#   interface.py  — typed module boundary (file-operations API, §4.3/4.4)
#   capability.py — unforgeable service handles (§4.6)
#   ownership.py  — borrow guards for host-side mutable state (§4.4)
#   services.py   — kernel services API, two bindings (§4.5, §4.9)
#   registry.py   — module registration + mount dispatch table (§4.2, §5.2)
#   upgrade.py    — online upgrade: quiesce/extract/migrate/restore (§4.8)

from repro.core.capability import (BlockDeviceCap, Capability, CapabilityError,
                                   MeshCap, MetricsCap, RngCap, SuperBlockCap)
from repro.core.interface import (Attr, BATCHABLE_OPS, BentoFilesystem,
                                  BentoModule, CompletionEntry, Errno,
                                  FileKind, FsError, ROOT_INO, SubmissionEntry)
from repro.core.ownership import Borrow, BorrowError, Owned
from repro.core.registry import (BentoQueue, Mount, OpGate, mount,
                                 register_bento)
from repro.core.upgrade import UpgradeError, transfer_state, upgrade

__all__ = [
    "Attr", "BATCHABLE_OPS", "BentoFilesystem", "BentoModule", "BentoQueue",
    "BlockDeviceCap", "Borrow", "BorrowError", "Capability", "CapabilityError",
    "CompletionEntry", "Errno", "FileKind", "FsError", "MeshCap", "MetricsCap",
    "Mount", "OpGate", "ROOT_INO", "RngCap", "SubmissionEntry",
    "SuperBlockCap", "UpgradeError", "mount", "register_bento",
    "transfer_state", "upgrade",
]
