"""Kernel Services API (BentoKS, paper §4.5–4.7).

Extensions never touch raw devices or kernel structures; they call these
methods with capability proof. Two bindings expose the SAME API (paper §4.9
— same code in kernel and userspace):

* ``kernel_binding``   — host-memory device, Pallas-crc32c checksums
                         (TPU-path checksum; interpret-mode on CPU),
* ``userspace_binding`` — file-backed device, zlib crc32.

Swap the binding, not the file system.
"""

from __future__ import annotations

import threading
import time as _time
import zlib
from typing import Callable, List, Optional

from repro.core.capability import (BlockDeviceCap, CapabilityError,
                                   SuperBlockCap, mint_blockdev,
                                   mint_superblock)
from repro.fs.blockdev import BlockDevice
from repro.fs.buffercache import BufferCache, BufferHead


class _SbState:
    """Kernel-side superblock object wrapped by SuperBlockCap."""

    def __init__(self, dev: BlockDevice, cache: BufferCache):
        self.block_size = dev.block_size
        self.n_blocks = dev.n_blocks
        self.device_id = dev.device_id
        self.cache = cache


class KernelServices:
    """What a Bento file system may do to the kernel."""

    def __init__(self, dev: BlockDevice, *, checksum: Callable[[bytes], int],
                 checksum_batch: Optional[Callable] = None,
                 writeback: str = "delayed", cache_capacity: int = 4096,
                 binding: str = "kernel"):
        self._dev = dev
        self.binding = binding
        self._cache = BufferCache(dev, capacity=cache_capacity,
                                  writeback=writeback)
        self._sb_state = _SbState(dev, self._cache)
        self._checksum = checksum
        self._checksum_batch = checksum_batch
        self._log: List[str] = []
        # Batching observability: the fs_micro --batched acceptance check
        # reads these (one checksum_batch launch per flushed batch, bulk
        # bread instead of per-block bread).
        self.counters = {"checksum_calls": 0, "checksum_batch_calls": 0,
                         "checksum_blocks": 0, "bread_many_calls": 0,
                         "bread_many_blocks": 0}
        # counter increments are read-modify-writes; concurrent read units
        # (parallel multi-submitter drain) share them
        self._counter_lock = threading.Lock()

    # --- capabilities ---------------------------------------------------------------
    def superblock(self) -> SuperBlockCap:
        return mint_superblock(self._sb_state)

    def blockdev_cap(self) -> BlockDeviceCap:
        return mint_blockdev(self._dev)

    @staticmethod
    def _cache_of(sb: SuperBlockCap) -> BufferCache:
        if not isinstance(sb, SuperBlockCap):
            raise CapabilityError("sb_bread requires a SuperBlockCap")
        return sb._raw().cache

    # --- block I/O (the sb_bread family, §4.5) -----------------------------------------
    def sb_bread(self, sb: SuperBlockCap, blockno: int) -> BufferHead:
        return self._cache_of(sb).bread(blockno)

    def sb_bread_many(self, sb: SuperBlockCap, blocknos,
                      fetched=None) -> List[BufferHead]:
        """Batched sb_bread: one cache pass for a whole submission batch.
        Heads come back in request order; each must still be released
        (brelse / context exit) — ownership rules are per-buffer.
        ``fetched`` collects device-fetched blocknos for verified reads."""
        blocknos = list(blocknos)
        with self._counter_lock:
            self.counters["bread_many_calls"] += 1
            self.counters["bread_many_blocks"] += len(blocknos)
        return self._cache_of(sb).bread_many(blocknos, fetched=fetched)

    def sb_brelse_many(self, sb: SuperBlockCap,
                       heads: List[BufferHead]) -> None:
        """Batched brelse: release a bread_many batch's heads under one
        cache-lock acquisition instead of one per head."""
        self._cache_of(sb).brelse_many(heads)

    def sb_getblk_zero(self, sb: SuperBlockCap, blockno: int) -> BufferHead:
        return self._cache_of(sb).getblk_zero(blockno)

    def bwrite_sync(self, sb: SuperBlockCap, bh: BufferHead) -> None:
        self._cache_of(sb).write_now(bh)

    def flush(self, sb: SuperBlockCap, blocknos: Optional[List[int]] = None) -> int:
        """Batched writeback — the `writepages` analogue."""
        return self._cache_of(sb).flush(blocknos)

    def n_dirty(self, sb: SuperBlockCap) -> int:
        return self._cache_of(sb).n_dirty

    def sb_invalidate_blocks(self, sb: SuperBlockCap, blocknos) -> None:
        """Drop specific cached blocks (no writeback) so the next read
        refetches the device — the journal's chain-member rollback path."""
        self._cache_of(sb).invalidate_blocks(blocknos)

    # --- misc services -----------------------------------------------------------------
    def create_lock(self) -> threading.RLock:
        return threading.RLock()

    def checksum(self, data: bytes) -> int:
        with self._counter_lock:
            self.counters["checksum_calls"] += 1
        return self._checksum(data)

    def checksum_batch(self, blocks) -> List[int]:
        """Checksum many blocks in one call — the journal commit path uses
        this so the Pallas kernel launches once per transaction, not once
        per block."""
        blocks = list(blocks)
        with self._counter_lock:
            self.counters["checksum_batch_calls"] += 1
            self.counters["checksum_blocks"] += len(blocks)
        if self._checksum_batch is not None:
            return self._checksum_batch(blocks)
        return [self._checksum(b) for b in blocks]

    def time(self) -> float:
        return _time.time()

    def log_warn(self, msg: str) -> None:
        self._log.append(msg)

    # --- teardown ----------------------------------------------------------------------
    def unmount_checks(self) -> None:
        self._cache.flush()
        self._cache.assert_no_leaks()


def _crc32_zlib(data: bytes) -> int:
    return zlib.crc32(data) & 0xFFFFFFFF


def _blockhash_pallas(data: bytes) -> int:
    from repro.kernels.blockhash import ops as bh_ops

    return bh_ops.checksum(data)


def kernel_binding(dev: BlockDevice, **kw) -> KernelServices:
    """Kernel-mode services: Pallas blockhash checksums on TPU (interpret
    mode is a correctness harness, not a perf path — on CPU the host crc is
    used unless REPRO_FORCE_PALLAS_CHECKSUM=1, which tests set)."""
    import os

    import jax

    use_pallas = (jax.default_backend() == "tpu"
                  or os.environ.get("REPRO_FORCE_PALLAS_CHECKSUM") == "1")
    cks, cks_b = _crc32_zlib, None
    if use_pallas:
        try:
            from repro.kernels.blockhash import ops as bh_ops
            bh_ops.checksum(b"probe")  # probe at bind time, not commit time
            cks, cks_b = _blockhash_pallas, bh_ops.checksum_batch
        except Exception:  # kernels unavailable/broken — fall back
            pass
    return KernelServices(dev, checksum=cks, checksum_batch=cks_b,
                          binding="kernel", **kw)


def userspace_binding(dev: BlockDevice, **kw) -> KernelServices:
    return KernelServices(dev, checksum=_crc32_zlib, binding="userspace", **kw)
