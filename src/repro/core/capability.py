"""Capability types (paper §4.6).

The kernel operates on raw handles; exposing them directly to extensions
would void every safety property. Instead the runtime ("kernel") mints
*capability types* — unforgeable wrappers whose possession is proof of
access. Extensions cannot construct them (private mint token), cannot cast
them, and can only reach the underlying resource through the methods the
capability exposes.

In Rust this is a compile-time guarantee; in Python we enforce it at
runtime (mint-token check in ``__init__``) and under test (the capability
contract suite in tests/test_core_contracts.py). The *architecture* — what
may cross the boundary — matches the paper exactly.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

_MINT = object()  # private mint token — only this module can create capabilities


class CapabilityError(Exception):
    """An extension tried to forge, copy or misuse a capability."""


class Capability:
    """Base: unforgeable handle around a kernel object."""

    __slots__ = ("_obj", "_revoked")

    def __init__(self, obj: Any, _token: Any = None):
        if _token is not _MINT:
            raise CapabilityError(
                f"{type(self).__name__} cannot be constructed by extensions; "
                "it is minted by the runtime only")
        self._obj = obj
        self._revoked = False

    # -- runtime-side API ------------------------------------------------------
    @classmethod
    def _mint(cls, obj: Any, *args, **kw) -> "Capability":
        return cls(obj, *args, _token=_MINT, **kw)

    def _revoke(self) -> None:
        """Kernel-side: invalidate (used during online upgrade quiesce)."""
        self._revoked = True

    def _raw(self) -> Any:
        """Kernel-side only: unwrap. Named with underscore; extensions using
        it are violating the contract (checked in review/tests, as unsafe
        blocks are in Rust)."""
        self._check()
        return self._obj

    def _check(self) -> None:
        if self._revoked:
            raise CapabilityError(
                f"{type(self).__name__} used after revocation (stale handle "
                "across an upgrade or unmount)")

    def __reduce__(self):  # capabilities must not be serialized/smuggled
        raise CapabilityError("capabilities cannot be pickled")

    def __deepcopy__(self, memo):
        raise CapabilityError("capabilities cannot be copied")


class SuperBlockCap(Capability):
    """Proof of access to a mounted file system's superblock (§4.6).

    Exposes exactly what a file system needs: geometry reads and block I/O
    through the buffer cache (``sb_bread`` analogue lives on the kernel
    services API, which requires this capability as proof).
    """

    @property
    def block_size(self) -> int:
        self._check()
        return self._obj.block_size

    @property
    def n_blocks(self) -> int:
        self._check()
        return self._obj.n_blocks

    @property
    def device_id(self) -> str:
        self._check()
        return self._obj.device_id


class BlockDeviceCap(Capability):
    """Raw device grant (mkfs and the journal need it)."""

    @property
    def n_blocks(self) -> int:
        self._check()
        return self._obj.n_blocks

    @property
    def block_size(self) -> int:
        self._check()
        return self._obj.block_size


class MeshCap(Capability):
    """Grant of the device mesh to distributed extensions (trainer modules).

    Extensions may *read* topology and build shardings; they may not
    re-initialize the runtime or grab raw devices.
    """

    @property
    def axis_names(self):
        self._check()
        return tuple(self._obj.axis_names)

    @property
    def shape(self):
        self._check()
        return tuple(self._obj.devices.shape)

    def sharding_ctx(self, ruleset: str = "baseline"):
        self._check()
        from repro.distributed.sharding import ShardingCtx
        return ShardingCtx.for_mesh(self._obj, ruleset)


class RngCap(Capability):
    """Deterministic RNG stream grant (extensions cannot reseed globally)."""

    def next_key(self):
        self._check()
        import jax
        key, sub = jax.random.split(self._obj["key"])
        self._obj["key"] = key
        return sub


class MetricsCap(Capability):
    """Append-only metrics channel (extensions cannot read others' metrics)."""

    def emit(self, name: str, value: float, step: Optional[int] = None) -> None:
        self._check()
        self._obj.append((name, float(value), step))


def mint_superblock(state) -> SuperBlockCap:
    return SuperBlockCap._mint(state)


def mint_blockdev(dev) -> BlockDeviceCap:
    return BlockDeviceCap._mint(dev)


def mint_mesh(mesh) -> MeshCap:
    return MeshCap._mint(mesh)


def mint_rng(seed: int) -> RngCap:
    import jax
    return RngCap._mint({"key": jax.random.PRNGKey(seed)})


def mint_metrics(sink: list) -> MetricsCap:
    return MetricsCap._mint(sink)
