"""Trainer supervisor: the Bento upgrade protocol applied to training.

One quiesce->extract->restore protocol (core.upgrade) gives four
fault-tolerance features:

  * checkpoint/restart  — extract -> serialize through the Bento FS,
  * failure recovery    — supervisor catches worker failures (injected in
                          tests via ``failure_hook``), restores the last
                          checkpoint and replays deterministically,
  * elastic rescale     — extract -> re-jit for a new mesh -> device_put
                          with the new shardings -> resume,
  * online upgrade      — swap the model/optimizer module version mid-run
                          with state migration (examples/online_upgrade_demo).
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.core.interface import BentoModule
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.distributed.sharding import ShardingCtx
from repro.models import lm, params as P
from repro.optim.adamw import adamw_init_specs
from repro.train.step import make_train_step
from repro import checkpoint as ckpt


class WorkerFailure(Exception):
    """Simulated node loss (tests inject it via failure_hook)."""


class Trainer(BentoModule):
    NAME = "trainer"
    VERSION = 1

    def __init__(self, cfg: ModelConfig, run: RunConfig, *, global_batch: int,
                 seq_len: int, mesh=None, ruleset: str = "baseline",
                 seed: int = 0, ckpt_view=None, ckpt_root: str = "/ckpt",
                 ckpt_every: int = 0, ckpt_pipeline_depth: Optional[int] = None,
                 failure_hook: Optional[Callable[[int], None]] = None,
                 data=None):
        self.cfg, self.run = cfg, run
        self.global_batch, self.seq_len = global_batch, seq_len
        self.seed = seed
        self.ckpt_view, self.ckpt_root, self.ckpt_every = ckpt_view, ckpt_root, ckpt_every
        # None defers to the checkpoint store's default/env knob; 0 pins
        # the serial reference engine (restores stay byte-identical)
        self.ckpt_pipeline_depth = ckpt_pipeline_depth
        self.failure_hook = failure_hook
        self.metrics_log: list = []
        self.recoveries = 0
        self.data = data or SyntheticLM(cfg, global_batch, seq_len, seed=seed)
        self._build(mesh, ruleset)
        self._init_state()
        self.step_idx = 0
        self.last_restore_stats: Dict[str, Any] = {}
        self._prefetch: Optional[Prefetcher] = None

    # --- build / init -----------------------------------------------------------
    def _build(self, mesh, ruleset: str) -> None:
        self.mesh = mesh
        self.ruleset = ruleset
        self.ctx = (ShardingCtx.for_mesh(mesh, ruleset) if mesh is not None
                    else ShardingCtx.null())
        self.pspecs = lm.param_specs(self.cfg)
        self.ospecs = adamw_init_specs(self.pspecs, self.run)
        fn = make_train_step(self.cfg, self.run, self.ctx, self.global_batch)
        if mesh is not None:
            from repro.launch.programs import _ns_tree
            self.param_shardings = _ns_tree(self.pspecs, self.ctx)
            self.opt_shardings = _ns_tree(self.ospecs, self.ctx)
            self._step_fn = jax.jit(
                fn, out_shardings=(self.param_shardings, self.opt_shardings, None),
                donate_argnums=(0, 1))
        else:
            self.param_shardings = self.opt_shardings = None
            self._step_fn = jax.jit(fn, donate_argnums=(0, 1))

    def _init_state(self) -> None:
        rng = jax.random.PRNGKey(self.seed)
        self.params = P.materialize(self.pspecs, rng, dtype=self.run.param_dtype)
        self.opt_state = P.materialize(self.ospecs, rng, dtype="float32")
        if self.param_shardings is not None:
            self.params = jax.device_put(self.params, self.param_shardings)
            self.opt_state = jax.device_put(self.opt_state, self.opt_shardings)

    # --- stepping ------------------------------------------------------------------
    def _fetch(self, step: int) -> Dict[str, np.ndarray]:
        return self.data.batch(step)

    def train(self, n_steps: int) -> Dict[str, float]:
        """Supervised loop with recovery; returns final metrics."""
        last = {}
        self._prefetch = Prefetcher(self._fetch, start_step=self.step_idx)
        try:
            while self.step_idx < n_steps:
                try:
                    if self.failure_hook is not None:
                        self.failure_hook(self.step_idx)
                    sidx, batch = self._prefetch.next()
                    assert sidx == self.step_idx, (sidx, self.step_idx)
                    last = self.run_step(batch)
                    if (self.ckpt_every and self.ckpt_view is not None
                            and self.step_idx % self.ckpt_every == 0):
                        self.save_checkpoint()
                except WorkerFailure:
                    self.recoveries += 1
                    self.recover()
        finally:
            if self._prefetch:
                self._prefetch.close()
                self._prefetch = None
        return last

    def run_step(self, batch: Dict[str, np.ndarray]) -> Dict[str, float]:
        self.params, self.opt_state, metrics = self._step_fn(
            self.params, self.opt_state, batch)
        m = {k: float(v) for k, v in metrics.items()}
        m["step"] = self.step_idx
        self.metrics_log.append(m)
        self.step_idx += 1
        return m

    # --- §4.8 state transfer ------------------------------------------------------------
    def extract_state(self) -> Dict[str, Any]:
        return {
            "params": jax.device_get(self.params),
            "opt_state": jax.device_get(self.opt_state),
            "step": self.step_idx,
            "seed": self.seed,
        }

    def restore_state(self, state: Dict[str, Any], from_version: int = 1) -> None:
        params, opt = state["params"], state["opt_state"]
        if self.param_shardings is not None:
            params = jax.device_put(params, self.param_shardings)
            opt = jax.device_put(opt, self.opt_shardings)
        self.params, self.opt_state = params, opt
        self.step_idx = state["step"]
        new_seed = state.get("seed", self.seed)
        if new_seed != self.seed and isinstance(self.data, SyntheticLM):
            self.data = SyntheticLM(self.cfg, self.global_batch, self.seq_len,
                                    seed=new_seed)
        self.seed = new_seed

    def state_schema(self):
        return ("params", "opt_state", "step", "seed")

    # --- checkpoint / recovery -------------------------------------------------------------
    def _ckpt_shardings(self):
        if self.param_shardings is None:
            return None
        return {"params": self.param_shardings, "opt": self.opt_shardings}

    def save_checkpoint(self) -> None:
        """Shard-per-file v2 save: the live shardings become the stored
        shard grid, so a restart on a different mesh reshards on restore
        instead of gathering full tensors."""
        assert self.ckpt_view is not None
        root = f"{self.ckpt_root}/step_{self.step_idx:08d}"
        extra = None
        if self.mesh is not None:
            from repro.launch.mesh import mesh_axis_sizes
            extra = {"mesh_axes": mesh_axis_sizes(self.mesh),
                     "ruleset": self.ruleset}
        ckpt.save(self.ckpt_view, root,
                  {"params": self.params, "opt": self.opt_state},
                  step=self.step_idx, shardings=self._ckpt_shardings(),
                  extra=extra, pipeline_depth=self.ckpt_pipeline_depth)

    def restore_checkpoint(self, step: Optional[int] = None) -> bool:
        assert self.ckpt_view is not None
        if step is None:
            step = ckpt.latest_step(self.ckpt_view, self.ckpt_root)
        if step is None:
            return False
        root = f"{self.ckpt_root}/step_{step:08d}"
        like = {"params": self.params, "opt": self.opt_state}
        self.last_restore_stats = {}
        tree, _mf = ckpt.load(
            self.ckpt_view, root, like,
            sharding_tree=self._ckpt_shardings(),
            stats=self.last_restore_stats,
            pipeline_depth=self.ckpt_pipeline_depth)
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step_idx = step
        # job-restart latency is the fleet-scale payoff: report how much
        # of the restore's fetch work the pipeline hid behind assembly
        pipe = self.last_restore_stats.get("pipeline", {})
        self.last_restore_stats["overlap_ratio"] = \
            pipe.get("overlap_ratio", 0.0)
        return True

    def recover(self) -> None:
        """Node-failure path: restore last durable state and replay."""
        if self._prefetch:
            self._prefetch.close()
        if self.ckpt_view is not None and self.restore_checkpoint():
            pass  # restored from FS
        else:
            self._init_state()  # cold restart
            self.step_idx = 0
        self._prefetch = Prefetcher(self._fetch, start_step=self.step_idx)

    # --- elastic rescale ----------------------------------------------------------------------
    def elastic_rescale(self, new_mesh, ruleset: str = "baseline") -> None:
        """Quiesce -> extract -> rebuild for the new mesh -> restore."""
        state = self.extract_state()
        if self._prefetch:
            self._prefetch.close()
            self._prefetch = None
        self._build(new_mesh, ruleset)
        self.restore_state(state)
