"""Training step: grad accumulation (microbatch scan), clipping, AdamW.

``make_train_step`` closes over configs and the sharding context; the
returned function is pure and jit-able with in/out shardings supplied by the
launcher (ShapeDtypeStruct shardings in, PartitionSpec trees out).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.distributed.sharding import ShardingCtx
from repro.models import lm
from repro.optim.adamw import OptState, adamw_update


def data_parallel_size(ctx: ShardingCtx) -> int:
    if ctx.mesh is None:
        return 1
    sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    dp = sizes.get("data", 1)
    if "pod" in sizes:
        dp *= sizes["pod"]
    return dp


def num_accum_steps(run: RunConfig, ctx: ShardingCtx, global_batch: int) -> int:
    if run.microbatch_per_data_shard <= 0:
        return 1
    dp = data_parallel_size(ctx)
    micro_global = run.microbatch_per_data_shard * dp
    if global_batch % micro_global != 0:
        return 1
    return max(1, global_batch // micro_global)


def make_train_step(cfg: ModelConfig, run: RunConfig, ctx: ShardingCtx,
                    global_batch: int):
    n_accum = num_accum_steps(run, ctx, global_batch)
    accum_dt = jnp.dtype(run.grad_accum_dtype)
    compute_dt = jnp.dtype(run.compute_dtype)

    def loss_of(params, batch):
        return lm.loss_fn(cfg, run, ctx, params, batch)

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def _cast_params(params):
        # Mixed precision: one cast of the fp32 master BEFORE the microbatch
        # scan, so FSDP all-gathers inside the loop move bf16, not fp32, and
        # the cast itself is hoisted out of the accumulation loop.
        return jax.tree.map(
            lambda p: p.astype(compute_dt)
            if jnp.issubdtype(p.dtype, jnp.floating) and p.dtype != compute_dt
            else p, params)

    def train_step(params, opt_state: OptState, batch: Dict):
        params_c = _cast_params(params)
        if n_accum == 1:
            (loss, metrics), grads = grad_fn(params_c, batch)
        else:
            micro = {k: v.reshape((n_accum, v.shape[0] // n_accum) + v.shape[1:])
                     for k, v in batch.items() if v.ndim >= 1}

            def body(carry, mb):
                g_acc, loss_acc = carry
                (loss, _), g = grad_fn(params_c, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dt), g_acc, g)
                return (g_acc, loss_acc + loss.astype(jnp.float32)), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dt), params)
            (grads, loss_sum), _ = jax.lax.scan(body, (g0, jnp.float32(0.0)), micro)
            grads = jax.tree.map(lambda g: (g / n_accum), grads)
            loss = loss_sum / n_accum
            metrics = {"loss": loss}
        params, opt_state, stats = adamw_update(grads, params, opt_state, run)
        metrics = dict(metrics)
        metrics.update(stats)
        metrics = {k: v.astype(jnp.float32) for k, v in metrics.items()}
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, run: RunConfig, ctx: ShardingCtx):
    def eval_step(params, batch):
        loss, metrics = lm.loss_fn(cfg, run, ctx, params, batch)
        return {k: v.astype(jnp.float32) for k, v in metrics.items()}

    return eval_step
