import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # Dump the post-SPMD / pre-optimization HLO per compile: the CPU backend
    # then promotes bf16 compute to f32 (float-normalization), which would
    # double every collective/dot byte count vs what a TPU executes, so the
    # roofline is derived from this dtype-faithful snapshot instead of the
    # final CPU module (see EXPERIMENTS §Roofline-method).
    f"--xla_dump_to=/tmp/repro_spmd_dump_{os.getpid()} "
    "--xla_dump_hlo_pass_re=spmd-partitioning --xla_dump_hlo_as_text")
_SPMD_DUMP_DIR = f"/tmp/repro_spmd_dump_{os.getpid()}"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
512 placeholder host devices, prove memory fit, and extract roofline terms.

The two lines above MUST stay first: jax locks the device count on first
init, and only the dry-run wants 512 fake devices (tests/benches see 1).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b \
        --shape train_4k --mesh single --out results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all  # every cell, both meshes
"""

import argparse
import gzip
import json
import time
import traceback

import jax

from repro.configs import SHAPES_BY_NAME, registry
from repro.distributed.sharding import ShardingCtx
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.programs import build_program

# TPU v5e hardware model (per chip).
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link (1 active link assumed — conservative)


def run_cell(arch: str, shape_name: str, mesh_kind: str, ruleset: str,
             out_dir: str, smoke: bool = False, dump_hlo: str = "",
             run_overrides: dict | None = None) -> dict:
    bundle = registry.get(arch)
    shape = SHAPES_BY_NAME[shape_name]
    cell_id = f"{arch}__{shape_name}__{mesh_kind}__{ruleset}"
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "ruleset": ruleset, "ok": False}

    reason = bundle.skip_reason(shape_name)
    if reason:
        result.update(skipped=True, reason=reason, ok=True)
        _write(out_dir, cell_id, result)
        print(f"SKIP {cell_id}: {reason}")
        return result

    cfg = bundle.smoke if smoke else bundle.model
    run = bundle.run_for(shape_name).replace(sharding_rules=ruleset)
    if run_overrides:
        run = run.replace(**run_overrides)
        result["run_overrides"] = dict(run_overrides)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    ctx = ShardingCtx.for_mesh(mesh, ruleset)

    t0 = time.time()
    try:
        _clean_spmd_dump()
        prog = build_program(cfg, run, shape, ctx)
        with mesh:
            lowered = jax.jit(
                prog.fn,
                out_shardings=prog.out_shardings,
                donate_argnums=prog.donate_argnums,
            ).lower(*prog.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            print(mem)  # proves it fits
            cost = compiled.cost_analysis()
            print({k: cost[k] for k in ("flops", "bytes accessed")
                   if k in cost})
        text = compiled.as_text()
        spmd_text = _read_spmd_dump()
        summary = hlo_analysis.analyze(spmd_text if spmd_text else text)
        post_opt = hlo_analysis.analyze(text)
        if dump_hlo:
            os.makedirs(dump_hlo, exist_ok=True)
            with gzip.open(os.path.join(dump_hlo, cell_id + ".hlo.gz"), "wt") as f:
                f.write(text)
            if spmd_text:
                with gzip.open(os.path.join(dump_hlo, cell_id + ".spmd.hlo.gz"),
                               "wt") as f:
                    f.write(spmd_text)
        n_chips = mesh.devices.size
        arg_b = int(mem.argument_size_in_bytes)
        tmp_b = int(mem.temp_size_in_bytes)
        out_b = int(mem.output_size_in_bytes)
        alias_b = int(mem.alias_size_in_bytes)
        live_b = arg_b + tmp_b + out_b - alias_b
        terms = {
            "compute_s": summary.dot_flops / PEAK_FLOPS,
            "memory_s": summary.dot_bytes / HBM_BW,
            "collective_s": summary.collective_wire_bytes / ICI_BW,
        }
        result.update(
            ok=True,
            n_chips=n_chips,
            program=prog.name,
            meta=prog.meta,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            per_device_bytes={
                "arguments": arg_b, "temps": tmp_b, "outputs": out_b,
                "aliased": alias_b, "live_peak_est": live_b,
            },
            fits_16gb=bool(live_b <= 16 * 1024 ** 3),
            cost_analysis_raw={
                "flops": float(cost.get("flops", -1.0)),
                "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
            },
            hlo={
                "source": "after_spmd_partitioning" if spmd_text else "post_opt",
                "dot_flops": summary.dot_flops,
                "dot_bytes": summary.dot_bytes,
                "collective_wire_bytes": summary.collective_wire_bytes,
                "per_op": summary.per_op,
                "n_while": summary.n_while,
                "max_trip": summary.max_trip,
            },
            hlo_post_opt={
                "dot_flops": post_opt.dot_flops,
                "collective_wire_bytes": post_opt.collective_wire_bytes,
            },
            roofline_terms_s=terms,
            dominant=max(terms, key=terms.get),
        )
        print(f"OK {cell_id}: chips={n_chips} "
              f"live={live_b/2**30:.2f}GiB/dev "
              f"compute={terms['compute_s']*1e3:.2f}ms "
              f"memory={terms['memory_s']*1e3:.2f}ms "
              f"collective={terms['collective_s']*1e3:.2f}ms "
              f"[compile {t_compile:.0f}s]")
    except Exception as e:  # noqa: BLE001 — record the failure, it's a bug
        result.update(ok=False, error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
        print(f"FAIL {cell_id}: {type(e).__name__}: {str(e)[:400]}")
    _write(out_dir, cell_id, result)
    return result


def _clean_spmd_dump() -> None:
    if os.path.isdir(_SPMD_DUMP_DIR):
        for f in os.listdir(_SPMD_DUMP_DIR):
            try:
                os.unlink(os.path.join(_SPMD_DUMP_DIR, f))
            except OSError:
                pass


def _read_spmd_dump() -> str:
    """Newest after-spmd-partitioning snapshot from this cell's compile."""
    if not os.path.isdir(_SPMD_DUMP_DIR):
        return ""
    cands = [os.path.join(_SPMD_DUMP_DIR, f) for f in os.listdir(_SPMD_DUMP_DIR)
             if "after_spmd-partitioning" in f and f.endswith(".txt")]
    if not cands:
        return ""
    newest = max(cands, key=os.path.getmtime)
    with open(newest) as f:
        return f.read()


def _write(out_dir: str, cell_id: str, result: dict) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell_id + ".json"), "w") as f:
        json.dump(result, f, indent=1, default=float)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--ruleset", default="baseline")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CI fast path)")
    ap.add_argument("--dump-hlo", default="", help="dir for gzipped HLO text")
    ap.add_argument("--all", action="store_true", help="every cell, both meshes")
    ap.add_argument("--microbatch", type=int, default=-1,
                    help="override RunConfig.microbatch_per_data_shard")
    ap.add_argument("--scan-group", type=int, default=-1)
    ap.add_argument("--remat", default="")
    ap.add_argument("--moe-impl", default="")
    args = ap.parse_args()
    overrides = {}
    if args.microbatch >= 0:
        overrides["microbatch_per_data_shard"] = args.microbatch
    if args.scan_group >= 0:
        overrides["scan_group"] = args.scan_group
    if args.remat:
        overrides["remat"] = args.remat
    if args.moe_impl:
        overrides["moe_impl"] = args.moe_impl

    archs = registry.arch_ids() if args.arch in ("all",) or args.all else [args.arch]
    shapes = list(SHAPES_BY_NAME) if args.shape == "all" or args.all else [args.shape]
    meshes = ["single", "multi"] if (args.mesh == "both" or args.all) else [args.mesh]

    failures = 0
    for a in archs:
        for s in shapes:
            for m in meshes:
                r = run_cell(a, s, m, args.ruleset, args.out, smoke=args.smoke,
                             dump_hlo=args.dump_hlo, run_overrides=overrides)
                failures += 0 if r.get("ok") else 1
    if failures:
        raise SystemExit(f"{failures} dry-run cells FAILED")


if __name__ == "__main__":
    main()
