"""Program builders: assemble (fn, ShapeDtypeStruct args, out_shardings,
donate) per (arch x shape x mesh x ruleset) cell — shared by the dry-run,
the trainer and the server.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding

from repro.configs.base import ArchBundle, ModelConfig, RunConfig, ShapeSpec
from repro.distributed.sharding import ShardingCtx
from repro.models import lm, params as P
from repro.optim.adamw import adamw_init_specs
from repro.serve.step import make_decode_step, make_prefill_step
from repro.train.step import make_train_step


@dataclasses.dataclass
class Program:
    name: str
    fn: Callable
    args: Tuple[Any, ...]  # ShapeDtypeStruct pytrees (dry-run) — also the
    # template for materialization in real runs
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    meta: Dict[str, Any]


def _ns_tree(spec_tree, ctx: ShardingCtx):
    if ctx.mesh is None:
        return None
    return P.map_specs(lambda s: NamedSharding(ctx.mesh, ctx.spec(s.logical, s.shape)),
                       spec_tree)


def build_program(cfg: ModelConfig, run: RunConfig, shape: ShapeSpec,
                  ctx: ShardingCtx) -> Program:
    pspecs = lm.param_specs(cfg)
    batch_specs = lm.input_specs(cfg, shape)
    meta = {
        "arch": cfg.name, "shape": shape.name, "kind": shape.kind,
        "params": P.count_params(pspecs),
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
    }

    if shape.kind == "train":
        params_sds = P.shape_dtype_tree(pspecs, ctx, dtype=run.param_dtype)
        ospecs = adamw_init_specs(pspecs, run)
        opt_sds = P.shape_dtype_tree(ospecs, ctx, dtype="float32")
        batch_sds = P.shape_dtype_tree(batch_specs, ctx, dtype="int32")
        fn = make_train_step(cfg, run, ctx, shape.global_batch)
        out_shardings = (_ns_tree(pspecs, ctx), _ns_tree(ospecs, ctx), None)
        return Program("train_step", fn, (params_sds, opt_sds, batch_sds),
                       out_shardings, (0, 1), meta)

    # Serving: bf16 weights.
    params_sds = P.shape_dtype_tree(pspecs, ctx, dtype=run.compute_dtype)
    batch_sds = P.shape_dtype_tree(batch_specs, ctx, dtype="int32")
    if shape.kind == "prefill":
        fn = make_prefill_step(cfg, run, ctx)
        cache_specs = lm.cache_specs(cfg, shape)
        out_shardings = (None, _ns_tree(cache_specs, ctx))
        return Program("prefill_step", fn, (params_sds, batch_sds),
                       out_shardings, (), meta)

    assert shape.kind == "decode"
    cache_specs = lm.cache_specs(cfg, shape)
    cache_sds = P.shape_dtype_tree(cache_specs, ctx, dtype=run.compute_dtype)
    fn = make_decode_step(cfg, run, ctx)
    out_shardings = (None, _ns_tree(cache_specs, ctx))
    return Program("serve_step", fn, (params_sds, cache_sds, batch_sds),
                   out_shardings, (1,), meta)


def input_specs(cfg: ModelConfig, shape: ShapeSpec, ctx: ShardingCtx):
    """ShapeDtypeStruct stand-ins for every model input of this cell
    (weak-type-correct, shardable, no device allocation)."""
    return P.shape_dtype_tree(lm.input_specs(cfg, shape), ctx, dtype="int32")
