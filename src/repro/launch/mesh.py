"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state. Single pod: 16x16 = 256 chips
("data", "model"). Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model").
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=axis_types)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the host devices (tests/examples)."""
    n = data * model
    if len(jax.devices()) < n:
        raise RuntimeError(f"need {n} devices, have {len(jax.devices())}")
    axis_types = (jax.sharding.AxisType.Auto,) * 2
    return jax.make_mesh((data, model), ("data", "model"), axis_types=axis_types)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
