"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state. Single pod: 16x16 = 256 chips
("data", "model"). Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model").
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes, **kw):
    # jax.sharding.AxisType (and make_mesh's axis_types kwarg) only exist
    # on newer jax lines; Auto is already the default everywhere it does
    if hasattr(jax.sharding, "AxisType"):
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kw)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the host devices (tests/examples)."""
    n = data * model
    if len(jax.devices()) < n:
        raise RuntimeError(f"need {n} devices, have {len(jax.devices())}")
    return _make_mesh((data, model), ("data", "model"))


def make_elastic_mesh(data: int = 1, model: int = 1, *, devices=None):
    """Mesh over an explicit device PREFIX — the elastic-restore shapes.

    ``make_host_mesh`` spans every host device, so halved/doubled
    topologies of the same job can't coexist in one process; this builds
    ("data", "model") over ``devices`` (default: the first data*model
    host devices), which is how the reshard benchmark/tests stand up
    source and target meshes side by side."""
    n = data * model
    if devices is None:
        devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return _make_mesh((data, model), ("data", "model"),
                      devices=list(devices)[:n])


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
