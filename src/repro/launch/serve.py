"""Serving launcher: batched prefill + decode loop (greedy).

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import ShapeSpec
from repro.distributed.sharding import ShardingCtx
from repro.models import lm, params as P
from repro.serve.step import make_decode_step, make_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    bundle = registry.get(args.arch)
    cfg = bundle.smoke if args.smoke else bundle.model
    run = bundle.run
    ctx = ShardingCtx.null()

    rng = jax.random.PRNGKey(0)
    prm = P.materialize(lm.param_specs(cfg), rng, dtype=run.compute_dtype)
    max_len = args.prompt_len + args.gen

    batch = {"tokens": jax.random.randint(rng, (args.batch, args.prompt_len),
                                          0, cfg.vocab_size, jnp.int32)}
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.02 * jnp.ones(
            (args.batch, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frame_embeds"] = 0.02 * jnp.ones(
            (args.batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)

    # prefill emits a cache sized for the prompt; decode needs room for
    # generation -> pad the prompt-time cache up to max_len.
    prefill = jax.jit(make_prefill_step(cfg, run, ctx))
    decode = jax.jit(make_decode_step(cfg, run, ctx))

    t0 = time.time()
    tok, cache = prefill(prm, batch)

    def pad_seq(x):  # (..., S, H, D) -> room for generated tokens
        padw = [(0, 0)] * x.ndim
        padw[-3] = (0, args.gen)
        return jnp.pad(x, padw)

    ring = cfg.sliding_window > 0  # SWA ring buffer keeps its window size
    if not ring:
        if cfg.family in ("dense", "moe"):
            cache = {"k": pad_seq(cache["k"]), "v": pad_seq(cache["v"])}
        elif cfg.family == "vlm":
            cache = {"self": {"k": pad_seq(cache["self"]["k"]),
                              "v": pad_seq(cache["self"]["v"])},
                     "cross": cache["cross"]}
        elif cfg.family == "audio":
            cache = {"k": pad_seq(cache["k"]), "v": pad_seq(cache["v"]),
                     "ck": cache["ck"], "cv": cache["cv"]}
        elif cfg.family == "hybrid" and "attn" in cache:
            cache = {"mamba": cache["mamba"],
                     "attn": {"k": pad_seq(cache["attn"]["k"]),
                              "v": pad_seq(cache["attn"]["v"])}}
    t_prefill = time.time() - t0

    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.int32(args.prompt_len + i)
        tok, cache = decode(prm, cache, {"tokens": tok[:, None], "pos": pos})
        out_tokens.append(np.asarray(tok))
    t_decode = time.time() - t0
    gen = np.stack(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prefill={t_prefill*1e3:.0f}ms "
          f"decode={t_decode/max(args.gen-1,1)*1e3:.1f}ms/tok")
    print("generated token ids (first row):", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
