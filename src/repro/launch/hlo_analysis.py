"""Post-SPMD HLO text analysis for the roofline.

``jax``'s ``compiled.cost_analysis()`` counts while-loop bodies ONCE
regardless of trip count (scan-over-layers would be undercounted ~L times),
so we parse the optimized per-device HLO ourselves:

  * build the computation call graph (while bodies weighted by trip count,
    extracted from the loop-condition's comparison constant),
  * sum matmul FLOPs from ``dot`` instructions (2 * prod(out) * prod(contract)),
  * sum collective "wire bytes per chip" with ring-model factors per op type,
  * report weighted per-op-type counts — the collective schedule.

Caveats (documented in EXPERIMENTS §Roofline-method): conditional branches
are counted as always-taken (corrected analytically for zamba2's shared
block); elementwise FLOPs are ignored (matmul-dominated workloads); trip
count uses the largest s32 constant in the loop condition.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s+=\s+((?:\([^()]*\))|(?:\w+\[[^\]]*\](?:\{[^}]*\})?)|(?:\w+\[\]))\s+([\w\-]+)\(")
_COMP_NAME_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (tuples summed)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    symbols: Dict[str, str]  # instr name -> type string


def parse_computations(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.rstrip()
        if cur is None:
            # A computation header is any line ending in "{" with a "->"
            # result arrow (or the ENTRY computation). Tuple-typed parameter
            # lists contain nested parens, so match loosely on the name.
            if stripped.endswith("{") and ("->" in stripped
                                           or stripped.startswith("ENTRY")):
                m = _COMP_NAME_RE.match(stripped)
                if m:
                    cur = Computation(m.group(1), [], {})
                    if stripped.startswith("ENTRY"):
                        entry = cur.name
            continue
        if stripped.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        im = _INSTR_RE.match(stripped)
        if im:
            ins = Instr(im.group(1), im.group(2), im.group(3), stripped)
            cur.instrs.append(ins)
            cur.symbols[ins.name] = ins.type_str
        else:
            # parameter lines: "%p = f32[...] parameter(0)" match the same RE;
            # anything else (constants w/ values etc.) — try loose capture.
            lm = re.match(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s+=\s+(\S+)", stripped)
            if lm:
                cur.symbols[lm.group(1)] = lm.group(2)
    return comps, entry


_CALLEE_PATTERNS = [
    (re.compile(r"body=%?([\w.\-]+)"), "body"),
    (re.compile(r"condition=%?([\w.\-]+)"), "cond"),
    (re.compile(r"to_apply=%?([\w.\-]+)"), "call"),
    (re.compile(r"calls=%?([\w.\-]+)"), "call"),
    (re.compile(r"branch_computations=\{([^}]*)\}"), "branches"),
    (re.compile(r"true_computation=%?([\w.\-]+)"), "call"),
    (re.compile(r"false_computation=%?([\w.\-]+)"), "call"),
]

_CONST_RE = re.compile(r"constant\((\d+)\)")


def trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    best = 1
    for ins in comp.instrs:
        for m in _CONST_RE.finditer(ins.line):
            best = max(best, int(m.group(1)))
    # also look at raw symbol lines (constants parsed loosely)
    return best


def compute_multipliers(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    mult: Dict[str, float] = {entry: 1.0}
    order = [entry]
    seen = {entry}
    # BFS propagation (HLO call graphs are DAGs).
    idx = 0
    while idx < len(order):
        cname = order[idx]
        idx += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult.get(cname, 1.0)
        for ins in comp.instrs:
            callees: List[Tuple[str, float]] = []
            line = ins.line
            if ins.opcode == "while":
                bm = re.search(r"body=%?([\w.\-]+)", line)
                cm = re.search(r"condition=%?([\w.\-]+)", line)
                trips = trip_count(comps, cm.group(1)) if cm else 1
                if bm:
                    callees.append((bm.group(1), float(trips)))
                if cm:
                    callees.append((cm.group(1), float(trips)))
            else:
                for pat, kind in _CALLEE_PATTERNS[2:]:
                    for mm in pat.finditer(line):
                        if kind == "branches":
                            for nm in re.findall(r"%?([\w.\-]+)", mm.group(1)):
                                callees.append((nm, 1.0))
                        else:
                            callees.append((mm.group(1), 1.0))
            for callee, factor in callees:
                if callee not in comps:
                    continue
                mult[callee] = mult.get(callee, 0.0) + m * factor
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)
    return mult


_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _wire_bytes(opcode: str, out_bytes: int, in_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    if opcode.startswith("all-reduce"):
        return 2.0 * out_bytes * frac
    if opcode.startswith("all-gather"):
        return out_bytes * frac
    if opcode.startswith("reduce-scatter"):
        return (in_bytes if in_bytes else out_bytes * g) * frac
    if opcode.startswith("all-to-all"):
        return out_bytes * frac
    if opcode.startswith("collective-permute"):
        return float(out_bytes)
    return 0.0


_DOT_OPERANDS_RE = re.compile(r"dot\(%([\w.\-]+),\s*%([\w.\-]+)\)")
_RHS_CONTRACT_RE = re.compile(r"rhs_contracting_dims=\{([\d,]*)\}")


@dataclasses.dataclass
class HloSummary:
    dot_flops: float = 0.0
    dot_bytes: float = 0.0  # operand+output bytes of dots (HBM traffic proxy)
    collective_wire_bytes: float = 0.0
    collective_op_bytes: float = 0.0
    per_op: Dict[str, Dict[str, float]] = dataclasses.field(default_factory=dict)
    n_while: int = 0
    max_trip: int = 1

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def analyze(text: str) -> HloSummary:
    comps, entry = parse_computations(text)
    if entry is None:
        return HloSummary()
    mult = compute_multipliers(comps, entry)
    s = HloSummary()
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for ins in comp.instrs:
            if ins.opcode == "while":
                s.n_while += 1
                cm = re.search(r"condition=%?([\w.\-]+)", ins.line)
                if cm:
                    s.max_trip = max(s.max_trip, trip_count(comps, cm.group(1)))
            if ins.opcode == "dot":
                out_dims = shape_dims(ins.type_str)
                out_elems = 1
                for d in out_dims:
                    out_elems *= d
                contract = 1
                om = _DOT_OPERANDS_RE.search(ins.line)
                rc = _RHS_CONTRACT_RE.search(ins.line)
                op_bytes = shape_bytes(ins.type_str)
                if om and rc:
                    rhs_type = comp.symbols.get(om.group(2), "")
                    rdims = shape_dims(rhs_type)
                    for i in rc.group(1).split(","):
                        if i and int(i) < len(rdims):
                            contract *= rdims[int(i)]
                    lhs_type = comp.symbols.get(om.group(1), "")
                    op_bytes += shape_bytes(rhs_type) + shape_bytes(lhs_type)
                s.dot_flops += m * 2.0 * out_elems * contract
                s.dot_bytes += m * op_bytes
                continue
            base = next((c for c in COLLECTIVES if ins.opcode.startswith(c)), None)
            if base is None or ins.opcode.endswith("-done"):
                continue
            g = _group_size(ins.line)
            out_b = shape_bytes(ins.type_str)
            # best-effort operand resolve (reduce-scatter input size)
            in_b = 0
            oper = re.search(ins.opcode + r"\(%([\w.\-]+)", ins.line)
            if oper:
                in_b = shape_bytes(comp.symbols.get(oper.group(1), ""))
            wire = _wire_bytes(ins.opcode, out_b, in_b, g)
            s.collective_wire_bytes += m * wire
            s.collective_op_bytes += m * out_b
            d = s.per_op.setdefault(base, {"count": 0.0, "bytes": 0.0, "wire": 0.0})
            d["count"] += m
            d["bytes"] += m * out_b
            d["wire"] += m * wire
    return s
