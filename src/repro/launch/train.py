"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --smoke --steps 50 --batch 8 --seq 128 --ckpt-every 10

Full-config production runs use the same entry point with a real TPU mesh
(jax.distributed.initialize on the pod slice); on this CPU container the
smoke configs are the runnable path.
"""

from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs import registry
from repro.fs.mounts import make_mount
from repro.launch.mesh import make_host_mesh
from repro.train.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data-mesh", type=int, default=0,
                    help=">0: data-parallel ways over host devices")
    ap.add_argument("--ruleset", default="baseline")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args()

    bundle = registry.get(args.arch)
    cfg = bundle.smoke if args.smoke else bundle.model
    run = bundle.run.replace(microbatch_per_data_shard=0)
    mesh = make_host_mesh(args.data_mesh, 1) if args.data_mesh > 1 else None

    mf = None
    ckpt_view = None
    if args.ckpt_every:
        mf = make_mount("bento", n_blocks=65536)
        ckpt_view = mf.view

    t = Trainer(cfg, run, global_batch=args.batch, seq_len=args.seq,
                mesh=mesh, ruleset=args.ruleset,
                ckpt_view=ckpt_view, ckpt_every=args.ckpt_every)
    t0 = time.time()
    t.train(args.steps)
    wall = time.time() - t0
    first, last = t.metrics_log[0], t.metrics_log[-1]
    print(f"arch={cfg.name} steps={args.steps} wall={wall:.1f}s "
          f"loss {first['loss']:.4f} -> {last['loss']:.4f} "
          f"({args.steps * args.batch * args.seq / wall:.0f} tok/s)")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(t.metrics_log, f, indent=1)
    if mf is not None:
        mf.close()


if __name__ == "__main__":
    main()
