from repro.optim.adamw import (OptState, adamw_init_specs, adamw_update,
                               cosine_schedule)

__all__ = ["OptState", "adamw_init_specs", "adamw_update", "cosine_schedule"]
