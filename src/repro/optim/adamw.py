"""AdamW with dtype-configurable moments and Adafactor-style factored second
moment (needed to fit 405B-class optimizer state on 16 GB chips).

State layout mirrors the parameter pytree (so ZeRO-1 sharding falls out of
the same logical-axis rules), declared via TensorSpec like everything else.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.models import params as P


class OptState(NamedTuple):
    step: Any  # scalar int32
    mu: Any  # first moment (param-shaped tree)
    nu: Any  # second moment (param-shaped, or factored dict per leaf)
    master: Any = None  # optional fp32 master copy (RunConfig.master_weights)


def _factorable(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 8 and shape[-2] >= 8


def _nu_spec(spec: P.TensorSpec, run: RunConfig):
    if run.factored_second_moment and _factorable(spec.shape):
        row = P.TensorSpec(spec.shape[:-1], spec.logical[:-1], init="zeros",
                           dtype="float32")
        col = P.TensorSpec(spec.shape[:-2] + spec.shape[-1:],
                           spec.logical[:-2] + spec.logical[-1:], init="zeros",
                           dtype="float32")
        return {"_factored_row": row, "_factored_col": col}
    return P.TensorSpec(spec.shape, spec.logical, init="zeros",
                        dtype=run.moment_dtype)


def adamw_init_specs(param_specs, run: RunConfig) -> OptState:
    """Declarative optimizer-state specs mirroring the param specs."""
    mu = P.map_specs(
        lambda s: P.TensorSpec(s.shape, s.logical, init="zeros",
                               dtype=run.moment_dtype), param_specs)
    nu = P.map_specs(lambda s: _nu_spec(s, run), param_specs)
    step = P.TensorSpec((), (), init="zeros", dtype="int32")
    master = None
    if run.master_weights:
        master = P.map_specs(
            lambda s: P.TensorSpec(s.shape, s.logical, init=s.init,
                                   scale=s.scale, dtype="float32"), param_specs)
    return OptState(step=step, mu=mu, nu=nu, master=master)


def cosine_schedule(step, base_lr: float, warmup: int = 200, total: int = 10_000):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(warmup, 1), 1.0)
    progress = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    return base_lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * progress))


B1, B2, EPS = 0.9, 0.95, 1e-8


def _is_factored(nu_leaf) -> bool:
    return isinstance(nu_leaf, dict) and "_factored_row" in nu_leaf


def _update_leaf(g, p, mu, nu, lr, wd, step):
    g32 = g.astype(jnp.float32)
    mu_new = (B1 * mu.astype(jnp.float32) + (1 - B1) * g32)
    if _is_factored(nu):
        row = nu["_factored_row"].astype(jnp.float32)
        col = nu["_factored_col"].astype(jnp.float32)
        g2 = jnp.square(g32) + 1e-30
        row_new = B2 * row + (1 - B2) * jnp.mean(g2, axis=-1)
        col_new = B2 * col + (1 - B2) * jnp.mean(g2, axis=-2)
        r = row_new / jnp.maximum(jnp.mean(row_new, axis=-1, keepdims=True), 1e-30)
        v_hat = r[..., None] * col_new[..., None, :]
        nu_new = {"_factored_row": row_new, "_factored_col": col_new}
    else:
        nu32 = nu.astype(jnp.float32)
        nu_new_full = B2 * nu32 + (1 - B2) * jnp.square(g32)
        v_hat = nu_new_full
        nu_new = nu_new_full.astype(nu.dtype)
    # bias correction
    t = step.astype(jnp.float32) + 1.0
    mu_hat = mu_new / (1 - B1 ** t)
    v_corr = v_hat / (1 - B2 ** t)
    upd = mu_hat / (jnp.sqrt(v_corr) + EPS)
    p32 = p.astype(jnp.float32)
    if p.ndim >= 2:  # decoupled weight decay on matrices only
        upd = upd + wd * p32
    p_new = (p32 - lr * upd).astype(p.dtype)
    return p_new, mu_new.astype(mu.dtype), nu_new


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(grads, params, state: OptState, run: RunConfig):
    """One AdamW step with global-norm clipping. Returns (params, state, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, run.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if run.grad_clip > 0 else jnp.float32(1.0)
    grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
    lr = cosine_schedule(state.step, run.learning_rate)

    is_leaf = lambda x: _is_factored(x)
    flat_g, treedef = jax.tree.flatten(grads)
    # master_weights: the optimizer math runs on the fp32 master; the bf16
    # params are re-derived by casting (mixed-precision with master-in-optstate).
    src = state.master if state.master is not None else params
    flat_p = jax.tree.flatten(src)[0]
    flat_mu = jax.tree.flatten(state.mu)[0]
    flat_nu = jax.tree.flatten(state.nu, is_leaf=is_leaf)[0]
    out_p, out_mu, out_nu = [], [], []
    for g, p, mu, nu in zip(flat_g, flat_p, flat_mu, flat_nu):
        pn, mn, nn = _update_leaf(g, p, mu, nu, lr, run.weight_decay, state.step)
        out_p.append(pn)
        out_mu.append(mn)
        out_nu.append(nn)
    new_src = jax.tree.unflatten(treedef, out_p)
    if state.master is not None:
        master = new_src
        new_params = jax.tree.map(lambda m, p: m.astype(p.dtype), master, params)
    else:
        master = None
        new_params = new_src
    mu = jax.tree.unflatten(treedef, out_mu)
    nu_def = jax.tree.structure(state.nu, is_leaf=is_leaf)
    nu = jax.tree.unflatten(nu_def, out_nu)
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step=state.step + 1, mu=mu, nu=nu,
                                master=master), stats
