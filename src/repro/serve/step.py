"""Serving programs: prefill and single-token decode (greedy head)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.distributed.sharding import ShardingCtx
from repro.models import lm


def make_prefill_step(cfg: ModelConfig, run: RunConfig, ctx: ShardingCtx):
    def prefill_step(params, batch):
        logits, cache = lm.prefill_fn(cfg, run, ctx, params, batch)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, run: RunConfig, ctx: ShardingCtx):
    def decode_step(params, cache, batch):
        logits, cache = lm.decode_fn(cfg, run, ctx, params, cache, batch)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    return decode_step
