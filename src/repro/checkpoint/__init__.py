from repro.checkpoint.store import latest_step, load, save

__all__ = ["latest_step", "load", "save"]
