"""Checkpointing through the Bento file system — shard-native v2 format.

Pytrees serialize SHARD-PER-FILE with a JSON manifest carrying shapes,
dtypes, tree structure, the per-leaf shard grid (logical PartitionSpec +
mesh axis sizes) and per-shard checksums (the kernel-services hash —
Pallas blockhash in the kernel binding). Save/restore round-trips through
the journaled xv6/ext4like store, so checkpoint durability inherits the
journal's crash-atomicity (manifest written last = commit point), and the
grid makes the checkpoint topology-elastic: restore onto a DIFFERENT mesh
plans per-target-shard reads (repro.distributed.resharding) and executes
them as streamed offset reads over ``read_many``, re-slicing in flight —
a full leaf is never materialized on the restoring host.

v1 manifests (whole-leaf files, no shard records) keep loading through
the same machinery as a 1-shard grid. The same extract->serialize path
backs all four fault-tolerance features (upgrade / restart / elastic
reshard / failure recovery).
"""

from __future__ import annotations

import io
import json
from typing import Any, Dict, List, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.core.interface import Errno, FsError
from repro.distributed.resharding import (
    Index, ShardGrid, index_volume, normalize_index, plan_target_shard,
    plan_volume,
)
from repro.fs.posix import PosixView

MANIFEST = "manifest.json"
FORMAT_VERSION = 2

# Shards cross the boundary in bounded submission batches: one crossing per
# ~chunk instead of per file, without buffering the whole checkpoint
# (serialized bytes would otherwise double peak memory on save).
_BATCH_BYTES = 64 << 20
_BATCH_FILES = 64

# ml_dtypes that numpy serializes as void: stored as integer views instead.
_WIRE_DTYPES = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                "float8_e5m2": np.uint8}


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _flatten_shardings(tree) -> List:
    """Flatten a per-leaf sharding/grid tree. None entries mean "this leaf
    is unsharded" and must stay leaves, not collapse as empty subtrees."""
    return jax.tree.flatten(tree, is_leaf=lambda v: v is None)[0]


def _np_dtype(dtype_s: str) -> np.dtype:
    if dtype_s in _WIRE_DTYPES:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, dtype_s))
    return np.dtype(dtype_s)


def _serialize(arr: np.ndarray) -> bytes:
    # numpy can't serialize ml_dtypes (bf16 -> void): save a same-width
    # integer view and record the real dtype in the manifest.
    wire = arr.view(_WIRE_DTYPES[str(arr.dtype)]) \
        if str(arr.dtype) in _WIRE_DTYPES else arr
    if not wire.flags["C_CONTIGUOUS"]:  # ascontiguousarray promotes 0-d
        wire = np.ascontiguousarray(wire)
    buf = io.BytesIO()
    np.save(buf, wire)
    return buf.getvalue()


def _resolve_grid(shape, leaf, sharding) -> ShardGrid:
    """Per-leaf shard grid: an explicit ShardGrid (virtual grids — crash
    torture and single-device tests shard without devices), a
    NamedSharding, or the leaf's OWN sharding when none is given (a leaf
    already laid out across a mesh saves shard-per-device for free)."""
    if isinstance(sharding, ShardGrid):
        if sharding.shape != tuple(shape):
            raise ValueError(
                f"ShardGrid shape {sharding.shape} != leaf shape {shape}")
        grid = sharding
    elif isinstance(sharding, NamedSharding):
        grid = ShardGrid.from_sharding(shape, sharding)
    elif sharding is None and isinstance(leaf, jax.Array) \
            and isinstance(getattr(leaf, "sharding", None), NamedSharding):
        grid = ShardGrid.from_sharding(shape, leaf.sharding)
    else:
        grid = ShardGrid.trivial(shape)
    return grid if grid.n_shards > 1 else ShardGrid.trivial(shape)


def _shard_arrays(leaf, grid: ShardGrid):
    """Yield ``(j, shard ndarray)`` without materializing the full leaf
    when the leaf's device layout already matches the grid (the common
    save path); otherwise fall back to slicing a device_get'd copy."""
    if grid.n_shards == 1:
        yield 0, np.asarray(jax.device_get(leaf))
        return
    by_index = {}
    if isinstance(leaf, jax.Array):
        try:
            for sh in leaf.addressable_shards:
                by_index.setdefault(
                    normalize_index(sh.index, grid.shape), sh.data)
        except Exception:  # noqa: BLE001 — any layout oddity -> fallback
            by_index = {}
    full = None
    for j in range(grid.n_shards):
        idx = grid.index(j)
        data = by_index.get(idx)
        if data is not None:
            yield j, np.asarray(jax.device_get(data))
        else:
            if full is None:
                full = np.asarray(jax.device_get(leaf))
            yield j, np.ascontiguousarray(
                full[tuple(slice(lo, hi) for lo, hi in idx)])


def _first_leaf_names(root: str, gen: int):
    sfx = f"_g{gen}" if gen else ""
    # both naming lines: v1 whole-leaf files and v2 shard files — a
    # crashed attempt from either format must not be overwritten short
    return (f"{root}/leaf_00000{sfx}.npy", f"{root}/leaf_00000_s000{sfx}.npy")


def save(view: PosixView, root: str, tree, *, step: int,
         checksum=None, extra: Optional[Dict] = None,
         shardings=None) -> Dict:
    """Save ``tree`` shard-per-file. ``shardings``: optional pytree
    matching ``tree`` of NamedSharding | ShardGrid | None deciding each
    leaf's grid (default: the leaf's own device layout)."""
    view.makedirs(root)
    leaves, treedef = _flatten(tree)
    grids = None
    if shardings is not None:
        grids = _flatten_shardings(shardings)
        if len(grids) != len(leaves):
            raise ValueError(
                f"shardings tree has {len(grids)} leaves, model has "
                f"{len(leaves)} — incompatible trees")
    manifest_path = f"{root}/{MANIFEST}"
    # Re-saves bump a GENERATION tag baked into the shard names, so the new
    # files never overwrite the ones the LIVE manifest references — the
    # old checkpoint (manifest AND data) stays fully intact until the
    # manifest swap commits, and stale-generation shards are collected
    # after it. Without this, a crash mid-shard-write would tear the
    # previous good checkpoint's data under its still-live manifest.
    gen, old_exists = 0, view.exists(manifest_path)
    if old_exists:
        try:
            gen = int(json.loads(view.read_file(manifest_path))
                      .get("gen", 0)) + 1
        except (ValueError, FsError):
            gen = 1  # old manifest torn/unreadable: start a fresh line
    # whatever suggested the tag, probe past any shard names a CRASHED
    # attempt already occupies (its swap never committed, so the live
    # manifest still names the previous gen): fresh writes must never
    # land on a stale same-name file — a shorter overwrite would keep
    # the old tail, because write never truncates
    while leaves and any(view.exists(p)
                         for p in _first_leaf_names(root, gen)):
        gen += 1
    suffix = f"_g{gen}" if gen else ""
    manifest = {
        "version": FORMAT_VERSION,
        "step": step,
        "gen": gen,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "leaves": [],
        "extra": extra or {},
    }
    items, pending_bytes = [], 0
    for i, leaf in enumerate(leaves):
        if not (hasattr(leaf, "shape") and hasattr(leaf, "dtype")):
            leaf = np.asarray(leaf)  # python scalars
        shape = tuple(int(d) for d in leaf.shape)
        grid = _resolve_grid(shape, leaf, grids[i] if grids else None)
        rec = {"shape": list(shape), "dtype": str(leaf.dtype),
               "shards": []}
        rec.update(grid.to_manifest())
        for j, shard in _shard_arrays(leaf, grid):
            raw = _serialize(shard)
            path = f"{root}/leaf_{i:05d}_s{j:03d}{suffix}.npy"
            items.append((path, raw))
            pending_bytes += len(raw)
            rec["shards"].append({
                "path": path,
                "coords": list(grid.coords(j)),
                "index": [[lo, hi] for lo, hi in grid.index(j)],
                # payload position inside the .npy — lets restore stream
                # sub-shard slices as offset reads without parsing headers
                "data_off": len(raw) - shard.nbytes,
                "checksum": checksum(raw) if checksum else None,
            })
            if len(items) >= _BATCH_FILES or pending_bytes >= _BATCH_BYTES:
                view.write_many(items)
                items, pending_bytes = [], 0
        manifest["leaves"].append(rec)
    # The manifest is the commit point, enforced by the manifest's own
    # linked chain: shard batches (including the final one) are plain
    # batches — strict mode raises a failing write's real errno before the
    # manifest submission ever happens — and then the manifest's
    # create→write→flush CHAIN commits everything. Since the chain-aware
    # journal reservation landed, a chain is one bounded journal
    # transaction (crash-atomic, sized by capacity), so bulk shard data
    # must NOT be chained — only the small manifest chain is, and its
    # flush commits any still-pending shard blocks with it (one transaction
    # when they fit together; begin_chain pre-commits them first when they
    # don't, which is equally safe — they are invisible without the
    # manifest). A crash at any device write before that commit leaves no
    # manifest at all — the aborted save is invisible to latest_step;
    # after it, manifest AND every shard it names are durable together.
    #
    # Re-saves over an EXISTING checkpoint never touch the live manifest
    # (or, thanks to the generation tag, its shards): the new manifest is
    # committed under a tmp name, then swapped in with one journaled
    # rename-overwrite (+fsync to make the swap durable). The old
    # checkpoint stays fully intact until the rename transaction commits,
    # so the previous good one survives a crash at ANY device write of a
    # re-save — the old truncate-then-rewrite path had a window where
    # neither version did. Both properties are enumerated per crash point
    # by tests/test_crash_torture.py (v1 whole-leaf and v2 sharded saves).
    raw_manifest = json.dumps(manifest).encode()
    if items:
        view.write_many(items)
    try:
        if not old_exists:
            _commit_manifest(view, manifest_path, raw_manifest)
        else:
            tmp_path = f"{root}/.{MANIFEST}.tmp"
            try:
                if view.exists(tmp_path):  # stale tmp of a crashed re-save
                    view.unlink(tmp_path)
                _commit_manifest(view, tmp_path, raw_manifest)
                view.rename(tmp_path, manifest_path)
                view.fsync(manifest_path)  # commit the swap's journal txn
            except FsError:
                # failed re-save: drop the tmp husk — the OLD manifest is
                # still the live checkpoint, untouched
                try:
                    if view.exists(tmp_path):
                        view.unlink(tmp_path)
                except FsError:
                    pass
                raise
    except FsError:
        # a manifest created whose WRITE then failed is an empty husk —
        # remove it so the aborted save is indistinguishable from no save
        try:
            if view.exists(manifest_path) \
                    and view.stat(manifest_path).size == 0:
                view.unlink(manifest_path)
        except FsError:
            pass
        raise
    # the swap is durable: collect shard files the live manifest no longer
    # references (prior generations + orphans of crashed attempts). Pure
    # garbage collection — a crash skipping it just leaves dead files the
    # next successful save sweeps up.
    live = {s["path"].rsplit("/", 1)[-1]
            for rec in manifest["leaves"] for s in rec["shards"]}
    stale = [f"{root}/{name}" for name in view.listdir(root)
             if name.startswith("leaf_") and name not in live]
    if stale:
        try:
            view.unlink_many(stale, strict=False)
        except FsError:
            pass
    return manifest


def _commit_manifest(view: PosixView, path: str, raw: bytes) -> None:
    """Create ``path`` and make ``raw`` durable in it: a chained
    create→write→flush when it fits one journal transaction
    (crash-atomic), else the ENOSPC refusal falls back to an unchained
    write + fsync — a torn fresh file reads as "no checkpoint" (and for a
    re-save the tear hits only the TMP name, never the live manifest), and
    a genuinely full device just raises ENOSPC again here."""
    try:
        view.create_and_write_many([(path, raw)], fsync=True)
    except FsError as e:
        if e.errno != Errno.ENOSPC:
            raise
        view.write_file(path, raw)
        view.fsync(path)


# --- restore ----------------------------------------------------------------


def _leaf_name(rec: Dict) -> str:
    return rec["shards"][0]["path"].rsplit("/", 1)[-1]


def _normalize_rec(rec: Dict) -> Dict:
    """v1 whole-leaf records load through the v2 machinery as a 1-shard
    grid covering the full leaf."""
    if "shards" in rec:
        return rec
    shape = rec["shape"]
    return {"shape": shape, "dtype": rec["dtype"],
            "spec": [[] for _ in shape], "axes": {},
            "shards": [{"path": rec["path"], "coords": [0] * len(shape),
                        "index": [[0, int(d)] for d in shape],
                        "checksum": rec.get("checksum")}]}


def _validate_manifest(manifest: Dict, leaves_like, treedef) -> List[Dict]:
    """n_leaves + treedef + per-leaf dtype/shape against ``like_tree`` —
    an incompatible tree must fail loudly naming the first bad leaf, not
    silently unflatten into the wrong structure."""
    if manifest["n_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, model expects "
            f"{len(leaves_like)} — incompatible trees")
    saved_td = manifest.get("treedef")
    if saved_td is not None and saved_td != str(treedef):
        raise ValueError(
            "checkpoint tree structure does not match the model:\n"
            f"  checkpoint: {saved_td}\n"
            f"  model:      {treedef}")
    recs = [_normalize_rec(rec) for rec in manifest["leaves"]]
    for i, (rec, like) in enumerate(zip(recs, leaves_like)):
        if not (hasattr(like, "shape") and hasattr(like, "dtype")):
            like = np.asarray(like)
        if str(like.dtype) != rec["dtype"]:
            raise ValueError(
                f"leaf {i} ({_leaf_name(rec)}): checkpoint dtype "
                f"{rec['dtype']} != model dtype {like.dtype}")
        if list(tuple(like.shape)) != list(rec["shape"]):
            raise ValueError(
                f"leaf {i} ({_leaf_name(rec)}): checkpoint shape "
                f"{tuple(rec['shape'])} != model shape "
                f"{tuple(like.shape)}")
    return recs


class _Peak:
    """Host-side materialized-byte ledger for one leaf restore: raw read
    bytes + assembly buffers in flight (the thing the reshard path must
    keep strictly below full-tensor size for sharded targets)."""

    def __init__(self):
        self.cur = 0
        self.peak = 0

    def add(self, n: int) -> None:
        self.cur += n
        self.peak = max(self.peak, self.cur)

    def sub(self, n: int) -> None:
        self.cur -= n


def _verify_shards(view: PosixView, srecs, src_idx, need, checksum,
                   peak: _Peak, itemsize: int, full_bytes: int):
    """Whole-file checksum pass over the shards a restore will touch,
    BEFORE assembly buffers exist: read chunks are byte-budgeted (sized
    from the manifest's index extents) and dropped right after hashing,
    so verification never stacks up toward full-tensor bytes."""
    todo = [j for j in sorted(need)
            if srecs[j].get("checksum") is not None]
    est = {j: index_volume(src_idx[j]) * itemsize + 512 for j in todo}
    budget = max(1, min(_BATCH_BYTES, full_bytes // 2))
    while todo:
        chunk, pend = [], 0
        while todo and (not chunk or (pend + est[todo[0]] <= budget
                                      and len(chunk) < _BATCH_FILES)):
            pend += est[todo[0]]
            chunk.append(todo.pop(0))
        raws = view.read_many([srecs[j]["path"] for j in chunk])
        total = sum(len(r) for r in raws)
        peak.add(total)
        bad = None
        for j, raw in zip(chunk, raws):
            if bad is None and checksum(raw) != srecs[j]["checksum"]:
                bad = srecs[j]["path"]
        peak.sub(total)
        if bad is not None:
            raise IOError(f"checksum mismatch in shard {bad}")


def _file_runs(src_index: Index, src_slice: Index, dtype: np.dtype):
    """Contiguous byte runs of ``src_slice`` inside its shard's .npy
    payload (C order): yields ``(payload_off, nbytes, outer_coords,
    piece_shape)``. Runs coalesce over the largest fully-covered suffix
    of dims, so a slice wanting the whole shard is ONE run."""
    s_shape = tuple(hi - lo for lo, hi in src_index)
    ext = tuple(hi - lo for lo, hi in src_slice)
    ndim = len(s_shape)
    # strides (in elements) of the shard array
    strides = [1] * ndim
    for d in range(ndim - 2, -1, -1):
        strides[d] = strides[d + 1] * s_shape[d + 1]
    # t = first dim of the contiguous tail: every dim AFTER t is fully
    # covered, so dim t's extent rides along in one run
    t = ndim - 1
    while t > 0 and ext[t] == s_shape[t] \
            and src_slice[t][0] == 0:
        t -= 1
    if ndim == 0:
        yield 0, dtype.itemsize, (), ()
        return
    tail = 1
    for d in range(t + 1, ndim):
        tail *= s_shape[d]
    run_elems = ext[t] * tail
    piece_shape = ext[t:]
    if run_elems == 0 or any(e == 0 for e in ext):
        return
    for outer in np.ndindex(*ext[:t]):
        off = src_slice[t][0] * strides[t]
        for d, c in enumerate(outer):
            off += (src_slice[d][0] + c) * strides[d]
        yield (off * dtype.itemsize, run_elems * dtype.itemsize,
               outer, piece_shape)


def _flat_dst(buf: np.ndarray, dst_slice: Index):
    """Flat view of ``buf[dst_slice]`` when the slab is C-contiguous
    (the slice covers every dim after the first), else None."""
    for d, (lo, hi) in enumerate(dst_slice[1:], 1):
        if (lo, hi) != (0, buf.shape[d]):
            return None
    return buf[tuple(slice(lo, hi) for lo, hi in dst_slice)].reshape(-1)


def _fill_buffer(view: PosixView, buf: np.ndarray, ops, srecs, src_idx,
                 dtype: np.dtype, peak: _Peak) -> int:
    """Execute one target shard's read plan as budget-bounded batches of
    OFFSET reads (the streamed ``read_many`` path): raw bytes in flight
    stay under ~half the target buffer, so assembly peaks at ~1.5x the
    target shard — never the full leaf. A single run bigger than the
    budget (target shard == whole source shard, the identity-transfer
    case) lands on a contiguous slab of ``buf`` and is itself read in
    budget-sized flat pieces. Returns crossings issued."""
    budget = max(1, min(_BATCH_BYTES, buf.nbytes // 2 or buf.itemsize))
    specs, places, pend, crossings = [], [], 0, 0

    def flush():
        nonlocal specs, places, pend, crossings
        if not specs:
            return
        raws = view.read_many(specs)
        crossings += 1
        total = sum(len(r) for r in raws)
        peak.add(total)
        for raw, (dst_view, outer, piece_shape) in zip(raws, places):
            piece = np.frombuffer(raw, dtype=dtype).reshape(piece_shape)
            if outer == ():
                dst_view[...] = piece
            else:
                dst_view[outer] = piece
        peak.sub(total)
        specs, places, pend = [], [], 0

    for op in ops:
        s = srecs[op.src]
        if "data_off" not in s:
            # no payload offset recorded (hand-written manifest): fall
            # back to one whole-file read for this shard
            raw = view.read_file(s["path"])
            crossings += 1
            peak.add(len(raw))
            arr = np.load(io.BytesIO(raw)).view(dtype)
            buf[tuple(slice(lo, hi) for lo, hi in op.dst_slice)] = \
                arr[tuple(slice(lo, hi) for lo, hi in op.src_slice)]
            peak.sub(len(raw))
            continue
        sl = tuple(slice(lo, hi) for lo, hi in op.dst_slice)
        # 0-d: buf[()] yields a scalar copy, not a view — use buf[...]
        dst_view = buf[sl] if sl else buf[...]
        for off, nbytes, outer, piece_shape in _file_runs(
                src_idx[op.src], op.src_slice, dtype):
            if outer == () and nbytes > budget:
                flat = _flat_dst(buf, op.dst_slice) if sl else None
                if flat is not None:
                    # one run would peak at buf + run: stream it instead
                    step = max(dtype.itemsize,
                               budget // dtype.itemsize * dtype.itemsize)
                    base, done = s["data_off"] + off, 0
                    while done < nbytes:
                        n = min(step, nbytes - done)
                        raw = view.read_many([(s["path"], base + done, n)])[0]
                        crossings += 1
                        peak.add(len(raw))
                        e0 = done // dtype.itemsize
                        flat[e0:e0 + n // dtype.itemsize] = \
                            np.frombuffer(raw, dtype=dtype)
                        peak.sub(len(raw))
                        done += n
                    continue
            specs.append((s["path"], s["data_off"] + off, nbytes))
            places.append((dst_view, outer, piece_shape))
            pend += nbytes
            if pend >= budget or len(specs) >= 4 * _BATCH_FILES:
                flush()
    flush()
    return crossings


def _restore_streamed(view: PosixView, rec: Dict, target, checksum,
                      peak: _Peak, info: Dict):
    """Multi-shard leaf restore: plan per target shard, stream slices."""
    shape = tuple(rec["shape"])
    dtype = _np_dtype(rec["dtype"])
    srecs = rec["shards"]
    src_idx = [tuple((int(lo), int(hi)) for lo, hi in s["index"])
               for s in srecs]
    if isinstance(target, NamedSharding):
        dmap = target.addressable_devices_indices_map(shape)
        groups: Dict[Index, list] = {}
        for dev, idx in dmap.items():
            groups.setdefault(normalize_index(idx, shape), []).append(dev)
        plans = {di: plan_target_shard(src_idx, di) for di in groups}
        need = {op.src for ops in plans.values() for op in ops}
    else:
        full = tuple((0, d) for d in shape)
        plans = {full: plan_target_shard(src_idx, full)}
        groups = {full: None}
        need = {op.src for op in plans[full]}
    info["n_target_groups"] = len(groups)
    info["max_target_bytes"] = max(
        (index_volume(di) * dtype.itemsize for di in groups), default=0)
    if checksum:
        full_bytes = index_volume(
            tuple((0, d) for d in shape)) * dtype.itemsize
        _verify_shards(view, srecs, src_idx, need, checksum, peak,
                       dtype.itemsize, full_bytes)
    arrays = []
    for di in sorted(groups):
        ops = plans[di]
        if plan_volume(ops) != index_volume(di):
            raise IOError(
                f"shard records cover {plan_volume(ops)} of "
                f"{index_volume(di)} elements for slice {di} of "
                f"{_leaf_name(rec)} — incomplete checkpoint")
        buf = np.empty(tuple(hi - lo for lo, hi in di), dtype)
        peak.add(buf.nbytes)
        _fill_buffer(view, buf, ops, srecs, src_idx, dtype, peak)
        if groups[di] is None:
            leaf = jax.device_put(buf) if target is None \
                else jax.device_put(buf, target)
            peak.sub(buf.nbytes)
            return leaf
        for dev in groups[di]:
            arrays.append(jax.device_put(buf, dev))
        peak.sub(buf.nbytes)
    return jax.make_array_from_single_device_arrays(shape, target, arrays)


def load(view: PosixView, root: str, like_tree, *, checksum=None,
         sharding_tree=None, stats: Optional[Dict] = None):
    """Restore into the structure of ``like_tree``; optionally assemble
    each leaf under the matching sharding from ``sharding_tree`` (elastic
    rescale onto a different mesh — multi-shard leaves restore via the
    streamed reshard plan, never materializing the full tensor). ``stats``
    (a dict, mutated) collects per-leaf peak/full byte counts."""
    manifest = json.loads(view.read_file(f"{root}/{MANIFEST}"))
    leaves_like, treedef = _flatten(like_tree)
    recs = _validate_manifest(manifest, leaves_like, treedef)
    shardings: List[Any] = [None] * len(leaves_like)
    if sharding_tree is not None:
        shardings = _flatten_shardings(sharding_tree)
        if len(shardings) != len(leaves_like):
            raise ValueError(
                f"sharding tree has {len(shardings)} leaves, model has "
                f"{len(leaves_like)} — incompatible trees")
    out: List[Any] = [None] * len(recs)
    leaf_stats: List[Dict] = []

    def note(i, rec, peak, streamed, info=None):
        full = index_volume(tuple(
            (0, d) for d in rec["shape"])) * _np_dtype(rec["dtype"]).itemsize
        leaf_stats.append({"leaf": i, "peak_bytes": peak.peak,
                           "full_bytes": full,
                           "n_src_shards": len(rec["shards"]),
                           "streamed": streamed, **(info or {})})

    # single-shard leaves batch v1-style: one crossing per ~_BATCH_FILES
    # whole files; multi-shard leaves go through the streamed plan
    pend: List[int] = []

    def flush_simple():
        raws = view.read_many([recs[i]["shards"][0]["path"] for i in pend])
        for i, raw in zip(pend, raws):
            rec, s = recs[i], recs[i]["shards"][0]
            peak = _Peak()
            peak.add(len(raw))
            if checksum and s.get("checksum") is not None \
                    and checksum(raw) != s["checksum"]:
                raise IOError(f"checksum mismatch in shard {s['path']}")
            arr = np.load(io.BytesIO(raw))
            if rec["dtype"] in _WIRE_DTYPES:
                import ml_dtypes
                arr = arr.view(getattr(ml_dtypes, rec["dtype"]))
            if list(arr.shape) != list(rec["shape"]):
                raise IOError(f"shape mismatch in {s['path']}")
            peak.add(arr.nbytes)
            target = shardings[i]
            out[i] = jax.device_put(arr) if target is None \
                else jax.device_put(arr, target)
            peak.sub(len(raw) + arr.nbytes)
            note(i, rec, peak, streamed=False)
        pend.clear()

    for i, rec in enumerate(recs):
        if len(rec["shards"]) == 1:
            pend.append(i)
            if len(pend) >= _BATCH_FILES:
                flush_simple()
        else:
            peak, info = _Peak(), {}
            out[i] = _restore_streamed(view, rec, shardings[i], checksum,
                                       peak, info)
            note(i, rec, peak, streamed=True, info=info)
    if pend:
        flush_simple()
    if stats is not None:
        stats["leaves"] = leaf_stats
        stats["version"] = manifest.get("version", 1)
    return jax.tree.unflatten(treedef, out), manifest


def latest_step(view: PosixView, base: str) -> Optional[int]:
    """Newest step with a PARSEABLE manifest — an empty or torn manifest
    (crash inside the save's final commit window) is treated as no
    checkpoint, so restart falls back to the previous good step."""
    if not view.exists(base):
        return None
    steps = []
    for name in view.listdir(base):
        if name.startswith("step_"):
            try:
                json.loads(view.read_file(f"{base}/{name}/{MANIFEST}"))
                steps.append(int(name.split("_")[1]))
            except (FsError, ValueError, IndexError):
                continue
    return max(steps) if steps else None
