"""Checkpointing through the Bento file system.

Pytrees serialize leaf-per-file with a JSON manifest carrying shapes,
dtypes, tree structure and per-leaf checksums (the kernel-services hash —
Pallas blockhash in the kernel binding). Save/restore round-trips through
the journaled xv6/ext4like store, so checkpoint durability inherits the
journal's crash-atomicity (manifest written last = commit point).

The same extract->serialize path backs all four fault-tolerance features
(upgrade / restart / elastic reshard / failure recovery): restore accepts a
target sharding context and device_puts leaves to a NEW mesh, which is the
elastic-rescale path.
"""

from __future__ import annotations

import io
import json
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.core.interface import Errno, FsError
from repro.fs.posix import PosixView

MANIFEST = "manifest.json"

# Leaves cross the boundary in bounded submission batches: one crossing per
# ~chunk instead of per leaf, without buffering the whole checkpoint
# (serialized bytes would otherwise double peak memory on save).
_BATCH_BYTES = 64 << 20
_BATCH_LEAVES = 64

# ml_dtypes that numpy serializes as void: stored as integer views instead.
_WIRE_DTYPES = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                "float8_e5m2": np.uint8}


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(view: PosixView, root: str, tree, *, step: int,
         checksum=None, extra: Optional[Dict] = None) -> Dict:
    view.makedirs(root)
    leaves, treedef = _flatten(tree)
    manifest_path = f"{root}/{MANIFEST}"
    # Re-saves bump a GENERATION tag baked into the leaf names, so the new
    # leaves never overwrite the ones the LIVE manifest references — the
    # old checkpoint (manifest AND data) stays fully intact until the
    # manifest swap commits, and stale-generation leaves are collected
    # after it. Without this, a crash mid-leaf-write would tear the
    # previous good checkpoint's data under its still-live manifest.
    gen, old_exists = 0, view.exists(manifest_path)
    if old_exists:
        try:
            gen = int(json.loads(view.read_file(manifest_path))
                      .get("gen", 0)) + 1
        except (ValueError, FsError):
            gen = 1  # old manifest torn/unreadable: start a fresh line
    # whatever suggested the tag, probe past any leaf names a CRASHED
    # attempt already occupies (its swap never committed, so the live
    # manifest still names the previous gen): fresh leaf writes must
    # never land on a stale same-name file — a shorter overwrite would
    # keep the old tail, because write never truncates
    while leaves and view.exists(
            f"{root}/leaf_00000{f'_g{gen}' if gen else ''}.npy"):
        gen += 1
    suffix = f"_g{gen}" if gen else ""
    manifest = {
        "step": step,
        "gen": gen,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "leaves": [],
        "extra": extra or {},
    }
    items, pending_bytes = [], 0
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        # numpy can't serialize ml_dtypes (bf16 -> void): save a same-width
        # integer view and record the real dtype in the manifest.
        save_arr = arr.view(_WIRE_DTYPES[str(arr.dtype)]) \
            if str(arr.dtype) in _WIRE_DTYPES else arr
        buf = io.BytesIO()
        np.save(buf, save_arr)
        raw = buf.getvalue()
        path = f"{root}/leaf_{i:05d}{suffix}.npy"
        items.append((path, raw))
        pending_bytes += len(raw)
        manifest["leaves"].append({
            "path": path,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "checksum": checksum(raw) if checksum else None,
        })
        if len(items) >= _BATCH_LEAVES or pending_bytes >= _BATCH_BYTES:
            view.write_many(items)
            items, pending_bytes = [], 0
    # The manifest is the commit point, enforced by the manifest's own
    # linked chain: leaf batches (including the final one) are plain
    # batches — strict mode raises a failing leaf's real errno before the
    # manifest submission ever happens — and then the manifest's
    # create→write→flush CHAIN commits everything. Since the chain-aware
    # journal reservation landed, a chain is one bounded journal
    # transaction (crash-atomic, sized by capacity), so bulk leaf data
    # must NOT be chained — only the small manifest chain is, and its
    # flush commits any still-pending leaf blocks with it (one transaction
    # when they fit together; begin_chain pre-commits them first when they
    # don't, which is equally safe — they are invisible without the
    # manifest). A crash at any device write before that commit leaves no
    # manifest at all — the aborted save is invisible to latest_step;
    # after it, manifest AND every leaf it names are durable together.
    #
    # Re-saves over an EXISTING checkpoint never touch the live manifest
    # (or, thanks to the generation tag, its leaves): the new manifest is
    # committed under a tmp name, then swapped in with one journaled
    # rename-overwrite (+fsync to make the swap durable). The old
    # checkpoint stays fully intact until the rename transaction commits,
    # so the previous good one survives a crash at ANY device write of a
    # re-save — the old truncate-then-rewrite path had a window where
    # neither version did. Both properties are enumerated per crash point
    # by tests/test_crash_torture.py.
    raw_manifest = json.dumps(manifest).encode()
    if items:
        view.write_many(items)
    try:
        if not old_exists:
            _commit_manifest(view, manifest_path, raw_manifest)
        else:
            tmp_path = f"{root}/.{MANIFEST}.tmp"
            try:
                if view.exists(tmp_path):  # stale tmp of a crashed re-save
                    view.unlink(tmp_path)
                _commit_manifest(view, tmp_path, raw_manifest)
                view.rename(tmp_path, manifest_path)
                view.fsync(manifest_path)  # commit the swap's journal txn
            except FsError:
                # failed re-save: drop the tmp husk — the OLD manifest is
                # still the live checkpoint, untouched
                try:
                    if view.exists(tmp_path):
                        view.unlink(tmp_path)
                except FsError:
                    pass
                raise
    except FsError:
        # a manifest created whose WRITE then failed is an empty husk —
        # remove it so the aborted save is indistinguishable from no save
        try:
            if view.exists(manifest_path) \
                    and view.stat(manifest_path).size == 0:
                view.unlink(manifest_path)
        except FsError:
            pass
        raise
    # the swap is durable: collect leaves the live manifest no longer
    # references (prior generations + orphans of crashed attempts). Pure
    # garbage collection — a crash skipping it just leaves dead files the
    # next successful save sweeps up.
    live = {rec["path"].rsplit("/", 1)[-1] for rec in manifest["leaves"]}
    stale = [f"{root}/{name}" for name in view.listdir(root)
             if name.startswith("leaf_") and name not in live]
    if stale:
        try:
            view.unlink_many(stale, strict=False)
        except FsError:
            pass
    return manifest


def _commit_manifest(view: PosixView, path: str, raw: bytes) -> None:
    """Create ``path`` and make ``raw`` durable in it: a chained
    create→write→flush when it fits one journal transaction
    (crash-atomic), else the ENOSPC refusal falls back to an unchained
    write + fsync — a torn fresh file reads as "no checkpoint" (and for a
    re-save the tear hits only the TMP name, never the live manifest), and
    a genuinely full device just raises ENOSPC again here."""
    try:
        view.create_and_write_many([(path, raw)], fsync=True)
    except FsError as e:
        if e.errno != Errno.ENOSPC:
            raise
        view.write_file(path, raw)
        view.fsync(path)


def load(view: PosixView, root: str, like_tree, *, checksum=None,
         sharding_tree=None):
    """Restore into the structure of ``like_tree``; optionally device_put
    each leaf with the matching sharding from ``sharding_tree`` (elastic
    rescale onto a different mesh)."""
    manifest = json.loads(view.read_file(f"{root}/{MANIFEST}"))
    leaves_like, treedef = _flatten(like_tree)
    if manifest["n_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, model expects "
            f"{len(leaves_like)} — incompatible trees")
    shardings = None
    if sharding_tree is not None:
        shardings = _flatten(sharding_tree)[0]
    out = []
    # leaves read in bounded submission batches (see _BATCH_LEAVES): one
    # boundary crossing per chunk, raw bytes live only within their chunk
    recs = manifest["leaves"]
    for lo in range(0, len(recs), _BATCH_LEAVES):
        chunk = recs[lo: lo + _BATCH_LEAVES]
        raws = view.read_many([rec["path"] for rec in chunk])
        for i, (rec, raw) in enumerate(zip(chunk, raws), start=lo):
            if checksum and rec.get("checksum") is not None:
                if checksum(raw) != rec["checksum"]:
                    raise IOError(f"checksum mismatch in {rec['path']}")
            arr = np.load(io.BytesIO(raw))
            if rec["dtype"] in _WIRE_DTYPES:
                import ml_dtypes
                arr = arr.view(getattr(ml_dtypes, rec["dtype"]))
            if list(arr.shape) != rec["shape"]:
                raise IOError(f"shape mismatch in {rec['path']}")
            if shardings is not None:
                out.append(jax.device_put(arr, shardings[i]))
            else:
                out.append(jax.device_put(arr))
    return jax.tree.unflatten(treedef, out), manifest


def latest_step(view: PosixView, base: str) -> Optional[int]:
    """Newest step with a PARSEABLE manifest — an empty or torn manifest
    (crash inside the save's final commit window) is treated as no
    checkpoint, so restart falls back to the previous good step."""
    if not view.exists(base):
        return None
    steps = []
    for name in view.listdir(base):
        if name.startswith("step_"):
            try:
                json.loads(view.read_file(f"{base}/{name}/{MANIFEST}"))
                steps.append(int(name.split("_")[1]))
            except (FsError, ValueError, IndexError):
                continue
    return max(steps) if steps else None
