"""Checkpointing through the Bento file system.

Pytrees serialize leaf-per-file with a JSON manifest carrying shapes,
dtypes, tree structure and per-leaf checksums (the kernel-services hash —
Pallas blockhash in the kernel binding). Save/restore round-trips through
the journaled xv6/ext4like store, so checkpoint durability inherits the
journal's crash-atomicity (manifest written last = commit point).

The same extract->serialize path backs all four fault-tolerance features
(upgrade / restart / elastic reshard / failure recovery): restore accepts a
target sharding context and device_puts leaves to a NEW mesh, which is the
elastic-rescale path.
"""

from __future__ import annotations

import io
import json
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.core.interface import Errno, FsError
from repro.fs.posix import PosixView

MANIFEST = "manifest.json"

# Leaves cross the boundary in bounded submission batches: one crossing per
# ~chunk instead of per leaf, without buffering the whole checkpoint
# (serialized bytes would otherwise double peak memory on save).
_BATCH_BYTES = 64 << 20
_BATCH_LEAVES = 64

# ml_dtypes that numpy serializes as void: stored as integer views instead.
_WIRE_DTYPES = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                "float8_e5m2": np.uint8}


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(view: PosixView, root: str, tree, *, step: int,
         checksum=None, extra: Optional[Dict] = None) -> Dict:
    view.makedirs(root)
    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "leaves": [],
        "extra": extra or {},
    }
    items, pending_bytes = [], 0
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        # numpy can't serialize ml_dtypes (bf16 -> void): save a same-width
        # integer view and record the real dtype in the manifest.
        save_arr = arr.view(_WIRE_DTYPES[str(arr.dtype)]) \
            if str(arr.dtype) in _WIRE_DTYPES else arr
        buf = io.BytesIO()
        np.save(buf, save_arr)
        raw = buf.getvalue()
        path = f"{root}/leaf_{i:05d}.npy"
        items.append((path, raw))
        pending_bytes += len(raw)
        manifest["leaves"].append({
            "path": path,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "checksum": checksum(raw) if checksum else None,
        })
        if len(items) >= _BATCH_LEAVES or pending_bytes >= _BATCH_BYTES:
            view.write_many(items)
            items, pending_bytes = [], 0
    # The manifest is the commit point, enforced by the manifest's own
    # linked chain: leaf batches (including the final one) are plain
    # batches — strict mode raises a failing leaf's real errno before the
    # manifest submission ever happens — and then the manifest's
    # create→write→flush CHAIN commits everything. Since the chain-aware
    # journal reservation landed, a chain is one bounded journal
    # transaction (crash-atomic, sized by capacity), so bulk leaf data
    # must NOT be chained — only the small manifest chain is, and its
    # flush commits any still-pending leaf blocks with it (one transaction
    # when they fit together; begin_chain pre-commits them first when they
    # don't, which is equally safe — they are invisible without the
    # manifest). A crash at any device
    # write before that commit leaves no manifest at all — the aborted
    # save is invisible to latest_step; after it, manifest AND every leaf
    # it names are durable together (proven exhaustively by the crash
    # harness, tests/test_crash_torture.py).
    manifest_path = f"{root}/{MANIFEST}"
    raw_manifest = json.dumps(manifest).encode()
    if items:
        view.write_many(items)
    try:
        try:
            if view.exists(manifest_path):  # re-save over an old checkpoint
                # clear first so a SHORTER manifest never keeps a stale
                # tail (json would see trailing garbage); a crash between
                # the truncate and the commit leaves an empty/torn
                # manifest, which latest_step already reads as "no
                # checkpoint"
                view.truncate(manifest_path, 0)
                view.write_many([(manifest_path, raw_manifest)],
                                fsync=True, chain=True)
            else:
                view.create_and_write_many([(manifest_path, raw_manifest)],
                                           fsync=True)
        except FsError as e:
            if e.errno != Errno.ENOSPC:
                raise
            # a chain is a bounded journal transaction: a manifest bigger
            # than one is refused ENOSPC up front. Fall back to an
            # unchained write + fsync — crash safety degrades gracefully
            # (latest_step already ignores torn/unparseable manifests), and
            # a genuinely full device just raises ENOSPC again here.
            # NB a crash mid-overwrite of an EXISTING over-capacity
            # manifest can tear it (same exposure as before chain
            # transactions existed — multi-txn writes were never atomic);
            # an atomic tmp+rename swap needs rename-overwrite support,
            # tracked in ROADMAP.
            view.write_file(manifest_path, raw_manifest)
            view.fsync(manifest_path)
    except FsError:
        # a manifest created whose WRITE then failed is an empty husk —
        # remove it so the aborted save is indistinguishable from no save
        try:
            if view.exists(manifest_path) \
                    and view.stat(manifest_path).size == 0:
                view.unlink(manifest_path)
        except FsError:
            pass
        raise
    return manifest


def load(view: PosixView, root: str, like_tree, *, checksum=None,
         sharding_tree=None):
    """Restore into the structure of ``like_tree``; optionally device_put
    each leaf with the matching sharding from ``sharding_tree`` (elastic
    rescale onto a different mesh)."""
    manifest = json.loads(view.read_file(f"{root}/{MANIFEST}"))
    leaves_like, treedef = _flatten(like_tree)
    if manifest["n_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, model expects "
            f"{len(leaves_like)} — incompatible trees")
    shardings = None
    if sharding_tree is not None:
        shardings = _flatten(sharding_tree)[0]
    out = []
    # leaves read in bounded submission batches (see _BATCH_LEAVES): one
    # boundary crossing per chunk, raw bytes live only within their chunk
    recs = manifest["leaves"]
    for lo in range(0, len(recs), _BATCH_LEAVES):
        chunk = recs[lo: lo + _BATCH_LEAVES]
        raws = view.read_many([rec["path"] for rec in chunk])
        for i, (rec, raw) in enumerate(zip(chunk, raws), start=lo):
            if checksum and rec.get("checksum") is not None:
                if checksum(raw) != rec["checksum"]:
                    raise IOError(f"checksum mismatch in {rec['path']}")
            arr = np.load(io.BytesIO(raw))
            if rec["dtype"] in _WIRE_DTYPES:
                import ml_dtypes
                arr = arr.view(getattr(ml_dtypes, rec["dtype"]))
            if list(arr.shape) != rec["shape"]:
                raise IOError(f"shape mismatch in {rec['path']}")
            if shardings is not None:
                out.append(jax.device_put(arr, shardings[i]))
            else:
                out.append(jax.device_put(arr))
    return jax.tree.unflatten(treedef, out), manifest


def latest_step(view: PosixView, base: str) -> Optional[int]:
    """Newest step with a PARSEABLE manifest — an empty or torn manifest
    (crash inside the save's final commit window) is treated as no
    checkpoint, so restart falls back to the previous good step."""
    if not view.exists(base):
        return None
    steps = []
    for name in view.listdir(base):
        if name.startswith("step_"):
            try:
                json.loads(view.read_file(f"{base}/{name}/{MANIFEST}"))
                steps.append(int(name.split("_")[1]))
            except (FsError, ValueError, IndexError):
                continue
    return max(steps) if steps else None
