"""Checkpointing through the Bento file system — shard-native v2 format.

Pytrees serialize SHARD-PER-FILE with a JSON manifest carrying shapes,
dtypes, tree structure, the per-leaf shard grid (logical PartitionSpec +
mesh axis sizes) and per-shard checksums (the kernel-services hash —
Pallas blockhash in the kernel binding). Save/restore round-trips through
the journaled xv6/ext4like store, so checkpoint durability inherits the
journal's crash-atomicity (manifest written last = commit point), and the
grid makes the checkpoint topology-elastic: restore onto a DIFFERENT mesh
plans per-target-shard reads (repro.distributed.resharding) and executes
them as streamed offset reads over ``read_many``, re-slicing in flight —
a full leaf is never materialized on the restoring host.

v1 manifests (whole-leaf files, no shard records) keep loading through
the same machinery as a 1-shard grid. The same extract->serialize path
backs all four fault-tolerance features (upgrade / restart / elastic
reshard / failure recovery).

Pipelined restore (the overlap engine)
--------------------------------------
``load`` runs at a configurable ``pipeline_depth`` (default 2, env
``REPRO_CKPT_PIPELINE_DEPTH``):

* depth 0 — the serial two-pass reference path: a whole-file checksum
  pre-pass over every shard the plan touches, then budget-bounded offset
  reads filling each target buffer. Checksummed bytes cross the
  fs boundary twice.
* depth 1 — single-pass folded verification, inline: the restore is
  compiled into an ordered task list where the FIRST op touching a
  checksummed shard fetches the whole file once, hashes it (one
  ``checksum_batch`` launch per fetched chunk when the batched hash is
  given) and serves that op's slices straight from the fetched bytes;
  later ops on a verified shard are plain offset reads. Every byte
  crosses once.
* depth >= 2 — the same task list with a prefetch thread: the NEXT
  task's ``read_many`` is issued through that thread's own dedicated
  ``SubmitterQueue`` (PosixView submitter queues are thread-local)
  while the main thread verifies and assembles the current buffer via
  ``jax.make_array_from_single_device_arrays``. Assembly stays strict
  FIFO, so results are byte-identical at every depth and failures
  (checksum mismatch, read errors) surface exactly where the serial
  path raises them — speculatively fetched bytes after a failure are
  dropped, never assembled.

Peak-budget protocol: per-leaf materialized bytes stay METERED at every
depth. Each assembly unit's serial read budget (~half its target
buffer) is split into ``budget/depth`` chunks, and admission is a
counted token window of ``depth`` tokens where a task's weight is
``ceil(bytes/chunk)`` capped at ``depth`` — in-flight raw bytes never
exceed the SERIAL budget (an oversized whole-file unit runs exclusive),
buffers allocate lazily in their unit's first assembly step and release
in its finalize step, so the pipelined per-leaf peak stays within the
serial peak while the window keeps up to ``depth`` fetches in flight.
Save gets the symmetric write-behind: shard batches drain on one FIFO
worker thread (device write ORDER unchanged) while the main thread
serializes the next leaf, joined — first error re-raised — BEFORE the
manifest commit, so the manifest-last crash protocol is untouched.
"""

from __future__ import annotations

import io
import json
import os
import queue
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.core.interface import Errno, FsError
from repro.distributed.resharding import (
    Index, ShardGrid, chunk_ops, index_volume, normalize_index,
    plan_target_shard, plan_volume, shift_ops,
)
from repro.fs.posix import PosixView

MANIFEST = "manifest.json"
FORMAT_VERSION = 2

# Shards cross the boundary in bounded submission batches: one crossing per
# ~chunk instead of per file, without buffering the whole checkpoint
# (serialized bytes would otherwise double peak memory on save).
_BATCH_BYTES = 64 << 20
_BATCH_FILES = 64

# ml_dtypes that numpy serializes as void: stored as integer views instead.
_WIRE_DTYPES = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                "float8_e5m2": np.uint8}

# Pipeline depth: 0 = serial two-pass reference, 1 = folded single-pass
# inline, >= 2 = prefetch thread `depth` window tokens ahead.
_DEPTH_ENV = "REPRO_CKPT_PIPELINE_DEPTH"
_DEFAULT_DEPTH = 2

# Restores smaller than this run the task list inline even at depth >= 2:
# the prefetch thread's spawn + queue traffic costs more than overlapping
# a handful of tiny fetches could recover. Tests that pin worker-thread
# behavior on small fixtures monkeypatch this to 0.
_INLINE_BYTES = 16 << 10


def _resolve_depth(arg: Optional[int]) -> int:
    if arg is None:
        try:
            arg = int(os.environ.get(_DEPTH_ENV, _DEFAULT_DEPTH))
        except ValueError:
            arg = _DEFAULT_DEPTH
    return max(0, int(arg))


class _WriteBehind:
    """Write-behind lane for save: shard batches drain through ONE FIFO
    worker thread (with its own thread-local ``SubmitterQueue``) while
    the main thread serializes the next leaf. The queue is bounded to
    ``depth`` batches so serialization runs at most that far ahead of
    the device; the single worker keeps device write order identical to
    the synchronous path, and ``close()`` joins and re-raises the first
    write error BEFORE the manifest commit — the manifest-last crash
    protocol sees exactly the same device-write sequence."""

    def __init__(self, view: PosixView, depth: int):
        self._view = view
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, depth))
        self._err: Optional[BaseException] = None
        self._t = threading.Thread(target=self._run,
                                   name="ckpt-write-behind", daemon=True)
        self._t.start()

    def _run(self) -> None:
        while True:
            batch = self._q.get()
            if batch is None:
                return
            if self._err is None:
                try:
                    self._view.write_many(batch)
                except BaseException as e:  # noqa: BLE001 — close re-raises
                    self._err = e

    def put(self, batch) -> None:
        if self._err is not None:
            self.close()  # drains the worker and raises the write error
        self._q.put(batch)

    def close(self) -> None:
        self._q.put(None)
        self._t.join()
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def abandon(self) -> None:
        """Teardown on a serialization error without masking it."""
        try:
            self._q.put(None)
            self._t.join(timeout=30)
        except Exception:  # noqa: BLE001 — best-effort cleanup
            pass


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _flatten_shardings(tree) -> List:
    """Flatten a per-leaf sharding/grid tree. None entries mean "this leaf
    is unsharded" and must stay leaves, not collapse as empty subtrees."""
    return jax.tree.flatten(tree, is_leaf=lambda v: v is None)[0]


def _np_dtype(dtype_s: str) -> np.dtype:
    if dtype_s in _WIRE_DTYPES:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, dtype_s))
    return np.dtype(dtype_s)


def _serialize(arr: np.ndarray) -> bytes:
    # numpy can't serialize ml_dtypes (bf16 -> void): save a same-width
    # integer view and record the real dtype in the manifest.
    wire = arr.view(_WIRE_DTYPES[str(arr.dtype)]) \
        if str(arr.dtype) in _WIRE_DTYPES else arr
    if not wire.flags["C_CONTIGUOUS"]:  # ascontiguousarray promotes 0-d
        wire = np.ascontiguousarray(wire)
    buf = io.BytesIO()
    np.save(buf, wire)
    return buf.getvalue()


def _resolve_grid(shape, leaf, sharding) -> ShardGrid:
    """Per-leaf shard grid: an explicit ShardGrid (virtual grids — crash
    torture and single-device tests shard without devices), a
    NamedSharding, or the leaf's OWN sharding when none is given (a leaf
    already laid out across a mesh saves shard-per-device for free)."""
    if isinstance(sharding, ShardGrid):
        if sharding.shape != tuple(shape):
            raise ValueError(
                f"ShardGrid shape {sharding.shape} != leaf shape {shape}")
        grid = sharding
    elif isinstance(sharding, NamedSharding):
        grid = ShardGrid.from_sharding(shape, sharding)
    elif sharding is None and isinstance(leaf, jax.Array) \
            and isinstance(getattr(leaf, "sharding", None), NamedSharding):
        grid = ShardGrid.from_sharding(shape, leaf.sharding)
    else:
        grid = ShardGrid.trivial(shape)
    return grid if grid.n_shards > 1 else ShardGrid.trivial(shape)


def _shard_arrays(leaf, grid: ShardGrid):
    """Yield ``(j, shard ndarray)`` without materializing the full leaf
    when the leaf's device layout already matches the grid (the common
    save path); otherwise fall back to slicing a device_get'd copy."""
    if grid.n_shards == 1:
        yield 0, np.asarray(jax.device_get(leaf))
        return
    by_index = {}
    if isinstance(leaf, jax.Array):
        try:
            for sh in leaf.addressable_shards:
                by_index.setdefault(
                    normalize_index(sh.index, grid.shape), sh.data)
        except Exception:  # noqa: BLE001 — any layout oddity -> fallback
            by_index = {}
    full = None
    for j in range(grid.n_shards):
        idx = grid.index(j)
        data = by_index.get(idx)
        if data is not None:
            yield j, np.asarray(jax.device_get(data))
        else:
            if full is None:
                full = np.asarray(jax.device_get(leaf))
            yield j, np.ascontiguousarray(
                full[tuple(slice(lo, hi) for lo, hi in idx)])


def _first_leaf_names(root: str, gen: int):
    sfx = f"_g{gen}" if gen else ""
    # both naming lines: v1 whole-leaf files and v2 shard files — a
    # crashed attempt from either format must not be overwritten short
    return (f"{root}/leaf_00000{sfx}.npy", f"{root}/leaf_00000_s000{sfx}.npy")


def save(view: PosixView, root: str, tree, *, step: int,
         checksum=None, extra: Optional[Dict] = None,
         shardings=None, pipeline_depth: Optional[int] = None) -> Dict:
    """Save ``tree`` shard-per-file. ``shardings``: optional pytree
    matching ``tree`` of NamedSharding | ShardGrid | None deciding each
    leaf's grid (default: the leaf's own device layout).
    ``pipeline_depth`` >= 2 (the default, see ``_DEPTH_ENV``) drains
    shard batches write-behind while the next leaf serializes; 0/1 keep
    the fully synchronous path. Device write order and the manifest-last
    commit protocol are identical either way."""
    depth = _resolve_depth(pipeline_depth)
    leaves, treedef = _flatten(tree)
    grids = None
    if shardings is not None:
        grids = _flatten_shardings(shardings)
        if len(grids) != len(leaves):
            raise ValueError(
                f"shardings tree has {len(grids)} leaves, model has "
                f"{len(leaves)} — incompatible trees")
    manifest_path = f"{root}/{MANIFEST}"
    # Re-saves bump a GENERATION tag baked into the shard names, so the new
    # files never overwrite the ones the LIVE manifest references — the
    # old checkpoint (manifest AND data) stays fully intact until the
    # manifest swap commits, and stale-generation shards are collected
    # after it. Without this, a crash mid-shard-write would tear the
    # previous good checkpoint's data under its still-live manifest.
    # ONE read probes for an existing checkpoint and fetches its gen in
    # the same round trip; re-saves (the trainer's steady state) skip
    # the makedirs walk entirely
    gen, old_exists = 0, False
    try:
        raw_old = view.read_file(manifest_path)
        old_exists = True
        try:
            gen = int(json.loads(raw_old).get("gen", 0)) + 1
        except ValueError:
            gen = 1  # old manifest torn: start a fresh line
    except FsError as e:
        if e.errno == Errno.ENOENT:
            view.makedirs(root)  # first save at this root
        else:
            # present but unreadable — treat like a torn manifest so the
            # commit still goes through the tmp+rename swap, never a
            # direct overwrite of whatever is on disk
            old_exists, gen = True, 1
    # whatever suggested the tag, probe past any shard names a CRASHED
    # attempt already occupies (its swap never committed, so the live
    # manifest still names the previous gen): fresh writes must never
    # land on a stale same-name file — a shorter overwrite would keep
    # the old tail, because write never truncates
    while leaves and any(
            not isinstance(st, FsError)
            for st in view.stat_many(list(_first_leaf_names(root, gen)),
                                     strict=False)):
        gen += 1
    suffix = f"_g{gen}" if gen else ""
    manifest = {
        "version": FORMAT_VERSION,
        "step": step,
        "gen": gen,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "leaves": [],
        "extra": extra or {},
    }
    # symmetric with load's inline shortcut: a checkpoint this small
    # finishes before the drain thread would even start paying off
    est_bytes = sum(getattr(l, "nbytes", 16) for l in leaves)
    sink = (_WriteBehind(view, depth)
            if depth >= 2 and est_bytes >= _INLINE_BYTES else None)
    items, pending_bytes = [], 0
    try:
        for i, leaf in enumerate(leaves):
            if not (hasattr(leaf, "shape") and hasattr(leaf, "dtype")):
                leaf = np.asarray(leaf)  # python scalars
            shape = tuple(int(d) for d in leaf.shape)
            grid = _resolve_grid(shape, leaf, grids[i] if grids else None)
            rec = {"shape": list(shape), "dtype": str(leaf.dtype),
                   "shards": []}
            rec.update(grid.to_manifest())
            for j, shard in _shard_arrays(leaf, grid):
                raw = _serialize(shard)
                path = f"{root}/leaf_{i:05d}_s{j:03d}{suffix}.npy"
                items.append((path, raw))
                pending_bytes += len(raw)
                rec["shards"].append({
                    "path": path,
                    "coords": list(grid.coords(j)),
                    "index": [[lo, hi] for lo, hi in grid.index(j)],
                    # payload position inside the .npy — lets restore
                    # stream sub-shard slices as offset reads without
                    # parsing headers
                    "data_off": len(raw) - shard.nbytes,
                    "checksum": checksum(raw) if checksum else None,
                })
                if len(items) >= _BATCH_FILES \
                        or pending_bytes >= _BATCH_BYTES:
                    if sink is not None:
                        sink.put(items)
                    else:
                        view.write_many(items)
                    items, pending_bytes = [], 0
            manifest["leaves"].append(rec)
    except BaseException:
        if sink is not None:
            sink.abandon()
        raise
    # The manifest is the commit point, enforced by the manifest's own
    # linked chain: shard batches (including the final one) are plain
    # batches — strict mode raises a failing write's real errno before the
    # manifest submission ever happens — and then the manifest's
    # create→write→flush CHAIN commits everything. Since the chain-aware
    # journal reservation landed, a chain is one bounded journal
    # transaction (crash-atomic, sized by capacity), so bulk shard data
    # must NOT be chained — only the small manifest chain is, and its
    # flush commits any still-pending shard blocks with it (one transaction
    # when they fit together; begin_chain pre-commits them first when they
    # don't, which is equally safe — they are invisible without the
    # manifest). A crash at any device write before that commit leaves no
    # manifest at all — the aborted save is invisible to latest_step;
    # after it, manifest AND every shard it names are durable together.
    #
    # Re-saves over an EXISTING checkpoint never touch the live manifest
    # (or, thanks to the generation tag, its shards): the new manifest is
    # committed under a tmp name, then swapped in with one journaled
    # rename-overwrite (+fsync to make the swap durable). The old
    # checkpoint stays fully intact until the rename transaction commits,
    # so the previous good one survives a crash at ANY device write of a
    # re-save — the old truncate-then-rewrite path had a window where
    # neither version did. Both properties are enumerated per crash point
    # by tests/test_crash_torture.py (v1 whole-leaf and v2 sharded saves).
    raw_manifest = json.dumps(manifest).encode()
    if sink is not None:
        # join the write-behind lane — a failed shard write raises its
        # real errno HERE, before the manifest submission ever happens,
        # exactly like the synchronous path's strict write_many
        try:
            if items:
                sink.put(items)
        finally:
            sink.close()
    elif items:
        view.write_many(items)
    try:
        if not old_exists:
            _commit_manifest(view, manifest_path, raw_manifest)
        else:
            tmp_path = f"{root}/.{MANIFEST}.tmp"
            try:
                if view.exists(tmp_path):  # stale tmp of a crashed re-save
                    view.unlink(tmp_path)
                _commit_manifest(view, tmp_path, raw_manifest)
                view.rename(tmp_path, manifest_path)
                view.fsync(manifest_path)  # commit the swap's journal txn
            except FsError:
                # failed re-save: drop the tmp husk — the OLD manifest is
                # still the live checkpoint, untouched
                try:
                    if view.exists(tmp_path):
                        view.unlink(tmp_path)
                except FsError:
                    pass
                raise
    except FsError:
        # a manifest created whose WRITE then failed is an empty husk —
        # remove it so the aborted save is indistinguishable from no save
        try:
            if view.exists(manifest_path) \
                    and view.stat(manifest_path).size == 0:
                view.unlink(manifest_path)
        except FsError:
            pass
        raise
    # the swap is durable: collect shard files the live manifest no longer
    # references (prior generations + orphans of crashed attempts). Pure
    # garbage collection — a crash skipping it just leaves dead files the
    # next successful save sweeps up.
    live = {s["path"].rsplit("/", 1)[-1]
            for rec in manifest["leaves"] for s in rec["shards"]}
    stale = [f"{root}/{name}" for name in view.listdir(root)
             if name.startswith("leaf_") and name not in live]
    if stale:
        try:
            view.unlink_many(stale, strict=False)
        except FsError:
            pass
    return manifest


def _commit_manifest(view: PosixView, path: str, raw: bytes) -> None:
    """Create ``path`` and make ``raw`` durable in it: a chained
    create→write→flush when it fits one journal transaction
    (crash-atomic), else the ENOSPC refusal falls back to an unchained
    write + fsync — a torn fresh file reads as "no checkpoint" (and for a
    re-save the tear hits only the TMP name, never the live manifest), and
    a genuinely full device just raises ENOSPC again here."""
    try:
        view.create_and_write_many([(path, raw)], fsync=True)
    except FsError as e:
        if e.errno != Errno.ENOSPC:
            raise
        view.write_file(path, raw)
        view.fsync(path)


# --- restore ----------------------------------------------------------------


def _leaf_name(rec: Dict) -> str:
    return rec["shards"][0]["path"].rsplit("/", 1)[-1]


def _normalize_rec(rec: Dict) -> Dict:
    """v1 whole-leaf records load through the v2 machinery as a 1-shard
    grid covering the full leaf."""
    if "shards" in rec:
        return rec
    shape = rec["shape"]
    return {"shape": shape, "dtype": rec["dtype"],
            "spec": [[] for _ in shape], "axes": {},
            "shards": [{"path": rec["path"], "coords": [0] * len(shape),
                        "index": [[0, int(d)] for d in shape],
                        "checksum": rec.get("checksum")}]}


def _validate_manifest(manifest: Dict, leaves_like, treedef) -> List[Dict]:
    """n_leaves + treedef + per-leaf dtype/shape against ``like_tree`` —
    an incompatible tree must fail loudly naming the first bad leaf, not
    silently unflatten into the wrong structure."""
    if manifest["n_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, model expects "
            f"{len(leaves_like)} — incompatible trees")
    saved_td = manifest.get("treedef")
    if saved_td is not None and saved_td != str(treedef):
        raise ValueError(
            "checkpoint tree structure does not match the model:\n"
            f"  checkpoint: {saved_td}\n"
            f"  model:      {treedef}")
    recs = [_normalize_rec(rec) for rec in manifest["leaves"]]
    for i, (rec, like) in enumerate(zip(recs, leaves_like)):
        if not (hasattr(like, "shape") and hasattr(like, "dtype")):
            like = np.asarray(like)
        if str(like.dtype) != rec["dtype"]:
            raise ValueError(
                f"leaf {i} ({_leaf_name(rec)}): checkpoint dtype "
                f"{rec['dtype']} != model dtype {like.dtype}")
        if list(tuple(like.shape)) != list(rec["shape"]):
            raise ValueError(
                f"leaf {i} ({_leaf_name(rec)}): checkpoint shape "
                f"{tuple(rec['shape'])} != model shape "
                f"{tuple(like.shape)}")
    return recs


class _Peak:
    """Host-side materialized-byte ledger for one leaf restore: raw read
    bytes + assembly buffers in flight (the thing the reshard path must
    keep strictly below full-tensor size for sharded targets).
    Thread-safe: the pipelined engine's prefetch worker adds raw bytes
    at fetch time while the main thread subtracts after assembly."""

    def __init__(self):
        self.cur = 0
        self.peak = 0
        self._lock = threading.Lock()

    def add(self, n: int) -> None:
        with self._lock:
            self.cur += n
            self.peak = max(self.peak, self.cur)

    def sub(self, n: int) -> None:
        with self._lock:
            self.cur -= n


def _verify_shards(view: PosixView, srecs, src_idx, need, checksum,
                   peak: _Peak, itemsize: int, full_bytes: int):
    """Whole-file checksum pass over the shards a restore will touch,
    BEFORE assembly buffers exist: read chunks are byte-budgeted (sized
    from the manifest's index extents) and dropped right after hashing,
    so verification never stacks up toward full-tensor bytes."""
    todo = [j for j in sorted(need)
            if srecs[j].get("checksum") is not None]
    est = {j: index_volume(src_idx[j]) * itemsize + 512 for j in todo}
    budget = max(1, min(_BATCH_BYTES, full_bytes // 2))
    while todo:
        chunk, pend = [], 0
        while todo and (not chunk or (pend + est[todo[0]] <= budget
                                      and len(chunk) < _BATCH_FILES)):
            pend += est[todo[0]]
            chunk.append(todo.pop(0))
        raws = view.read_many([srecs[j]["path"] for j in chunk])
        total = sum(len(r) for r in raws)
        peak.add(total)
        bad = None
        for j, raw in zip(chunk, raws):
            if bad is None and checksum(raw) != srecs[j]["checksum"]:
                bad = srecs[j]["path"]
        peak.sub(total)
        if bad is not None:
            raise IOError(f"checksum mismatch in shard {bad}")


def _file_runs(src_index: Index, src_slice: Index, dtype: np.dtype):
    """Contiguous byte runs of ``src_slice`` inside its shard's .npy
    payload (C order): yields ``(payload_off, nbytes, outer_coords,
    piece_shape)``. Runs coalesce over the largest fully-covered suffix
    of dims, so a slice wanting the whole shard is ONE run."""
    s_shape = tuple(hi - lo for lo, hi in src_index)
    ext = tuple(hi - lo for lo, hi in src_slice)
    ndim = len(s_shape)
    # strides (in elements) of the shard array
    strides = [1] * ndim
    for d in range(ndim - 2, -1, -1):
        strides[d] = strides[d + 1] * s_shape[d + 1]
    # t = first dim of the contiguous tail: every dim AFTER t is fully
    # covered, so dim t's extent rides along in one run
    t = ndim - 1
    while t > 0 and ext[t] == s_shape[t] \
            and src_slice[t][0] == 0:
        t -= 1
    if ndim == 0:
        yield 0, dtype.itemsize, (), ()
        return
    tail = 1
    for d in range(t + 1, ndim):
        tail *= s_shape[d]
    run_elems = ext[t] * tail
    piece_shape = ext[t:]
    if run_elems == 0 or any(e == 0 for e in ext):
        return
    for outer in np.ndindex(*ext[:t]):
        off = src_slice[t][0] * strides[t]
        for d, c in enumerate(outer):
            off += (src_slice[d][0] + c) * strides[d]
        yield (off * dtype.itemsize, run_elems * dtype.itemsize,
               outer, piece_shape)


def _flat_dst(buf: np.ndarray, dst_slice: Index):
    """Flat view of ``buf[dst_slice]`` when the slab is C-contiguous
    (the slice covers every dim after the first), else None."""
    for d, (lo, hi) in enumerate(dst_slice[1:], 1):
        if (lo, hi) != (0, buf.shape[d]):
            return None
    return buf[tuple(slice(lo, hi) for lo, hi in dst_slice)].reshape(-1)


def _fill_buffer(view: PosixView, buf: np.ndarray, ops, srecs, src_idx,
                 dtype: np.dtype, peak: _Peak) -> int:
    """Execute one target shard's read plan as budget-bounded batches of
    OFFSET reads (the streamed ``read_many`` path): raw bytes in flight
    stay under ~half the target buffer, so assembly peaks at ~1.5x the
    target shard — never the full leaf. A single run bigger than the
    budget (target shard == whole source shard, the identity-transfer
    case) lands on a contiguous slab of ``buf`` and is itself read in
    budget-sized flat pieces. Returns crossings issued."""
    budget = max(1, min(_BATCH_BYTES, buf.nbytes // 2 or buf.itemsize))
    specs, places, pend, crossings = [], [], 0, 0

    def flush():
        nonlocal specs, places, pend, crossings
        if not specs:
            return
        raws = view.read_many(specs)
        crossings += 1
        total = sum(len(r) for r in raws)
        peak.add(total)
        for raw, (dst_view, outer, piece_shape) in zip(raws, places):
            piece = np.frombuffer(raw, dtype=dtype).reshape(piece_shape)
            if outer == ():
                dst_view[...] = piece
            else:
                dst_view[outer] = piece
        peak.sub(total)
        specs, places, pend = [], [], 0

    for op in ops:
        s = srecs[op.src]
        if "data_off" not in s:
            # no payload offset recorded (hand-written manifest): fall
            # back to one whole-file read for this shard
            raw = view.read_file(s["path"])
            crossings += 1
            peak.add(len(raw))
            arr = np.load(io.BytesIO(raw)).view(dtype)
            buf[tuple(slice(lo, hi) for lo, hi in op.dst_slice)] = \
                arr[tuple(slice(lo, hi) for lo, hi in op.src_slice)]
            peak.sub(len(raw))
            continue
        sl = tuple(slice(lo, hi) for lo, hi in op.dst_slice)
        # 0-d: buf[()] yields a scalar copy, not a view — use buf[...]
        dst_view = buf[sl] if sl else buf[...]
        for off, nbytes, outer, piece_shape in _file_runs(
                src_idx[op.src], op.src_slice, dtype):
            if outer == () and nbytes > budget:
                flat = _flat_dst(buf, op.dst_slice) if sl else None
                if flat is not None:
                    # one run would peak at buf + run: stream it instead
                    step = max(dtype.itemsize,
                               budget // dtype.itemsize * dtype.itemsize)
                    base, done = s["data_off"] + off, 0
                    while done < nbytes:
                        n = min(step, nbytes - done)
                        raw = view.read_many([(s["path"], base + done, n)])[0]
                        crossings += 1
                        peak.add(len(raw))
                        e0 = done // dtype.itemsize
                        flat[e0:e0 + n // dtype.itemsize] = \
                            np.frombuffer(raw, dtype=dtype)
                        peak.sub(len(raw))
                        done += n
                    continue
            specs.append((s["path"], s["data_off"] + off, nbytes))
            places.append((dst_view, outer, piece_shape))
            pend += nbytes
            if pend >= budget or len(specs) >= 4 * _BATCH_FILES:
                flush()
    flush()
    return crossings


def _restore_streamed(view: PosixView, rec: Dict, target, checksum,
                      peak: _Peak, info: Dict):
    """Multi-shard leaf restore: plan per target shard, stream slices."""
    shape = tuple(rec["shape"])
    dtype = _np_dtype(rec["dtype"])
    srecs = rec["shards"]
    src_idx = [tuple((int(lo), int(hi)) for lo, hi in s["index"])
               for s in srecs]
    if isinstance(target, ShardGrid):
        # Uneven (non-divisible) target grids: jax's NamedSharding
        # refuses non-divisible tilings outright, so elastic restores
        # onto uneven meshes carry a ShardGrid target instead. Every —
        # possibly short or empty — cell gets its own reshard plan
        # (exercising remainder slicing) and lands, shifted to global
        # coordinates, in ONE full-shape host buffer; the result is
        # device_put whole. max_target_bytes == full_bytes marks the
        # leaf exempt from the strict sub-full peak budget (there is no
        # per-device placement to stream into).
        if target.shape != shape:
            raise ValueError(
                f"target grid shape {target.shape} != leaf shape {shape}")
        full = tuple((0, d) for d in shape)
        cells = [c for c in target.indices() if index_volume(c) > 0]
        ops: List = []
        for cell in cells:
            cops = plan_target_shard(src_idx, cell)
            if plan_volume(cops) != index_volume(cell):
                raise IOError(
                    f"shard records cover {plan_volume(cops)} of "
                    f"{index_volume(cell)} elements for slice {cell} of "
                    f"{_leaf_name(rec)} — incomplete checkpoint")
            ops.extend(shift_ops(cops, cell))
        info["n_target_groups"] = len(cells)
        info["max_target_bytes"] = index_volume(full) * dtype.itemsize
        if checksum:
            need = {op.src for op in ops}
            _verify_shards(view, srecs, src_idx, need, checksum, peak,
                           dtype.itemsize, index_volume(full)
                           * dtype.itemsize)
        buf = np.empty(shape, dtype)
        peak.add(buf.nbytes)
        _fill_buffer(view, buf, ops, srecs, src_idx, dtype, peak)
        leaf = jax.device_put(buf)
        peak.sub(buf.nbytes)
        return leaf
    if isinstance(target, NamedSharding):
        dmap = target.addressable_devices_indices_map(shape)
        groups: Dict[Index, list] = {}
        for dev, idx in dmap.items():
            groups.setdefault(normalize_index(idx, shape), []).append(dev)
        plans = {di: plan_target_shard(src_idx, di) for di in groups}
        need = {op.src for ops in plans.values() for op in ops}
    else:
        full = tuple((0, d) for d in shape)
        plans = {full: plan_target_shard(src_idx, full)}
        groups = {full: None}
        need = {op.src for op in plans[full]}
    info["n_target_groups"] = len(groups)
    info["max_target_bytes"] = max(
        (index_volume(di) * dtype.itemsize for di in groups), default=0)
    if checksum:
        full_bytes = index_volume(
            tuple((0, d) for d in shape)) * dtype.itemsize
        _verify_shards(view, srecs, src_idx, need, checksum, peak,
                       dtype.itemsize, full_bytes)
    arrays = []
    for di in sorted(groups):
        ops = plans[di]
        if plan_volume(ops) != index_volume(di):
            raise IOError(
                f"shard records cover {plan_volume(ops)} of "
                f"{index_volume(di)} elements for slice {di} of "
                f"{_leaf_name(rec)} — incomplete checkpoint")
        buf = np.empty(tuple(hi - lo for lo, hi in di), dtype)
        peak.add(buf.nbytes)
        _fill_buffer(view, buf, ops, srecs, src_idx, dtype, peak)
        if groups[di] is None:
            leaf = jax.device_put(buf) if target is None \
                else jax.device_put(buf, target)
            peak.sub(buf.nbytes)
            return leaf
        for dev in groups[di]:
            arrays.append(jax.device_put(buf, dev))
        peak.sub(buf.nbytes)
    return jax.make_array_from_single_device_arrays(shape, target, arrays)


# --- pipelined restore engine ----------------------------------------------


class _Task:
    """One pipelined-restore work unit: ``specs`` (``read_many`` specs;
    may be empty for pure-assembly steps like unit finalizers) are
    fetched — possibly ahead, on the prefetch thread — then
    ``on_ready(raws)`` runs on the main thread in strict FIFO order.
    ``peak`` (optional) meters the fetched raw bytes from fetch until
    assembly finishes; ``weight`` is the number of tokens the task
    occupies in ``win`` — its leaf's admission window — while in
    flight. Windows are PER LEAF (plus one shared window for the
    simple-batch tasks): an oversized fetch runs exclusive within its
    own leaf, bounding that leaf's metered peak, without stalling the
    prefetch of the NEXT leaf behind the current leaf's assembly —
    that cross-leaf overlap is where the restore pipeline's win
    actually comes from."""

    __slots__ = ("specs", "on_ready", "peak", "weight", "win")

    def __init__(self, specs, on_ready, peak=None, weight=1, win=None):
        self.specs = specs
        self.on_ready = on_ready
        self.peak = peak
        self.weight = weight
        self.win = win


class _Window:
    """Counted-token admission window — the pipeline's byte budget.

    ``depth`` tokens total, ONE window per leaf; a unit-weight task
    carries at most one chunk budget of raw bytes, so a leaf's in-flight
    raw stays <= depth x chunk == the unit's SERIAL read budget. An
    oversized task weighs ``depth`` and runs exclusive — within its own
    leaf only, so it never blocks another leaf's prefetch. ``abort()``
    wakes a blocked producer when the consumer dies mid-restore."""

    def __init__(self, depth: int):
        self._depth = depth
        self._avail = depth
        self._cv = threading.Condition()
        self._aborted = False

    def acquire(self, weight: int) -> bool:
        weight = min(weight, self._depth)
        with self._cv:
            while self._avail < weight and not self._aborted:
                self._cv.wait()
            if self._aborted:
                return False
            self._avail -= weight
            return True

    def release(self, weight: int) -> None:
        weight = min(weight, self._depth)
        with self._cv:
            self._avail += weight
            self._cv.notify_all()

    def abort(self) -> None:
        with self._cv:
            self._aborted = True
            self._cv.notify_all()


def _run_inline(view: PosixView, tasks: List[_Task], timing: Dict) -> None:
    """depth-1 execution: the task list runs on the calling thread —
    single-pass folded verification without prefetch."""
    for t in tasks:
        t0 = time.perf_counter()
        raws = view.read_many(t.specs) if t.specs else []
        timing["fetch_s"] += time.perf_counter() - t0
        total = sum(len(r) for r in raws)
        if t.peak is not None:
            t.peak.add(total)
        kept = 0
        try:
            t0 = time.perf_counter()
            kept = t.on_ready(raws) or 0
            timing["assemble_s"] += time.perf_counter() - t0
        finally:
            if t.peak is not None:
                t.peak.sub(total - kept)


def _run_pipelined(view: PosixView, tasks: List[_Task], depth: int,
                   timing: Dict) -> None:
    """depth>=2 execution: a prefetch worker fetches ahead under the
    token window (its ``read_many`` submissions ride the worker thread's
    own thread-local ``SubmitterQueue``); the main thread assembles in
    FIFO order, so failures surface exactly where the serial path would
    raise them and speculatively fetched bytes after a failure are
    dropped, never assembled."""
    fallback = _Window(depth)
    for t in tasks:
        if t.win is None:
            t.win = fallback
    wins = {id(t.win): t.win for t in tasks}.values()
    results: "queue.Queue" = queue.Queue()
    stop = threading.Event()

    def worker():
        for t in tasks:
            if not t.win.acquire(t.weight) or stop.is_set():
                return
            try:
                t0 = time.perf_counter()
                raws = view.read_many(t.specs) if t.specs else []
                timing["fetch_s"] += time.perf_counter() - t0
            except BaseException as e:  # noqa: BLE001 — re-raised on main
                results.put((t, e, 0))
                return
            total = sum(len(r) for r in raws)
            if t.peak is not None:
                t.peak.add(total)
            results.put((t, raws, total))

    th = threading.Thread(target=worker, name="ckpt-prefetch", daemon=True)
    th.start()
    try:
        for _ in tasks:
            t, payload, total = results.get()
            if isinstance(payload, BaseException):
                raise payload
            kept = 0
            try:
                t0 = time.perf_counter()
                kept = t.on_ready(payload) or 0
                timing["assemble_s"] += time.perf_counter() - t0
            finally:
                if t.peak is not None:
                    t.peak.sub(total - kept)
                t.win.release(t.weight)
    except BaseException:
        stop.set()
        for w in wins:
            w.abort()
        raise
    finally:
        th.join(timeout=30)


def _flat_ok(ushape, dst_slice: Index) -> bool:
    """True when ``buf[dst_slice]`` is C-contiguous (the slice covers
    every dim after the first) — the shape-only twin of ``_flat_dst``."""
    return all((lo, hi) == (0, ushape[d])
               for d, (lo, hi) in enumerate(dst_slice[1:], 1))


def _unit_tasks(view: PosixView, srecs, src_idx, dtype: np.dtype, ops,
                di: Index, depth: int, peak: _Peak, checksum,
                checksum_batch, verified: set, finalize,
                memo=None) -> List[_Task]:
    """Compile ONE assembly unit (one target buffer) into tasks.

    Folded verification: the first op touching a checksummed shard in
    ``verified``-order becomes a whole-file unit — fetched once, hashed
    (one ``checksum_batch`` launch per fetched chunk) and that op's
    slices served straight from the fetched bytes; later ops on a
    verified shard are plain offset reads. The buffer allocates lazily
    in the unit's first assembly step; the trailing zero-spec task runs
    ``finalize(buf)`` and releases the buffer's peak bytes.

    ``memo`` (built by ``_leaf_tasks`` when depth >= 2) retains the most
    recently fetched whole-file shard so that LATER units reading the
    same shard assemble straight from RAM instead of re-fetching slices
    through the store — the retained bytes stay on the peak ledger, and
    a zero-spec drop task queued before the next memoized fetch keeps at
    most one retained shard live at a time."""
    itemsize = dtype.itemsize
    ushape = tuple(hi - lo for lo, hi in di)
    unit_full = tuple((0, hi - lo) for lo, hi in di)
    ubytes = index_volume(di) * itemsize
    serial_budget = max(1, min(_BATCH_BYTES, ubytes // 2 or itemsize))
    chunk = max(itemsize, serial_budget // max(1, depth))
    state = {"buf": None, "buf_bytes": 0}
    tasks: List[_Task] = []

    def buf() -> np.ndarray:
        if state["buf"] is None:
            state["buf"] = np.empty(ushape, dtype)
            state["buf_bytes"] = state["buf"].nbytes
            peak.add(state["buf_bytes"])
        return state["buf"]

    def weigh(est: int) -> int:
        return min(depth, max(1, -(-est // chunk)))

    # whole-file units: first-touch verification + no-data_off shards
    wf = {"entries": [], "est": 0}  # entries: (path, expected, apply)

    def flush_wf():
        entries = wf["entries"]
        if not entries:
            return
        est = wf["est"]
        wf["entries"], wf["est"] = [], 0

        def on_ready(raws, entries=entries):
            need = [k for k, e in enumerate(entries) if e[1] is not None]
            if need:
                if checksum_batch is not None:
                    got = checksum_batch([raws[k] for k in need])
                else:
                    got = [checksum(raws[k]) for k in need]
                for k, g in zip(need, got):
                    if g != entries[k][1]:
                        raise IOError(
                            f"checksum mismatch in shard {entries[k][0]}")
            kept = 0
            for raw, (_path, _exp, apply) in zip(raws, entries):
                kept += apply(raw)
            return kept

        tasks.append(_Task([e[0] for e in entries], on_ready,
                           peak=peak, weight=weigh(est)))

    def add_wf(op, s, expected, memoize=False):
        vol = index_volume(src_idx[op.src])
        est = vol * itemsize + 512
        if wf["entries"] and wf["est"] + est > chunk:
            flush_wf()
        s_shape = tuple(hi - lo for lo, hi in src_idx[op.src])

        def apply(raw, op=op, s=s, s_shape=s_shape, vol=vol,
                  memoize=memoize):
            if "data_off" in s:
                arr = np.frombuffer(raw, dtype=dtype,
                                    offset=s["data_off"],
                                    count=vol).reshape(s_shape)
            else:
                arr = np.load(io.BytesIO(raw)).view(dtype)
            src = arr[tuple(slice(lo, hi) for lo, hi in op.src_slice)]
            if memoize:
                # retain the decoded shard for later units of this
                # leaf; its bytes stay on the ledger until the drop
                # task (or the leaf-end cleanup) releases them
                if "data_off" in s:
                    kept = len(raw)  # arr aliases raw
                else:
                    kept = 0  # np.load copied; raw itself is free
                    peak.add(arr.nbytes)
                memo["src"], memo["arr"] = op.src, arr
                memo["bytes"] = len(raw) if "data_off" in s else arr.nbytes
                b = buf()
                b[tuple(slice(lo, hi) for lo, hi in op.dst_slice)] = src
                return kept
            if state["buf"] is None and op.dst_slice == unit_full:
                # identity serve: the verified file IS the buffer
                # (zero copy) — exact coverage means no other op writes
                # this unit, so the read-only view is safe. Returning
                # len(raw) keeps the raw's bytes on the ledger until
                # the finalize step instead of end-of-assembly.
                state["buf"] = src
                state["buf_bytes"] = len(raw)
                return len(raw)
            b = buf()
            b[tuple(slice(lo, hi) for lo, hi in op.dst_slice)] = src
            return 0

        wf["entries"].append((s["path"], expected, apply))
        wf["est"] += est

    # offset-read runs (verified / checksum-free shards with data_off)
    run = {"specs": [], "places": [], "pend": 0}

    def flush_runs():
        specs, places = run["specs"], run["places"]
        if not specs:
            return
        est = run["pend"]
        run["specs"], run["places"], run["pend"] = [], [], 0

        def on_ready(raws, places=places):
            b = buf()
            for raw, pl in zip(raws, places):
                if pl[0] == "flat":
                    _k, dsl, e0, n = pl
                    flat = b[tuple(slice(lo, hi) for lo, hi in dsl)] \
                        .reshape(-1)
                    flat[e0:e0 + n] = np.frombuffer(raw, dtype=dtype)
                else:
                    _k, dsl, outer, pshape = pl
                    sl = tuple(slice(lo, hi) for lo, hi in dsl)
                    dst = b[sl] if sl else b[...]
                    piece = np.frombuffer(raw, dtype=dtype).reshape(pshape)
                    if outer == ():
                        dst[...] = piece
                    else:
                        dst[outer] = piece

        tasks.append(_Task(specs, on_ready, peak=peak, weight=weigh(est)))

    def add_runs(op, s):
        for off, nbytes, outer, pshape in _file_runs(
                src_idx[op.src], op.src_slice, dtype):
            if outer == () and nbytes > chunk \
                    and _flat_ok(ushape, op.dst_slice):
                # an oversized contiguous run streams as its own chain
                # of flat-slab tasks instead of one giant fetch
                flush_runs()
                step = max(itemsize, chunk // itemsize * itemsize)
                base, done_b = s["data_off"] + off, 0
                while done_b < nbytes:
                    n = min(step, nbytes - done_b)
                    run["specs"].append((s["path"], base + done_b, n))
                    run["places"].append(
                        ("flat", op.dst_slice, done_b // itemsize,
                         n // itemsize))
                    run["pend"] += n
                    flush_runs()
                    done_b += n
                continue
            run["specs"].append((s["path"], s["data_off"] + off, nbytes))
            run["places"].append(("nd", op.dst_slice, outer, pshape))
            run["pend"] += nbytes
            if run["pend"] >= chunk or len(run["specs"]) >= 4 * _BATCH_FILES:
                flush_runs()

    def add_memo(op):
        def on_ready(_raws, op=op):
            if memo["src"] != op.src:
                raise IOError(
                    f"restore memo lost shard {op.src} mid-leaf")
            src = memo["arr"][
                tuple(slice(lo, hi) for lo, hi in op.src_slice)]
            b = buf()
            sl = tuple(slice(lo, hi) for lo, hi in op.dst_slice)
            if sl:
                b[sl] = src
            else:
                b[...] = src

        tasks.append(_Task([], on_ready))

    # chunk_ops bounds each op-group's destination bytes; flushing both
    # accumulators at group boundaries keeps every task within roughly
    # one chunk budget of raw bytes (whole-file units excepted — their
    # weight covers the full file)
    for group in chunk_ops(ops, itemsize, chunk, max_ops=4 * _BATCH_FILES):
        for op in group:
            s = srecs[op.src]
            if memo is not None and op.src == memo["psrc"]:
                add_memo(op)  # served from the retained shard, no fetch
                continue
            first = (checksum is not None
                     and s.get("checksum") is not None
                     and op.src not in verified)
            if first or "data_off" not in s:
                if first:
                    verified.add(op.src)
                if memo is not None and op.src in memo["worthy"]:
                    # the old retained shard must leave the ledger
                    # before this exclusive whole-file fetch starts;
                    # the drop task's window token enforces that order
                    flush_wf()
                    if memo["psrc"] is not None:
                        tasks.append(_Task([], memo["drop"]))
                    add_wf(op, s, s["checksum"] if first else None,
                           memoize=True)
                    flush_wf()
                    memo["psrc"] = op.src
                else:
                    add_wf(op, s, s["checksum"] if first else None)
            else:
                add_runs(op, s)
        flush_wf()
        flush_runs()

    def fin(_raws):
        b = buf()
        finalize(b)
        peak.sub(state["buf_bytes"])

    # the finalizer holds one token of ITS OWN leaf's window: the same
    # leaf's next unit must not fetch while this unit's buffer (possibly
    # an aliased whole-file raw) is still on the peak ledger — but other
    # leaves' windows are untouched, so their prefetch overlaps this
    # leaf's device_put
    tasks.append(_Task([], fin))
    return tasks


def _leaf_tasks(view: PosixView, rec: Dict, target, checksum,
                checksum_batch, depth: int, peak: _Peak, info: Dict,
                done) -> List[_Task]:
    """Compile one multi-shard leaf's restore into an ordered task list;
    ``done(leaf)`` fires from the last finalize with the assembled
    array. FIFO execution means at most one of the leaf's unit buffers
    is ever live, exactly like the serial path."""
    shape = tuple(rec["shape"])
    dtype = _np_dtype(rec["dtype"])
    itemsize = dtype.itemsize
    srecs = rec["shards"]
    src_idx = [tuple((int(lo), int(hi)) for lo, hi in s["index"])
               for s in srecs]
    full = tuple((0, d) for d in shape)

    def check(ops, di):
        if plan_volume(ops) != index_volume(di):
            raise IOError(
                f"shard records cover {plan_volume(ops)} of "
                f"{index_volume(di)} elements for slice {di} of "
                f"{_leaf_name(rec)} — incomplete checkpoint")

    tasks: List[_Task] = []
    verified: set = set()

    def memo_plan(unit_ops, max_unit_bytes):
        """Shards fetched whole (first-touch verify / no data_off) that
        MORE units will read again are worth retaining in RAM — if the
        retained bytes plus a unit buffer still fit well under the full
        tensor, so the metered-peak discipline survives."""
        if depth < 2:
            return None  # depth 1 has no budget headroom for a memo
        full_b = index_volume(full) * itemsize
        counts: Dict[int, int] = {}
        for ops in unit_ops:
            for op in ops:
                counts[op.src] = counts.get(op.src, 0) + 1
        worthy = set()
        for src, n in counts.items():
            s = srecs[src]
            wf_first = ((checksum is not None
                         and s.get("checksum") is not None)
                        or "data_off" not in s)
            # +512 covers the npy header, which rides the ledger as
            # part of len(raw) and dominates for tiny shards
            sb = index_volume(src_idx[src]) * itemsize + 512
            if n > 1 and wf_first and sb + 2 * max_unit_bytes <= full_b:
                worthy.add(src)
        if not worthy:
            return None
        m = {"psrc": None, "src": None, "arr": None, "bytes": 0,
             "worthy": worthy}

        def drop(_raws=None):
            if m["arr"] is not None:
                peak.sub(m["bytes"])
                m["src"] = m["arr"] = None
                m["bytes"] = 0

        m["drop"] = drop
        return m

    if isinstance(target, NamedSharding):
        dmap = target.addressable_devices_indices_map(shape)
        groups: Dict[Index, list] = {}
        for dev, idx in dmap.items():
            groups.setdefault(normalize_index(idx, shape), []).append(dev)
        info["n_target_groups"] = len(groups)
        info["max_target_bytes"] = max(
            (index_volume(di) * itemsize for di in groups), default=0)
        arrays: List = []
        dis = sorted(groups)
        unit_ops = []
        for di in dis:
            ops = plan_target_shard(src_idx, di)
            check(ops, di)
            unit_ops.append(ops)
        memo = memo_plan(unit_ops, info["max_target_bytes"])
        for u_i, di in enumerate(dis):

            def finalize(b, devs=groups[di], last=(u_i == len(dis) - 1)):
                for dev in devs:
                    arrays.append(jax.device_put(b, dev))
                if last:
                    done(jax.make_array_from_single_device_arrays(
                        shape, target, arrays))

            tasks += _unit_tasks(view, srecs, src_idx, dtype,
                                 unit_ops[u_i], di, depth, peak,
                                 checksum, checksum_batch, verified,
                                 finalize, memo=memo)
        if memo is not None:
            tasks.append(_Task([], memo["drop"]))
    elif isinstance(target, ShardGrid):
        # uneven target grids: same protocol as the serial branch — all
        # cells plan separately, shift into ONE full-shape host buffer
        if target.shape != shape:
            raise ValueError(
                f"target grid shape {target.shape} != leaf shape {shape}")
        cells = [c for c in target.indices() if index_volume(c) > 0]
        ops = []
        for cell in cells:
            cops = plan_target_shard(src_idx, cell)
            check(cops, cell)
            ops.extend(shift_ops(cops, cell))
        info["n_target_groups"] = len(cells)
        info["max_target_bytes"] = index_volume(full) * itemsize
        tasks += _unit_tasks(view, srecs, src_idx, dtype, ops, full,
                             depth, peak, checksum, checksum_batch,
                             verified,
                             lambda b: done(jax.device_put(b)))
    else:
        ops = plan_target_shard(src_idx, full)
        check(ops, full)
        info["n_target_groups"] = 1
        info["max_target_bytes"] = index_volume(full) * itemsize
        tasks += _unit_tasks(
            view, srecs, src_idx, dtype, ops, full, depth, peak,
            checksum, checksum_batch, verified,
            lambda b: done(jax.device_put(b) if target is None
                           else jax.device_put(b, target)))
    return tasks


def _build_tasks(view: PosixView, recs, shardings, checksum,
                 checksum_batch, depth: int, out, note) -> List[_Task]:
    """Compile the whole restore into one ordered task list: single-shard
    leaves batch v1-style (one crossing per ~``_BATCH_FILES`` whole
    files, one hash launch per fetched chunk); multi-shard leaves expand
    through the reshard plan compiler. Every multi-shard leaf gets its
    OWN admission window (simple batches share one): an oversized fetch
    is exclusive only within its leaf, so leaf N+1 prefetches while
    leaf N assembles."""
    tasks: List[_Task] = []
    simple_win = _Window(depth)
    batch = {"idx": [], "est": 0}

    def flush_simple():
        idxs = batch["idx"]
        if not idxs:
            return
        est = batch["est"]
        batch["idx"], batch["est"] = [], 0

        def on_ready(raws, idxs=idxs):
            got = None
            if checksum is not None and checksum_batch is not None:
                need = [k for k, i in enumerate(idxs)
                        if recs[i]["shards"][0].get("checksum") is not None]
                if need:
                    got = dict(zip(
                        need, checksum_batch([raws[k] for k in need])))
            for k, (i, raw) in enumerate(zip(idxs, raws)):
                rec, s = recs[i], recs[i]["shards"][0]
                peak = _Peak()
                peak.add(len(raw))
                if checksum and s.get("checksum") is not None:
                    g = got[k] if got is not None else checksum(raw)
                    if g != s["checksum"]:
                        raise IOError(
                            f"checksum mismatch in shard {s['path']}")
                arr = np.load(io.BytesIO(raw))
                if rec["dtype"] in _WIRE_DTYPES:
                    import ml_dtypes
                    arr = arr.view(getattr(ml_dtypes, rec["dtype"]))
                if list(arr.shape) != list(rec["shape"]):
                    raise IOError(f"shape mismatch in {s['path']}")
                peak.add(arr.nbytes)
                target = shardings[i]
                if target is None or isinstance(target, ShardGrid):
                    # a 1-shard source with a (possibly uneven) grid
                    # target has no device placement to honor
                    out[i] = jax.device_put(arr)
                else:
                    out[i] = jax.device_put(arr, target)
                peak.sub(len(raw) + arr.nbytes)
                note(i, rec, peak, streamed=False)

        tasks.append(_Task(
            [recs[i]["shards"][0]["path"] for i in idxs], on_ready,
            weight=min(depth, max(1, -(-est // _BATCH_BYTES))),
            win=simple_win))

    for i, rec in enumerate(recs):
        if len(rec["shards"]) == 1:
            batch["idx"].append(i)
            batch["est"] += index_volume(
                tuple((0, int(d)) for d in rec["shape"])) \
                * _np_dtype(rec["dtype"]).itemsize + 512
            if len(batch["idx"]) >= _BATCH_FILES:
                flush_simple()
        else:
            peak, info = _Peak(), {}

            def done(leaf, i=i, rec=rec, peak=peak, info=info):
                out[i] = leaf
                note(i, rec, peak, streamed=True, info=info)

            lts = _leaf_tasks(view, rec, shardings[i], checksum,
                              checksum_batch, depth, peak, info, done)
            leaf_win = _Window(depth)
            for t in lts:
                t.win = leaf_win
            tasks += lts
    flush_simple()
    return tasks


def load(view: PosixView, root: str, like_tree, *, checksum=None,
         checksum_batch=None, sharding_tree=None,
         stats: Optional[Dict] = None,
         pipeline_depth: Optional[int] = None):
    """Restore into the structure of ``like_tree``; optionally assemble
    each leaf under the matching sharding from ``sharding_tree`` (elastic
    rescale onto a different mesh — multi-shard leaves restore via the
    streamed reshard plan, never materializing the full tensor; an
    uneven ShardGrid target assembles one full host array per leaf).
    ``stats`` (a dict, mutated) collects per-leaf peak/full byte counts
    plus a ``pipeline`` record (depth, fetch/assemble seconds, overlap
    ratio). ``pipeline_depth`` selects the engine (see the module
    docstring); ``checksum_batch`` (optional, e.g.
    ``KernelServices.checksum_batch``) hashes each fetched chunk in one
    launch on the folded-verification paths."""
    t_start = time.perf_counter()
    depth = _resolve_depth(pipeline_depth)
    manifest = json.loads(view.read_file(f"{root}/{MANIFEST}"))
    leaves_like, treedef = _flatten(like_tree)
    recs = _validate_manifest(manifest, leaves_like, treedef)
    shardings: List[Any] = [None] * len(leaves_like)
    if sharding_tree is not None:
        shardings = _flatten_shardings(sharding_tree)
        if len(shardings) != len(leaves_like):
            raise ValueError(
                f"sharding tree has {len(shardings)} leaves, model has "
                f"{len(leaves_like)} — incompatible trees")
    out: List[Any] = [None] * len(recs)
    leaf_stats: List[Dict] = []

    def note(i, rec, peak, streamed, info=None):
        full = index_volume(tuple(
            (0, d) for d in rec["shape"])) * _np_dtype(rec["dtype"]).itemsize
        leaf_stats.append({"leaf": i, "peak_bytes": peak.peak,
                           "full_bytes": full,
                           "n_src_shards": len(rec["shards"]),
                           "streamed": streamed, **(info or {})})

    timing = {"fetch_s": 0.0, "assemble_s": 0.0}
    if depth <= 0:
        _load_serial(view, recs, shardings, checksum, out, note)
    else:
        tasks = _build_tasks(view, recs, shardings, checksum,
                             checksum_batch, depth, out, note)
        total = sum(
            index_volume(tuple((0, d) for d in r["shape"]))
            * _np_dtype(r["dtype"]).itemsize for r in recs)
        if depth == 1 or total < _INLINE_BYTES:
            # a restore this small has nothing worth prefetching — the
            # worker thread's spawn/teardown and lock traffic would cost
            # more than any overlap buys, so the SAME task list (folded
            # verification included) runs on the calling thread
            _run_inline(view, tasks, timing)
        else:
            _run_pipelined(view, tasks, depth, timing)
    if stats is not None:
        stats["leaves"] = sorted(leaf_stats, key=lambda s: s["leaf"])
        stats["version"] = manifest.get("version", 1)
        wall = max(time.perf_counter() - t_start, 1e-9)
        busy = timing["fetch_s"] + timing["assemble_s"]
        stats["pipeline"] = {
            "depth": depth,
            "fetch_s": timing["fetch_s"],
            "assemble_s": timing["assemble_s"],
            "wall_s": wall,
            # fraction of the wall the fetch and assemble phases ran
            # concurrently — 0 by construction for depth <= 1
            "overlap_ratio": max(0.0, busy - wall) / wall,
        }
    return jax.tree.unflatten(treedef, out), manifest


def _load_serial(view: PosixView, recs, shardings, checksum, out,
                 note) -> None:
    """The depth-0 reference path: serial two-pass restore (whole-file
    verify pre-pass, then offset-read fill), kept verbatim as the
    overlap-off baseline the pipelined engine is differentially tested
    and benchmarked against."""
    # single-shard leaves batch v1-style: one crossing per ~_BATCH_FILES
    # whole files; multi-shard leaves go through the streamed plan
    pend: List[int] = []

    def flush_simple():
        raws = view.read_many([recs[i]["shards"][0]["path"] for i in pend])
        for i, raw in zip(pend, raws):
            rec, s = recs[i], recs[i]["shards"][0]
            peak = _Peak()
            peak.add(len(raw))
            if checksum and s.get("checksum") is not None \
                    and checksum(raw) != s["checksum"]:
                raise IOError(f"checksum mismatch in shard {s['path']}")
            arr = np.load(io.BytesIO(raw))
            if rec["dtype"] in _WIRE_DTYPES:
                import ml_dtypes
                arr = arr.view(getattr(ml_dtypes, rec["dtype"]))
            if list(arr.shape) != list(rec["shape"]):
                raise IOError(f"shape mismatch in {s['path']}")
            peak.add(arr.nbytes)
            target = shardings[i]
            if target is None or isinstance(target, ShardGrid):
                # a 1-shard source with a (possibly uneven) grid target
                # has no device placement to honor
                out[i] = jax.device_put(arr)
            else:
                out[i] = jax.device_put(arr, target)
            peak.sub(len(raw) + arr.nbytes)
            note(i, rec, peak, streamed=False)
        pend.clear()

    for i, rec in enumerate(recs):
        if len(rec["shards"]) == 1:
            pend.append(i)
            if len(pend) >= _BATCH_FILES:
                flush_simple()
        else:
            peak, info = _Peak(), {}
            out[i] = _restore_streamed(view, rec, shardings[i], checksum,
                                       peak, info)
            note(i, rec, peak, streamed=True, info=info)
    if pend:
        flush_simple()


def latest_step(view: PosixView, base: str) -> Optional[int]:
    """Newest step with a PARSEABLE manifest — an empty or torn manifest
    (crash inside the save's final commit window) is treated as no
    checkpoint, so restart falls back to the previous good step."""
    if not view.exists(base):
        return None
    steps = []
    for name in view.listdir(base):
        if name.startswith("step_"):
            try:
                json.loads(view.read_file(f"{base}/{name}/{MANIFEST}"))
                steps.append(int(name.split("_")[1]))
            except (FsError, ValueError, IndexError):
                continue
    return max(steps) if steps else None
