"""File provenance as a stackable Bento layer (paper §6, the headline demo).

The paper's signature move is adding provenance tracking to a RUNNING
kernel file system with milliseconds of interruption: Bento-prov wraps
xv6, intercepts every operation, and logs who touched what — installed by
the online-upgrade path, not a remount. ``ProvFilesystem`` is that layer
for this repo: a ``BentoFilesystem`` that owns no disk format of its own,
delegates every scalar and batched/chained op to an INNER module
(xv6/ext4like), and appends one plain-value record per successful mutation
to an on-device log::

    {"op", "ino", "parent", "name", "pid", "submitter", "ts", ...}

Design rules, in order of importance:

* **The log is journal-protected and ordered.** Records are appended
  through the inner module's own ``write`` path, so they stage into the
  SAME write-ahead journal as the mutations they describe. Records are
  always staged AFTER their mutation on the same thread, and a journal
  commit installs the whole pending set atomically — so a committed record
  can never describe an uncommitted mutation: the log never references an
  inode or name the recovered file system doesn't explain.

* **Namespace mutations commit with their record in ONE transaction.**
  Scalar create/mkdir/unlink/rmdir/rename run inside a chain-scoped
  journal reservation (``Journal.begin_chain``) that also covers the
  record append: after a crash, the mutation and its record are durable
  together or not at all (old-XOR-new), proven per crash point by
  ``repro.fs.crashsim.torture_prov``. SQE_LINK chains get the same
  guarantee through the existing chain hooks — ``chain_begin`` forwards to
  the inner fs with the record footprint added to the reservation (the
  ``extra_blocks`` log-allocation hook), so one journal transaction spans
  the chain's data AND its provenance.

* **Every dispatch shape composes.** ``submit_batch`` delegates whole
  entry runs to the inner module (its vectorized ``_many`` paths, write
  coalescing and cross-submitter coalescing survive intact), then appends
  one combined record batch; chain members arriving one-at-a-time from
  ``execute_batch`` are detected via ``journal.in_chain_here`` and their
  appends are bracketed with the member-undo scope so a failed append
  rolls back cleanly mid-chain.

* **The log hides from the namespace.** It lives at a reserved root name
  (``PROV_LOG_NAME``), created lazily on first record; the layer filters
  it from ``lookup``/``readdir`` and refuses direct mutation, so wrapped
  and plain mounts expose identical trees. Downgrading strips the layer
  but leaves the log durable — the next wrap adopts it and keeps
  appending (sequence numbers are line positions, so history stays
  monotonic across plain→prov→plain cycles).

Install/remove on a live mount via ``repro.core.upgrade``::

    wrap_layer(mount, ProvFilesystem)   # plain -> prov, no remount
    unwrap_layer(mount)                 # prov -> plain

Queries cross the boundary as the ``read_provenance`` op (scalar, batched
and FUSE dispatch all carry it), surfaced to applications as
``PosixView.read_provenance``.
"""

from __future__ import annotations

import contextlib
import json
import os
import threading
from typing import Any, Dict, List, Optional, Tuple

from repro.core.capability import SuperBlockCap
from repro.core.interface import (Attr, BentoFilesystem, CompletionEntry,
                                  Errno, FileKind, FsError, ROOT_INO,
                                  SubmissionEntry)

# Reserved root name of the on-device log. Hidden by the layer; visible as
# an ordinary file if the image is mounted plain (documented, harmless).
PROV_LOG_NAME = ".bento-prov"
# Rotation scratch file: the compacted log is built here, then atomically
# swapped over PROV_LOG_NAME via rename-overwrite (old-XOR-new retention).
PROV_LOG_TMP = ".bento-prov.new"

# Ops that mutate state and therefore earn a record.
PROV_MUTATING_OPS = frozenset({
    "create", "mkdir", "unlink", "rmdir", "rename", "write", "truncate"})

# Per-record upper bound for reservation estimates (json line incl. names).
_REC_BYTES_EST = 224


class ProvFilesystem(BentoFilesystem):
    """Stackable provenance layer over any journaled BentoFilesystem."""

    NAME = "prov"
    VERSION = 1

    # Rotation threshold: once the log exceeds this many bytes, _append
    # compacts it to the newest half of its records (0 disables). Long
    # torture runs otherwise grow the log without bound.
    ROTATE_BYTES = 256 * 1024

    def __init__(self, inner: BentoFilesystem):
        self.inner = inner
        self.ks = None
        self._log_ino = 0       # 0: not yet discovered/created (lazy)
        self._log_size = 0
        # byte offset of each complete record line, maintained across
        # appends so incremental queries read only the log's suffix; None
        # until the first full scan (or after a dropped append resync)
        self._line_index: Optional[List[int]] = None
        # seq of the log's first retained line (> 0 after a rotation
        # dropped history; recovered from the head marker line on rescan)
        self._seq_base = 0
        self.rotate_bytes = self.ROTATE_BYTES
        self._plock = threading.RLock()  # serializes append/size bookkeeping
        self.prov_stats = {"records": 0, "append_errors": 0, "appends": 0,
                           "rotations": 0, "rotate_errors": 0}

    # the benchmark/torture tooling reaches for module.journal / .opts —
    # keep those windows open through the layer
    @property
    def journal(self):
        return getattr(self.inner, "journal", None)

    @property
    def opts(self):
        return getattr(self.inner, "opts", None)

    @property
    def stats(self):
        return getattr(self.inner, "stats", {})

    # --- lifecycle -------------------------------------------------------------
    def init(self, sb: SuperBlockCap, services) -> None:
        self.inner.init(sb, services)
        self.ks = services
        self._discover_log()

    def destroy(self) -> None:
        self.inner.destroy()

    def _discover_log(self) -> None:
        """Adopt an existing on-device log (remount, re-wrap after a
        downgrade); creation stays lazy so attaching the layer writes
        nothing — the upgrade pause stays read-only."""
        try:
            attr = self.inner.lookup(ROOT_INO, PROV_LOG_NAME)
            self._log_ino, self._log_size = attr.ino, attr.size
        except FsError:
            self._log_ino, self._log_size = 0, 0
        self._line_index = None

    def _ensure_log(self) -> None:
        if self._log_ino == 0:
            attr = self.inner.create(ROOT_INO, PROV_LOG_NAME)
            self._log_ino, self._log_size = attr.ino, attr.size

    # --- §4.8 state transfer: layer-aware passthrough ----------------------------
    def extract_state(self) -> Dict:
        st = dict(self.inner.extract_state())
        st["prov"] = {"log_ino": self._log_ino, "log_size": self._log_size,
                      "seq_base": self._seq_base,
                      "rotate_bytes": self.rotate_bytes,
                      "stats": dict(self.prov_stats)}
        return st

    def restore_state(self, state: Dict, from_version: int) -> None:
        inner_state = {k: v for k, v in state.items() if k != "prov"}
        self.inner.restore_state(inner_state, from_version)
        p = state.get("prov")
        if p:  # prov -> prov upgrade: carry the layer's own state
            self._log_ino = int(p.get("log_ino", 0))
            self._log_size = int(p.get("log_size", 0))
            self._seq_base = int(p.get("seq_base", 0))
            self.rotate_bytes = int(p.get("rotate_bytes", self.ROTATE_BYTES))
            self.prov_stats.update(p.get("stats", {}))
        else:  # plain -> prov wrap: bootstrap from the device
            self._discover_log()

    def state_schema(self) -> Tuple[str, ...]:
        return tuple(self.inner.state_schema()) + ("prov",)

    def optional_state_keys(self) -> Tuple[str, ...]:
        # the layer can bootstrap from the device when wrapping a plain
        # module whose extract never emitted "prov"
        return tuple(self.inner.optional_state_keys()) + ("prov",)

    # --- the record pipeline -----------------------------------------------------
    def _rec(self, op: str, *, ino: int = 0, parent: int = 0, name: str = "",
             sub: Optional[str] = None, **extra) -> Dict[str, Any]:
        # submitter precedence: the entry's declared identity (SQPOLL-style
        # queues stamp it), else the run identity the inner fs is currently
        # draining for, else the executing thread — a guess, but an honest
        # one, and the only option for direct scalar calls
        if sub is None:
            sub = getattr(self.inner, "_current_submitter", None)
        if sub is None:
            sub = f"tid:{threading.get_ident()}"
        r = {"op": op, "ino": ino, "parent": parent, "name": name,
             "pid": os.getpid(), "submitter": sub,
             "ts": self.ks.time() if self.ks is not None else 0.0}
        r.update(extra)
        return r

    def _append(self, records: List[Dict[str, Any]]) -> None:
        """Append records to the on-device log via the inner write path
        (journal-staged). A failed append (journal pressure) degrades to a
        counted, warned drop — it never fails the mutation it describes,
        which already happened; inside a chain it is bracketed with the
        member-undo scope so partial staging rolls back instead of leaving
        a torn line in the chain transaction."""
        if not records:
            return
        lines = [json.dumps(r, separators=(",", ":")).encode() + b"\n"
                 for r in records]
        data = b"".join(lines)
        j = self.journal
        # lock order: inner fs lock BEFORE the layer's append lock, always —
        # the scalar path already holds _oplock (txn scope) when it reaches
        # here, while inner.write would re-acquire it inside _plock; taking
        # it first keeps one global order (oplock -> plock) and no deadlock
        oplock = getattr(self.inner, "_oplock", None) or contextlib.nullcontext()
        with oplock, self._plock:
            try:
                self._ensure_log()
                bracket = j is not None and j.in_chain_here
                if bracket:
                    j.chain_member_begin()
                try:
                    self.inner.write(self._log_ino, self._log_size, data)
                except BaseException:
                    if bracket:
                        j.chain_member_abort()
                    raise
                if bracket:
                    j.chain_member_end()
                if self._line_index is not None:
                    pos = self._log_size
                    for ln in lines:
                        self._line_index.append(pos)
                        pos += len(ln)
                self._log_size += len(data)
                self.prov_stats["records"] += len(records)
                self.prov_stats["appends"] += 1
                self._maybe_rotate()
            except FsError as e:
                self.prov_stats["append_errors"] += 1
                self._line_index = None  # torn tail: rebuild on next read
                if self._log_ino:
                    try:  # resync size after any rollback
                        self._log_size = self.inner.getattr(self._log_ino).size
                    except FsError:
                        pass
                if self.ks is not None:
                    self.ks.log_warn(f"prov: record append dropped: {e}")

    def _maybe_rotate(self) -> None:
        """Compact the log once it exceeds ``rotate_bytes``: keep the newest
        half of its records behind a ``_rotate`` marker line carrying the
        first kept record's absolute seq, so sequence numbers stay monotonic
        across rotations. The compacted log is built at a scratch name and
        swapped in with rename-overwrite — ONE journal transaction replaces
        old with new, so a crash mid-rotation leaves either the full old
        log or the compacted one, never a torn mix (old-XOR-new). Skipped
        inside chain scopes (a rotation is many transactions) and counted
        in ``prov_stats["rotations"]``. Callers hold oplock + _plock."""
        j = self.journal
        if (self.rotate_bytes <= 0 or self._log_size <= self.rotate_bytes
                or self._log_ino == 0
                or (j is not None and j.in_chain_here)):
            return
        if self._line_index is None:
            self._rescan()
        idx = self._line_index
        if idx is None or len(idx) < 2:
            return
        keep_from = len(idx) // 2
        new_base = self._seq_base + keep_from
        start = idx[keep_from]
        marker = json.dumps({"op": "_rotate", "base": new_base},
                            separators=(",", ":")).encode() + b"\n"
        try:
            tail = self.inner.read(self._log_ino, start,
                                   self._log_size - start)
            try:  # adopt a stray scratch file from a crashed rotation
                attr = self.inner.lookup(ROOT_INO, PROV_LOG_TMP)
                self.inner.truncate(attr.ino, 0)
            except FsError:
                attr = self.inner.create(ROOT_INO, PROV_LOG_TMP)
            self.inner.write(attr.ino, 0, marker + tail)
            # the atomic cutover: displaces (and frees) the old log inode
            self.inner.rename(ROOT_INO, PROV_LOG_TMP, ROOT_INO,
                              PROV_LOG_NAME)
        except FsError as e:
            self.prov_stats["rotate_errors"] += 1
            if self.ks is not None:
                self.ks.log_warn(f"prov: rotation skipped: {e}")
            return
        self._log_ino = attr.ino
        self._log_size = len(marker) + len(tail)
        self._seq_base = new_base
        self._line_index = None  # offsets all shifted: rebuild lazily
        self.prov_stats["rotations"] += 1

    def _append_blocks(self, n_records: int) -> int:
        """Journal-blocks upper bound for appending ``n_records`` (the
        reservation padding for chain scopes), via the inner fs's
        log-allocation hook; +6 when the log file itself must be created
        inside the same transaction."""
        if n_records == 0:
            return 0
        est = self.inner.estimate_append_blocks(n_records * _REC_BYTES_EST)
        if self._log_ino == 0:  # lazy log creation joins the transaction
            est += getattr(self.inner, "_CHAIN_OP_BLOCKS", {}).get("create", 6)
        return est

    @contextlib.contextmanager
    def _txn_scope(self, op: str):
        """One journal transaction spanning a scalar namespace mutation AND
        its provenance record (the old-XOR-new guarantee). Reuses the chain
        reservation machinery: commits requested inside the scope defer to
        its close, so neither the group-commit heuristic nor the per-op
        commit policy can tear mutation from record. No-ops when a chain
        scope is already open on THIS thread (the chain IS the transaction)
        or when the combined footprint could never fit (degrades to
        record-after ordering, which still keeps the log explainable)."""
        j = self.journal
        oplock = getattr(self.inner, "_oplock", None)
        if j is None or oplock is None:
            yield
            return
        # take the fs lock BEFORE inspecting chain state — and ask about
        # THIS thread's chain scope specifically (in_chain_here): with
        # sharded lock domains another thread's chain can be open
        # concurrently, and it must not suppress our one-txn scope
        oplock.acquire()
        opened = False
        try:
            if not j.in_chain_here:
                est = (getattr(self.inner, "_CHAIN_OP_BLOCKS", {})
                       .get(op, 16) + self._append_blocks(1))
                try:
                    j.begin_chain(est)
                    opened = True
                except FsError:
                    pass  # tiny journal: fall back to ordered-append only
            yield
        finally:
            if opened:
                j.end_chain()
            oplock.release()

    # --- namespace guards (the log hides from the tree) ---------------------------
    @staticmethod
    def _guard_name(parent: int, name) -> bool:
        return parent == ROOT_INO and name in (PROV_LOG_NAME, PROV_LOG_TMP)

    def _guard_entry(self, e: SubmissionEntry) -> Optional[Errno]:
        """Errno for entries that touch the reserved log name (None for the
        overwhelmingly common clean case)."""
        kw = e.kwargs or {}

        def arg(i, k):
            return e.args[i] if len(e.args) > i else kw.get(k)

        if e.op in ("lookup", "unlink", "rmdir"):
            if self._guard_name(arg(0, "parent"), arg(1, "name")):
                return Errno.ENOENT
        elif e.op in ("create", "mkdir"):
            if self._guard_name(arg(0, "parent"), arg(1, "name")):
                return Errno.EINVAL
        elif e.op == "rename":
            if self._guard_name(arg(0, "parent"), arg(1, "name")):
                return Errno.ENOENT
            if self._guard_name(arg(2, "newparent"), arg(3, "newname")):
                return Errno.EINVAL
        return None

    # --- scalar ops ----------------------------------------------------------------
    # reads delegate straight through; namespace mutations run in a
    # one-transaction scope with their record; data mutations record after
    # (ordered staging keeps the log explainable without capping write size)

    def getattr(self, ino: int) -> Attr:
        return self.inner.getattr(ino)

    def lookup(self, parent: int, name: str) -> Attr:
        if self._guard_name(parent, name):
            raise FsError(Errno.ENOENT, name)
        return self.inner.lookup(parent, name)

    def readdir(self, ino: int) -> List[Tuple[str, int, FileKind]]:
        out = self.inner.readdir(ino)
        if ino == ROOT_INO:
            out = [e for e in out
                   if e[0] not in (PROV_LOG_NAME, PROV_LOG_TMP)]
        return out

    def read(self, ino: int, off: int, size: int) -> bytes:
        return self.inner.read(ino, off, size)

    def statfs(self) -> Dict[str, int]:
        return self.inner.statfs()

    def fsync(self, ino: int) -> None:
        self.inner.fsync(ino)

    def flush(self) -> None:
        self.inner.flush()

    def create(self, parent: int, name: str) -> Attr:
        if self._guard_name(parent, name):
            raise FsError(Errno.EINVAL, f"{name} is reserved")
        with self._txn_scope("create"):
            attr = self.inner.create(parent, name)
            self._append([self._rec("create", ino=attr.ino, parent=parent,
                                    name=name)])
        return attr

    def mkdir(self, parent: int, name: str) -> Attr:
        if self._guard_name(parent, name):
            raise FsError(Errno.EINVAL, f"{name} is reserved")
        with self._txn_scope("mkdir"):
            attr = self.inner.mkdir(parent, name)
            self._append([self._rec("mkdir", ino=attr.ino, parent=parent,
                                    name=name)])
        return attr

    def unlink(self, parent: int, name: str) -> None:
        if self._guard_name(parent, name):
            raise FsError(Errno.ENOENT, name)
        with self._txn_scope("unlink"):
            self.inner.unlink(parent, name)
            self._append([self._rec("unlink", parent=parent, name=name)])

    def rmdir(self, parent: int, name: str) -> None:
        if self._guard_name(parent, name):
            raise FsError(Errno.ENOENT, name)
        with self._txn_scope("rmdir"):
            self.inner.rmdir(parent, name)
            self._append([self._rec("rmdir", parent=parent, name=name)])

    def rename(self, parent: int, name: str, newparent: int,
               newname: str) -> None:
        if self._guard_name(parent, name):
            raise FsError(Errno.ENOENT, name)
        if self._guard_name(newparent, newname):
            raise FsError(Errno.EINVAL, f"{newname} is reserved")
        with self._txn_scope("rename"):
            self.inner.rename(parent, name, newparent, newname)
            self._append([self._rec("rename", parent=parent, name=name,
                                    newparent=newparent, newname=newname)])

    def write(self, ino: int, off: int, data: bytes) -> int:
        n = self.inner.write(ino, off, data)
        self._append([self._rec("write", ino=ino, off=off, len=n)])
        return n

    def truncate(self, ino: int, size: int) -> None:
        self.inner.truncate(ino, size)
        self._append([self._rec("truncate", ino=ino, size=size)])

    # --- batched boundary -----------------------------------------------------------
    def submit_batch(self, entries) -> List[CompletionEntry]:
        """Delegate whole runs to the inner module (its vectorized fast
        paths are the point of the batched boundary), then append one
        combined record batch for the successful mutations — completion
        order IS log order. Two kinds of entry never reach the inner
        module: ones touching the reserved log name complete with their
        guard errno, and ``read_provenance`` entries are answered by THIS
        layer (the inner module would refuse the op it knows nothing
        about), so the batched query path works like the scalar one."""
        if not isinstance(entries, list):
            entries = list(entries)
        if any(e.op == "read_provenance"
               or self._guard_entry(e) is not None for e in entries):
            comps: List[CompletionEntry] = []
            for e in entries:  # rare path: per-entry, guards interleaved
                if e.op == "read_provenance":
                    comps.append(self._dispatch_one(e))
                    continue
                g = self._guard_entry(e)
                if g is not None:
                    comps.append(CompletionEntry(e.user_data, errno=g))
                else:
                    comps.extend(self._delegate_run([e]))
            return comps
        return self._delegate_run(entries)

    def _delegate_run(self, entries: List[SubmissionEntry]
                      ) -> List[CompletionEntry]:
        comps = self.inner.submit_batch(entries)
        recs = []
        for e, c in zip(entries, comps):
            if c.errno is not None:
                continue
            if e.op in PROV_MUTATING_OPS:
                recs.append(self._rec_for_entry(e, c))
            elif e.op == "readdir":
                # the log-hiding filter must hold on the batched path too
                ino = e.args[0] if e.args else (e.kwargs or {}).get("ino")
                if ino == ROOT_INO:
                    c.result = [t for t in c.result
                                if t[0] not in (PROV_LOG_NAME, PROV_LOG_TMP)]
        self._append(recs)
        return comps

    def _rec_for_entry(self, e: SubmissionEntry,
                       c: CompletionEntry) -> Dict[str, Any]:
        kw = e.kwargs or {}
        sub = getattr(e, "submitter", None)  # the entry's declared identity

        def arg(i, k, default=0):
            v = e.args[i] if len(e.args) > i else kw.get(k, default)
            return v

        if e.op in ("create", "mkdir"):
            return self._rec(e.op, ino=c.result.ino, parent=arg(0, "parent"),
                             name=arg(1, "name", ""), sub=sub)
        if e.op in ("unlink", "rmdir"):
            return self._rec(e.op, parent=arg(0, "parent"),
                             name=arg(1, "name", ""), sub=sub)
        if e.op == "rename":
            return self._rec("rename", parent=arg(0, "parent"),
                             name=arg(1, "name", ""),
                             newparent=arg(2, "newparent"),
                             newname=arg(3, "newname", ""), sub=sub)
        if e.op == "write":
            return self._rec("write", ino=arg(0, "ino"), off=arg(1, "off"),
                             len=c.result, sub=sub)
        return self._rec("truncate", ino=arg(0, "ino"), size=arg(1, "size"),
                         sub=sub)

    # --- chain hooks: one txn spans data + provenance --------------------------------
    def chain_begin(self, entries) -> Optional[Errno]:
        if self.journal is None:  # non-journaled inner: plain forwarding
            return self.inner.chain_begin(entries)
        n_mut = sum(1 for e in entries if e.op in PROV_MUTATING_OPS)
        return self.inner.chain_begin(
            entries, extra_blocks=self._append_blocks(n_mut))

    def chain_end(self) -> None:
        self.inner.chain_end()

    # --- lock-domain hooks: scheduling delegates to the inner fs ---------------------
    def group_footprint(self, entries):
        """Parallel-drain footprint — the inner module's own estimate.
        Every mutating group carries the inner fs's ALLOC domain, which
        also serializes this layer's log appends (the log inode is not in
        any footprint, but only ALLOC holders write it); read_provenance
        is unknown to the inner estimator and maps to None, the global
        exclusive lock."""
        fn = getattr(self.inner, "group_footprint", None)
        return fn(entries) if fn is not None else None

    def domain_scope(self, footprint):
        return self.inner.domain_scope(footprint)

    # --- the query op -----------------------------------------------------------------
    def _rescan(self) -> None:
        """Full-log scan rebuilding the line-offset index and the seq base
        (a head ``_rotate`` marker, when present, supplies the base and is
        itself absorbed — never indexed, never returned)."""
        raw = self.inner.read(self._log_ino, 0, self._log_size)
        offsets: List[int] = []
        base = 0
        pos = 0
        for i, line in enumerate(raw.split(b"\n")[:-1]):  # complete lines
            if i == 0:
                try:
                    r = json.loads(line)
                except ValueError:
                    r = None
                if isinstance(r, dict) and r.get("op") == "_rotate":
                    base = int(r.get("base", 0))
                    pos += len(line) + 1
                    continue
            offsets.append(pos)
            pos += len(line) + 1
        self._line_index = offsets
        self._seq_base = base

    def read_provenance(self, since: int = 0, offset: int = 0,
                        limit: Optional[int] = None) -> List[Dict[str, Any]]:
        """Records with ``seq >= since`` in append (== execution) order;
        ``offset`` skips that many records of the selection and ``limit``
        caps the page size, so a consumer can walk an arbitrarily large log
        in bounded payloads (``since=last_seq+1`` between polls, or fixed
        ``since`` with a sliding ``offset``). Reads through the journal
        overlay, so records of not-yet-committed mutations are visible to a
        live query — durability follows the data's fsync, exactly like the
        mutations themselves. The line-offset index (kept current by
        ``_append``, rebuilt after drops/rotation) turns any page into ONE
        ranged read of exactly the lines requested. Records dropped by
        rotation are simply absent: a ``since`` below the retained base
        returns from the oldest survivor. Unparseable lines (a dropped
        append's torn tail) are skipped, never fatal."""
        if offset < 0 or (limit is not None and limit < 0):
            raise FsError(Errno.EINVAL, "negative offset/limit")
        oplock = getattr(self.inner, "_oplock", None) or contextlib.nullcontext()
        with oplock, self._plock:  # same order as _append: oplock -> plock
            if self._log_ino == 0:
                self._discover_log()
            if self._log_ino == 0:
                return []
            if self._line_index is None:
                self._rescan()
            idx, base = self._line_index, self._seq_base
            pos = max(since - base, 0) + offset
            end = len(idx) if limit is None else min(pos + limit, len(idx))
            if pos >= end:
                return []
            start_b = idx[pos]
            end_b = idx[end] if end < len(idx) else self._log_size
            raw = self.inner.read(self._log_ino, start_b, end_b - start_b)
            out = []
            for i, line in enumerate(raw.split(b"\n")[:-1]):
                try:
                    r = json.loads(line)
                except ValueError:
                    continue
                r["seq"] = base + pos + i
                out.append(r)
            return out
