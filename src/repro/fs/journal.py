"""Write-ahead journal (xv6 ``log.c`` semantics, with checksums).

Transactions collect dirty block numbers; ``commit`` writes the data into
the journal area, then a checksummed header (the commit record), then
installs the blocks to their home locations, then clears the header. After
a crash, ``recover`` replays any committed-but-uninstalled transaction.
Absorption (same block logged twice in one txn) is implemented, as is group
commit (several ops per transaction until fsync or the log fills).

The per-block checksum in the commit record uses the kernel-services
checksum (Pallas crc32c in the kernel binding) — torn journal writes are
detected at recovery.

Chain transactions
------------------

Single operations reserve journal space per (sub-)operation via the fs's
``_begin_op``.  A linked SQE chain (``SQE_LINK`` — e.g. create →
write(PrevResult) → fsync) is a larger atomicity unit: ALL of its members'
``log_write``s must land in ONE transaction, or a crash between two
commits leaves a half-applied chain on disk.  ``begin_chain`` /
``end_chain`` make the chain the reservation unit:

* ``begin_chain(estimated_blocks)`` — sizing rule: the caller estimates the
  chain's whole journal footprint from its *submission entries* (data
  blocks plus per-op metadata overhead, an upper bound).  If the estimate
  exceeds the journal's total capacity the chain can NEVER fit and
  ``JournalFull`` (an ``FsError`` carrying ``ENOSPC``) is raised *before a
  single block is staged* — the ENOSPC-before-staging rule: the caller
  fails the chain's first member with ``ENOSPC`` and cancels the rest, so
  an unserviceable chain leaves no trace in the transaction.  If the chain
  fits but not next to the currently pending blocks, the open transaction
  is committed first (a legal pre-chain boundary).
* while a chain is open, ``commit`` is REFUSED: it is deferred (recorded)
  instead of executed, so neither an in-chain fsync/flush nor a group-
  commit heuristic can tear the chain across two commit records.
* ``end_chain`` closes the scope and executes the deferred commit, if one
  was requested — the whole chain becomes durable atomically.

A crash at any device write therefore leaves either the whole chain
installed after ``recover`` or none of it.

Concurrent reservations (sharded lock domains)
----------------------------------------------

The parallel multi-submitter drain (``core.registry`` +
``fs/xv6.LockDomainTable``) dispatches non-overlapping groups on worker
threads, so more than one chain scope can be OPEN at once — one per
thread. The chain scope is therefore per-thread state
(``_chain_scopes[tid]``), and the journal stays the ONLY global
serialization point:

* ``begin_chain`` admits a new reservation only while the pending
  transaction plus every ACTIVE reservation still fits capacity; when
  other chains hold reservations it waits for them to close instead of
  forcing a commit (commit mid-chain would tear them);
* ``commit`` defers while the CALLING thread holds a chain scope (the
  single-thread rule, unchanged) or while ANY open chain has staged
  blocks — committing then would split that chain across two commit
  records. The deferred commit runs when the last scope closes.
* the mutating side above the journal serializes on the allocation
  domain (``fs/xv6.LockDomainTable``), so at most one chain with staged
  blocks exists at a time — member-abort rollback can never clobber a
  concurrent chain's staging. Read-only chains stage nothing and run
  fully concurrent.
"""

from __future__ import annotations

import struct
import threading
from typing import Dict, List, Optional

from repro.core.capability import SuperBlockCap
from repro.core.interface import Errno, FsError
from repro.fs.layout import BSIZE, SuperBlock

_HDR_FMT_HEAD = "<III"  # magic, n, seq
_HDR_MAGIC = 0x4A524E4C  # "JRNL"


class JournalFull(FsError):
    """Operation/chain footprint exceeds the journal.

    An ``FsError`` (errno ``ENOSPC``) so the batched boundary's errno-
    isolation path turns it into a per-entry completion instead of letting
    it escape ``submit_batch`` as a raw exception."""

    def __init__(self, msg: str = ""):
        super().__init__(Errno.ENOSPC, msg)


class _ChainScope:
    """One thread's open chain reservation: its size (for admission of
    further concurrent chains), its member undo log, and whether any of
    its blocks are already staged (a staged chain pins ``commit``)."""

    __slots__ = ("est", "member_undo", "staged")

    def __init__(self, est: int):
        self.est = est
        self.member_undo: Optional[Dict[int, Optional[bytes]]] = None
        self.staged = False


class Journal:
    def __init__(self, services, sb_cap: SuperBlockCap, sb: SuperBlock,
                 *, batched_install: bool = False):
        self.ks = services
        self.sb_cap = sb_cap
        self.sb = sb
        self.capacity = sb.nlog - 1  # minus header block
        self.batched_install = batched_install  # writepages-style install
        self._lock = threading.RLock()
        self._cv = threading.Condition(self._lock)  # chain-scope transitions
        self._pending: Dict[int, bytes] = {}  # home blockno -> data (absorbed)
        self._seq = 0
        # chain scopes are PER-THREAD: the parallel drain runs independent
        # chains on worker threads concurrently (each serialized above the
        # journal by its lock domains); tid -> scope
        self._chain_scopes: Dict[int, _ChainScope] = {}
        self._chain_deferred = False  # a commit was requested mid-chain
        self._op_undo: Dict[int, Optional[Dict[int, Optional[bytes]]]] = {}
        # called after any undo-rollback so the fs can drop in-memory
        # state (inode cache, dir indexes) derived from the rolled-back
        # staging; set by the fs at init
        self.rollback_listener = None
        self.commits = 0
        self.blocks_logged = 0
        self.chains = 0          # chain reservations taken
        self.chain_precommits = 0  # commits forced to make room for a chain

    @property
    def room(self) -> int:
        """Blocks the open transaction can still absorb — the blockstore's
        dedup pass bounds its per-transaction staging with this."""
        return self.capacity - len(self._pending)

    # --- write path ---------------------------------------------------------------
    def log_write(self, blockno: int, data: bytes) -> None:
        """Stage a block into the current transaction (absorbs duplicates).

        NB: never commits mid-operation — ops reserve space via the fs's
        ``_begin_op`` (xv6 ``begin_op`` semantics) or, for a linked chain,
        via ``begin_chain``, so a crash can only land between whole
        operations/chains, keeping each one atomic."""
        with self._lock:
            tid = threading.get_ident()
            scope = self._chain_scopes.get(tid)
            # undo entry BEFORE the overflow check: callers mutate the
            # cache buffer first, so even a refused log_write must leave
            # its block invalidatable by the rollback
            undo = (scope.member_undo if scope is not None
                    else self._op_undo.get(tid))
            if undo is not None and blockno not in undo:
                undo[blockno] = self._pending.get(blockno)
            if len(self._pending) >= self.capacity and blockno not in self._pending:
                if scope is None:
                    # overflow outside a chain: roll the current op scope
                    # back NOW, so the ENOSPC that reaches the caller means
                    # "this (sub-)op staged nothing" — a later group commit
                    # can never install a torn op (in-chain overflows roll
                    # back in chain_member_abort instead)
                    self._rollback_locked(self._op_undo.get(tid))
                    self._op_undo[tid] = None
                raise JournalFull(
                    f"operation overflowed the journal ({self.capacity} blocks) "
                    "— missing _begin_op/begin_chain reservation")
            self._pending[blockno] = bytes(data)
            if scope is not None:
                scope.staged = True

    def commit(self) -> None:
        with self._lock:
            if threading.get_ident() in self._chain_scopes or \
                    any(s.staged for s in self._chain_scopes.values()):
                # Refused mid-chain: a chain must land in ONE transaction,
                # so neither the chain's own thread nor a concurrent
                # committer may split an open chain's staged blocks across
                # two commit records. Recorded and executed by the LAST
                # end_chain. (A concurrent commit while only empty chain
                # scopes are open proceeds — nothing of theirs can tear.)
                self._chain_deferred = True
                return
            self._commit_locked()

    # --- chain-scoped reservation (linked SQE chains) ------------------------------
    @property
    def in_chain(self) -> bool:
        """Some thread holds an open chain scope (any thread)."""
        return bool(self._chain_scopes)

    @property
    def in_chain_here(self) -> bool:
        """Chain scope open AND owned by the calling thread. The member-
        bracketing fast path in ``submit_batch`` checks this BEFORE taking
        the fs lock — a concurrent submitter on another thread must see
        False, or it would clobber the owner's member undo log."""
        return threading.get_ident() in self._chain_scopes

    def begin_chain(self, estimated_blocks: int) -> None:
        """Open a chain scope sized for ``estimated_blocks`` journal blocks
        (an upper bound computed from the chain's submission entries).

        Raises ``JournalFull`` (ENOSPC) BEFORE anything is staged when the
        chain can never fit the journal; commits the open transaction first
        when the chain fits but not alongside the pending blocks. While
        OTHER threads hold chain reservations the open transaction cannot
        be committed out from under them, so an admission that does not fit
        waits for those scopes to close instead."""
        with self._lock:
            tid = threading.get_ident()
            if tid in self._chain_scopes:
                raise RuntimeError("nested begin_chain — chains may not nest")
            if estimated_blocks > self.capacity:
                raise JournalFull(
                    f"chain needs ~{estimated_blocks} journal blocks, "
                    f"capacity is {self.capacity} — cannot be made atomic")
            while True:
                reserved = sum(s.est for s in self._chain_scopes.values())
                if len(self._pending) + reserved + estimated_blocks \
                        <= self.capacity:
                    break
                if not self._chain_scopes:
                    # alone: a pre-chain commit is a legal boundary
                    self.chain_precommits += 1
                    self._commit_locked()
                    break
                self._cv.wait()  # concurrent scopes close via end_chain
            self._chain_scopes[tid] = _ChainScope(estimated_blocks)
            self.chains += 1

    def end_chain(self) -> None:
        """Close the calling thread's chain scope; when the LAST scope
        closes, run the commit an in-chain fsync/flush deferred (the whole
        chain becomes durable atomically)."""
        with self._lock:
            self._chain_scopes.pop(threading.get_ident(), None)
            if not self._chain_scopes and self._chain_deferred:
                self._chain_deferred = False
                self._commit_locked()
            self._cv.notify_all()

    # Per-MEMBER bracketing inside a chain scope: the reservation estimate
    # is an upper bound only for literal payloads (a PrevResult-fed write's
    # size is unknowable at begin_chain), so a member may still overflow
    # mid-staging. The undo log scopes that damage to the member: abort
    # restores every block the member touched, so an ENOSPC member stages
    # NOTHING — earlier (successful) members' blocks stay, matching
    # io_uring link semantics, and no torn member can ever be committed.
    def chain_member_begin(self) -> None:
        with self._lock:
            scope = self._chain_scopes.get(threading.get_ident())
            if scope is not None:
                scope.member_undo = {}

    def chain_member_end(self) -> None:
        with self._lock:
            scope = self._chain_scopes.get(threading.get_ident())
            if scope is not None:
                scope.member_undo = None

    def chain_member_abort(self) -> None:
        with self._lock:
            scope = self._chain_scopes.get(threading.get_ident())
            if scope is None:
                return
            undo, scope.member_undo = scope.member_undo, None
            self._rollback_locked(undo)

    # --- op-scoped undo (non-chain reservations) ------------------------------------
    def begin_op_scope(self) -> None:
        """Arm the undo log for one (sub-)operation's staging — called by
        the fs's ``_begin_op``. An overflow before the next scope rolls
        back to this point, so ENOSPC always means "nothing staged by the
        failing (sub-)op" on the scalar and unchained paths too. The scope
        is per-thread, like the chain scopes."""
        with self._lock:
            self._op_undo[threading.get_ident()] = {}

    def _rollback_locked(self, undo: Optional[Dict[int, Optional[bytes]]]
                         ) -> None:
        for blockno, prior in (undo or {}).items():
            if prior is None:
                self._pending.pop(blockno, None)
            else:
                self._pending[blockno] = prior
        # ops mutate CACHE buffers in place before logging; drop the
        # scope's blocks so reads refetch the device and re-overlay the
        # (restored) pending state, and let the fs drop derived caches
        if undo:
            self.ks.sb_invalidate_blocks(self.sb_cap, list(undo))
            if self.rollback_listener is not None:
                self.rollback_listener()

    def pending_get(self, blockno: int):
        """Read-through overlay: committed-but-unstaged data visible to
        readers (xv6 pins these buffers; we overlay instead)."""
        with self._lock:
            return self._pending.get(blockno)

    def pending_snapshot(self) -> Dict[int, bytes]:
        """One-lock copy of the overlay for batched readers: a vectorized
        read path consults this dict instead of taking the journal lock
        once per block."""
        with self._lock:
            return dict(self._pending)

    def _commit_locked(self) -> None:
        if not self._pending:
            return
        items = sorted(self._pending.items())
        assert len(items) <= self.capacity
        # 1) write data blocks into the journal area
        for i, (_home, data) in enumerate(items):
            with self.ks.sb_getblk_zero(self.sb_cap, self.sb.logstart + 1 + i) as bh:
                bh.data()[:] = data
                self.ks.bwrite_sync(self.sb_cap, bh)
        # 2) commit record (header with checksums) — the commit point
        # (batched: one Pallas kernel launch per transaction)
        sums = self.ks.checksum_batch([data for _h, data in items])
        hdr = struct.pack(_HDR_FMT_HEAD, _HDR_MAGIC, len(items), self._seq)
        for (home, _data), cks in zip(items, sums):
            hdr += struct.pack("<II", home, cks)
        with self.ks.sb_getblk_zero(self.sb_cap, self.sb.logstart) as bh:
            bh.data()[: len(hdr)] = hdr
            self.ks.bwrite_sync(self.sb_cap, bh)
        # 3) install to home locations
        if self.batched_install:
            # writepages-style: stage dirty, one sorted batched flush.
            for home, data in items:
                with self.ks.sb_getblk_zero(self.sb_cap, home) as bh:
                    bh.data()[:] = data
                    bh.mark_dirty()
            self.ks.flush(self.sb_cap, [h for h, _ in items])
        else:
            for home, data in items:
                with self.ks.sb_getblk_zero(self.sb_cap, home) as bh:
                    bh.data()[:] = data
                    self.ks.bwrite_sync(self.sb_cap, bh)
        # 4) clear the header
        with self.ks.sb_getblk_zero(self.sb_cap, self.sb.logstart) as bh:
            self.ks.bwrite_sync(self.sb_cap, bh)
        self.commits += 1
        self.blocks_logged += len(items)
        self._seq += 1
        self._pending.clear()

    # --- recovery -------------------------------------------------------------------
    def recover(self) -> int:
        """Replay a committed transaction found in the journal. Returns the
        number of blocks installed (0 if log was clean or torn)."""
        with self.ks.sb_bread(self.sb_cap, self.sb.logstart) as bh:
            raw = bytes(bh.data())
        magic, n, _seq = struct.unpack_from(_HDR_FMT_HEAD, raw)
        if magic != _HDR_MAGIC or n == 0 or n > self.capacity:
            return 0
        entries = []
        off = struct.calcsize(_HDR_FMT_HEAD)
        for i in range(n):
            home, cks = struct.unpack_from("<II", raw, off + 8 * i)
            entries.append((home, cks))
        # verify checksums against journal data blocks (torn-write detection)
        datas = []
        raws = []
        for i, (home, _cks) in enumerate(entries):
            with self.ks.sb_bread(self.sb_cap, self.sb.logstart + 1 + i) as bh:
                raws.append(bytes(bh.data()))
        sums = self.ks.checksum_batch(raws)
        for (home, cks), data, got in zip(entries, raws, sums):
            if got != cks:
                return 0  # torn commit: discard
            datas.append((home, data))
        for home, data in datas:
            with self.ks.sb_getblk_zero(self.sb_cap, home) as bh:
                bh.data()[:] = data
                self.ks.bwrite_sync(self.sb_cap, bh)
        with self.ks.sb_getblk_zero(self.sb_cap, self.sb.logstart) as bh:
            self.ks.bwrite_sync(self.sb_cap, bh)
        return n

    # --- upgrade support (§4.8) --------------------------------------------------------
    def extract_state(self) -> Dict:
        with self._lock:
            return {"pending": dict(self._pending), "seq": self._seq}

    def restore_state(self, state: Dict) -> None:
        with self._lock:
            self._pending = dict(state.get("pending", {}))
            self._seq = int(state.get("seq", 0))
            # chains never span an upgrade (the gate drains whole batches,
            # and a chain lives inside one batch) — reset defensively
            self._chain_scopes = {}
            self._chain_deferred = False
            self._op_undo = {}
