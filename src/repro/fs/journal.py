"""Write-ahead journal (xv6 ``log.c`` semantics, with checksums).

Transactions collect dirty block numbers; ``commit`` writes the data into
the journal area, then a checksummed header (the commit record), then
installs the blocks to their home locations, then clears the header. After
a crash, ``recover`` replays any committed-but-uninstalled transaction.
Absorption (same block logged twice in one txn) is implemented, as is group
commit (several ops per transaction until fsync or the log fills).

The per-block checksum in the commit record uses the kernel-services
checksum (Pallas crc32c in the kernel binding) — torn journal writes are
detected at recovery.
"""

from __future__ import annotations

import struct
import threading
from typing import Dict, List

from repro.core.capability import SuperBlockCap
from repro.fs.layout import BSIZE, SuperBlock

_HDR_FMT_HEAD = "<III"  # magic, n, seq
_HDR_MAGIC = 0x4A524E4C  # "JRNL"


class JournalFull(Exception):
    pass


class Journal:
    def __init__(self, services, sb_cap: SuperBlockCap, sb: SuperBlock,
                 *, batched_install: bool = False):
        self.ks = services
        self.sb_cap = sb_cap
        self.sb = sb
        self.capacity = sb.nlog - 1  # minus header block
        self.batched_install = batched_install  # writepages-style install
        self._lock = threading.RLock()
        self._pending: Dict[int, bytes] = {}  # home blockno -> data (absorbed)
        self._seq = 0
        self.commits = 0
        self.blocks_logged = 0

    # --- write path ---------------------------------------------------------------
    def log_write(self, blockno: int, data: bytes) -> None:
        """Stage a block into the current transaction (absorbs duplicates).

        NB: never commits mid-operation — ops reserve space via the fs's
        ``_begin_op`` (xv6 ``begin_op`` semantics), so a crash can only land
        between whole operations, keeping every op atomic."""
        with self._lock:
            if len(self._pending) >= self.capacity and blockno not in self._pending:
                raise JournalFull(
                    f"operation overflowed the journal ({self.capacity} blocks) "
                    "— missing _begin_op reservation")
            self._pending[blockno] = bytes(data)

    def commit(self) -> None:
        with self._lock:
            self._commit_locked()

    def pending_get(self, blockno: int):
        """Read-through overlay: committed-but-unstaged data visible to
        readers (xv6 pins these buffers; we overlay instead)."""
        with self._lock:
            return self._pending.get(blockno)

    def pending_snapshot(self) -> Dict[int, bytes]:
        """One-lock copy of the overlay for batched readers: a vectorized
        read path consults this dict instead of taking the journal lock
        once per block."""
        with self._lock:
            return dict(self._pending)

    def _commit_locked(self) -> None:
        if not self._pending:
            return
        items = sorted(self._pending.items())
        assert len(items) <= self.capacity
        # 1) write data blocks into the journal area
        for i, (_home, data) in enumerate(items):
            with self.ks.sb_getblk_zero(self.sb_cap, self.sb.logstart + 1 + i) as bh:
                bh.data()[:] = data
                self.ks.bwrite_sync(self.sb_cap, bh)
        # 2) commit record (header with checksums) — the commit point
        # (batched: one Pallas kernel launch per transaction)
        sums = self.ks.checksum_batch([data for _h, data in items])
        hdr = struct.pack(_HDR_FMT_HEAD, _HDR_MAGIC, len(items), self._seq)
        for (home, _data), cks in zip(items, sums):
            hdr += struct.pack("<II", home, cks)
        with self.ks.sb_getblk_zero(self.sb_cap, self.sb.logstart) as bh:
            bh.data()[: len(hdr)] = hdr
            self.ks.bwrite_sync(self.sb_cap, bh)
        # 3) install to home locations
        if self.batched_install:
            # writepages-style: stage dirty, one sorted batched flush.
            for home, data in items:
                with self.ks.sb_getblk_zero(self.sb_cap, home) as bh:
                    bh.data()[:] = data
                    bh.mark_dirty()
            self.ks.flush(self.sb_cap, [h for h, _ in items])
        else:
            for home, data in items:
                with self.ks.sb_getblk_zero(self.sb_cap, home) as bh:
                    bh.data()[:] = data
                    self.ks.bwrite_sync(self.sb_cap, bh)
        # 4) clear the header
        with self.ks.sb_getblk_zero(self.sb_cap, self.sb.logstart) as bh:
            self.ks.bwrite_sync(self.sb_cap, bh)
        self.commits += 1
        self.blocks_logged += len(items)
        self._seq += 1
        self._pending.clear()

    # --- recovery -------------------------------------------------------------------
    def recover(self) -> int:
        """Replay a committed transaction found in the journal. Returns the
        number of blocks installed (0 if log was clean or torn)."""
        with self.ks.sb_bread(self.sb_cap, self.sb.logstart) as bh:
            raw = bytes(bh.data())
        magic, n, _seq = struct.unpack_from(_HDR_FMT_HEAD, raw)
        if magic != _HDR_MAGIC or n == 0 or n > self.capacity:
            return 0
        entries = []
        off = struct.calcsize(_HDR_FMT_HEAD)
        for i in range(n):
            home, cks = struct.unpack_from("<II", raw, off + 8 * i)
            entries.append((home, cks))
        # verify checksums against journal data blocks (torn-write detection)
        datas = []
        raws = []
        for i, (home, _cks) in enumerate(entries):
            with self.ks.sb_bread(self.sb_cap, self.sb.logstart + 1 + i) as bh:
                raws.append(bytes(bh.data()))
        sums = self.ks.checksum_batch(raws)
        for (home, cks), data, got in zip(entries, raws, sums):
            if got != cks:
                return 0  # torn commit: discard
            datas.append((home, data))
        for home, data in datas:
            with self.ks.sb_getblk_zero(self.sb_cap, home) as bh:
                bh.data()[:] = data
                self.ks.bwrite_sync(self.sb_cap, bh)
        with self.ks.sb_getblk_zero(self.sb_cap, self.sb.logstart) as bh:
            self.ks.bwrite_sync(self.sb_cap, bh)
        return n

    # --- upgrade support (§4.8) --------------------------------------------------------
    def extract_state(self) -> Dict:
        with self._lock:
            return {"pending": dict(self._pending), "seq": self._seq}

    def restore_state(self, state: Dict) -> None:
        with self._lock:
            self._pending = dict(state.get("pending", {}))
            self._seq = int(state.get("seq", 0))
