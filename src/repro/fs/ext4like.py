"""Ext4-like optimized baseline (the paper's "commercial grade" reference).

Same on-disk format as the xv6 fs (so the benchmarks isolate *implementation
quality*, like the paper's ext4 data=journal comparison isolates it from
journaling mode), plus the optimizations a production file system has and
xv6 lacks:

  * extent-style allocation: contiguous multi-block runs claimed in one
    bitmap scan (one journaled bitmap block per run instead of per block),
  * an in-memory directory hash index (ext4 htree analogue) instead of
    linear dirent scans,
  * write coalescing: full-block appends skip the read-modify-write,
  * a larger journal with the same group commit + batched install.

Simplifications vs real ext4 are documented in DESIGN.md.
"""

from __future__ import annotations

import threading
from typing import Dict, List, Optional, Tuple

from repro.core.interface import Errno, FsError
from repro.fs import layout as L
from repro.fs.xv6 import Xv6FileSystem, Xv6Options


class Ext4LikeFileSystem(Xv6FileSystem):
    NAME = "ext4like"
    VERSION = 1

    def __init__(self, options: Xv6Options = Xv6Options(group_commit=True,
                                                        batched_install=True)):
        super().__init__(options)
        # dir index: dino -> {name: (bn, off, ino)}
        self._dirindex: Dict[int, Dict[str, Tuple[int, int, int]]] = {}

    # Chain reservations (see Xv6FileSystem.chain_begin): this fs's write
    # path extent-preallocates per sub-op, touching up to 6 metadata blocks
    # (bitmap runs + inode + indirect chain) per reservation — write()
    # below derives its per-reservation data budget from this same
    # constant, so estimate and staging can never drift apart.
    _CHAIN_WRITE_OVERHEAD = 6

    def _invalidate_caches_after_abort(self) -> None:
        # the live dir index may reflect rolled-back staging; it rebuilds
        # lazily through the restored journal overlay
        self._dirindex.clear()

    # --- extent allocator -------------------------------------------------------------
    def _balloc_run(self, want: int) -> List[int]:
        """Allocate up to ``want`` contiguous blocks with one bitmap pass."""
        with self._alloc_lock:
            bits_per = L.BSIZE * 8
            total = self.geo.size
            start = max(self._free_hint, self.geo.datastart)
            b = start
            run: List[int] = []
            scanned = 0
            bm_cache: Dict[int, bytearray] = {}
            while scanned < total - self.geo.datastart and len(run) < want:
                if b >= total:
                    b = self.geo.datastart
                    run = []
                bmno = self.geo.bmapstart + b // bits_per
                if bmno not in bm_cache:
                    with self._bread(bmno) as bh:
                        bm_cache[bmno] = bytearray(bh.data())
                buf = bm_cache[bmno]
                bit = b % bits_per
                if (buf[bit // 8] >> (bit % 8)) & 1:
                    run = []
                else:
                    run.append(b)
                b += 1
                scanned += 1
            if not run:
                raise FsError(Errno.ENOSPC, "device full")
            # mark the run used; journal each touched bitmap block once
            touched = set()
            for blk in run:
                bmno = self.geo.bmapstart + blk // bits_per
                buf = bm_cache[bmno]
                bit = blk % bits_per
                buf[bit // 8] |= 1 << (bit % 8)
                touched.add(bmno)
            for bmno in touched:
                with self._bread(bmno) as bh:
                    bh.data()[:] = bm_cache[bmno]
                    self._log(bmno, bytes(bh.data()))
            for blk in run:
                self._log(blk, bytes(L.BSIZE))  # zero (journaled)
            self._free_hint = run[-1] + 1
            return run

    def _balloc(self) -> int:
        return self._balloc_run(1)[0]

    # --- write path with extent preallocation ----------------------------------------------
    def write(self, ino: int, off: int, data: bytes) -> int:
        from repro.fs.xv6 import MAXOP_BLOCKS

        with self._oplock:
            di = self._iget(ino)
            if di.type == L.T_DIR:
                raise FsError(Errno.EISDIR, str(ino))
            end_bn = (off + len(data) + L.BSIZE - 1) // L.BSIZE
            if end_bn > L.MAXFILE_BLOCKS:
                raise FsError(Errno.EFBIG, str(ino))
            pos, n = off, len(data)
            written = 0
            # data blocks per journal reservation (metadata budget shared
            # with the chain estimator; dedup widens it)
            per_sub = max(MAXOP_BLOCKS - self._chain_write_overhead, 4)
            while written < n:
                self._begin_op()
                # extent-preallocate this sub-op's missing blocks as one run
                first_bn = pos // L.BSIZE
                last_bn = min(end_bn, first_bn + per_sub)
                missing = [bn for bn in range(first_bn, last_bn)
                           if self._bmap(ino, di, bn, alloc=False) == 0]
                if missing:
                    run: list = []
                    need = len(missing)
                    while need > 0:
                        got = self._balloc_run(need)
                        run.extend(got)
                        need -= len(got)
                    for bn, blk in zip(missing, run):
                        self._bmap_install(ino, di, bn, blk)
                sub_blocks = 0
                while written < n and sub_blocks < per_sub:
                    bn, boff = divmod(pos, L.BSIZE)
                    chunk = min(L.BSIZE - boff, n - written)
                    b = self._write_block_target(ino, di, bn)
                    if boff == 0 and chunk == L.BSIZE:
                        self._log(b, bytes(data[written: written + chunk]))
                    else:
                        with self._bread(b) as bh:
                            buf = bh.data()
                            buf[boff: boff + chunk] = data[written: written + chunk]
                            self._log(b, bytes(buf))
                    sub_blocks += 1
                    pos += chunk
                    written += chunk
                if pos > di.size:
                    di.size = pos
                    self._iupdate(ino, di)
            store = self._blockstore
            if store is not None and store.batch_depth == 0:
                store.flush_pending()  # scalar write: dedup in this txn
            self._end_op(True)
            return written

    # _bmap_install/_ind_set moved to Xv6FileSystem: the blockstore's CoW
    # remapping shares them with extent preallocation.

    # --- directory hash index ---------------------------------------------------------------
    def _index(self, dino: int, di: L.DiskInode) -> Dict[str, Tuple[int, int, int]]:
        idx = self._dirindex.get(dino)
        if idx is None:
            idx = {}
            for bn, off, e_ino, name in self._dir_entries(dino, di):
                if e_ino != 0:
                    idx[name] = (bn, off, e_ino)
            self._dirindex[dino] = idx
        return idx

    def _dirlookup(self, dino: int, di: L.DiskInode, name: str):
        hit = self._index(dino, di).get(name)
        if hit is not None and hit[2] == L.WHITEOUT_INO:
            return None  # overlay delete marker: the name reads as absent
        return hit if hit is not None else None

    def _dirlink(self, dino: int, name: str, ino: int) -> None:
        di = self._iget(dino)
        idx = self._index(dino, di)
        hit = idx.get(name)
        if hit is not None and hit[2] == L.WHITEOUT_INO:
            # create-over-whiteout flips the delete marker's slot in place
            # (same rule as xv6's scan path): one slot write, no duplicate
            # whiteout+live records for the name
            self._dir_set(dino, hit[0], hit[1], ino, name)
            return
        # append at end (holes tracked lazily via index rebuild)
        bn = di.size // L.BSIZE
        off = di.size % L.BSIZE
        di.size += L.DIRENT_SIZE
        self._iupdate(dino, di)
        b = self._bmap(dino, di, bn, alloc=True)
        with self._bread(b) as bh:
            bh.data()[off: off + L.DIRENT_SIZE] = L.pack_dirent(ino, name)
            self._log(b, bytes(bh.data()))
        idx[name] = (bn, off, ino)

    def _dir_unset(self, dino: int, bn: int, off: int) -> None:
        super()._dir_unset(dino, bn, off)
        idx = self._dirindex.get(dino)
        if idx is not None:
            for name, (b2, o2, _) in list(idx.items()):
                if b2 == bn and o2 == off:
                    del idx[name]
                    break

    def _dir_set(self, dino: int, bn: int, off: int, ino: int,
                 name: str) -> None:
        # rename-overwrite's in-place slot rewrite: whatever name occupied
        # this slot leaves the index, the new binding enters it
        super()._dir_set(dino, bn, off, ino, name)
        idx = self._dirindex.get(dino)
        if idx is not None:
            for nm, (b2, o2, _) in list(idx.items()):
                if b2 == bn and o2 == off:
                    del idx[nm]
                    break
            idx[name] = (bn, off, ino)

    def _dir_scan_state(self, dino: int, pdi) -> Dict:
        """Batched-metadata dir state — the LIVE hash index itself, so the
        batch's inserts/removes keep it current with zero extra scans
        (bulk dirindex maintenance). ``holes`` is None: this fs's scalar
        ``_dirlink`` always appends, and the batch must place dirents the
        same way."""
        return {"names": self._index(dino, pdi), "holes": None}

    # --- batched fast paths ------------------------------------------------------------------
    # read_many is inherited from Xv6FileSystem (already vectorized); the
    # overrides below add what the dir index and write coalescing buy a
    # batch that the base class can't know about.

    def lookup_many(self, reqs) -> List:
        """Vectorized lookup: one fs-lock acquisition, pure hash-index hits
        (no per-name dirent scan, no scalar re-dispatch)."""
        out: List = []
        with self._oplock:
            for args in reqs:
                try:
                    parent, name = args
                    pdi = self._iget(parent)
                    if pdi.type != L.T_DIR:
                        raise FsError(Errno.ENOTDIR, str(parent))
                    hit = self._index(parent, pdi).get(name)
                    if hit is None or hit[2] == L.WHITEOUT_INO:
                        raise FsError(Errno.ENOENT, name)
                    ino = hit[2]
                    out.append(self._attr(ino, self._iget(ino)))
                except FsError as e:
                    out.append(e)
                except (TypeError, ValueError):
                    out.append(FsError(Errno.EINVAL, "bad lookup args"))
            with self._stats_lock:  # concurrent lookup units share this
                self.stats["ops"] += len(reqs)  # count per entry, like scalar
        return out

    def write_many(self, reqs) -> List:
        """Batched write with coalescing: adjacent entries that continue the
        same inode's byte range merge into one write() (one extent
        preallocation + journal pass for the merged run, the batch analogue
        of this class's full-block append coalescing). If a merged run
        fails (e.g. ENOSPC partway), it is retried entry by entry so each
        entry still gets its own result — per-entry errno isolation holds
        even through the fast path. Dedup mounts share one batch-end
        dedup pass across the whole call."""
        store = self._blockstore
        if store is not None:
            store.batch_begin()
        try:
            return self._write_many_runs(reqs)
        finally:
            if store is not None:
                self._dedup_batch_end()

    def _write_many_runs(self, reqs) -> List:
        out: List = []
        with self._oplock:
            i, n = 0, len(reqs)
            while i < n:
                try:
                    ino, off, data = reqs[i]
                    if (not isinstance(data, (bytes, bytearray))
                            or not isinstance(off, int)):
                        raise TypeError("write args are (ino, int off, bytes)")
                    end = off + len(data)
                except (TypeError, ValueError):
                    out.append(FsError(Errno.EINVAL, "bad write args"))
                    i += 1
                    continue
                j = i + 1
                parts = [data]
                while j < n:
                    nxt = reqs[j]
                    if (not isinstance(nxt, tuple) or len(nxt) != 3
                            or nxt[0] != ino or nxt[1] != end
                            or not isinstance(nxt[2], (bytes, bytearray))):
                        break
                    parts.append(nxt[2])
                    end += len(nxt[2])
                    j += 1
                try:
                    self.write(ino, off, b"".join(parts) if len(parts) > 1
                               else parts[0])
                    out.extend(len(p) for p in parts)
                    # scalar write counted the merged run as one op; keep
                    # stats['ops'] meaning entries, like the other paths
                    with self._stats_lock:
                        self.stats["ops"] += len(parts) - 1
                except FsError as e:
                    if len(parts) == 1:
                        out.append(e)
                    else:
                        # merged run failed: retry per entry (idempotent
                        # rewrites) so isolation survives the fast path
                        out.extend(self._scalar_many("write", reqs[i:j]))
                i = j
        return out

    # --- state transfer keeps the index -----------------------------------------------------
    def extract_state(self) -> Dict:
        st = super().extract_state()
        st["dirindex"] = {d: dict(v) for d, v in self._dirindex.items()}
        return st

    def restore_state(self, state: Dict, from_version: int) -> None:
        super().restore_state(state, from_version)
        self._dirindex = {int(d): dict(v)
                          for d, v in state.get("dirindex", {}).items()}

    def state_schema(self):
        return super().state_schema() + ("dirindex",)

    def optional_state_keys(self):
        # a lazily-rebuilt cache: an upgrade FROM plain xv6 (which never
        # emits it) legally starts with an empty index — declaring it
        # optional keeps the schema honest without forcing a migrate hook
        return super().optional_state_keys() + ("dirindex",)
