"""The xv6 file system on the Bento file-operations API.

Faithful to the paper's evaluation vehicle: journaling (data=journal, like
the paper's ext4 mount), 12 direct + indirect + double-indirect addressing
(their 4 GB-file extension), locks around inode/block allocation (their
race fix), fixed-size directory entries.

One implementation, policy-parameterized, mounted three ways by the
benchmark matrix (see repro.fs.mounts):
  * bento  — group commit + batched (`writepages`) install,
  * vfs    — per-operation commit + synchronous install ("the VFS baseline
             was just written for this evaluation" — paper §6),
  * fuse   — same code behind a subprocess serialization bridge.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Tuple

from repro.core.capability import SuperBlockCap
from repro.core.interface import (Attr, BentoFilesystem, CompletionEntry,
                                  Errno, FileKind, FsError, ROOT_INO,
                                  SubmissionEntry)
from repro.fs import layout as L
from repro.fs.blockstore import BlockStore, DEDUP_TABLE_NAME
from repro.fs.journal import Journal, JournalFull


MAXOP_BLOCKS = 16  # journal blocks one (sub-)operation may touch


@dataclasses.dataclass(frozen=True)
class Xv6Options:
    group_commit: bool = True  # False: commit at end of every operation
    batched_install: bool = True  # writepages-style journal install
    commit_threshold: float = 0.75  # commit when journal this full
    dedup: bool = False  # content-addressed data plane (repro.fs.blockstore)


def mkfs(services, ninodes: int = 4096, nlog: int = 64) -> None:
    """Format the device: superblock, journal, inode table, bitmap, root."""
    sb_cap = services.superblock()
    n = sb_cap.n_blocks
    geo = L.geometry(n, ninodes=ninodes, nlog=nlog)
    with services.sb_getblk_zero(sb_cap, 0) as bh:
        bh.data()[:] = geo.pack()
        services.bwrite_sync(sb_cap, bh)
    # zero journal + inode table + bitmap
    for b in range(geo.logstart, geo.datastart):
        with services.sb_getblk_zero(sb_cap, b) as bh:
            services.bwrite_sync(sb_cap, bh)
    # mark metadata blocks used in the bitmap
    used = geo.datastart
    for b in range(used):
        _bitmap_set(services, sb_cap, geo, b, True)
    # root directory inode
    root = L.DiskInode(type=L.T_DIR, nlink=2, size=0)
    _write_inode_raw(services, sb_cap, geo, ROOT_INO, root)


def _bitmap_set(services, sb_cap, geo: L.SuperBlock, blockno: int, used: bool):
    bmblock = geo.bmapstart + blockno // (L.BSIZE * 8)
    bit = blockno % (L.BSIZE * 8)
    with services.sb_bread(sb_cap, bmblock) as bh:
        buf = bh.data()
        if used:
            buf[bit // 8] |= 1 << (bit % 8)
        else:
            buf[bit // 8] &= ~(1 << (bit % 8))
        services.bwrite_sync(sb_cap, bh)


def _write_inode_raw(services, sb_cap, geo, ino: int, di: L.DiskInode) -> None:
    blk = geo.inodestart + ino // L.IPB
    off = (ino % L.IPB) * L.INODE_SIZE
    with services.sb_bread(sb_cap, blk) as bh:
        bh.data()[off: off + L.INODE_SIZE] = di.pack()
        services.bwrite_sync(sb_cap, bh)


class Xv6FileSystem(BentoFilesystem):
    NAME = "xv6"
    VERSION = 1

    def __init__(self, options: Xv6Options = Xv6Options()):
        self.opts = options
        self.ks = None
        self.sb_cap: Optional[SuperBlockCap] = None
        self.geo: Optional[L.SuperBlock] = None
        self.journal: Optional[Journal] = None
        self._oplock = threading.RLock()  # big fs lock (paper: added locks)
        self._alloc_lock = threading.RLock()
        self._icache: Dict[int, L.DiskInode] = {}
        self._free_hint = 0
        self._free_inode_hint = 2
        self.stats = {"ops": 0, "commits_forced": 0}
        self._blockstore: Optional[BlockStore] = None
        self._current_submitter = None  # stamped per run by submit_batch
        # dedup widens the per-write metadata footprint (CoW copy block +
        # index-table blocks) — reservations must cover it
        self._chain_write_overhead = (self._CHAIN_WRITE_OVERHEAD
                                      + (3 if options.dedup else 0))

    # --- lifecycle -----------------------------------------------------------------
    def init(self, sb: SuperBlockCap, services) -> None:
        self.ks = services
        self.sb_cap = sb
        with services.sb_bread(sb, 0) as bh:
            self.geo = L.SuperBlock.unpack(bytes(bh.data()))
        if self.geo.magic != L.FSMAGIC:
            raise FsError(Errno.EINVAL, "bad magic: not an xv6 filesystem")
        self.journal = Journal(services, sb, self.geo,
                               batched_install=self.opts.batched_install)
        # after any journal rollback (op-scope overflow or chain-member
        # abort) the in-memory caches may reflect the rolled-back staging
        self.journal.rollback_listener = self._after_journal_rollback
        self.journal.recover()
        if self.opts.dedup:
            self._blockstore = BlockStore(self)
            self._blockstore.attach()

    def destroy(self) -> None:
        if self.journal:
            self.journal.commit()
        if self.ks and self.sb_cap:
            self.ks.flush(self.sb_cap)

    # --- §4.8 state transfer ------------------------------------------------------------
    def extract_state(self) -> Dict:
        self._dedup_drain()  # settle the index before quiescing
        self.flush()  # quiesced by the runtime; drain to a clean point
        state = {
            "icache": {ino: dataclasses.asdict(di)
                       for ino, di in self._icache.items()},
            "free_hint": self._free_hint,
            "free_inode_hint": self._free_inode_hint,
            "journal": self.journal.extract_state(),
            "stats": dict(self.stats),
        }
        if self._blockstore is not None:
            state["dedup"] = self._blockstore.extract_state()
        return state

    def restore_state(self, state: Dict, from_version: int) -> None:
        self._icache = {int(k): L.DiskInode(**v)
                        for k, v in state.get("icache", {}).items()}
        self._free_hint = state.get("free_hint", 0)
        self._free_inode_hint = state.get("free_inode_hint", 2)
        self.journal.restore_state(state.get("journal", {}))
        self.stats.update(state.get("stats", {}))
        if self._blockstore is not None and "dedup" in state:
            self._blockstore.restore_state(state["dedup"])

    def state_schema(self) -> Tuple[str, ...]:
        base = ("icache", "free_hint", "free_inode_hint", "journal", "stats")
        return base + ("dedup",) if self.opts.dedup else base

    def optional_state_keys(self) -> Tuple[str, ...]:
        # a dedup mount can absorb state from a plain predecessor (the
        # index reloads from the device) and vice versa
        return ("dedup",)

    # --- journal-aware block IO -----------------------------------------------------------
    def _bread(self, blockno: int):
        bh = self.ks.sb_bread(self.sb_cap, blockno)
        pend = self.journal.pending_get(blockno)
        if pend is not None and bytes(bh.data()) != pend:
            bh.data()[:] = pend
        return bh

    def _log(self, blockno: int, data: bytes) -> None:
        self.journal.log_write(blockno, data)

    def _begin_op(self) -> None:
        """Reserve journal space for one (sub-)operation — commits the
        running transaction first if it could not absorb MAXOP_BLOCKS more
        (xv6 begin_op), so operations are never torn across commits.

        Inside a chain scope this is a no-op: ``chain_begin`` already
        reserved the WHOLE chain's footprint, and a mid-chain commit here
        would tear the chain across two transactions."""
        if self.journal.in_chain:
            return
        if len(self.journal._pending) + MAXOP_BLOCKS >= self.journal.capacity:
            self.stats["commits_forced"] += 1
            self.journal.commit()
        self.journal.begin_op_scope()  # overflow rolls back to this point

    def _end_op(self, mutated: bool) -> None:
        self.stats["ops"] += 1
        if not mutated:
            return
        if self.journal.in_chain:
            # per-op commit policy (the VFS baseline) defers to end_chain —
            # one transaction per chain; the group-commit threshold
            # heuristic simply waits until the chain closes.
            if not self.opts.group_commit:
                self.journal.commit()
            return
        if not self.opts.group_commit:
            self.journal.commit()
        elif len(self.journal._pending) >= int(
                self.journal.capacity * self.opts.commit_threshold):
            self.stats["commits_forced"] += 1
            self.journal.commit()

    # --- chain-scoped reservation (SQE_LINK chains as one journal txn) --------------
    #
    # ``execute_batch`` calls chain_begin/chain_end around every chain
    # group. The estimate is an upper bound computed from the submission
    # entries (data blocks + per-op metadata overhead); absorption makes
    # the real footprint smaller. The fs lock is held for the WHOLE chain
    # scope so no concurrent op can slip a commit between two members (the
    # members re-enter it, it is reentrant).

    _CHAIN_WRITE_OVERHEAD = 4  # inode + bitmap + up to 2 indirect blocks
    _CHAIN_OP_BLOCKS = {
        # rename may also truncate a displaced target (dirent swap + two
        # parent inodes + displaced inode + bitmap blocks of freed data)
        "create": 6, "mkdir": 8, "unlink": 6, "rmdir": 8, "rename": 12,
        "getattr": 0, "lookup": 0, "read": 0, "readdir": 0, "statfs": 0,
        "fsync": 0, "flush": 0,
    }

    def _chain_entry_blocks(self, e: SubmissionEntry) -> int:
        if e.op == "write":
            kw = e.kwargs or {}
            off = e.args[1] if len(e.args) > 1 else kw.get("off")
            data = e.args[2] if len(e.args) > 2 else kw.get("data")
            if not isinstance(data, (bytes, bytearray)):
                return MAXOP_BLOCKS  # PrevResult/malformed payload: worst case
            start = off % L.BSIZE if isinstance(off, int) else 0
            nblocks = (start + len(data) + L.BSIZE - 1) // L.BSIZE
            return nblocks + self._chain_write_overhead
        return self._CHAIN_OP_BLOCKS.get(e.op, MAXOP_BLOCKS)

    def estimate_chain_blocks(self, entries) -> int:
        """Journal-blocks upper bound for a chain, from its entries."""
        return sum(self._chain_entry_blocks(e) for e in entries)

    def estimate_append_blocks(self, nbytes: int) -> int:
        """Journal-blocks upper bound for appending ``nbytes`` to an
        existing file — the log-block allocation hook a stacked layer
        (repro.fs.prov) uses to size the provenance records it will add to
        a reservation. Data blocks (+1 for a straddled boundary) plus this
        fs's per-write metadata overhead; subclasses with costlier write
        paths inherit their own ``_CHAIN_WRITE_OVERHEAD``."""
        return (nbytes + L.BSIZE - 1) // L.BSIZE + 1 + self._chain_write_overhead

    def chain_begin(self, entries, extra_blocks: int = 0):
        """Reserve ONE journal transaction for a whole chain group.
        ``extra_blocks`` is the stacked-layer hook: a wrapper that will
        stage additional blocks inside the same transaction (provenance
        records) adds its footprint to the reservation, so the atomicity
        estimate covers BOTH layers or the chain is refused up front."""
        est = self.estimate_chain_blocks(entries) + extra_blocks
        self._oplock.acquire()
        try:
            self.journal.begin_chain(est)
        except JournalFull as e:
            self._oplock.release()
            return e.errno  # ENOSPC before anything was staged
        except BaseException:
            # e.g. a device error inside the pre-chain commit: the scope
            # never opened, so execute_batch will not call chain_end —
            # release here or the fs lock leaks
            self._oplock.release()
            raise
        if self._blockstore is not None:
            self._blockstore.batch_begin()
        return None

    def chain_end(self) -> None:
        try:
            store = self._blockstore
            if store is not None and store.batch_dec() == 0:
                # dedup pass INSIDE the chain transaction: sharing rewrites
                # commit atomically with the writes that produced them
                store.flush_pending()
            self.journal.end_chain()  # runs any deferred (in-chain) commit
        finally:
            self._oplock.release()

    # --- inodes ---------------------------------------------------------------------------
    def _iget(self, ino: int) -> L.DiskInode:
        if not (0 < ino < self.geo.ninodes):
            raise FsError(Errno.ESTALE, f"bad ino {ino}")
        di = self._icache.get(ino)
        if di is None:
            blk = self.geo.inodestart + ino // L.IPB
            off = (ino % L.IPB) * L.INODE_SIZE
            with self._bread(blk) as bh:
                di = L.DiskInode.unpack(bytes(bh.data()), off)
            self._icache[ino] = di
        return di

    def _iupdate(self, ino: int, di: L.DiskInode) -> None:
        self._icache[ino] = di
        blk = self.geo.inodestart + ino // L.IPB
        off = (ino % L.IPB) * L.INODE_SIZE
        with self._bread(blk) as bh:
            bh.data()[off: off + L.INODE_SIZE] = di.pack()
            self._log(blk, bytes(bh.data()))

    def _ialloc(self, kind: int) -> int:
        with self._alloc_lock:  # paper: lock around inode allocation
            start = self._free_inode_hint
            for delta in range(self.geo.ninodes - 2):
                ino = 2 + (start - 2 + delta) % (self.geo.ninodes - 2)
                di = self._iget(ino)
                if di.type == L.T_FREE:
                    ndi = L.DiskInode(type=kind, nlink=1)
                    self._iupdate(ino, ndi)
                    self._free_inode_hint = ino + 1
                    return ino
            raise FsError(Errno.ENOSPC, "out of inodes")

    # --- block allocator ----------------------------------------------------------------------
    def _balloc(self) -> int:
        with self._alloc_lock:  # paper: lock around block allocation
            total = self.geo.size
            bits_per = L.BSIZE * 8
            start = max(self._free_hint, self.geo.datastart)
            for delta in range(total - self.geo.datastart):
                b = self.geo.datastart + (start - self.geo.datastart + delta) % (
                    total - self.geo.datastart)
                bmblock = self.geo.bmapstart + b // bits_per
                bit = b % bits_per
                with self._bread(bmblock) as bh:
                    buf = bh.data()
                    if not (buf[bit // 8] >> (bit % 8)) & 1:
                        buf[bit // 8] |= 1 << (bit % 8)
                        self._log(bmblock, bytes(buf))
                        self._free_hint = b + 1
                        # zero the block (journaled)
                        self._log(b, bytes(L.BSIZE))
                        return b
            raise FsError(Errno.ENOSPC, "device full")

    def _bfree_raw(self, b: int) -> None:
        """Clear the bitmap bit — the physical free, no refcounting."""
        with self._alloc_lock:
            bits_per = L.BSIZE * 8
            bmblock = self.geo.bmapstart + b // bits_per
            bit = b % bits_per
            with self._bread(bmblock) as bh:
                buf = bh.data()
                buf[bit // 8] &= ~(1 << (bit % 8))
                self._log(bmblock, bytes(buf))
            self._free_hint = min(self._free_hint, b)

    def _bfree(self, b: int) -> None:
        """Drop a reference to ``b``. On dedup mounts a shared block just
        loses one index reference (staged in this op's transaction); the
        bitmap bit clears only with the LAST reference."""
        if self._blockstore is not None and not self._blockstore.release(b):
            return
        self._bfree_raw(b)

    # --- bmap: logical file block -> device block ----------------------------------------------
    def _bmap(self, ino: int, di: L.DiskInode, bn: int, alloc: bool) -> int:
        NI = L.NINDIRECT
        if bn < L.NDIRECT:
            if di.addrs[bn] == 0:
                if not alloc:
                    return 0
                di.addrs[bn] = self._balloc()
                self._iupdate(ino, di)
            return di.addrs[bn]
        bn -= L.NDIRECT
        if bn < NI:
            return self._indirect(ino, di, L.NDIRECT, bn, alloc)
        bn -= NI
        if bn < NI * NI:
            # double indirect
            if di.addrs[L.NDIRECT + 1] == 0:
                if not alloc:
                    return 0
                di.addrs[L.NDIRECT + 1] = self._balloc()
                self._iupdate(ino, di)
            l1 = di.addrs[L.NDIRECT + 1]
            l2 = self._ind_entry(l1, bn // NI, alloc)
            if l2 == 0:
                return 0
            return self._ind_entry(l2, bn % NI, alloc)
        raise FsError(Errno.EFBIG, "file too large")

    def _indirect(self, ino: int, di: L.DiskInode, slot: int, idx: int,
                  alloc: bool) -> int:
        if di.addrs[slot] == 0:
            if not alloc:
                return 0
            di.addrs[slot] = self._balloc()
            self._iupdate(ino, di)
        return self._ind_entry(di.addrs[slot], idx, alloc)

    def _ind_entry(self, indblock: int, idx: int, alloc: bool) -> int:
        import struct
        with self._bread(indblock) as bh:
            buf = bh.data()
            (val,) = struct.unpack_from("<I", buf, idx * 4)
            if val == 0 and alloc:
                val = self._balloc()
                # NB: _balloc may journal this ind block via pending overlay;
                # re-read through the overlay before mutating.
                pend = self.journal.pending_get(indblock)
                if pend is not None:
                    buf[:] = pend
                struct.pack_into("<I", buf, idx * 4, val)
                self._log(indblock, bytes(buf))
        return val

    def _bmap_install(self, ino: int, di: L.DiskInode, bn: int, blk: int) -> None:
        """Point logical block bn at device block blk (journaled) — extent
        preallocation (ext4like) and the blockstore's CoW remapping both
        rewrite existing mappings through this."""
        import struct
        NI = L.NINDIRECT
        if bn < L.NDIRECT:
            di.addrs[bn] = blk
            self._iupdate(ino, di)
            return
        bnn = bn - L.NDIRECT
        if bnn < NI:
            if di.addrs[L.NDIRECT] == 0:
                di.addrs[L.NDIRECT] = self._balloc()
                self._iupdate(ino, di)
            self._ind_set(di.addrs[L.NDIRECT], bnn, blk)
            return
        bnn -= NI
        if di.addrs[L.NDIRECT + 1] == 0:
            di.addrs[L.NDIRECT + 1] = self._balloc()
            self._iupdate(ino, di)
        l2 = self._ind_entry(di.addrs[L.NDIRECT + 1], bnn // NI, alloc=True)
        self._ind_set(l2, bnn % NI, blk)

    def _ind_set(self, indblock: int, idx: int, val: int) -> None:
        import struct
        with self._bread(indblock) as bh:
            buf = bh.data()
            struct.pack_into("<I", buf, idx * 4, val)
            self._log(indblock, bytes(buf))

    def _write_block_target(self, ino: int, di: L.DiskInode, bn: int) -> int:
        """Resolve (and allocate) the device block a data write must land
        on. On dedup mounts the blockstore interposes: a shared block is
        CoW-broken to a private copy first, the stored hash is invalidated
        in this same transaction, and the block queues for the batch-end
        dedup pass."""
        b = self._bmap(ino, di, bn, alloc=True)
        if self._blockstore is not None and di.type == L.T_FILE:
            b = self._blockstore.note_write(ino, di, bn, b)
        return b

    # --- batched boundary: vectorized fast paths ------------------------------------------------
    #
    # One submission batch = one fs-lock acquisition, one journal-overlay
    # snapshot, one bulk buffer-cache pass (sb_bread_many). submit_batch
    # coalesces same-op runs into the *_many methods below; results lists
    # carry FsError values in failing slots (per-entry errno isolation).

    _MANY_OPS = {"read": "read_many", "write": "write_many",
                 "getattr": "getattr_many", "lookup": "lookup_many",
                 "create": "create_many", "mkdir": "mkdir_many",
                 "unlink": "unlink_many"}

    # chain members that can stage journal blocks (and so need the member
    # undo bracket); read-only members and commit-only members (fsync/flush
    # defer their commit to end_chain) skip the two journal-lock round
    # trips — measurable on the chained create→write hot path
    _CHAIN_MUTATING_OPS = frozenset({
        "create", "mkdir", "unlink", "rmdir", "rename", "write", "truncate"})

    def submit_batch(self, entries) -> List[CompletionEntry]:
        if not isinstance(entries, list):
            entries = list(entries)
        store = self._blockstore
        if store is not None:
            store.batch_begin()
        try:
            return self._submit_batch_scoped(entries)
        finally:
            if store is not None:
                self._dedup_batch_end()

    def _submit_batch_scoped(self, entries) -> List[CompletionEntry]:
        if self.journal is not None and self.journal.in_chain_here \
                and any(e.op in self._CHAIN_MUTATING_OPS for e in entries):
            # chain-member dispatch on the chain-owning thread
            # (execute_batch sends members one at a time; a CONCURRENT
            # submitter sees in_chain_here False and takes the plain path,
            # blocking on the fs lock the chain holds): bracket the
            # member's journal staging so a reservation estimate miss
            # (PrevResult-fed payload larger than guessed → JournalFull →
            # ENOSPC) rolls back cleanly — an ENOSPC member stages
            # NOTHING, so a later group commit can never make a torn
            # member durable.
            self.journal.chain_member_begin()
            comps = self._submit_batch_runs(entries)
            if any(c.errno == Errno.ENOSPC for c in comps):
                self.journal.chain_member_abort()  # fires rollback_listener
            else:
                self.journal.chain_member_end()
            return comps
        return self._submit_batch_runs(entries)

    def _after_journal_rollback(self) -> None:
        """Journal rollback listener: in-memory caches may hold the
        rolled-back staging (e.g. a torn write's inflated inode size) —
        drop them; they rebuild through the restored journal overlay.
        Subclasses layer their derived indexes in ``_invalidate_caches_
        after_abort``."""
        self._icache.clear()
        if self._blockstore is not None and self._blockstore._table_blocks:
            # refcounts/hashes staged by the rolled-back transaction are
            # gone from the journal overlay: rebuild from what survived
            self._blockstore.reload()
        self._invalidate_caches_after_abort()

    def _invalidate_caches_after_abort(self) -> None:
        """Subclass hook: drop derived in-memory state after a journal
        rollback (see ext4like's directory index)."""

    def _dedup_batch_end(self) -> None:
        """Close one batch scope; at depth zero, run the deferred dedup
        pass — in the open chain transaction if one is active, else in a
        trailing reservation of its own."""
        store = self._blockstore
        if store.batch_dec() != 0 or not store.pending:
            return
        with self._oplock:
            if self.journal.in_chain:
                store.flush_pending()
            else:
                self._begin_op()
                store.flush_pending()
                self._end_op(True)

    def _dedup_drain(self) -> None:
        """Settle any still-pending dedup work (quiesce/extract path)."""
        store = self._blockstore
        if store is None or not store.pending:
            return
        with self._oplock:
            if not self.journal.in_chain:
                self._begin_op()
                store.flush_pending()
                self._end_op(True)

    def _submit_batch_runs(self, entries) -> List[CompletionEntry]:
        comps: List[CompletionEntry] = []
        i, n = 0, len(entries)
        try:
            while i < n:
                # keyword-style entries keep scalar dispatch (the *_many
                # paths are positional); coalesce only positional same-op
                # runs — and only entries stamped with the same submitter,
                # so per-submitter attribution stays exact
                sub = getattr(entries[i], "submitter", None)
                self._current_submitter = sub
                many = (self._MANY_OPS.get(entries[i].op)
                        if not entries[i].kwargs else None)
                if many is None:
                    comps.append(self._dispatch_one(entries[i]))
                    i += 1
                    continue
                j = i
                while (j < n and entries[j].op == entries[i].op
                       and not entries[j].kwargs
                       and getattr(entries[j], "submitter", None) == sub):
                    j += 1
                run = entries[i:j]
                results = getattr(self, many)([e.args for e in run])
                for e, r in zip(run, results):
                    if isinstance(r, FsError):
                        comps.append(CompletionEntry(e.user_data, errno=r.errno))
                    else:
                        comps.append(CompletionEntry(e.user_data, result=r))
                i = j
        finally:
            self._current_submitter = None
        return comps

    def _bmap_ro(self, di: L.DiskInode, bn: int, ind_cache: Dict[int, bytes]) -> int:
        """Read-only bmap sharing one indirect-block cache across a batch
        (the scalar _bmap takes a cache-lock round trip per indirect hop)."""
        NI = L.NINDIRECT
        if bn < L.NDIRECT:
            return di.addrs[bn]
        bn -= L.NDIRECT
        if bn < NI:
            l1 = di.addrs[L.NDIRECT]
            return self._ind_ro(l1, bn, ind_cache) if l1 else 0
        bn -= NI
        if bn < NI * NI:
            l1 = di.addrs[L.NDIRECT + 1]
            if not l1:
                return 0
            l2 = self._ind_ro(l1, bn // NI, ind_cache)
            return self._ind_ro(l2, bn % NI, ind_cache) if l2 else 0
        raise FsError(Errno.EFBIG, "file too large")

    def _ind_ro(self, indblock: int, idx: int, ind_cache: Dict[int, bytes]) -> int:
        import struct
        raw = ind_cache.get(indblock)
        if raw is None:
            with self._bread(indblock) as bh:
                raw = bytes(bh.data())
            ind_cache[indblock] = raw
        return struct.unpack_from("<I", raw, idx * 4)[0]

    def read_many(self, reqs) -> List:
        """Vectorized read: plan every request's block segments first, then
        fetch all distinct data blocks in ONE buffer-cache pass and slice.
        Returns bytes per request, FsError in failing slots."""
        out: List = []
        with self._oplock:
            pend = self.journal.pending_snapshot()
            ind_cache: Dict[int, bytes] = {}
            plans: List = []
            needed = set()
            for args in reqs:
                try:
                    ino, off, size = args
                    if not isinstance(off, int) or not isinstance(size, int):
                        raise TypeError("read args are (ino, int off, int size)")
                    di = self._iget(ino)
                    if di.type == L.T_DIR:
                        raise FsError(Errno.EISDIR, str(ino))
                    segs = []
                    if off < di.size and size > 0:
                        size = min(size, di.size - off)
                        while size > 0:
                            bn, boff = divmod(off, L.BSIZE)
                            nn = min(L.BSIZE - boff, size)
                            b = self._bmap_ro(di, bn, ind_cache)
                            segs.append((b, boff, nn))
                            if b and b not in pend:
                                needed.add(b)
                            off += nn
                            size -= nn
                    plans.append(segs)
                except FsError as e:
                    plans.append(e)
                except (TypeError, ValueError):
                    plans.append(FsError(Errno.EINVAL, "bad read args"))
            fetched: List[int] = []
            try:
                heads = self.ks.sb_bread_many(self.sb_cap, sorted(needed),
                                              fetched=fetched)
            except Exception as e:  # device error: fail the batch's reads
                # as per-entry EIO — errors never cross as exceptions
                io_err = FsError(Errno.EIO, f"batched bread failed: {e}")
                self.stats["ops"] += len(reqs)
                return [p if isinstance(p, FsError) else io_err
                        for p in plans]
            bad = ()
            try:
                bufs = {bh.blockno: bh.data() for bh in heads}
                # verified reads: blocks that came off the DEVICE this pass
                # (cache hits were verified when first fetched; journal-
                # pending overlays are newer than their stored hash) are
                # re-hashed in ONE batched launch against the index
                bad = (self._blockstore.verify_fetched(bufs, fetched)
                       if self._blockstore is not None else ())
                for segs in plans:
                    if isinstance(segs, FsError):
                        out.append(segs)
                        continue
                    if bad and any(b in bad for b, _, _ in segs):
                        out.append(FsError(
                            Errno.EIO, "blockstore: checksum mismatch"))
                        continue
                    chunks = []
                    for b, boff, nn in segs:
                        if b == 0:
                            chunks.append(bytes(nn))  # hole
                        else:
                            src = pend.get(b) or bufs[b]
                            chunks.append(bytes(src[boff: boff + nn]))
                    out.append(chunks[0] if len(chunks) == 1 else b"".join(chunks))
            finally:
                for bh in heads:
                    bh.brelse()
            if bad:
                # a corrupt fetch must not linger as a trusted cache hit:
                # evict so every later read refetches and re-verifies (EIO
                # stays sticky until the device matches the index again)
                self.ks.sb_invalidate_blocks(self.sb_cap, sorted(bad))
            self.stats["ops"] += len(reqs)
        return out

    def _scalar_many(self, op: str, reqs) -> List:
        """Scalar loop under ONE fs-lock acquisition with per-entry errno
        capture — the shared body of the non-read vectorized paths.
        Arg-shape errors complete as EINVAL (pre-call bind check);
        implementation exceptions propagate, like scalar dispatch."""
        fn = getattr(self, op)
        out: List = []
        with self._oplock:
            for args in reqs:
                if not isinstance(args, tuple) \
                        or not self._entry_fits(op, args, None):
                    out.append(FsError(Errno.EINVAL, f"bad {op} args"))
                    continue
                try:
                    out.append(fn(*args))
                except FsError as e:
                    out.append(e)
        return out

    def write_many(self, reqs) -> List:
        """Batched write: one fs-lock acquisition; writes land in the open
        group-commit transaction, so a following fsync/flush entry commits
        the whole batch with one journal transaction (and one checksum_batch
        launch). Returns bytes-written per request, FsError where failed.
        On dedup mounts the whole batch shares ONE batch-end dedup pass
        (one blockhash launch), like submit_batch dispatch."""
        store = self._blockstore
        if store is None:
            return self._scalar_many("write", reqs)
        store.batch_begin()
        try:
            return self._scalar_many("write", reqs)
        finally:
            self._dedup_batch_end()

    def getattr_many(self, reqs) -> List:
        return self._scalar_many("getattr", reqs)

    def lookup_many(self, reqs) -> List:
        return self._scalar_many("lookup", reqs)

    # --- batched metadata: vectorized create/unlink ---------------------------------
    #
    # The scalar create/unlink rescan the parent directory once per call
    # (O(dir) each, O(dir^2) for a bulk phase). The vectorized paths scan
    # each touched directory ONCE per batch into a slot map that is kept
    # current as the batch mutates it — same allocation and placement
    # decisions as the scalar ops (first-fit holes, append at tail), so
    # batched and scalar execution produce identical trees.

    def _dir_scan_state(self, dino: int, pdi: L.DiskInode) -> Dict:
        """One-scan directory state for a batch: ``names`` maps name ->
        (bn, off, ino) like ``_dirlookup`` hits; ``holes`` lists free slots
        in scan order (the scalar first-fit order). Subclasses with a live
        index return it directly (repro.fs.ext4like)."""
        import collections
        names: Dict[str, Tuple[int, int, int]] = {}
        holes = collections.deque()
        for bn, off, e_ino, name in self._dir_entries(dino, pdi):
            if e_ino != 0:
                names.setdefault(name, (bn, off, e_ino))
            else:
                holes.append((bn, off))
        return {"names": names, "holes": holes}

    def _create_many_common(self, reqs, kind: int) -> List:
        op = "mkdir" if kind == L.T_DIR else "create"
        out: List = []
        with self._oplock:
            states: Dict[int, Dict] = {}
            for args in reqs:
                if not isinstance(args, tuple) \
                        or not self._entry_fits(op, args, None):
                    out.append(FsError(Errno.EINVAL, f"bad {op} args"))
                    continue
                parent, name = args
                try:
                    if (not isinstance(name, str) or not name or "/" in name
                            or len(name.encode()) > L.NAME_MAX):
                        raise FsError(Errno.EINVAL, str(name))
                    self._check_reserved(name)
                    self._begin_op()
                    pdi = self._iget(parent)
                    if pdi.type != L.T_DIR:
                        raise FsError(Errno.ENOTDIR, str(parent))
                    st = states.get(parent)
                    if st is None:
                        st = states[parent] = self._dir_scan_state(parent, pdi)
                    if name in st["names"]:
                        raise FsError(Errno.EEXIST, name)
                    ino = self._ialloc(kind)
                    if kind == L.T_DIR:
                        pdi = self._iget(parent)
                        pdi.nlink += 1  # ".." link
                        self._iupdate(parent, pdi)
                        di = self._iget(ino)
                        di.nlink = 2
                        self._iupdate(ino, di)
                    # place the dirent: first-fit hole, else append (the
                    # scalar _dirlink decisions, without its rescan)
                    if st["holes"]:
                        bn, off = st["holes"].popleft()
                    else:
                        pdi = self._iget(parent)
                        bn, off = divmod(pdi.size, L.BSIZE)
                        pdi.size += L.DIRENT_SIZE
                        self._iupdate(parent, pdi)
                    b = self._bmap(parent, self._iget(parent), bn, alloc=True)
                    with self._bread(b) as bh:
                        bh.data()[off: off + L.DIRENT_SIZE] = \
                            L.pack_dirent(ino, name)
                        self._log(b, bytes(bh.data()))
                    st["names"][name] = (bn, off, ino)
                    self._end_op(True)
                    out.append(self._attr(ino, self._iget(ino)))
                except FsError as e:
                    out.append(e)
        return out

    def create_many(self, reqs) -> List:
        """Vectorized create: one fs-lock acquisition, one directory scan
        per touched parent (kept live across the batch), per-entry errno
        isolation. Journal behaviour matches scalar: per-entry begin/end
        reservations inside the open group-commit transaction, so a
        following fsync/flush commits the whole batch with ONE
        checksum_batch launch."""
        return self._create_many_common(reqs, L.T_FILE)

    def mkdir_many(self, reqs) -> List:
        return self._create_many_common(reqs, L.T_DIR)

    def unlink_many(self, reqs) -> List:
        """Vectorized unlink: one fs-lock acquisition and one scan per
        touched parent (the scalar path rescans per name)."""
        out: List = []
        with self._oplock:
            states: Dict[int, Dict] = {}
            for args in reqs:
                if not isinstance(args, tuple) \
                        or not self._entry_fits("unlink", args, None):
                    out.append(FsError(Errno.EINVAL, "bad unlink args"))
                    continue
                parent, name = args
                try:
                    self._check_reserved(name)
                    self._begin_op()
                    pdi = self._iget(parent)
                    st = states.get(parent)
                    if st is None:
                        st = states[parent] = self._dir_scan_state(parent, pdi)
                    hit = st["names"].get(name)
                    if hit is None:
                        raise FsError(Errno.ENOENT, str(name))
                    bn, off, ino = hit
                    di = self._iget(ino)
                    if di.type == L.T_DIR:
                        raise FsError(Errno.EISDIR, str(name))
                    self._dir_unset_raw(parent, bn, off)
                    st["names"].pop(name, None)
                    if st["holes"] is not None:  # None: fs never reuses holes
                        st["holes"].append((bn, off))
                    di.nlink -= 1
                    if di.nlink <= 0:
                        self._itrunc(ino, di)
                        di.type = L.T_FREE
                    self._iupdate(ino, di)
                    self._end_op(True)
                    out.append(None)
                except FsError as e:
                    out.append(e)
        return out

    # --- attrs ------------------------------------------------------------------------------------
    def _attr(self, ino: int, di: L.DiskInode) -> Attr:
        kind = FileKind.DIR if di.type == L.T_DIR else FileKind.FILE
        return Attr(ino=ino, kind=kind, size=di.size, nlink=di.nlink)

    def getattr(self, ino: int) -> Attr:
        with self._oplock:
            di = self._iget(ino)
            if di.type == L.T_FREE:
                raise FsError(Errno.ESTALE, f"free inode {ino}")
            self._end_op(False)
            return self._attr(ino, di)

    # --- directories ---------------------------------------------------------------------------------
    def _dir_entries(self, ino: int, di: L.DiskInode):
        nblocks = (di.size + L.BSIZE - 1) // L.BSIZE
        for bn in range(nblocks):
            b = self._bmap(ino, di, bn, alloc=False)
            if b == 0:
                continue
            with self._bread(b) as bh:
                raw = bytes(bh.data())
            limit = min(L.BSIZE, di.size - bn * L.BSIZE)
            for off in range(0, limit, L.DIRENT_SIZE):
                e_ino, name = L.unpack_dirent(raw, off)
                yield bn, off, e_ino, name

    def _dirlookup(self, dino: int, di: L.DiskInode, name: str):
        for bn, off, e_ino, e_name in self._dir_entries(dino, di):
            if e_ino != 0 and e_name == name:
                return bn, off, e_ino
        return None

    def _dirlink(self, dino: int, name: str, ino: int) -> None:
        di = self._iget(dino)
        # reuse a hole if any
        slot = None
        for bn, off, e_ino, _ in self._dir_entries(dino, di):
            if e_ino == 0 and slot is None:
                slot = (bn, off)
        if slot is None:
            bn = di.size // L.BSIZE
            off = di.size % L.BSIZE
            slot = (bn, off)
            di.size += L.DIRENT_SIZE
            self._iupdate(dino, di)
        b = self._bmap(dino, di, slot[0], alloc=True)
        with self._bread(b) as bh:
            bh.data()[slot[1]: slot[1] + L.DIRENT_SIZE] = L.pack_dirent(ino, name)
            self._log(b, bytes(bh.data()))

    def _dir_unset_raw(self, dino: int, bn: int, off: int) -> None:
        """Clear one dirent slot on disk (journal-logged) — no index
        maintenance; subclasses layer theirs in ``_dir_unset``."""
        di = self._iget(dino)
        b = self._bmap(dino, di, bn, alloc=False)
        with self._bread(b) as bh:
            bh.data()[off: off + L.DIRENT_SIZE] = bytes(L.DIRENT_SIZE)
            self._log(b, bytes(bh.data()))

    def _dir_unset(self, dino: int, bn: int, off: int) -> None:
        self._dir_unset_raw(dino, bn, off)

    def _dir_set_raw(self, dino: int, bn: int, off: int, ino: int,
                     name: str) -> None:
        """Rewrite one existing dirent slot in place (journal-logged) —
        rename-overwrite's atomic replace: the target name flips from the
        displaced inode to the moved one in a single slot write, so even
        inside the transaction there is never a missing-name window."""
        di = self._iget(dino)
        b = self._bmap(dino, di, bn, alloc=False)
        with self._bread(b) as bh:
            bh.data()[off: off + L.DIRENT_SIZE] = L.pack_dirent(ino, name)
            self._log(b, bytes(bh.data()))

    def _dir_set(self, dino: int, bn: int, off: int, ino: int,
                 name: str) -> None:
        self._dir_set_raw(dino, bn, off, ino, name)

    def lookup(self, parent: int, name: str) -> Attr:
        with self._oplock:
            pdi = self._iget(parent)
            if pdi.type != L.T_DIR:
                raise FsError(Errno.ENOTDIR, str(parent))
            hit = self._dirlookup(parent, pdi, name)
            self._end_op(False)
            if hit is None:
                raise FsError(Errno.ENOENT, name)
            ino = hit[2]
            return self._attr(ino, self._iget(ino))

    def readdir(self, ino: int) -> List[Tuple[str, int, FileKind]]:
        with self._oplock:
            di = self._iget(ino)
            if di.type != L.T_DIR:
                raise FsError(Errno.ENOTDIR, str(ino))
            out = []
            hide = (DEDUP_TABLE_NAME if (self._blockstore is not None
                                         and ino == ROOT_INO) else None)
            for _, _, e_ino, name in self._dir_entries(ino, di):
                if e_ino != 0:
                    if name == hide:
                        continue
                    edi = self._iget(e_ino)
                    kind = FileKind.DIR if edi.type == L.T_DIR else FileKind.FILE
                    out.append((name, e_ino, kind))
            self._end_op(False)
            return out

    def _check_reserved(self, name: str) -> None:
        """The blockstore's index file is fs-internal: user operations may
        neither create, remove, nor rename over it."""
        if self._blockstore is not None and name == DEDUP_TABLE_NAME:
            raise FsError(Errno.EPERM, name)

    def _create_common(self, parent: int, name: str, kind: int,
                       _internal: bool = False) -> Attr:
        if len(name.encode()) > L.NAME_MAX or not name or "/" in name:
            raise FsError(Errno.EINVAL, name)
        if not _internal:
            self._check_reserved(name)
        with self._oplock:
            self._begin_op()
            pdi = self._iget(parent)
            if pdi.type != L.T_DIR:
                raise FsError(Errno.ENOTDIR, str(parent))
            if self._dirlookup(parent, pdi, name) is not None:
                raise FsError(Errno.EEXIST, name)
            ino = self._ialloc(kind)
            if kind == L.T_DIR:
                pdi = self._iget(parent)
                pdi.nlink += 1  # ".." link
                self._iupdate(parent, pdi)
                di = self._iget(ino)
                di.nlink = 2
                self._iupdate(ino, di)
            self._dirlink(parent, name, ino)
            self._end_op(True)
            return self._attr(ino, self._iget(ino))

    def create(self, parent: int, name: str) -> Attr:
        return self._create_common(parent, name, L.T_FILE)

    def mkdir(self, parent: int, name: str) -> Attr:
        return self._create_common(parent, name, L.T_DIR)

    def _itrunc(self, ino: int, di: L.DiskInode) -> None:
        import struct
        NI = L.NINDIRECT
        for i in range(L.NDIRECT):
            if di.addrs[i]:
                self._bfree(di.addrs[i])
                di.addrs[i] = 0
        if di.addrs[L.NDIRECT]:
            with self._bread(di.addrs[L.NDIRECT]) as bh:
                raw = bytes(bh.data())
            for i in range(NI):
                (v,) = struct.unpack_from("<I", raw, i * 4)
                if v:
                    self._bfree(v)
            self._bfree(di.addrs[L.NDIRECT])
            di.addrs[L.NDIRECT] = 0
        if di.addrs[L.NDIRECT + 1]:
            with self._bread(di.addrs[L.NDIRECT + 1]) as bh:
                raw1 = bytes(bh.data())
            for i in range(NI):
                (l2,) = struct.unpack_from("<I", raw1, i * 4)
                if l2:
                    with self._bread(l2) as bh:
                        raw2 = bytes(bh.data())
                    for j in range(NI):
                        (v,) = struct.unpack_from("<I", raw2, j * 4)
                        if v:
                            self._bfree(v)
                    self._bfree(l2)
            self._bfree(di.addrs[L.NDIRECT + 1])
            di.addrs[L.NDIRECT + 1] = 0
        di.size = 0
        self._iupdate(ino, di)

    def unlink(self, parent: int, name: str) -> None:
        self._check_reserved(name)
        with self._oplock:
            self._begin_op()
            pdi = self._iget(parent)
            hit = self._dirlookup(parent, pdi, name)
            if hit is None:
                raise FsError(Errno.ENOENT, name)
            bn, off, ino = hit
            di = self._iget(ino)
            if di.type == L.T_DIR:
                raise FsError(Errno.EISDIR, name)
            self._dir_unset(parent, bn, off)
            di.nlink -= 1
            if di.nlink <= 0:
                self._itrunc(ino, di)
                di.type = L.T_FREE
            self._iupdate(ino, di)
            self._end_op(True)

    def rmdir(self, parent: int, name: str) -> None:
        self._check_reserved(name)
        with self._oplock:
            self._begin_op()
            pdi = self._iget(parent)
            hit = self._dirlookup(parent, pdi, name)
            if hit is None:
                raise FsError(Errno.ENOENT, name)
            bn, off, ino = hit
            di = self._iget(ino)
            if di.type != L.T_DIR:
                raise FsError(Errno.ENOTDIR, name)
            if any(e_ino != 0 for _, _, e_ino, _ in self._dir_entries(ino, di)):
                raise FsError(Errno.ENOTEMPTY, name)
            self._dir_unset(parent, bn, off)
            self._itrunc(ino, di)
            di.type = L.T_FREE
            di.nlink = 0
            self._iupdate(ino, di)
            pdi = self._iget(parent)
            pdi.nlink -= 1
            self._iupdate(parent, pdi)
            self._end_op(True)

    def _assert_not_in_subtree(self, ino: int, newparent: int) -> None:
        """EINVAL when ``newparent`` lives inside the directory being
        moved — without this check the rename would detach the subtree
        into an unreachable cycle (POSIX EINVAL)."""
        stack = [ino]
        while stack:
            d = stack.pop()
            if d == newparent:
                raise FsError(Errno.EINVAL, "rename into own subtree")
            ddi = self._iget(d)
            for _, _, e_ino, _ in self._dir_entries(d, ddi):
                if e_ino != 0 and self._iget(e_ino).type == L.T_DIR:
                    stack.append(e_ino)

    def rename(self, parent: int, name: str, newparent: int, newname: str) -> None:
        """POSIX rename, overwrite included: an existing ``newname`` is
        atomically REPLACED, never refused EEXIST — files replace files,
        directories replace EMPTY directories (ENOTEMPTY otherwise;
        ENOTDIR/EISDIR on kind mismatch). The displaced inode drops its
        link (blocks freed when it reaches zero) inside the SAME journal
        reservation as the dirent swap, so a crash at any device write
        recovers to either the complete old mapping or the complete new
        one — ``newname`` always resolves, the displaced inode's blocks
        are freed exactly when the swap is durable (enumerated per crash
        point by tests/test_crash_torture.py)."""
        if (not isinstance(newname, str) or not newname or "/" in newname
                or len(newname.encode()) > L.NAME_MAX):
            raise FsError(Errno.EINVAL, str(newname))
        self._check_reserved(name)
        self._check_reserved(newname)
        with self._oplock:
            self._begin_op()
            pdi = self._iget(parent)
            if pdi.type != L.T_DIR:
                raise FsError(Errno.ENOTDIR, str(parent))
            hit = self._dirlookup(parent, pdi, name)
            if hit is None:
                raise FsError(Errno.ENOENT, name)
            bn, off, ino = hit
            ndi = self._iget(newparent)
            if ndi.type != L.T_DIR:
                raise FsError(Errno.ENOTDIR, str(newparent))
            if parent == newparent and name == newname:
                self._end_op(False)  # POSIX: rename onto itself is a no-op
                return
            sdi = self._iget(ino)
            if sdi.type == L.T_DIR and newparent != parent:
                self._assert_not_in_subtree(ino, newparent)
            existing = self._dirlookup(newparent, ndi, newname)
            if existing is not None:
                ebn, eoff, eino = existing
                edi = self._iget(eino)
                if edi.type == L.T_DIR and sdi.type != L.T_DIR:
                    raise FsError(Errno.EISDIR, newname)
                if edi.type != L.T_DIR and sdi.type == L.T_DIR:
                    raise FsError(Errno.ENOTDIR, newname)
                if edi.type == L.T_DIR and any(
                        e_ino != 0
                        for _, _, e_ino, _ in self._dir_entries(eino, edi)):
                    raise FsError(Errno.ENOTEMPTY, newname)
                # atomic replace: rewrite the target's slot to the moved
                # inode, clear the source slot, drop the displaced link —
                # all staged into this op's one journal transaction
                self._dir_unset(parent, bn, off)
                self._dir_set(newparent, ebn, eoff, ino, newname)
                if edi.type == L.T_DIR:
                    # displaced empty dir: its synthetic self-link pair
                    # dies with it, and newparent loses the ".." back-link
                    edi.nlink = 0
                    self._itrunc(eino, edi)
                    edi.type = L.T_FREE
                    self._iupdate(eino, edi)
                    ndi = self._iget(newparent)
                    ndi.nlink -= 1
                    self._iupdate(newparent, ndi)
                else:
                    edi.nlink -= 1
                    if edi.nlink <= 0:
                        self._itrunc(eino, edi)
                        edi.type = L.T_FREE
                    self._iupdate(eino, edi)
            else:
                self._dir_unset(parent, bn, off)
                self._dirlink(newparent, newname, ino)
            if sdi.type == L.T_DIR and parent != newparent:
                # a moved directory re-homes its ".." back-link
                pdi = self._iget(parent)
                pdi.nlink -= 1
                self._iupdate(parent, pdi)
                ndi = self._iget(newparent)
                ndi.nlink += 1
                self._iupdate(newparent, ndi)
            self._end_op(True)

    # --- file data ------------------------------------------------------------------------------------
    def read(self, ino: int, off: int, size: int) -> bytes:
        with self._oplock:
            di = self._iget(ino)
            if di.type == L.T_DIR:
                raise FsError(Errno.EISDIR, str(ino))
            if off >= di.size:
                return b""
            size = min(size, di.size - off)
            out = bytearray()
            while size > 0:
                bn, boff = divmod(off, L.BSIZE)
                n = min(L.BSIZE - boff, size)
                b = self._bmap(ino, di, bn, alloc=False)
                if b == 0:
                    out += bytes(n)  # hole
                else:
                    with self._bread(b) as bh:
                        out += bh.data()[boff: boff + n]
                off += n
                size -= n
            self._end_op(False)
            return bytes(out)

    def write(self, ino: int, off: int, data: bytes) -> int:
        with self._oplock:
            di = self._iget(ino)
            if di.type == L.T_DIR:
                raise FsError(Errno.EISDIR, str(ino))
            if (off + len(data) + L.BSIZE - 1) // L.BSIZE > L.MAXFILE_BLOCKS:
                raise FsError(Errno.EFBIG, str(ino))
            pos, n = off, len(data)
            written = 0
            blocks_in_subop = MAXOP_BLOCKS  # force reservation on first block
            meta = self._chain_write_overhead  # bitmap/inode/ind (+dedup)
            while written < n:
                if blocks_in_subop + meta >= MAXOP_BLOCKS:
                    self._begin_op()
                    blocks_in_subop = 0
                bn, boff = divmod(pos, L.BSIZE)
                chunk = min(L.BSIZE - boff, n - written)
                b = self._write_block_target(ino, di, bn)
                if boff == 0 and chunk == L.BSIZE:
                    self._log(b, bytes(data[written: written + chunk]))
                else:
                    with self._bread(b) as bh:
                        buf = bh.data()
                        buf[boff: boff + chunk] = data[written: written + chunk]
                        self._log(b, bytes(buf))
                blocks_in_subop += 1
                pos += chunk
                written += chunk
                # keep size durable per sub-op so a crash between sub-ops
                # leaves a consistent (shorter) file
                if pos > di.size:
                    di.size = pos
                    self._iupdate(ino, di)
            store = self._blockstore
            if store is not None and store.batch_depth == 0:
                # scalar (unbatched) write: dedup pass in THIS transaction
                store.flush_pending()
            self._end_op(True)
            return written

    def truncate(self, ino: int, size: int) -> None:
        with self._oplock:
            self._begin_op()
            di = self._iget(ino)
            if size == 0:
                self._itrunc(ino, di)
            elif size < di.size:
                di.size = size  # lazy: keep blocks (xv6-style simplicity)
                self._iupdate(ino, di)
            else:
                di.size = size
                self._iupdate(ino, di)
            self._end_op(True)

    def fsync(self, ino: int) -> None:
        with self._oplock:
            self.journal.commit()
            self._end_op(False)

    def flush(self) -> None:
        with self._oplock:
            self.journal.commit()
            self.ks.flush(self.sb_cap)

    def statfs(self) -> Dict[str, int]:
        with self._oplock:
            free = 0
            for bm in range(self.geo.bmapstart, self.geo.datastart):
                with self._bread(bm) as bh:
                    raw = bytes(bh.data())
                free += sum(8 - bin(byte).count("1") for byte in raw)
            total_data = self.geo.size - self.geo.datastart
            self._end_op(False)
            out = {"block_size": L.BSIZE, "total_blocks": self.geo.size,
                   "data_blocks": total_data, "free_blocks_est": free,
                   "journal_commits": self.journal.commits}
            if self._blockstore is not None:
                out.update(self._blockstore.statfs_extras())
            return out
