"""The xv6 file system on the Bento file-operations API.

Faithful to the paper's evaluation vehicle: journaling (data=journal, like
the paper's ext4 mount), 12 direct + indirect + double-indirect addressing
(their 4 GB-file extension), locks around inode/block allocation (their
race fix), fixed-size directory entries.

One implementation, policy-parameterized, mounted three ways by the
benchmark matrix (see repro.fs.mounts):
  * bento  — group commit + batched (`writepages`) install,
  * vfs    — per-operation commit + synchronous install ("the VFS baseline
             was just written for this evaluation" — paper §6),
  * fuse   — same code behind a subprocess serialization bridge.

Domain-lock protocol (killing the big fs lock)
----------------------------------------------
The paper ports xv6 by "adding locks" — one big fs lock. This module
shards it into LOCK DOMAINS, the way multi-queue block drivers shard a
single request lock by CPU:

  * the namespace is striped by inode number (``LockDomainTable``:
    N_STRIPES per-stripe locks), and
  * three special domains name the state every mutator shares: ``ALLOC``
    (block/inode allocator + journal staging), ``BLOCKSTORE`` (the dedup
    index), ``PROV`` (a stacked provenance log).

``group_footprint(entries)`` maps one dispatch group to the frozenset of
domains it can touch — computed from the submission entries alone, the
same shape inspection ``estimate_chain_blocks`` uses — or ``None`` when
the entries cannot prove a bound (rename/unlink rewrite foreign stripes,
PrevResult-fed arguments resolve at run time, statfs scans the world).
A parallel drainer (core.interface.execute_multi_batch with a worker
pool) runs each group inside ``domain_scope(footprint)``: global-SHARED
plus the footprint's stripe/special locks for a provable footprint,
global-EXCLUSIVE for ``None``. Scalar callers and every pre-existing
code path still ``with self._oplock`` — outside a scope that takes
global-EXCLUSIVE (the old big-lock semantics, reentrant); inside a scope
it is a no-op because the scope already holds everything the footprint
needs.

Soundness hangs on one invariant: EVERY mutating footprint includes
``ALLOC``, so at most one dispatch group stages journal blocks at any
moment — ``Journal`` commit stays the only global serialization point,
member-abort rollback can never clobber a concurrent group's staging,
and inode-table read-modify-writes are serialized without a lock of
their own. Read-only groups on disjoint stripes run fully concurrently.
"""

from __future__ import annotations

import contextlib
import dataclasses
import struct
import threading
from typing import Dict, FrozenSet, List, Optional, Tuple

from repro.core.capability import SuperBlockCap
from repro.core.interface import (Attr, BentoFilesystem, CompletionEntry,
                                  Errno, FileKind, FsError, PrevResult,
                                  ROOT_INO, SubmissionEntry)
from repro.fs import layout as L
from repro.fs.blockstore import BlockStore, DEDUP_TABLE_NAME
from repro.fs.journal import Journal, JournalFull


MAXOP_BLOCKS = 16  # journal blocks one (sub-)operation may touch


@dataclasses.dataclass(frozen=True)
class Xv6Options:
    group_commit: bool = True  # False: commit at end of every operation
    batched_install: bool = True  # writepages-style journal install
    commit_threshold: float = 0.75  # commit when journal this full
    dedup: bool = False  # content-addressed data plane (repro.fs.blockstore)


def mkfs(services, ninodes: int = 4096, nlog: int = 64) -> None:
    """Format the device: superblock, journal, inode table, bitmap, root."""
    sb_cap = services.superblock()
    n = sb_cap.n_blocks
    geo = L.geometry(n, ninodes=ninodes, nlog=nlog)
    with services.sb_getblk_zero(sb_cap, 0) as bh:
        bh.data()[:] = geo.pack()
        services.bwrite_sync(sb_cap, bh)
    # zero journal + inode table + bitmap
    for b in range(geo.logstart, geo.datastart):
        with services.sb_getblk_zero(sb_cap, b) as bh:
            services.bwrite_sync(sb_cap, bh)
    # mark metadata blocks used in the bitmap
    used = geo.datastart
    for b in range(used):
        _bitmap_set(services, sb_cap, geo, b, True)
    # root directory inode
    root = L.DiskInode(type=L.T_DIR, nlink=2, size=0)
    _write_inode_raw(services, sb_cap, geo, ROOT_INO, root)


def _bitmap_set(services, sb_cap, geo: L.SuperBlock, blockno: int, used: bool):
    bmblock = geo.bmapstart + blockno // (L.BSIZE * 8)
    bit = blockno % (L.BSIZE * 8)
    with services.sb_bread(sb_cap, bmblock) as bh:
        buf = bh.data()
        if used:
            buf[bit // 8] |= 1 << (bit % 8)
        else:
            buf[bit // 8] &= ~(1 << (bit % 8))
        services.bwrite_sync(sb_cap, bh)


def _write_inode_raw(services, sb_cap, geo, ino: int, di: L.DiskInode) -> None:
    blk = geo.inodestart + ino // L.IPB
    off = (ino % L.IPB) * L.INODE_SIZE
    with services.sb_bread(sb_cap, blk) as bh:
        bh.data()[off: off + L.INODE_SIZE] = di.pack()
        services.bwrite_sync(sb_cap, bh)


class _SharedExclusiveLock:
    """Writer-preferring shared/exclusive lock. Exclusive mode is
    reentrant per owning thread (the scalar paths nest ``_oplock``
    acquisitions: chain scope -> member dispatch -> scalar op). Shared
    mode is taken exactly once per domain scope and never re-entered —
    while a footprint is installed the ``_oplock`` handle's acquire is a
    no-op."""

    __slots__ = ("_lk", "_cond", "_readers", "_writer", "_depth",
                 "_waiting", "_parked")

    def __init__(self):
        # a plain Lock (not the Condition's default RLock) and direct
        # acquire/release: the uncontended exclusive round trip is THE
        # scalar-path hot lock (it replaced a bare RLock), so every
        # Python frame here is paid by every fs op
        self._lk = threading.Lock()
        self._cond = threading.Condition(self._lk)
        self._readers = 0
        self._writer = None   # owning tid while exclusive
        self._depth = 0       # exclusive reentrancy depth
        self._waiting = 0     # parked writers (block NEW readers)
        self._parked = 0      # threads inside a cond.wait (gate notify)

    def acquire_shared(self) -> None:
        lk = self._lk
        lk.acquire()
        try:
            if self._writer == threading.get_ident():
                self._depth += 1  # exclusive is stronger: just nest
                return
            while self._writer is not None or self._waiting:
                self._parked += 1
                try:
                    self._cond.wait()
                finally:
                    self._parked -= 1
            self._readers += 1
        finally:
            lk.release()

    def release_shared(self) -> None:
        lk = self._lk
        lk.acquire()
        try:
            if self._writer == threading.get_ident():
                self._depth -= 1
                return
            self._readers -= 1
            if not self._readers and self._parked:
                self._cond.notify_all()
        finally:
            lk.release()

    def acquire_exclusive(self) -> None:
        tid = threading.get_ident()
        lk = self._lk
        lk.acquire()
        if self._writer is None and not self._readers:
            # uncontended fast path (no cond bookkeeping, no waiters to
            # defer to — writers never queue behind parked writers)
            self._writer = tid
            self._depth = 1
            lk.release()
            return
        try:
            if self._writer == tid:
                self._depth += 1
                return
            self._waiting += 1
            try:
                while self._writer is not None or self._readers:
                    self._parked += 1
                    try:
                        self._cond.wait()
                    finally:
                        self._parked -= 1
            finally:
                self._waiting -= 1
            self._writer = tid
            self._depth = 1
        finally:
            lk.release()

    def release_exclusive(self) -> None:
        lk = self._lk
        lk.acquire()
        self._depth -= 1
        if not self._depth:
            self._writer = None
            if self._parked:
                self._cond.notify_all()
        lk.release()


class LockDomainTable:
    """Sharded fs-lock domains — the multi-queue answer to the paper's
    one big lock. The namespace is striped by inode number; three special
    domains name the state every mutator shares:

      * ``ALLOC``      — block/inode allocator + journal staging. Every
                         mutating footprint includes it, so at most one
                         dispatch group stages journal blocks at a time
                         and ``Journal`` commit stays the only global
                         serialization point.
      * ``BLOCKSTORE`` — the dedup index + batch scope (dedup mounts).
      * ``PROV``       — a stacked provenance layer's log (repro.fs.prov).

    A dispatch group either presents a *footprint* (frozenset of domain
    keys: acquire global-SHARED plus those locks, in one fixed order) or
    ``None`` (acquire global-EXCLUSIVE — the old big-lock behaviour).
    Non-overlapping footprints run concurrently; anything the estimator
    cannot pin falls back to exclusive and serializes with everyone."""

    N_STRIPES = 16
    ALLOC = "alloc"
    BLOCKSTORE = "blockstore"
    PROV = "prov"
    _SPECIALS = (ALLOC, BLOCKSTORE, PROV)

    def __init__(self, n_stripes: int = N_STRIPES):
        self.n_stripes = n_stripes
        self.shared_excl = _SharedExclusiveLock()
        self._stripes = [threading.RLock() for _ in range(n_stripes)]
        self._special = {name: threading.RLock() for name in self._SPECIALS}

    def stripe(self, ino: int) -> int:
        """Domain key for one inode's namespace stripe."""
        return ino % self.n_stripes

    def _lock(self, key):
        return (self._special[key] if isinstance(key, str)
                else self._stripes[key])

    @staticmethod
    def _order(key):
        # one global acquisition order: special domains first (by name),
        # then stripes ascending — all scopes sort the same way, so two
        # overlapping footprints can never deadlock on each other
        return (0, key) if isinstance(key, str) else (1, key)

    @contextlib.contextmanager
    def scope(self, footprint, tls):
        """Bracket ONE dispatch unit. ``tls`` is the ``_oplock`` handle's
        thread-local state: installing the footprint there turns every
        ``_oplock`` acquire inside the unit into a no-op (this scope
        already holds all the locks the footprint names)."""
        if footprint is None:
            self.shared_excl.acquire_exclusive()
            try:
                yield
            finally:
                self.shared_excl.release_exclusive()
            return
        self.shared_excl.acquire_shared()
        held = []
        try:
            for key in sorted(footprint, key=self._order):
                lk = self._lock(key)
                lk.acquire()
                held.append(lk)
            prev = getattr(tls, "domains", None)
            tls.domains = footprint
            try:
                yield
            finally:
                tls.domains = prev
        finally:
            for lk in reversed(held):
                lk.release()
            self.shared_excl.release_shared()


class _DomainTls(threading.local):
    # class default makes the per-op check a plain attribute load —
    # getattr-with-default on a bare threading.local costs an extra
    # dict probe on EVERY acquire/release of the hot big-lock path
    domains = None


class _DomainLockHandle:
    """Drop-in for the old ``threading.RLock`` big fs lock. Outside a
    domain scope, ``acquire``/``release`` take the table's global
    EXCLUSIVE mode — one lock, the big-lock semantics (and reentrant,
    which the scalar paths and repro.fs.prov rely on). Inside a domain
    scope (a parallel-drain worker with a footprint installed) they are
    no-ops: the scope holds global-shared plus every stripe and special
    domain the unit's footprint names, so the unchanged fs code bodies
    run already-locked."""

    __slots__ = ("_table", "_tls", "_se")

    def __init__(self, table: LockDomainTable):
        self._table = table
        self._tls = _DomainTls()
        self._se = table.shared_excl

    @property
    def installed_domains(self):
        """The footprint installed for THIS thread (None outside scopes)."""
        return self._tls.domains

    def acquire(self) -> bool:
        if self._tls.domains is None:
            self._se.acquire_exclusive()
        return True

    def release(self) -> None:
        if self._tls.domains is None:
            self._se.release_exclusive()

    # __enter__/__exit__ inline the uncontended-exclusive fast path: the
    # `with self._oplock:` bracket replaced a bare C RLock on EVERY fs op,
    # so each avoided Python frame here is a measurable share of scalar
    # throughput (the slow paths defer to _SharedExclusiveLock unchanged)

    def __enter__(self):
        if self._tls.domains is None:
            se = self._se
            lk = se._lk
            lk.acquire()
            if se._writer is None and not se._readers:
                se._writer = threading.get_ident()
                se._depth = 1
                lk.release()
            else:
                lk.release()
                se.acquire_exclusive()
        return self

    def __exit__(self, *exc) -> None:
        if self._tls.domains is None:
            se = self._se
            lk = se._lk
            lk.acquire()
            se._depth -= 1
            if not se._depth:
                se._writer = None
                if se._parked:
                    se._cond.notify_all()
            lk.release()


class Xv6FileSystem(BentoFilesystem):
    NAME = "xv6"
    VERSION = 1

    def __init__(self, options: Xv6Options = Xv6Options()):
        self.opts = options
        self.ks = None
        self.sb_cap: Optional[SuperBlockCap] = None
        self.geo: Optional[L.SuperBlock] = None
        self.journal: Optional[Journal] = None
        # big fs lock (paper: added locks) — sharded into lock domains;
        # plain acquire() is the global-exclusive mode (see module doc)
        self._domains = LockDomainTable()
        self._oplock = _DomainLockHandle(self._domains)
        self._alloc_lock = threading.RLock()
        self._stats_lock = threading.Lock()  # read units race on counters
        self._icache: Dict[int, L.DiskInode] = {}
        self._free_hint = 0
        self._free_inode_hint = 2
        self.stats = {"ops": 0, "commits_forced": 0}
        self._blockstore: Optional[BlockStore] = None
        self._current_submitter = None  # stamped per run by submit_batch
        # dedup widens the per-write metadata footprint (CoW copy block +
        # index-table blocks) — reservations must cover it
        self._chain_write_overhead = (self._CHAIN_WRITE_OVERHEAD
                                      + (3 if options.dedup else 0))

    # --- lifecycle -----------------------------------------------------------------
    def init(self, sb: SuperBlockCap, services) -> None:
        self.ks = services
        self.sb_cap = sb
        with services.sb_bread(sb, 0) as bh:
            self.geo = L.SuperBlock.unpack(bytes(bh.data()))
        if self.geo.magic != L.FSMAGIC:
            raise FsError(Errno.EINVAL, "bad magic: not an xv6 filesystem")
        self.journal = Journal(services, sb, self.geo,
                               batched_install=self.opts.batched_install)
        # after any journal rollback (op-scope overflow or chain-member
        # abort) the in-memory caches may reflect the rolled-back staging
        self.journal.rollback_listener = self._after_journal_rollback
        self.journal.recover()
        if self.opts.dedup:
            self._blockstore = BlockStore(self)
            self._blockstore.attach()

    def destroy(self) -> None:
        if self.journal:
            self.journal.commit()
        if self.ks and self.sb_cap:
            self.ks.flush(self.sb_cap)

    # --- §4.8 state transfer ------------------------------------------------------------
    def extract_state(self) -> Dict:
        self._dedup_drain()  # settle the index before quiescing
        self.flush()  # quiesced by the runtime; drain to a clean point
        state = {
            "icache": {ino: dataclasses.asdict(di)
                       for ino, di in self._icache.items()},
            "free_hint": self._free_hint,
            "free_inode_hint": self._free_inode_hint,
            "journal": self.journal.extract_state(),
            "stats": dict(self.stats),
        }
        if self._blockstore is not None:
            state["dedup"] = self._blockstore.extract_state()
        return state

    def restore_state(self, state: Dict, from_version: int) -> None:
        self._icache = {int(k): L.DiskInode(**v)
                        for k, v in state.get("icache", {}).items()}
        self._free_hint = state.get("free_hint", 0)
        self._free_inode_hint = state.get("free_inode_hint", 2)
        self.journal.restore_state(state.get("journal", {}))
        self.stats.update(state.get("stats", {}))
        if self._blockstore is not None and "dedup" in state:
            self._blockstore.restore_state(state["dedup"])

    def state_schema(self) -> Tuple[str, ...]:
        base = ("icache", "free_hint", "free_inode_hint", "journal", "stats")
        return base + ("dedup",) if self.opts.dedup else base

    def optional_state_keys(self) -> Tuple[str, ...]:
        # a dedup mount can absorb state from a plain predecessor (the
        # index reloads from the device) and vice versa
        return ("dedup",)

    # --- journal-aware block IO -----------------------------------------------------------
    def _bread(self, blockno: int):
        bh = self.ks.sb_bread(self.sb_cap, blockno)
        pend = self.journal.pending_get(blockno)
        if pend is not None and bytes(bh.data()) != pend:
            bh.data()[:] = pend
        return bh

    def _log(self, blockno: int, data: bytes) -> None:
        self.journal.log_write(blockno, data)

    def _begin_op(self) -> None:
        """Reserve journal space for one (sub-)operation — commits the
        running transaction first if it could not absorb MAXOP_BLOCKS more
        (xv6 begin_op), so operations are never torn across commits.

        Inside a chain scope this is a no-op: ``chain_begin`` already
        reserved the WHOLE chain's footprint, and a mid-chain commit here
        would tear the chain across two transactions. The check is
        per-thread (``in_chain_here``): another thread's open chain must
        not suppress THIS operation's reservation."""
        if self.journal.in_chain_here:
            return
        if len(self.journal._pending) + MAXOP_BLOCKS >= self.journal.capacity:
            self.stats["commits_forced"] += 1
            self.journal.commit()
        self.journal.begin_op_scope()  # overflow rolls back to this point

    def _end_op(self, mutated: bool) -> None:
        with self._stats_lock:  # concurrent read units share the counter
            self.stats["ops"] += 1
        if not mutated:
            return
        store = self._blockstore
        if store is not None and store.compaction_due():
            # churn (unlinks/truncates) left whole index blocks dead:
            # punch them inside THIS op's transaction, before any commit
            # below — the same crash-atomicity as the mutation itself
            store._maybe_compact()
        if self.journal.in_chain_here:
            # per-op commit policy (the VFS baseline) defers to end_chain —
            # one transaction per chain; the group-commit threshold
            # heuristic simply waits until the chain closes.
            if not self.opts.group_commit:
                self.journal.commit()
            return
        if not self.opts.group_commit:
            self.journal.commit()
        elif len(self.journal._pending) >= int(
                self.journal.capacity * self.opts.commit_threshold):
            self.stats["commits_forced"] += 1
            self.journal.commit()

    # --- chain-scoped reservation (SQE_LINK chains as one journal txn) --------------
    #
    # ``execute_batch`` calls chain_begin/chain_end around every chain
    # group. The estimate is an upper bound computed from the submission
    # entries (data blocks + per-op metadata overhead); absorption makes
    # the real footprint smaller. The fs lock is held for the WHOLE chain
    # scope so no concurrent op can slip a commit between two members (the
    # members re-enter it, it is reentrant).

    _CHAIN_WRITE_OVERHEAD = 4  # inode + bitmap + up to 2 indirect blocks
    _CHAIN_OP_BLOCKS = {
        # rename may also truncate a displaced target (dirent swap + two
        # parent inodes + displaced inode + bitmap blocks of freed data)
        "create": 6, "mkdir": 8, "unlink": 6, "rmdir": 8, "rename": 12,
        "getattr": 0, "lookup": 0, "read": 0, "readdir": 0, "statfs": 0,
        "fsync": 0, "flush": 0,
    }

    def _chain_entry_blocks(self, e: SubmissionEntry) -> int:
        if e.op == "write":
            kw = e.kwargs or {}
            off = e.args[1] if len(e.args) > 1 else kw.get("off")
            data = e.args[2] if len(e.args) > 2 else kw.get("data")
            if not isinstance(data, (bytes, bytearray)):
                return MAXOP_BLOCKS  # PrevResult/malformed payload: worst case
            start = off % L.BSIZE if isinstance(off, int) else 0
            nblocks = (start + len(data) + L.BSIZE - 1) // L.BSIZE
            return nblocks + self._chain_write_overhead
        return self._CHAIN_OP_BLOCKS.get(e.op, MAXOP_BLOCKS)

    def estimate_chain_blocks(self, entries) -> int:
        """Journal-blocks upper bound for a chain, from its entries."""
        return sum(self._chain_entry_blocks(e) for e in entries)

    def estimate_append_blocks(self, nbytes: int) -> int:
        """Journal-blocks upper bound for appending ``nbytes`` to an
        existing file — the log-block allocation hook a stacked layer
        (repro.fs.prov) uses to size the provenance records it will add to
        a reservation. Data blocks (+1 for a straddled boundary) plus this
        fs's per-write metadata overhead; subclasses with costlier write
        paths inherit their own ``_CHAIN_WRITE_OVERHEAD``."""
        return (nbytes + L.BSIZE - 1) // L.BSIZE + 1 + self._chain_write_overhead

    def chain_begin(self, entries, extra_blocks: int = 0):
        """Reserve ONE journal transaction for a whole chain group.
        ``extra_blocks`` is the stacked-layer hook: a wrapper that will
        stage additional blocks inside the same transaction (provenance
        records) adds its footprint to the reservation, so the atomicity
        estimate covers BOTH layers or the chain is refused up front."""
        est = self.estimate_chain_blocks(entries) + extra_blocks
        self._oplock.acquire()
        try:
            self.journal.begin_chain(est)
        except JournalFull as e:
            self._oplock.release()
            return e.errno  # ENOSPC before anything was staged
        except BaseException:
            # e.g. a device error inside the pre-chain commit: the scope
            # never opened, so execute_batch will not call chain_end —
            # release here or the fs lock leaks
            self._oplock.release()
            raise
        if self._blockstore is not None:
            self._blockstore.batch_begin()
        return None

    def chain_end(self) -> None:
        try:
            store = self._blockstore
            if store is not None and store.batch_dec() == 0:
                # dedup pass INSIDE the chain transaction: sharing rewrites
                # commit atomically with the writes that produced them
                store.flush_pending()
            self.journal.end_chain()  # runs any deferred (in-chain) commit
        finally:
            self._oplock.release()

    # --- lock-domain footprints (parallel multi-submitter drain) --------------------
    #
    # The parallel drainer keys its scheduling off these: two dispatch
    # groups whose footprints are disjoint run concurrently on worker
    # threads, overlapping (or unprovable) ones keep their submission
    # order. Computed from the entries alone — the same shape inspection
    # estimate_chain_blocks uses — never from live fs state.

    def _entry_domains(self, e: SubmissionEntry) -> Optional[set]:
        """Domain keys one submission entry can touch; None = not
        provable from the entry (global exclusive)."""
        if e.kwargs:
            return None  # kwargs entries keep scalar dispatch: not proven
        args = e.args
        if any(isinstance(a, PrevResult) for a in args):
            return None  # the target inode resolves at run time
        op = e.op
        if op in ("read", "getattr", "readdir", "lookup"):
            # read-only on one inode (lookup: the parent directory)
            if not args or not isinstance(args[0], int):
                return None
            doms = {self._domains.stripe(args[0])}
        elif op in ("write", "truncate", "fsync", "create", "mkdir"):
            # mutators: the op's stripe (create/mkdir: the parent's) plus
            # ALLOC — the invariant that keeps journal staging serial
            if not args or not isinstance(args[0], int):
                return None
            doms = {self._domains.stripe(args[0]), LockDomainTable.ALLOC}
        elif op == "flush":
            doms = {LockDomainTable.ALLOC}
        else:
            # unlink/rmdir/rename free inodes and rewrite foreign
            # stripes, statfs scans the world, unknown ops prove nothing
            return None
        if self._blockstore is not None:
            # every dispatch on a dedup mount opens a blockstore batch
            # scope (shared depth counter, pending set, verify stats)
            doms.add(LockDomainTable.BLOCKSTORE)
        return doms

    def group_footprint(self, entries) -> Optional[FrozenSet]:
        """Footprint of ONE dispatch group (union over its entries), or
        None when any entry needs the global exclusive lock."""
        out: set = set()
        for e in entries:
            d = self._entry_domains(e)
            if d is None:
                return None
            out |= d
        return frozenset(out)

    def domain_scope(self, footprint):
        """Context manager a parallel drainer wraps around one dispatch
        group: acquires the footprint's locks (or global exclusive for
        None) and installs the footprint thread-locally so the unchanged
        ``with self._oplock`` bodies inside run as no-ops."""
        return self._domains.scope(footprint, self._oplock._tls)

    # --- inodes ---------------------------------------------------------------------------
    def _iget(self, ino: int) -> L.DiskInode:
        if not (0 < ino < self.geo.ninodes):
            raise FsError(Errno.ESTALE, f"bad ino {ino}")
        di = self._icache.get(ino)
        if di is None:
            blk = self.geo.inodestart + ino // L.IPB
            off = (ino % L.IPB) * L.INODE_SIZE
            with self._bread(blk) as bh:
                di = L.DiskInode.unpack(bytes(bh.data()), off)
            self._icache[ino] = di
        return di

    def _iupdate(self, ino: int, di: L.DiskInode) -> None:
        self._icache[ino] = di
        blk = self.geo.inodestart + ino // L.IPB
        off = (ino % L.IPB) * L.INODE_SIZE
        with self._bread(blk) as bh:
            bh.data()[off: off + L.INODE_SIZE] = di.pack()
            self._log(blk, bytes(bh.data()))

    def _ialloc(self, kind: int) -> int:
        with self._alloc_lock:  # paper: lock around inode allocation
            start = self._free_inode_hint
            for delta in range(self.geo.ninodes - 2):
                ino = 2 + (start - 2 + delta) % (self.geo.ninodes - 2)
                di = self._iget(ino)
                if di.type == L.T_FREE:
                    ndi = L.DiskInode(type=kind, nlink=1)
                    self._iupdate(ino, ndi)
                    self._free_inode_hint = ino + 1
                    return ino
            raise FsError(Errno.ENOSPC, "out of inodes")

    # --- block allocator ----------------------------------------------------------------------
    def _balloc(self) -> int:
        with self._alloc_lock:  # paper: lock around block allocation
            total = self.geo.size
            bits_per = L.BSIZE * 8
            start = max(self._free_hint, self.geo.datastart)
            for delta in range(total - self.geo.datastart):
                b = self.geo.datastart + (start - self.geo.datastart + delta) % (
                    total - self.geo.datastart)
                bmblock = self.geo.bmapstart + b // bits_per
                bit = b % bits_per
                with self._bread(bmblock) as bh:
                    buf = bh.data()
                    if not (buf[bit // 8] >> (bit % 8)) & 1:
                        buf[bit // 8] |= 1 << (bit % 8)
                        self._log(bmblock, bytes(buf))
                        self._free_hint = b + 1
                        # zero the block (journaled)
                        self._log(b, bytes(L.BSIZE))
                        return b
            raise FsError(Errno.ENOSPC, "device full")

    def _bfree_raw(self, b: int) -> None:
        """Clear the bitmap bit — the physical free, no refcounting."""
        with self._alloc_lock:
            bits_per = L.BSIZE * 8
            bmblock = self.geo.bmapstart + b // bits_per
            bit = b % bits_per
            with self._bread(bmblock) as bh:
                buf = bh.data()
                buf[bit // 8] &= ~(1 << (bit % 8))
                self._log(bmblock, bytes(buf))
            self._free_hint = min(self._free_hint, b)

    def _bfree(self, b: int) -> None:
        """Drop a reference to ``b``. On dedup mounts a shared block just
        loses one index reference (staged in this op's transaction); the
        bitmap bit clears only with the LAST reference."""
        if self._blockstore is not None and not self._blockstore.release(b):
            return
        self._bfree_raw(b)

    # --- bmap: logical file block -> device block ----------------------------------------------
    def _bmap(self, ino: int, di: L.DiskInode, bn: int, alloc: bool) -> int:
        NI = L.NINDIRECT
        if bn < L.NDIRECT:
            if di.addrs[bn] == 0:
                if not alloc:
                    return 0
                di.addrs[bn] = self._balloc()
                self._iupdate(ino, di)
            return di.addrs[bn]
        bn -= L.NDIRECT
        if bn < NI:
            return self._indirect(ino, di, L.NDIRECT, bn, alloc)
        bn -= NI
        if bn < NI * NI:
            # double indirect
            if di.addrs[L.NDIRECT + 1] == 0:
                if not alloc:
                    return 0
                di.addrs[L.NDIRECT + 1] = self._balloc()
                self._iupdate(ino, di)
            l1 = di.addrs[L.NDIRECT + 1]
            l2 = self._ind_entry(l1, bn // NI, alloc)
            if l2 == 0:
                return 0
            return self._ind_entry(l2, bn % NI, alloc)
        raise FsError(Errno.EFBIG, "file too large")

    def _indirect(self, ino: int, di: L.DiskInode, slot: int, idx: int,
                  alloc: bool) -> int:
        if di.addrs[slot] == 0:
            if not alloc:
                return 0
            di.addrs[slot] = self._balloc()
            self._iupdate(ino, di)
        return self._ind_entry(di.addrs[slot], idx, alloc)

    def _ind_entry(self, indblock: int, idx: int, alloc: bool) -> int:
        import struct
        with self._bread(indblock) as bh:
            buf = bh.data()
            (val,) = struct.unpack_from("<I", buf, idx * 4)
            if val == 0 and alloc:
                val = self._balloc()
                # NB: _balloc may journal this ind block via pending overlay;
                # re-read through the overlay before mutating.
                pend = self.journal.pending_get(indblock)
                if pend is not None:
                    buf[:] = pend
                struct.pack_into("<I", buf, idx * 4, val)
                self._log(indblock, bytes(buf))
        return val

    def _bmap_install(self, ino: int, di: L.DiskInode, bn: int, blk: int) -> None:
        """Point logical block bn at device block blk (journaled) — extent
        preallocation (ext4like) and the blockstore's CoW remapping both
        rewrite existing mappings through this."""
        import struct
        NI = L.NINDIRECT
        if bn < L.NDIRECT:
            di.addrs[bn] = blk
            self._iupdate(ino, di)
            return
        bnn = bn - L.NDIRECT
        if bnn < NI:
            if di.addrs[L.NDIRECT] == 0:
                di.addrs[L.NDIRECT] = self._balloc()
                self._iupdate(ino, di)
            self._ind_set(di.addrs[L.NDIRECT], bnn, blk)
            return
        bnn -= NI
        if di.addrs[L.NDIRECT + 1] == 0:
            di.addrs[L.NDIRECT + 1] = self._balloc()
            self._iupdate(ino, di)
        l2 = self._ind_entry(di.addrs[L.NDIRECT + 1], bnn // NI, alloc=True)
        self._ind_set(l2, bnn % NI, blk)

    def _ind_set(self, indblock: int, idx: int, val: int) -> None:
        import struct
        with self._bread(indblock) as bh:
            buf = bh.data()
            struct.pack_into("<I", buf, idx * 4, val)
            self._log(indblock, bytes(buf))

    def _bmap_clear(self, ino: int, di: L.DiskInode, bn: int) -> None:
        """Punch a hole: drop logical block bn's device mapping
        (journaled). The caller owns freeing the device block — the
        blockstore's index compaction uses this to return fully-dead
        table blocks to the allocator."""
        self._bmap_install(ino, di, bn, 0)

    def _write_block_target(self, ino: int, di: L.DiskInode, bn: int) -> int:
        """Resolve (and allocate) the device block a data write must land
        on. On dedup mounts the blockstore interposes: a shared block is
        CoW-broken to a private copy first, the stored hash is invalidated
        in this same transaction, and the block queues for the batch-end
        dedup pass."""
        b = self._bmap(ino, di, bn, alloc=True)
        if self._blockstore is not None and di.type == L.T_FILE:
            b = self._blockstore.note_write(ino, di, bn, b)
        return b

    # --- batched boundary: vectorized fast paths ------------------------------------------------
    #
    # One submission batch = one fs-lock acquisition, one journal-overlay
    # snapshot, one bulk buffer-cache pass (sb_bread_many). submit_batch
    # coalesces same-op runs into the *_many methods below; results lists
    # carry FsError values in failing slots (per-entry errno isolation).

    _MANY_OPS = {"read": "read_many", "write": "write_many",
                 "getattr": "getattr_many", "lookup": "lookup_many",
                 "create": "create_many", "mkdir": "mkdir_many",
                 "unlink": "unlink_many"}

    # read-only vectorized ops coalesce across submitter stamps: nothing
    # on a read path consumes the attribution (the blockstore and the
    # provenance layer stamp mutations only), so a multi-submitter drain
    # can fuse every submitter's reads into ONE cache pass
    _RO_MANY_OPS = frozenset({"read", "getattr", "lookup"})

    # chain members that can stage journal blocks (and so need the member
    # undo bracket); read-only members and commit-only members (fsync/flush
    # defer their commit to end_chain) skip the two journal-lock round
    # trips — measurable on the chained create→write hot path
    _CHAIN_MUTATING_OPS = frozenset({
        "create", "mkdir", "unlink", "rmdir", "rename", "write", "truncate"})

    def submit_batch(self, entries) -> List[CompletionEntry]:
        if not isinstance(entries, list):
            entries = list(entries)
        store = self._blockstore
        if store is not None:
            store.batch_begin()
        try:
            return self._submit_batch_scoped(entries)
        finally:
            if store is not None:
                self._dedup_batch_end()

    def _submit_batch_scoped(self, entries) -> List[CompletionEntry]:
        if self.journal is not None and self.journal.in_chain_here \
                and any(e.op in self._CHAIN_MUTATING_OPS for e in entries):
            # chain-member dispatch on the chain-owning thread
            # (execute_batch sends members one at a time; a CONCURRENT
            # submitter sees in_chain_here False and takes the plain path,
            # blocking on the fs lock the chain holds): bracket the
            # member's journal staging so a reservation estimate miss
            # (PrevResult-fed payload larger than guessed → JournalFull →
            # ENOSPC) rolls back cleanly — an ENOSPC member stages
            # NOTHING, so a later group commit can never make a torn
            # member durable.
            self.journal.chain_member_begin()
            comps = self._submit_batch_runs(entries)
            if any(c.errno == Errno.ENOSPC for c in comps):
                self.journal.chain_member_abort()  # fires rollback_listener
            else:
                self.journal.chain_member_end()
            return comps
        return self._submit_batch_runs(entries)

    def _after_journal_rollback(self) -> None:
        """Journal rollback listener: in-memory caches may hold the
        rolled-back staging (e.g. a torn write's inflated inode size) —
        drop them; they rebuild through the restored journal overlay.
        Subclasses layer their derived indexes in ``_invalidate_caches_
        after_abort``."""
        self._icache.clear()
        if self._blockstore is not None and self._blockstore._table_blocks:
            # refcounts/hashes staged by the rolled-back transaction are
            # gone from the journal overlay: rebuild from what survived
            self._blockstore.reload()
        self._invalidate_caches_after_abort()

    def _invalidate_caches_after_abort(self) -> None:
        """Subclass hook: drop derived in-memory state after a journal
        rollback (see ext4like's directory index)."""

    def _dedup_batch_end(self) -> None:
        """Close one batch scope; at depth zero, run the deferred dedup
        pass — in the open chain transaction if one is active, else in a
        trailing reservation of its own. Also fires on pure-churn batches
        (no pending writes, but deletions left the index over the
        tombstone threshold) so compaction keeps up with unlink storms."""
        store = self._blockstore
        if store.batch_dec() != 0 or not (store.pending
                                          or store.compaction_due()):
            return
        with self._oplock:
            if self.journal.in_chain_here:
                store.flush_pending()
            else:
                self._begin_op()
                store.flush_pending()
                self._end_op(True)

    def _dedup_drain(self) -> None:
        """Settle any still-pending dedup work (quiesce/extract path)."""
        store = self._blockstore
        if store is None or not store.pending:
            return
        with self._oplock:
            if not self.journal.in_chain_here:
                self._begin_op()
                store.flush_pending()
                self._end_op(True)

    def _submit_batch_runs(self, entries) -> List[CompletionEntry]:
        comps: List[CompletionEntry] = []
        comps_append = comps.append
        many_ops_get = self._MANY_OPS.get
        i, n = 0, len(entries)
        try:
            while i < n:
                # keyword-style entries keep scalar dispatch (the *_many
                # paths are positional); coalesce only positional same-op
                # runs — and, for mutating ops, only entries stamped with
                # the same submitter, so per-submitter attribution stays
                # exact (read-only runs fuse across stamps: _RO_MANY_OPS)
                head = entries[i]
                sub = getattr(head, "submitter", None)
                self._current_submitter = sub
                op = head.op
                many = many_ops_get(op) if not head.kwargs else None
                if many is None:
                    comps_append(self._dispatch_one(head))
                    i += 1
                    continue
                any_sub = op in self._RO_MANY_OPS
                j = i + 1
                while j < n:
                    e = entries[j]
                    if (e.op != op or e.kwargs
                            or not (any_sub
                                    or getattr(e, "submitter", None) == sub)):
                        break
                    j += 1
                run = entries[i:j]
                results = getattr(self, many)([e.args for e in run])
                for e, r in zip(run, results):
                    if isinstance(r, FsError):
                        comps_append(CompletionEntry(e.user_data, errno=r.errno))
                    else:
                        comps_append(CompletionEntry(e.user_data, result=r))
                i = j
        finally:
            self._current_submitter = None
        return comps

    def _bmap_ro(self, di: L.DiskInode, bn: int, ind_cache: Dict[int, bytes]) -> int:
        """Read-only bmap sharing one indirect-block cache across a batch
        (the scalar _bmap takes a cache-lock round trip per indirect hop)."""
        NI = L.NINDIRECT
        if bn < L.NDIRECT:
            return di.addrs[bn]
        bn -= L.NDIRECT
        if bn < NI:
            l1 = di.addrs[L.NDIRECT]
            return self._ind_ro(l1, bn, ind_cache) if l1 else 0
        bn -= NI
        if bn < NI * NI:
            l1 = di.addrs[L.NDIRECT + 1]
            if not l1:
                return 0
            l2 = self._ind_ro(l1, bn // NI, ind_cache)
            return self._ind_ro(l2, bn % NI, ind_cache) if l2 else 0
        raise FsError(Errno.EFBIG, "file too large")

    _IND_FMT = struct.Struct("<%dI" % L.NINDIRECT)
    _IND_ONE = struct.Struct("<I")

    def _ind_raw(self, indblock: int, ind_cache: Dict[int, bytes]) -> bytes:
        raw = ind_cache.get(indblock)
        if raw is None:
            with self._bread(indblock) as bh:
                raw = bytes(bh.data())
            ind_cache[indblock] = raw
        return raw

    def _ind_ro(self, indblock: int, idx: int,
                ind_cache: Dict[int, bytes]) -> int:
        return self._IND_ONE.unpack_from(
            self._ind_raw(indblock, ind_cache), idx * 4)[0]

    def _ind_tuple(self, indblock: int,
                   ind_cache: Dict[int, bytes]) -> Tuple[int, ...]:
        """Decode a whole indirect block to a tuple in one struct call —
        pays off only when MANY entries get indexed (a vectorized batch
        reuses it thousands of times); a one-off lookup uses ``_ind_ro``'s
        single-record decode instead (~30x cheaper for one entry)."""
        return self._IND_FMT.unpack(self._ind_raw(indblock, ind_cache))

    def read_many(self, reqs) -> List:
        """Vectorized read: plan every request's block segments first, then
        fetch all distinct data blocks in ONE buffer-cache pass and slice.
        Returns bytes per request, FsError in failing slots."""
        out: List = []
        with self._oplock:
            pend = self.journal.pending_snapshot()
            ind_cache: Dict[int, Tuple[int, ...]] = {}
            plans: List = []
            needed = set()
            # hot loop: bind everything the per-request body touches once —
            # the planning pass runs tens of thousands of times per drain
            BSIZE, NDIRECT, T_DIR = L.BSIZE, L.NDIRECT, L.T_DIR
            L1_END = NDIRECT + L.NINDIRECT
            bmap_ro, iget = self._bmap_ro, self._iget
            ind_tuple = self._ind_tuple
            plans_append, needed_add = plans.append, needed.add
            inodes: Dict[int, L.DiskInode] = {}
            inodes_get = inodes.get
            # whole-L1 decode costs ~30 single-record decodes: eager only
            # when the batch is big enough to amortize it (a scalar read
            # routed through here as a run of one must not pay it)
            eager_l1 = len(reqs) >= 4
            for args in reqs:
                try:
                    ino, off, size = args
                    if not isinstance(off, int) or not isinstance(size, int):
                        raise TypeError("read args are (ino, int off, int size)")
                    ent = inodes_get(ino)
                    if ent is None:
                        di = iget(ino)
                        if di.type == T_DIR:
                            raise FsError(Errno.EISDIR, str(ino))
                        l1 = di.addrs[NDIRECT]
                        # resolve the whole L1 indirect block once per
                        # distinct inode, not once per request
                        inodes[ino] = ent = (
                            di, ind_tuple(l1, ind_cache)
                            if l1 and eager_l1 else None)
                    di, l1ents = ent
                    segs = []
                    dsize = di.size
                    if off < dsize and size > 0:
                        if size > dsize - off:
                            size = dsize - off
                        addrs = di.addrs
                        segs_append = segs.append
                        while size > 0:
                            bn, boff = divmod(off, BSIZE)
                            nn = BSIZE - boff
                            if nn > size:
                                nn = size
                            if bn < NDIRECT:
                                b = addrs[bn]
                            elif bn < L1_END and l1ents is not None:
                                b = l1ents[bn - NDIRECT]
                            else:
                                b = bmap_ro(di, bn, ind_cache)
                            segs_append((b, boff, nn))
                            if b and b not in pend:
                                needed_add(b)
                            off += nn
                            size -= nn
                    plans_append(segs)
                except FsError as e:
                    plans_append(e)
                except (TypeError, ValueError):
                    plans_append(FsError(Errno.EINVAL, "bad read args"))
            fetched: List[int] = []
            try:
                heads = self.ks.sb_bread_many(self.sb_cap, sorted(needed),
                                              fetched=fetched)
            except Exception as e:  # device error: fail the batch's reads
                # as per-entry EIO — errors never cross as exceptions
                io_err = FsError(Errno.EIO, f"batched bread failed: {e}")
                with self._stats_lock:
                    self.stats["ops"] += len(reqs)
                return [p if isinstance(p, FsError) else io_err
                        for p in plans]
            bad = ()
            try:
                bufs = {bh.blockno: bh.data() for bh in heads}
                # verified reads: blocks that came off the DEVICE this pass
                # (cache hits were verified when first fetched; journal-
                # pending overlays are newer than their stored hash) are
                # re-hashed in ONE batched launch against the index
                bad = (self._blockstore.verify_fetched(bufs, fetched)
                       if self._blockstore is not None else ())
                out_append, pend_get = out.append, pend.get
                for segs in plans:
                    if isinstance(segs, FsError):
                        out_append(segs)
                        continue
                    if bad and any(b in bad for b, _, _ in segs):
                        out_append(FsError(
                            Errno.EIO, "blockstore: checksum mismatch"))
                        continue
                    if len(segs) == 1:  # aligned single-block read: no
                        b, boff, nn = segs[0]  # chunk list round trip
                        if b == 0:
                            out_append(bytes(nn))
                        else:
                            src = pend_get(b) or bufs[b]
                            out_append(bytes(src[boff: boff + nn]))
                        continue
                    chunks = []
                    for b, boff, nn in segs:
                        if b == 0:
                            chunks.append(bytes(nn))  # hole
                        else:
                            src = pend_get(b) or bufs[b]
                            chunks.append(bytes(src[boff: boff + nn]))
                    out_append(b"".join(chunks))
            finally:
                self.ks.sb_brelse_many(self.sb_cap, heads)
            if bad:
                # a corrupt fetch must not linger as a trusted cache hit:
                # evict so every later read refetches and re-verifies (EIO
                # stays sticky until the device matches the index again)
                self.ks.sb_invalidate_blocks(self.sb_cap, sorted(bad))
            with self._stats_lock:
                self.stats["ops"] += len(reqs)
        return out

    def _scalar_many(self, op: str, reqs) -> List:
        """Scalar loop under ONE fs-lock acquisition with per-entry errno
        capture — the shared body of the non-read vectorized paths.
        Arg-shape errors complete as EINVAL (pre-call bind check);
        implementation exceptions propagate, like scalar dispatch."""
        fn = getattr(self, op)
        out: List = []
        with self._oplock:
            for args in reqs:
                if not isinstance(args, tuple) \
                        or not self._entry_fits(op, args, None):
                    out.append(FsError(Errno.EINVAL, f"bad {op} args"))
                    continue
                try:
                    out.append(fn(*args))
                except FsError as e:
                    out.append(e)
        return out

    def write_many(self, reqs) -> List:
        """Batched write: one fs-lock acquisition; writes land in the open
        group-commit transaction, so a following fsync/flush entry commits
        the whole batch with one journal transaction (and one checksum_batch
        launch). Returns bytes-written per request, FsError where failed.
        On dedup mounts the whole batch shares ONE batch-end dedup pass
        (one blockhash launch), like submit_batch dispatch."""
        store = self._blockstore
        if store is None:
            return self._scalar_many("write", reqs)
        store.batch_begin()
        try:
            return self._scalar_many("write", reqs)
        finally:
            self._dedup_batch_end()

    def getattr_many(self, reqs) -> List:
        return self._scalar_many("getattr", reqs)

    def lookup_many(self, reqs) -> List:
        return self._scalar_many("lookup", reqs)

    # --- batched metadata: vectorized create/unlink ---------------------------------
    #
    # The scalar create/unlink rescan the parent directory once per call
    # (O(dir) each, O(dir^2) for a bulk phase). The vectorized paths scan
    # each touched directory ONCE per batch into a slot map that is kept
    # current as the batch mutates it — same allocation and placement
    # decisions as the scalar ops (first-fit holes, append at tail), so
    # batched and scalar execution produce identical trees.

    def _dir_scan_state(self, dino: int, pdi: L.DiskInode) -> Dict:
        """One-scan directory state for a batch: ``names`` maps name ->
        (bn, off, ino) like ``_dirlookup`` hits; ``holes`` lists free slots
        in scan order (the scalar first-fit order). Subclasses with a live
        index return it directly (repro.fs.ext4like)."""
        import collections
        names: Dict[str, Tuple[int, int, int]] = {}
        holes = collections.deque()
        for bn, off, e_ino, name in self._dir_entries(dino, pdi):
            if e_ino == L.WHITEOUT_INO:
                continue  # delete marker: not a live name, not a free slot
            if e_ino != 0:
                names.setdefault(name, (bn, off, e_ino))
            else:
                holes.append((bn, off))
        return {"names": names, "holes": holes}

    def _create_many_common(self, reqs, kind: int) -> List:
        op = "mkdir" if kind == L.T_DIR else "create"
        out: List = []
        with self._oplock:
            states: Dict[int, Dict] = {}
            for args in reqs:
                if not isinstance(args, tuple) \
                        or not self._entry_fits(op, args, None):
                    out.append(FsError(Errno.EINVAL, f"bad {op} args"))
                    continue
                parent, name = args
                try:
                    if (not isinstance(name, str) or not name or "/" in name
                            or len(name.encode()) > L.NAME_MAX):
                        raise FsError(Errno.EINVAL, str(name))
                    self._check_reserved(name)
                    self._begin_op()
                    pdi = self._iget(parent)
                    if pdi.type != L.T_DIR:
                        raise FsError(Errno.ENOTDIR, str(parent))
                    st = states.get(parent)
                    if st is None:
                        st = states[parent] = self._dir_scan_state(parent, pdi)
                    if name in st["names"]:
                        raise FsError(Errno.EEXIST, name)
                    ino = self._ialloc(kind)
                    if kind == L.T_DIR:
                        pdi = self._iget(parent)
                        pdi.nlink += 1  # ".." link
                        self._iupdate(parent, pdi)
                        di = self._iget(ino)
                        di.nlink = 2
                        self._iupdate(ino, di)
                    # place the dirent: first-fit hole, else append (the
                    # scalar _dirlink decisions, without its rescan)
                    if st["holes"]:
                        bn, off = st["holes"].popleft()
                    else:
                        pdi = self._iget(parent)
                        bn, off = divmod(pdi.size, L.BSIZE)
                        pdi.size += L.DIRENT_SIZE
                        self._iupdate(parent, pdi)
                    b = self._bmap(parent, self._iget(parent), bn, alloc=True)
                    with self._bread(b) as bh:
                        bh.data()[off: off + L.DIRENT_SIZE] = \
                            L.pack_dirent(ino, name)
                        self._log(b, bytes(bh.data()))
                    st["names"][name] = (bn, off, ino)
                    self._end_op(True)
                    out.append(self._attr(ino, self._iget(ino)))
                except FsError as e:
                    out.append(e)
        return out

    def create_many(self, reqs) -> List:
        """Vectorized create: one fs-lock acquisition, one directory scan
        per touched parent (kept live across the batch), per-entry errno
        isolation. Journal behaviour matches scalar: per-entry begin/end
        reservations inside the open group-commit transaction, so a
        following fsync/flush commits the whole batch with ONE
        checksum_batch launch."""
        return self._create_many_common(reqs, L.T_FILE)

    def mkdir_many(self, reqs) -> List:
        return self._create_many_common(reqs, L.T_DIR)

    def unlink_many(self, reqs) -> List:
        """Vectorized unlink: one fs-lock acquisition and one scan per
        touched parent (the scalar path rescans per name)."""
        out: List = []
        with self._oplock:
            states: Dict[int, Dict] = {}
            for args in reqs:
                if not isinstance(args, tuple) \
                        or not self._entry_fits("unlink", args, None):
                    out.append(FsError(Errno.EINVAL, "bad unlink args"))
                    continue
                parent, name = args
                try:
                    self._check_reserved(name)
                    self._begin_op()
                    pdi = self._iget(parent)
                    st = states.get(parent)
                    if st is None:
                        st = states[parent] = self._dir_scan_state(parent, pdi)
                    hit = st["names"].get(name)
                    if hit is None:
                        raise FsError(Errno.ENOENT, str(name))
                    bn, off, ino = hit
                    di = self._iget(ino)
                    if di.type == L.T_DIR:
                        raise FsError(Errno.EISDIR, str(name))
                    self._dir_unset_raw(parent, bn, off)
                    st["names"].pop(name, None)
                    if st["holes"] is not None:  # None: fs never reuses holes
                        st["holes"].append((bn, off))
                    di.nlink -= 1
                    if di.nlink <= 0:
                        self._itrunc(ino, di)
                        di.type = L.T_FREE
                    self._iupdate(ino, di)
                    self._end_op(True)
                    out.append(None)
                except FsError as e:
                    out.append(e)
        return out

    # --- attrs ------------------------------------------------------------------------------------
    def _attr(self, ino: int, di: L.DiskInode) -> Attr:
        kind = FileKind.DIR if di.type == L.T_DIR else FileKind.FILE
        return Attr(ino=ino, kind=kind, size=di.size, nlink=di.nlink)

    def getattr(self, ino: int) -> Attr:
        with self._oplock:
            di = self._iget(ino)
            if di.type == L.T_FREE:
                raise FsError(Errno.ESTALE, f"free inode {ino}")
            self._end_op(False)
            return self._attr(ino, di)

    # --- directories ---------------------------------------------------------------------------------
    def _dir_entries(self, ino: int, di: L.DiskInode):
        nblocks = (di.size + L.BSIZE - 1) // L.BSIZE
        for bn in range(nblocks):
            b = self._bmap(ino, di, bn, alloc=False)
            if b == 0:
                continue
            with self._bread(b) as bh:
                raw = bytes(bh.data())
            limit = min(L.BSIZE, di.size - bn * L.BSIZE)
            for off in range(0, limit, L.DIRENT_SIZE):
                e_ino, name = L.unpack_dirent(raw, off)
                yield bn, off, e_ino, name

    def _dirlookup(self, dino: int, di: L.DiskInode, name: str):
        # whiteout markers (overlay delete sentinels) are not live entries:
        # the name they carry reads as ENOENT at this level — the overlay
        # inspects them through dir_entry_state instead
        for bn, off, e_ino, e_name in self._dir_entries(dino, di):
            if e_ino != 0 and e_ino != L.WHITEOUT_INO and e_name == name:
                return bn, off, e_ino
        return None

    def _dirlink(self, dino: int, name: str, ino: int) -> None:
        di = self._iget(dino)
        # reuse a hole if any; a whiteout marker for the SAME name is
        # flipped in place instead (one slot write replaces the delete
        # marker with the live entry — create-over-whiteout is atomic and
        # the directory never holds two slots for one name). Foreign
        # whiteouts are NOT holes: evicting another name's delete marker
        # would resurrect base content under an overlay.
        slot = None
        for bn, off, e_ino, e_name in self._dir_entries(dino, di):
            if e_ino == L.WHITEOUT_INO and e_name == name:
                self._dir_set(dino, bn, off, ino, name)
                return
            if e_ino == 0 and slot is None:
                slot = (bn, off)
        if slot is None:
            bn = di.size // L.BSIZE
            off = di.size % L.BSIZE
            slot = (bn, off)
            di.size += L.DIRENT_SIZE
            self._iupdate(dino, di)
        b = self._bmap(dino, di, slot[0], alloc=True)
        with self._bread(b) as bh:
            bh.data()[slot[1]: slot[1] + L.DIRENT_SIZE] = L.pack_dirent(ino, name)
            self._log(b, bytes(bh.data()))

    def _dir_unset_raw(self, dino: int, bn: int, off: int) -> None:
        """Clear one dirent slot on disk (journal-logged) — no index
        maintenance; subclasses layer theirs in ``_dir_unset``."""
        di = self._iget(dino)
        b = self._bmap(dino, di, bn, alloc=False)
        with self._bread(b) as bh:
            bh.data()[off: off + L.DIRENT_SIZE] = bytes(L.DIRENT_SIZE)
            self._log(b, bytes(bh.data()))

    def _dir_unset(self, dino: int, bn: int, off: int) -> None:
        self._dir_unset_raw(dino, bn, off)

    def _dir_set_raw(self, dino: int, bn: int, off: int, ino: int,
                     name: str) -> None:
        """Rewrite one existing dirent slot in place (journal-logged) —
        rename-overwrite's atomic replace: the target name flips from the
        displaced inode to the moved one in a single slot write, so even
        inside the transaction there is never a missing-name window."""
        di = self._iget(dino)
        b = self._bmap(dino, di, bn, alloc=False)
        with self._bread(b) as bh:
            bh.data()[off: off + L.DIRENT_SIZE] = L.pack_dirent(ino, name)
            self._log(b, bytes(bh.data()))

    def _dir_set(self, dino: int, bn: int, off: int, ino: int,
                 name: str) -> None:
        self._dir_set_raw(dino, bn, off, ino, name)

    # --- whiteout primitives (overlay mounts — see fs/overlay.py) -------------------
    # Plain mounts never create whiteouts; these exist so the overlay can
    # record "name deleted here" in a writable upper directory, masking the
    # same name in the immutable base. All mutations are journal-logged and
    # join the caller's open op/chain transaction.

    def dir_entry_state(self, dino: int, name: str):
        """Raw three-way dirent probe: ``("present", ino)`` for a live
        entry, ``("whiteout", None)`` for a delete marker, ``None`` when
        the name has no slot. Unlike ``lookup``, whiteouts are REPORTED,
        not skipped — the overlay's merge logic needs the distinction."""
        with self._oplock:
            di = self._iget(dino)
            if di.type != L.T_DIR:
                raise FsError(Errno.ENOTDIR, str(dino))
            out = None
            for _, _, e_ino, e_name in self._dir_entries(dino, di):
                if e_ino != 0 and e_name == name:
                    out = (("whiteout", None) if e_ino == L.WHITEOUT_INO
                           else ("present", e_ino))
                    break
            self._end_op(False)
            return out

    def dir_whiteouts(self, dino: int) -> List[str]:
        """Names carrying a delete marker in ``dino`` (readdir-merge and
        rmdir-purge input for the overlay)."""
        with self._oplock:
            di = self._iget(dino)
            if di.type != L.T_DIR:
                raise FsError(Errno.ENOTDIR, str(dino))
            out = [name for _, _, e_ino, name in self._dir_entries(dino, di)
                   if e_ino == L.WHITEOUT_INO]
            self._end_op(False)
            return out

    def dir_set_whiteout(self, dino: int, name: str) -> None:
        """Install a delete marker for ``name``. A live entry's slot is
        flipped in place (ONE slot write — no window where the name is
        missing but not yet masked; the caller owns the displaced inode's
        links), an existing marker is left alone, otherwise a slot is
        allocated like ``_dirlink``."""
        with self._oplock:
            self._begin_op()
            di = self._iget(dino)
            if di.type != L.T_DIR:
                raise FsError(Errno.ENOTDIR, str(dino))
            for bn, off, e_ino, e_name in self._dir_entries(dino, di):
                if e_ino != 0 and e_name == name:
                    if e_ino != L.WHITEOUT_INO:
                        self._dir_set(dino, bn, off, L.WHITEOUT_INO, name)
                    self._end_op(True)
                    return
            self._dirlink(dino, name, L.WHITEOUT_INO)
            self._end_op(True)

    def dir_clear_whiteout(self, dino: int, name: str) -> None:
        """Remove ``name``'s delete marker, leaving a reusable hole (no-op
        when none exists). Rename-over-base uses it when the moved name
        stops masking base content."""
        with self._oplock:
            self._begin_op()
            di = self._iget(dino)
            mutated = False
            for bn, off, e_ino, e_name in self._dir_entries(dino, di):
                if e_ino == L.WHITEOUT_INO and e_name == name:
                    self._dir_unset(dino, bn, off)
                    mutated = True
                    break
            self._end_op(mutated)

    def exchange(self, parent: int, name: str, newparent: int,
                 newname: str) -> None:
        """RENAME_EXCHANGE analogue: atomically swap two existing entries
        (both must resolve — ENOENT otherwise). Two in-place slot rewrites
        inside one journal reservation, so neither name ever dangles: even
        mid-transaction each slot always holds one of the two inodes, and
        a crash recovers to both-old or both-new. Directories may swap
        with files; a cross-directory dir swap re-homes both ".."
        back-links."""
        self._check_reserved(name)
        self._check_reserved(newname)
        with self._oplock:
            self._begin_op()
            pdi = self._iget(parent)
            if pdi.type != L.T_DIR:
                raise FsError(Errno.ENOTDIR, str(parent))
            ndi = self._iget(newparent)
            if ndi.type != L.T_DIR:
                raise FsError(Errno.ENOTDIR, str(newparent))
            a = self._dirlookup(parent, pdi, name)
            if a is None:
                raise FsError(Errno.ENOENT, name)
            b = self._dirlookup(newparent, ndi, newname)
            if b is None:
                raise FsError(Errno.ENOENT, newname)
            abn, aoff, aino = a
            bbn, boff, bino = b
            if aino == bino or (parent == newparent and name == newname):
                self._end_op(False)
                return
            adi = self._iget(aino)
            bdi = self._iget(bino)
            if parent != newparent:
                # swapping directories across parents moves each subtree
                # under the other parent — the cycle check applies both ways
                if adi.type == L.T_DIR:
                    self._assert_not_in_subtree(aino, newparent)
                if bdi.type == L.T_DIR:
                    self._assert_not_in_subtree(bino, parent)
            self._dir_set(parent, abn, aoff, bino, name)
            self._dir_set(newparent, bbn, boff, aino, newname)
            if parent != newparent and adi.type != bdi.type:
                # ".." re-homing nets out unless exactly one side is a dir
                gain = 1 if bdi.type == L.T_DIR else -1
                pdi = self._iget(parent)
                pdi.nlink += gain
                self._iupdate(parent, pdi)
                ndi = self._iget(newparent)
                ndi.nlink -= gain
                self._iupdate(newparent, ndi)
            self._end_op(True)

    def lookup(self, parent: int, name: str) -> Attr:
        with self._oplock:
            pdi = self._iget(parent)
            if pdi.type != L.T_DIR:
                raise FsError(Errno.ENOTDIR, str(parent))
            hit = self._dirlookup(parent, pdi, name)
            self._end_op(False)
            if hit is None:
                raise FsError(Errno.ENOENT, name)
            ino = hit[2]
            return self._attr(ino, self._iget(ino))

    def readdir(self, ino: int) -> List[Tuple[str, int, FileKind]]:
        with self._oplock:
            di = self._iget(ino)
            if di.type != L.T_DIR:
                raise FsError(Errno.ENOTDIR, str(ino))
            out = []
            hide = (DEDUP_TABLE_NAME if (self._blockstore is not None
                                         and ino == ROOT_INO) else None)
            for _, _, e_ino, name in self._dir_entries(ino, di):
                if e_ino != 0 and e_ino != L.WHITEOUT_INO:
                    if name == hide:
                        continue
                    edi = self._iget(e_ino)
                    kind = FileKind.DIR if edi.type == L.T_DIR else FileKind.FILE
                    out.append((name, e_ino, kind))
            self._end_op(False)
            return out

    def _check_reserved(self, name: str) -> None:
        """The blockstore's index file is fs-internal: user operations may
        neither create, remove, nor rename over it."""
        if self._blockstore is not None and name == DEDUP_TABLE_NAME:
            raise FsError(Errno.EPERM, name)

    def _create_common(self, parent: int, name: str, kind: int,
                       _internal: bool = False) -> Attr:
        if len(name.encode()) > L.NAME_MAX or not name or "/" in name:
            raise FsError(Errno.EINVAL, name)
        if not _internal:
            self._check_reserved(name)
        with self._oplock:
            self._begin_op()
            pdi = self._iget(parent)
            if pdi.type != L.T_DIR:
                raise FsError(Errno.ENOTDIR, str(parent))
            if self._dirlookup(parent, pdi, name) is not None:
                raise FsError(Errno.EEXIST, name)
            ino = self._ialloc(kind)
            if kind == L.T_DIR:
                pdi = self._iget(parent)
                pdi.nlink += 1  # ".." link
                self._iupdate(parent, pdi)
                di = self._iget(ino)
                di.nlink = 2
                self._iupdate(ino, di)
            self._dirlink(parent, name, ino)
            self._end_op(True)
            return self._attr(ino, self._iget(ino))

    def create(self, parent: int, name: str) -> Attr:
        return self._create_common(parent, name, L.T_FILE)

    def mkdir(self, parent: int, name: str) -> Attr:
        return self._create_common(parent, name, L.T_DIR)

    def _itrunc(self, ino: int, di: L.DiskInode) -> None:
        import struct
        NI = L.NINDIRECT
        for i in range(L.NDIRECT):
            if di.addrs[i]:
                self._bfree(di.addrs[i])
                di.addrs[i] = 0
        if di.addrs[L.NDIRECT]:
            with self._bread(di.addrs[L.NDIRECT]) as bh:
                raw = bytes(bh.data())
            for i in range(NI):
                (v,) = struct.unpack_from("<I", raw, i * 4)
                if v:
                    self._bfree(v)
            self._bfree(di.addrs[L.NDIRECT])
            di.addrs[L.NDIRECT] = 0
        if di.addrs[L.NDIRECT + 1]:
            with self._bread(di.addrs[L.NDIRECT + 1]) as bh:
                raw1 = bytes(bh.data())
            for i in range(NI):
                (l2,) = struct.unpack_from("<I", raw1, i * 4)
                if l2:
                    with self._bread(l2) as bh:
                        raw2 = bytes(bh.data())
                    for j in range(NI):
                        (v,) = struct.unpack_from("<I", raw2, j * 4)
                        if v:
                            self._bfree(v)
                    self._bfree(l2)
            self._bfree(di.addrs[L.NDIRECT + 1])
            di.addrs[L.NDIRECT + 1] = 0
        di.size = 0
        self._iupdate(ino, di)

    def unlink(self, parent: int, name: str) -> None:
        self._check_reserved(name)
        with self._oplock:
            self._begin_op()
            pdi = self._iget(parent)
            hit = self._dirlookup(parent, pdi, name)
            if hit is None:
                raise FsError(Errno.ENOENT, name)
            bn, off, ino = hit
            di = self._iget(ino)
            if di.type == L.T_DIR:
                raise FsError(Errno.EISDIR, name)
            self._dir_unset(parent, bn, off)
            di.nlink -= 1
            if di.nlink <= 0:
                self._itrunc(ino, di)
                di.type = L.T_FREE
            self._iupdate(ino, di)
            self._end_op(True)

    def rmdir(self, parent: int, name: str) -> None:
        self._check_reserved(name)
        with self._oplock:
            self._begin_op()
            pdi = self._iget(parent)
            hit = self._dirlookup(parent, pdi, name)
            if hit is None:
                raise FsError(Errno.ENOENT, name)
            bn, off, ino = hit
            di = self._iget(ino)
            if di.type != L.T_DIR:
                raise FsError(Errno.ENOTDIR, name)
            if any(e_ino != 0 for _, _, e_ino, _ in self._dir_entries(ino, di)):
                raise FsError(Errno.ENOTEMPTY, name)
            self._dir_unset(parent, bn, off)
            self._itrunc(ino, di)
            di.type = L.T_FREE
            di.nlink = 0
            self._iupdate(ino, di)
            pdi = self._iget(parent)
            pdi.nlink -= 1
            self._iupdate(parent, pdi)
            self._end_op(True)

    def _assert_not_in_subtree(self, ino: int, newparent: int) -> None:
        """EINVAL when ``newparent`` lives inside the directory being
        moved — without this check the rename would detach the subtree
        into an unreachable cycle (POSIX EINVAL)."""
        stack = [ino]
        while stack:
            d = stack.pop()
            if d == newparent:
                raise FsError(Errno.EINVAL, "rename into own subtree")
            ddi = self._iget(d)
            for _, _, e_ino, _ in self._dir_entries(d, ddi):
                if e_ino != 0 and e_ino != L.WHITEOUT_INO \
                        and self._iget(e_ino).type == L.T_DIR:
                    stack.append(e_ino)

    def rename(self, parent: int, name: str, newparent: int, newname: str) -> None:
        """POSIX rename, overwrite included: an existing ``newname`` is
        atomically REPLACED, never refused EEXIST — files replace files,
        directories replace EMPTY directories (ENOTEMPTY otherwise;
        ENOTDIR/EISDIR on kind mismatch). The displaced inode drops its
        link (blocks freed when it reaches zero) inside the SAME journal
        reservation as the dirent swap, so a crash at any device write
        recovers to either the complete old mapping or the complete new
        one — ``newname`` always resolves, the displaced inode's blocks
        are freed exactly when the swap is durable (enumerated per crash
        point by tests/test_crash_torture.py)."""
        if (not isinstance(newname, str) or not newname or "/" in newname
                or len(newname.encode()) > L.NAME_MAX):
            raise FsError(Errno.EINVAL, str(newname))
        self._check_reserved(name)
        self._check_reserved(newname)
        with self._oplock:
            self._begin_op()
            pdi = self._iget(parent)
            if pdi.type != L.T_DIR:
                raise FsError(Errno.ENOTDIR, str(parent))
            hit = self._dirlookup(parent, pdi, name)
            if hit is None:
                raise FsError(Errno.ENOENT, name)
            bn, off, ino = hit
            ndi = self._iget(newparent)
            if ndi.type != L.T_DIR:
                raise FsError(Errno.ENOTDIR, str(newparent))
            if parent == newparent and name == newname:
                self._end_op(False)  # POSIX: rename onto itself is a no-op
                return
            sdi = self._iget(ino)
            if sdi.type == L.T_DIR and newparent != parent:
                self._assert_not_in_subtree(ino, newparent)
            existing = self._dirlookup(newparent, ndi, newname)
            if existing is not None:
                ebn, eoff, eino = existing
                edi = self._iget(eino)
                if edi.type == L.T_DIR and sdi.type != L.T_DIR:
                    raise FsError(Errno.EISDIR, newname)
                if edi.type != L.T_DIR and sdi.type == L.T_DIR:
                    raise FsError(Errno.ENOTDIR, newname)
                if edi.type == L.T_DIR and any(
                        e_ino != 0
                        for _, _, e_ino, _ in self._dir_entries(eino, edi)):
                    raise FsError(Errno.ENOTEMPTY, newname)
                # atomic replace: rewrite the target's slot to the moved
                # inode, clear the source slot, drop the displaced link —
                # all staged into this op's one journal transaction
                self._dir_unset(parent, bn, off)
                self._dir_set(newparent, ebn, eoff, ino, newname)
                if edi.type == L.T_DIR:
                    # displaced empty dir: its synthetic self-link pair
                    # dies with it, and newparent loses the ".." back-link
                    edi.nlink = 0
                    self._itrunc(eino, edi)
                    edi.type = L.T_FREE
                    self._iupdate(eino, edi)
                    ndi = self._iget(newparent)
                    ndi.nlink -= 1
                    self._iupdate(newparent, ndi)
                else:
                    edi.nlink -= 1
                    if edi.nlink <= 0:
                        self._itrunc(eino, edi)
                        edi.type = L.T_FREE
                    self._iupdate(eino, edi)
            else:
                self._dir_unset(parent, bn, off)
                self._dirlink(newparent, newname, ino)
            if sdi.type == L.T_DIR and parent != newparent:
                # a moved directory re-homes its ".." back-link
                pdi = self._iget(parent)
                pdi.nlink -= 1
                self._iupdate(parent, pdi)
                ndi = self._iget(newparent)
                ndi.nlink += 1
                self._iupdate(newparent, ndi)
            self._end_op(True)

    # --- file data ------------------------------------------------------------------------------------
    def read(self, ino: int, off: int, size: int) -> bytes:
        with self._oplock:
            di = self._iget(ino)
            if di.type == L.T_DIR:
                raise FsError(Errno.EISDIR, str(ino))
            if off >= di.size:
                return b""
            size = min(size, di.size - off)
            out = bytearray()
            while size > 0:
                bn, boff = divmod(off, L.BSIZE)
                n = min(L.BSIZE - boff, size)
                b = self._bmap(ino, di, bn, alloc=False)
                if b == 0:
                    out += bytes(n)  # hole
                else:
                    with self._bread(b) as bh:
                        out += bh.data()[boff: boff + n]
                off += n
                size -= n
            self._end_op(False)
            return bytes(out)

    def write(self, ino: int, off: int, data: bytes) -> int:
        with self._oplock:
            di = self._iget(ino)
            if di.type == L.T_DIR:
                raise FsError(Errno.EISDIR, str(ino))
            if (off + len(data) + L.BSIZE - 1) // L.BSIZE > L.MAXFILE_BLOCKS:
                raise FsError(Errno.EFBIG, str(ino))
            pos, n = off, len(data)
            written = 0
            blocks_in_subop = MAXOP_BLOCKS  # force reservation on first block
            meta = self._chain_write_overhead  # bitmap/inode/ind (+dedup)
            while written < n:
                if blocks_in_subop + meta >= MAXOP_BLOCKS:
                    self._begin_op()
                    blocks_in_subop = 0
                bn, boff = divmod(pos, L.BSIZE)
                chunk = min(L.BSIZE - boff, n - written)
                b = self._write_block_target(ino, di, bn)
                if boff == 0 and chunk == L.BSIZE:
                    self._log(b, bytes(data[written: written + chunk]))
                else:
                    with self._bread(b) as bh:
                        buf = bh.data()
                        buf[boff: boff + chunk] = data[written: written + chunk]
                        self._log(b, bytes(buf))
                blocks_in_subop += 1
                pos += chunk
                written += chunk
                # keep size durable per sub-op so a crash between sub-ops
                # leaves a consistent (shorter) file
                if pos > di.size:
                    di.size = pos
                    self._iupdate(ino, di)
            store = self._blockstore
            if store is not None and store.batch_depth == 0:
                # scalar (unbatched) write: dedup pass in THIS transaction
                store.flush_pending()
            self._end_op(True)
            return written

    def truncate(self, ino: int, size: int) -> None:
        with self._oplock:
            self._begin_op()
            di = self._iget(ino)
            if size == 0:
                self._itrunc(ino, di)
            elif size < di.size:
                di.size = size  # lazy: keep blocks (xv6-style simplicity)
                self._iupdate(ino, di)
            else:
                di.size = size
                self._iupdate(ino, di)
            self._end_op(True)

    def fsync(self, ino: int) -> None:
        with self._oplock:
            self.journal.commit()
            self._end_op(False)

    def flush(self) -> None:
        with self._oplock:
            self.journal.commit()
            self.ks.flush(self.sb_cap)

    def statfs(self) -> Dict[str, int]:
        with self._oplock:
            # settle any deferred dedup pass FIRST: pending CoW/refcount
            # state makes the bitmap transiently stale, which is exactly
            # how the crashsim free-block audit used to drift on dedup
            # mounts (fs/crashsim.py torture_rename invariant)
            self._dedup_drain()
            with self._alloc_lock:  # a stable bitmap snapshot
                # count zero bits only for block numbers < geo.size: the
                # last bitmap block's trailing padding bits are zero but
                # name no real block, and counting them inflated the
                # estimate by the pad width on small devices
                free = 0
                for bm in range(self.geo.bmapstart, self.geo.datastart):
                    with self._bread(bm) as bh:
                        raw = bytes(bh.data())
                    limit = self.geo.size - (bm - self.geo.bmapstart) \
                        * L.BSIZE * 8
                    if limit <= 0:
                        break
                    if limit < L.BSIZE * 8:
                        nbytes, rem = divmod(limit, 8)
                        raw = raw[:nbytes + 1] if rem else raw[:nbytes]
                        if rem:  # mask off bits past the last real block
                            raw = raw[:-1] + bytes(
                                [raw[-1] | (0xFF << rem) & 0xFF])
                    free += sum(8 - bin(byte).count("1") for byte in raw)
            total_data = self.geo.size - self.geo.datastart
            self._end_op(False)
            out = {"block_size": L.BSIZE, "total_blocks": self.geo.size,
                   "data_blocks": total_data, "free_blocks_est": free,
                   "journal_commits": self.journal.commits}
            if self._blockstore is not None:
                extras = self._blockstore.statfs_extras()
                out.update(extras)
                # dedup-aware estimate: free_blocks_est stays PHYSICAL
                # (bitmap truth — the crash audits rely on it); the
                # logical view adds back what sharing saved, so a
                # capacity planner sees how much namespace the device
                # can still absorb. Both are asserted against a full
                # inode walk in tests/test_blockstore.py.
                out["free_blocks_logical_est"] = (
                    free + extras.get("dedup_saved_blocks", 0))
            return out
