"""Exhaustive crash-point torture harness (CrashMonkey-style enumeration).

"Bento and the Art of Repeated Research" argues that crash-consistency
claims must be re-verifiable by systematic, repeatable infrastructure, not
ad-hoc spot checks. This module is that infrastructure for the journaled
file systems in this repo:

* a *golden run* first measures a workload's total device-write footprint;
* the workload is then re-executed once per crash point N = 0..total with
  power loss injected after the Nth device write (N = 0: the very first
  write never lands; N = total: the no-crash control) — EVERY device-write
  crash point is enumerated, not a sampled subset;
* after each crash the device is remounted cold — fresh buffer cache,
  fresh fs instance, ``Journal.recover()`` runs at init — and an invariant
  callback judges the recovered state.

Each iteration rebuilds the device from scratch (mkfs + the caller's
``setup``, flushed durable before the write counter starts), so every
crash point replays an identical write stream: the sweep is deterministic
and a failure names the exact write it crashed on.

The canonical sweep — a linked create → write(PrevResult) → fsync chain
that must be all-or-nothing after recovery (the chain-transaction
guarantee of ``repro.fs.journal``) — is built in, used by the test tree
and runnable standalone as a CI smoke::

    PYTHONPATH=src python -m repro.fs.crashsim --quick

``--quick`` bounds the sweep to a stratified subset of crash points
(first/last + an even stride) so it fits a CI smoke budget; without it
every crash point runs.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, List, Optional, Sequence

from repro.core.services import kernel_binding
from repro.fs.blockdev import (BlockDeviceError, LazyBlockDevice,
                               MemBlockDevice)
from repro.fs.mounts import DirectMount
from repro.fs.posix import PosixView
from repro.fs.xv6 import mkfs


@dataclasses.dataclass
class CrashCtx:
    """What a workload/setup callback gets to drive: a freshly formatted
    device behind a DirectMount (same chain executor and journal path as
    the gated mounts, none of the gate noise)."""

    dev: MemBlockDevice
    ks: object
    fs: object
    mount: DirectMount
    view: PosixView


@dataclasses.dataclass
class Recovered:
    """Post-crash, post-recovery state handed to invariants/callers."""

    crash_point: int   # writes that LANDED before power loss
    total_writes: int  # the workload's full footprint (golden run)
    crashed: bool      # False only for the N == total control iteration
    dev: MemBlockDevice
    ks: object
    fs: object
    mount: DirectMount
    view: PosixView


def quick_points(total: int, n: int = 12) -> List[int]:
    """Bounded, stratified crash-point subset: first, last, no-crash
    control, and an even stride in between — the CI smoke budget."""
    if total + 1 <= n:
        return list(range(total + 1))
    stride = max(1, (total + n - 1) // n)
    return sorted(set(range(0, total + 1, stride)) | {0, 1, total - 1, total})


class CrashSim:
    """Deterministic crash-point sweeps over a journaled Bento fs."""

    def __init__(self, fs_factory: Callable[[], object], *,
                 n_blocks: int = 2048, ninodes: int = 256, nlog: int = 32,
                 writeback: str = "delayed",
                 device_factory: Optional[Callable[[], object]] = None,
                 format_device: bool = True):
        self.fs_factory = fs_factory
        self.n_blocks = n_blocks
        self.ninodes = ninodes
        self.nlog = nlog
        self.writeback = writeback
        # non-default devices (a LazyBlockDevice over a golden image) plug
        # in here; format_device=False skips mkfs for devices whose
        # provider already carries a formatted image
        self.device_factory = device_factory
        self.format_device = format_device

    # --- plumbing -------------------------------------------------------------------
    def _mount(self, dev: MemBlockDevice) -> CrashCtx:
        """Cold mount: fresh services (fresh cache) + fresh fs instance;
        the fs's init runs journal recovery."""
        ks = kernel_binding(dev, writeback=self.writeback)
        fs = self.fs_factory()
        fs.init(ks.superblock(), ks)
        m = DirectMount(fs)
        return CrashCtx(dev, ks, fs, m, PosixView(m))

    def boot(self, setup: Optional[Callable[[CrashCtx], None]] = None
             ) -> CrashCtx:
        """The canonical cold-boot recipe (public — tests use it for
        non-crash setups too): fresh device + mkfs + mount + durable
        setup, write counter armed at zero so crash points index workload
        writes only."""
        dev = (MemBlockDevice(self.n_blocks) if self.device_factory is None
               else self.device_factory())
        if self.format_device:
            ks = kernel_binding(dev, writeback=self.writeback)
            mkfs(ks, ninodes=self.ninodes, nlog=self.nlog)
        ctx = self._mount(dev)
        if setup is not None:
            setup(ctx)
            ctx.fs.flush()  # setup is durable regardless of the crash point
        dev._writes_seen = 0
        return ctx

    # --- public API -----------------------------------------------------------------
    def measure(self, workload: Callable[[CrashCtx], None], *,
                setup: Optional[Callable[[CrashCtx], None]] = None) -> int:
        """Golden run: the workload's device-write footprint (no crash)."""
        ctx = self.boot(setup)
        ctx.dev.fail_after_writes = 1 << 30  # arm the counter, never fire
        workload(ctx)
        total = ctx.dev._writes_seen
        ctx.dev.fail_after_writes = -1
        return total

    def run_one(self, workload: Callable[[CrashCtx], None], point: int, *,
                total: Optional[int] = None,
                setup: Optional[Callable[[CrashCtx], None]] = None
                ) -> Recovered:
        """One iteration: crash after ``point`` device writes, power back
        on, remount cold (recovery runs), return the recovered state."""
        ctx = self.boot(setup)
        ctx.dev.fail_after_writes = point
        crashed = False
        try:
            workload(ctx)
        except BlockDeviceError:
            crashed = True
        ctx.dev.fail_after_writes = -1  # power back on
        rec = self._mount(ctx.dev)
        return Recovered(point, -1 if total is None else total, crashed,
                         rec.dev, rec.ks, rec.fs, rec.mount, rec.view)

    def sweep(self, workload: Callable[[CrashCtx], None],
              invariant: Callable[[Recovered], None], *,
              setup: Optional[Callable[[CrashCtx], None]] = None,
              points: Optional[Sequence[int]] = None,
              quick: bool = False) -> int:
        """Enumerate crash points and assert the invariant at each.

        ``points`` overrides the enumeration; ``quick`` bounds it via
        ``quick_points``. Default: EVERY point, 0..total inclusive (the
        last is the no-crash control). Returns the number of points swept;
        an invariant failure re-raises naming the crash point."""
        total = self.measure(workload, setup=setup)
        if points is None:
            points = quick_points(total) if quick else range(total + 1)
        for point in points:
            rec = self.run_one(workload, point, total=total, setup=setup)
            try:
                invariant(rec)
            except AssertionError as e:
                raise AssertionError(
                    f"invariant violated at crash point {point}/{total} "
                    f"(crashed={rec.crashed}): {e}") from e
        return len(list(points))


# --- FUSE daemon torture: the file-backed device, across processes ---------------


@dataclasses.dataclass
class FuseRecovered:
    """Post-crash state of the daemon path: a FRESH daemon remounted the
    survived backing file (journal recovery ran daemon-side at init)."""

    crash_point: int
    total_writes: int
    crashed: bool
    mount: object   # FuseMount over the recovered image
    view: PosixView


class FuseCrashSim:
    """Crash-point sweeps THROUGH the FUSE daemon (the userspace binding's
    file-backed device — the path no in-process harness can reach).

    Power loss is injected in the daemon's ``FileBlockDevice`` over the
    ``__ctl__`` side-channel (optionally TEARING the dying write mid-block
    via ``torn_bytes`` — the journal checksums must catch that), the
    daemon is then SIGKILLed without any flush, and the backing file is
    remounted by a fresh daemon with mkfs skipped, so ``Journal.recover``
    runs against exactly what survived. Same golden-run/enumerate/remount
    protocol as ``CrashSim``; each iteration costs two daemon processes,
    so sweeps here favour ``quick=True``."""

    def __init__(self, *, n_blocks: int = 2048, fs_kind: str = "xv6",
                 torn_bytes: int = -1):
        self.n_blocks = n_blocks
        self.fs_kind = fs_kind
        self.torn_bytes = torn_bytes

    def _boot(self, setup):
        """Fresh backing file + daemon + durable setup, injection counter
        armed at zero so crash points index workload writes only."""
        import os
        import tempfile

        from repro.fs.fusebridge import FuseMount

        tmpdir = tempfile.mkdtemp(prefix="fusecrash_")
        backing = os.path.join(tmpdir, "disk.img")
        m = FuseMount(n_blocks=self.n_blocks, fs_kind=self.fs_kind,
                      backing_path=backing)
        view = PosixView(m)
        if setup is not None:
            setup(view)
            m.call("flush")  # setup durable regardless of the crash point
        m.ctl("fail_after_writes", 1 << 30, self.torn_bytes)  # arm counter
        return tmpdir, backing, m, view

    @staticmethod
    def _cleanup(tmpdir) -> None:
        import shutil

        shutil.rmtree(tmpdir, ignore_errors=True)

    def measure(self, workload, *, setup=None) -> int:
        tmpdir, _backing, m, view = self._boot(setup)
        try:
            workload(view, m)
            return m.ctl("writes_seen")
        finally:
            m.kill()
            self._cleanup(tmpdir)

    def run_one(self, workload, point: int, *, total: int = -1, setup=None):
        """One iteration: boot fresh, arm the crash at ``point``, run the
        workload (daemon-side power loss surfaces client-side as
        RuntimeError), kill -9 the daemon, remount the survived image."""
        from repro.fs.fusebridge import FuseMount

        tmpdir, backing, m, view = self._boot(setup)
        m.ctl("fail_after_writes", point, self.torn_bytes)
        crashed = False
        try:
            workload(view, m)
        except (RuntimeError, EOFError, OSError):
            crashed = True  # the daemon's device lost power mid-op
        m.kill()
        m2 = FuseMount(n_blocks=self.n_blocks, fs_kind=self.fs_kind,
                       backing_path=backing, reuse=True)
        rec = FuseRecovered(point, total, crashed, m2, PosixView(m2))
        rec._tmpdir = tmpdir  # cleaned by sweep/caller via finish()
        return rec

    def finish(self, rec: FuseRecovered) -> None:
        rec.mount.kill()
        self._cleanup(rec._tmpdir)

    def sweep(self, workload, invariant, *, setup=None, points=None,
              quick: bool = True) -> int:
        total = self.measure(workload, setup=setup)
        if points is None:
            points = quick_points(total) if quick else range(total + 1)
        for point in points:
            rec = self.run_one(workload, point, total=total, setup=setup)
            try:
                invariant(rec)
            except AssertionError as e:
                raise AssertionError(
                    f"fuse invariant violated at crash point {point}/{total}"
                    f" (crashed={rec.crashed}): {e}") from e
            finally:
                self.finish(rec)
        return len(list(points))


def torture_fuse(*, payload_blocks: int = 1, quick: bool = True,
                 torn_bytes: int = -1, fs_kind: str = "xv6") -> int:
    """Sweep a chained create→write(PrevResult)→fsync THROUGH the daemon:
    all-or-nothing must hold across a real process kill + file-backed
    remount (and with ``torn_bytes`` armed, across a torn final write)."""
    from repro.core.interface import PrevResult, SQE_LINK, SubmissionEntry

    payload = b"F" * (payload_blocks * 4096 + 17)

    def workload(view, m):
        comps = m.submit([
            SubmissionEntry("create", (1, "f"), user_data="c",
                            flags=SQE_LINK),
            SubmissionEntry("write", (PrevResult("ino"), 0, payload),
                            user_data="w", flags=SQE_LINK),
            SubmissionEntry("fsync", (PrevResult("ino", back=2),),
                            user_data="s"),
        ])
        bad = [(c.user_data, c.errno) for c in comps if not c.ok]
        assert not bad, f"chain failed without a crash: {bad}"

    def invariant(rec: FuseRecovered) -> None:
        if rec.view.exists("/f"):
            got = rec.view.read_file("/f")
            assert got == payload, (
                f"half-applied chain through the daemon: /f has {len(got)}B"
                f" (expected {len(payload)}B or no file)")
        else:
            assert rec.crashed, "no crash, yet /f is missing"
        rec.view.statfs()
        rec.view.listdir("/")

    sim = FuseCrashSim(fs_kind=fs_kind, torn_bytes=torn_bytes)
    return sim.sweep(workload, invariant, quick=quick)


# --- the canonical chain torture (acceptance sweep + CI smoke) -------------------


def chain_workload(payload: bytes, name: str = "f"
                   ) -> Callable[[CrashCtx], None]:
    """The PR's headline unit: a linked create → write(PrevResult("ino"))
    → fsync chain submitted as one batch."""
    from repro.core.interface import PrevResult, SQE_LINK, SubmissionEntry

    def run(ctx: CrashCtx) -> None:
        comps = ctx.mount.submit([
            SubmissionEntry("create", (1, name), user_data="c",
                            flags=SQE_LINK),
            SubmissionEntry("write", (PrevResult("ino"), 0, payload),
                            user_data="w", flags=SQE_LINK),
            SubmissionEntry("fsync", (PrevResult("ino", back=2),),
                            user_data="s"),
        ])
        bad = [(c.user_data, c.errno) for c in comps if not c.ok]
        assert not bad, f"chain failed without a crash: {bad}"

    return run


def all_or_nothing(payload: bytes, path: str = "/f"
                   ) -> Callable[[Recovered], None]:
    """After recovery the chain is indivisible: the file either does not
    exist at all, or exists with the COMPLETE payload — a dirent without
    data, a short file, or a torn tail all fail. The no-crash control
    (crashed=False) must see the file. General fs consistency (statfs,
    readdir) must hold at every point."""

    def invariant(rec: Recovered) -> None:
        if rec.view.exists(path):
            got = rec.view.read_file(path)
            assert got == payload, (
                f"half-applied chain: {path} exists with {len(got)}B "
                f"(expected {len(payload)}B or no file)")
        else:
            assert rec.crashed, f"no crash, yet {path} is missing"
        rec.view.statfs()
        rec.view.listdir("/")

    return invariant


def _fs_factory(kind: str):
    from repro.fs.ext4like import Ext4LikeFileSystem
    from repro.fs.xv6 import Xv6FileSystem, Xv6Options

    return {
        "xv6": lambda: Xv6FileSystem(Xv6Options()),
        "ext4like": lambda: Ext4LikeFileSystem(),
    }[kind]


def torture_chain(kind: str = "xv6", *, payload_blocks: int = 2,
                  quick: bool = False) -> int:
    """Sweep the canonical chain on one fs kind; returns points swept."""
    payload = b"C" * (payload_blocks * 4096 + 17)  # off-block tail: torn shows
    sim = CrashSim(_fs_factory(kind))
    return sim.sweep(chain_workload(payload), all_or_nothing(payload),
                     quick=quick)


def torture_rename(kind: str = "xv6", *, quick: bool = False) -> int:
    """Sweep a rename ONTO an existing name (the POSIX overwrite path):
    after recovery at every crash point, the new name must still resolve
    (to the old content before the swap committed, to the moved content
    after), the old name must be gone exactly when the swap is durable,
    and the displaced inode's blocks must be freed with it — both
    end-states' free-block counts are golden-measured first, so block
    leaks fail the sweep, not just torn names."""
    a, b = b"A" * (2 * 4096 + 7), b"B" * (3 * 4096 + 3)

    def setup(ctx: CrashCtx) -> None:
        ctx.view.write_file("/old", a)
        ctx.view.write_file("/new", b)

    def workload(ctx: CrashCtx) -> None:
        ctx.view.rename("/old", "/new")
        ctx.view.fsync("/new")

    sim = CrashSim(_fs_factory(kind))
    # golden free-block counts for the two legal end states
    ctx = sim.boot(setup)
    free_before = ctx.view.statfs()["free_blocks_est"]
    workload(ctx)
    free_after = ctx.view.statfs()["free_blocks_est"]

    def invariant(rec: Recovered) -> None:
        new_data = rec.view.read_file("/new")  # /new must ALWAYS resolve
        free = rec.view.statfs()["free_blocks_est"]
        if rec.view.exists("/old"):
            assert rec.crashed, "no crash, yet the rename did not happen"
            assert rec.view.read_file("/old") == a
            assert new_data == b, "target clobbered before the swap committed"
            assert free == free_before, \
                f"block leak pre-swap: {free} != {free_before}"
        else:
            assert new_data == a, "old name gone but target not the moved file"
            assert free == free_after, \
                f"displaced blocks not freed: {free} != {free_after}"
        rec.view.listdir("/")

    return sim.sweep(workload, invariant, setup=setup, quick=quick)


# --- sharded-checkpoint torture: old XOR complete-new, shard files and all -------


def torture_ckpt_shards(kind: str = "xv6", *, quick: bool = False) -> int:
    """Sweep a v2 SHARDED checkpoint re-save (shard-per-file + manifest
    rename swap, repro.checkpoint.store) over a LIVE previous checkpoint:
    after power loss at every device write, a cold remount must restore
    either the previous checkpoint or the COMPLETE new one — every shard
    file present, every per-shard checksum clean, never a mix of
    generations and never zero restorable checkpoints."""
    import numpy as np

    from repro.checkpoint import store
    from repro.distributed.resharding import ShardGrid

    grid = ShardGrid.from_spec((8, 8), ("d", "m"), {"d": 2, "m": 2})
    old_tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8),
                "b": np.full((6,), 3.0, np.float32)}
    new_tree = {"w": old_tree["w"] + 100.0,
                "b": np.full((6,), 7.0, np.float32)}
    like = {"w": np.zeros((8, 8), np.float32),
            "b": np.zeros((6,), np.float32)}
    grids = {"w": grid, "b": None}

    def setup(ctx: CrashCtx) -> None:
        store.save(ctx.view, "/ckpt/step_1", old_tree, step=1,
                   checksum=ctx.ks.checksum, shardings=grids)

    def workload(ctx: CrashCtx) -> None:
        store.save(ctx.view, "/ckpt/step_1", new_tree, step=1,
                   checksum=ctx.ks.checksum, shardings=grids)

    def invariant(rec: Recovered) -> None:
        # a live manifest must exist at every point (the old one until the
        # swap commits, the new one after) and load must verify EVERY
        # shard file's checksum on the way in
        assert store.latest_step(rec.view, "/ckpt") == 1, \
            "no restorable checkpoint after the crash"
        got, man = store.load(rec.view, "/ckpt/step_1", like,
                              checksum=rec.ks.checksum)
        wrec = [r for r in man["leaves"] if r["shape"] == [8, 8]][0]
        assert len(wrec["shards"]) == 4, \
            f"live manifest names {len(wrec['shards'])} shards, not 4"
        w, b = np.asarray(got["w"]), np.asarray(got["b"])
        if np.array_equal(w, new_tree["w"]):
            assert np.array_equal(b, new_tree["b"]), \
                "mixed generations restored: new w, old b"
        else:
            assert np.array_equal(w, old_tree["w"]), "w is neither gen"
            assert np.array_equal(b, old_tree["b"]), \
                "mixed generations restored: old w, new b"
            assert rec.crashed, "no crash, yet the re-save is not live"
        rec.view.statfs()

    sim = CrashSim(_fs_factory(kind), nlog=64)
    return sim.sweep(workload, invariant, setup=setup, quick=quick)


# --- provenance-log torture: the log must always be explainable ------------------


def _prov_factory(kind: str):
    from repro.fs.prov import ProvFilesystem

    base = _fs_factory(kind)
    return lambda: ProvFilesystem(base())


def torture_prov(kind: str = "xv6", *, quick: bool = False) -> int:
    """Sweep a scripted mutation sequence through the provenance layer:
    after power loss at EVERY device write, the recovered log must never
    reference an inode or name the recovered file system doesn't explain.

    For namespace ops the layer commits mutation + record in ONE journal
    transaction (old-XOR-new), which makes the invariant exact and
    bidirectional: replaying the recovered log's namespace records over
    the durable setup state must reproduce the recovered tree EXACTLY —
    a record without its mutation, a mutation without its record, or a
    reordering all fail the sweep. File content is checked one-directional
    for writes (record durable ⇒ data durable)."""
    payload = b"W" * (4096 + 33)

    def setup(ctx: CrashCtx) -> None:
        ctx.view.write_file("/seed", b"s" * 4096)

    def workload(ctx: CrashCtx) -> None:
        # fsyncs split the stream into several journal transactions, so
        # the sweep sees genuine PREFIX states (ops 1..k durable), not
        # just all-or-nothing of one group commit
        v = ctx.view
        v.create("/a")
        v.write_file("/a", payload, create=False)
        v.fsync("/a")
        v.mkdir("/d")
        v.rename("/seed", "/d/renamed")
        v.fsync("/d")
        v.create("/b")
        v.unlink("/a")
        v.fsync("/b")

    # records the durable setup leaves in the log (identical every boot)
    sim = CrashSim(_prov_factory(kind))
    ctx0 = sim.boot(setup)
    n_setup = len(ctx0.fs.read_provenance())

    def invariant(rec: Recovered) -> None:
        recs = rec.fs.read_provenance()[n_setup:]
        # replay namespace records over the setup namespace: {path: ino}
        dirs = {"/": 1}
        names = {"/seed": None}
        for r in recs:
            if r["op"] == "create":
                parent = "/" if r["parent"] == 1 else "/d"
                names[f"{parent.rstrip('/')}/{r['name']}"] = r["ino"]
            elif r["op"] == "mkdir":
                dirs[f"/{r['name']}"] = r["ino"]
            elif r["op"] == "unlink":
                names.pop(f"/{r['name']}", None)
            elif r["op"] == "rename":
                ino = names.pop(f"/{r['name']}")
                names[f"/d/{r['newname']}"] = ino
        # bidirectional namespace equality (old-XOR-new per record)
        got_root = set(rec.view.listdir("/"))
        want_root = ({p[1:] for p in names if p.count("/") == 1}
                     | {d[1:] for d in dirs if d != "/"})
        assert got_root == want_root, \
            f"log does not explain the tree: fs={got_root} log={want_root}"
        for d, dino in dirs.items():
            if d == "/":
                continue
            assert rec.view.stat(d).ino == dino, f"{d}: wrong ino"
            got_d = set(rec.view.listdir(d))
            want_d = {p.split("/")[-1] for p in names
                      if p.startswith(d + "/")}
            assert got_d == want_d, f"{d} mismatch: {got_d} != {want_d}"
        for path, ino in names.items():
            if ino is not None:
                assert rec.view.stat(path).ino == ino, f"{path}: wrong ino"
        # writes: record durable ⇒ data durable (never the reverse claim)
        if any(r["op"] == "write" and r.get("len") == len(payload)
               for r in recs) and "/a" in names:
            assert rec.view.read_file("/a") == payload, "write record " \
                "durable but its data is not"
        rec.view.statfs()

    return sim.sweep(workload, invariant, setup=setup, quick=quick)


def torture_prov_chain(kind: str = "xv6", *, quick: bool = False) -> int:
    """The chained shape: one journal transaction must span the chain's
    data AND its provenance records — after recovery the file and its
    create/write records exist together or not at all."""
    payload = b"Q" * (2 * 4096 + 17)
    sim = CrashSim(_prov_factory(kind), nlog=64)

    def invariant(rec: Recovered) -> None:
        recs = rec.fs.read_provenance()
        have_file = rec.view.exists("/f")
        have_recs = [r["op"] for r in recs if r.get("name") == "f"
                     or (r["op"] == "write" and r.get("len") == len(payload))]
        if have_file:
            assert rec.view.read_file("/f") == payload, "half-applied chain"
            assert have_recs == ["create", "write"], \
                f"chain durable without its records: {have_recs}"
        else:
            assert rec.crashed, "no crash, yet /f is missing"
            assert not have_recs, \
                f"records durable without their chain: {have_recs}"
        rec.view.listdir("/")

    return sim.sweep(chain_workload(payload), invariant, quick=quick)


# --- dedup-index torture: the content-addressed plane must stay exact ------------


def _dedup_factory(kind: str):
    from repro.fs.ext4like import Ext4LikeFileSystem
    from repro.fs.xv6 import Xv6FileSystem, Xv6Options

    return {
        "xv6": lambda: Xv6FileSystem(Xv6Options(dedup=True)),
        "ext4like": lambda: Ext4LikeFileSystem(Xv6Options(dedup=True)),
    }[kind]


def _dedup_audit(rec: Recovered) -> None:
    """The refcount-exact audit. Walk EVERY inode on the recovered image
    and rebuild, from the metadata alone, the per-block reference map the
    dedup index claims to maintain; then require exact agreement:

    * index == walk, block for block and count for count — a stale entry,
      a missed decrement, or a lost CoW break all fail;
    * bitmap == reachability — every allocated data block is reachable
      from some inode (no leaks) and every reachable block is allocated
      (no double-frees), shared blocks counted once;
    * every VALID index hash matches its block's recomputed checksum — a
      hash that survived a crash it shouldn't have fails here.

    Because index records journal in the same transaction as the write
    that caused them, all three must hold at every crash point."""
    import repro.fs.layout as L

    fs, store, geo = rec.fs, rec.fs._blockstore, rec.fs.geo
    refs: dict = {}      # data block -> walked reference count (files only)
    reachable: set = set()
    for ino in range(1, geo.ninodes):
        di = fs._iget(ino)
        if di.type not in (L.T_FILE, L.T_DIR):
            continue
        counted = di.type == L.T_FILE and ino != store.table_ino
        cache: dict = {}
        for bn in range((di.size + L.BSIZE - 1) // L.BSIZE):
            b = fs._bmap_ro(di, bn, cache)
            if b == 0:
                continue
            reachable.add(b)
            if counted:
                refs[b] = refs.get(b, 0) + 1
        l1, l2 = di.addrs[L.NDIRECT], di.addrs[L.NDIRECT + 1]
        if l1:
            reachable.add(l1)
        if l2:
            reachable.add(l2)
            with fs._bread(l2) as bh:
                raw = bytes(bh.data())
            for k in range(L.NINDIRECT):
                p = int.from_bytes(raw[4 * k: 4 * k + 4], "little")
                if p:
                    reachable.add(p)

    idx = {b: rc for b, rc in store.refcnt.items() if rc > 0}
    if idx != refs:
        only_i = {b: idx[b] for b in set(idx) - set(refs)}
        only_w = {b: refs[b] for b in set(refs) - set(idx)}
        diff = {b: (idx[b], refs[b]) for b in set(idx) & set(refs)
                if idx[b] != refs[b]}
        raise AssertionError(
            f"dedup index not refcount-exact: index-only={only_i} "
            f"walk-only={only_w} count-mismatch={diff}")

    bits_per = L.BSIZE * 8
    allocated = set()
    for bm in range(geo.bmapstart, geo.datastart):
        with fs._bread(bm) as bh:
            raw = bytes(bh.data())
        base = (bm - geo.bmapstart) * bits_per
        for byte_i, byte in enumerate(raw):
            if not byte:
                continue
            for bit in range(8):
                if byte >> bit & 1:
                    b = base + byte_i * 8 + bit
                    if geo.datastart <= b < geo.size:
                        allocated.add(b)
    leaked = allocated - reachable
    dangling = reachable - allocated
    assert not leaked, \
        f"block leak (allocated, unreachable): {sorted(leaked)[:8]}"
    assert not dangling, \
        f"double-free (reachable, not allocated): {sorted(dangling)[:8]}"

    hashed = sorted(store.hashval)
    if hashed:
        contents = []
        for b in hashed:
            with fs._bread(b) as bh:
                contents.append(bytes(bh.data()))
        for b, h in zip(hashed, fs.ks.checksum_batch(contents)):
            assert store.refcnt.get(b, 0) > 0, \
                f"valid hash on unreferenced block {b}"
            assert h == store.hashval[b], f"stale hash on block {b}"
    rec.view.statfs()
    rec.view.listdir("/")


def torture_dedup(kind: str = "xv6", *, quick: bool = False) -> int:
    """Sweep a dup-heavy write → CoW overwrite → unlink sequence on a
    dedup mount and run the refcount-exact audit (``_dedup_audit``) after
    power loss at every device write. The workload crosses every index
    transition: fresh tracking, sharing (dedup hit), a copy-on-write
    break of a shared block, and reference release down to a physical
    free — each staged in the same journal transaction as its cause, so
    the recovered index can never drift from the recovered metadata."""
    D, U = b"D" * 4096, b"u" * 4096

    def setup(ctx: CrashCtx) -> None:
        ctx.view.write_file("/base", D * 2)  # durable dup source

    def workload(ctx: CrashCtx) -> None:
        v = ctx.view
        v.write_file("/c1", D * 2)       # full dup: shares with /base
        v.fsync("/c1")
        v.write_file("/c2", D + U)       # half dup, half unique
        v.fsync("/c2")
        v.write_file("/c1", b"X" * 4096, off=0, create=False)  # CoW break
        v.fsync("/c1")
        v.unlink("/c2")                  # shared ref drops, unique frees
        v.fsync("/base")

    sim = CrashSim(_dedup_factory(kind))
    return sim.sweep(workload, _dedup_audit, setup=setup, quick=quick)


def torture_dedup_churn(kind: str = "xv6", *, quick: bool = False) -> int:
    """Sweep sustained create/delete churn that drives the dedup index
    through COMPACTION (a fully-dead table block punched back to the
    allocator) and REMATERIALIZATION (a record landing on the punched
    hole), with the refcount-exact audit at every power-loss point.

    Geometry: one table block maps 512 consecutive data blocks, so the
    durable setup probes where allocation currently sits (root dir and
    the index file itself claim the first few data blocks) and plants a
    distinct-block filler that UNDERSHOOTS table block 0's record range
    by a small margin — metadata blocks (indirects, dir growth) carry no
    refcount and never keep a table block alive, so exact alignment is
    unnecessary. The workload's churn file then spans the boundary into
    table block 1 and is the only thing live there, so emptying it
    (punch fires inside the unlink transaction) and re-writing across
    the boundary (remat fires inside the write transaction) exercise
    both transitions. Every block's content is unique — self-dedup
    would collapse the ranges. The golden run asserts both transitions
    actually happen — a sweep that never compacts proves nothing."""
    per_blk = 4096 // 8  # records per table block (_REC_SIZE == 8)

    def _blocks(tag: int, n: int) -> bytes:
        # n blocks, each 4096B of globally-unique content (no self-dedup)
        return b"".join((tag + i).to_bytes(4, "big") * 1024
                        for i in range(n))

    filler_len = [0]

    def setup(ctx: CrashCtx) -> None:
        v, store = ctx.view, ctx.fs._blockstore
        v.write_file("/probe", _blocks(9 << 24, 1))
        v.fsync("/probe")
        idx = max(store.refcnt) - ctx.fs.geo.datastart
        filler_len[0] = per_blk - 1 - idx - 16  # 16-record undershoot
        v.write_file("/filler", _blocks(0, filler_len[0]))
        v.fsync("/filler")

    def workload(ctx: CrashCtx) -> None:
        v = ctx.view
        v.write_file("/churn", _blocks(1 << 16, 96))  # spans into block 1
        v.fsync("/churn")
        v.unlink("/churn")                  # last live records die: punch
        v.fsync("/filler")
        v.write_file("/re", _blocks(2 << 16, 64))  # back into hole: remat
        v.fsync("/re")

    sim = CrashSim(_dedup_factory(kind), n_blocks=2048, nlog=64)
    # prove the golden run crosses both transitions
    ctx = sim.boot(setup)
    workload(ctx)
    st = ctx.fs._blockstore.stats
    assert st["compactions"] > 0, "churn workload never compacted"
    assert st["remats"] > 0, "churn workload never rematerialized"

    def invariant(rec: Recovered) -> None:
        _dedup_audit(rec)
        assert rec.view.read_file("/filler") == _blocks(0, filler_len[0])

    return sim.sweep(workload, invariant, setup=setup, quick=quick)


# --- parallel-drain torture: sharded lock domains vs the serial drain -------------


def torture_parallel(kind: str = "xv6", *, quick: bool = False,
                     dedup: bool = False, workers: int = 4) -> int:
    """The tentpole's proof: drive a multi-submitter drain — one mutating
    submitter (a linked create→write→fsync chain) plus three read-only
    submitters on disjoint inode stripes — through the footprint-scheduled
    PARALLEL executor at every power-loss point, and require that

    * the recovered device image is BYTE-IDENTICAL to the serial drain's
      at the same crash point (mutations are ALLOC-serialized and reads
      write nothing, so the device write stream — and therefore every
      crash point — must be exactly the serial drain's), and
    * the chain stays all-or-nothing and the read targets stay intact,
      under both executors.

    ``dedup=True`` runs the same sweep on a dedup mount, where every
    footprint carries the BLOCKSTORE domain — the degenerate
    fully-serialized schedule must ALSO match the serial drain."""
    import concurrent.futures as _cf

    from repro.core.interface import (PrevResult, SQE_LINK, SubmissionEntry,
                                      execute_multi_batch)

    payload = b"P" * (2 * 4096 + 9)
    seed = b"r" * (4096 + 11)

    def setup(ctx: CrashCtx) -> None:
        for i in range(4):
            ctx.view.write_file(f"/r{i}", seed)

    def make_workload(pool):
        def run(ctx: CrashCtx) -> None:
            inos = [ctx.view.stat(f"/r{i}").ino for i in range(4)]
            mut = [
                SubmissionEntry("create", (1, "f"), user_data="c",
                                flags=SQE_LINK),
                SubmissionEntry("write", (PrevResult("ino"), 0, payload),
                                user_data="w", flags=SQE_LINK),
                SubmissionEntry("fsync", (PrevResult("ino", back=2),),
                                user_data="s"),
            ]
            readers = [[SubmissionEntry("read", (ino, 0, len(seed)))
                        for ino in inos] for _ in range(3)]
            segs = execute_multi_batch(ctx.fs.submit_batch, [mut] + readers,
                                       pool=pool)
            bad = [(c.user_data, c.errno) for c in segs[0] if not c.ok]
            assert not bad, f"chain failed without a crash: {bad}"
            for seg in segs[1:]:
                for c in seg:
                    assert c.ok and c.result == seed, "reader saw bad data"
        return run

    factory = _dedup_factory(kind) if dedup else _fs_factory(kind)
    sim = CrashSim(factory)
    serial, chk = make_workload(None), all_or_nothing(payload)
    total = sim.measure(serial, setup=setup)
    points = quick_points(total) if quick else range(total + 1)
    pool = _cf.ThreadPoolExecutor(max_workers=workers)
    try:
        parallel = make_workload(pool)
        for point in points:
            rp = sim.run_one(parallel, point, total=total, setup=setup)
            rs = sim.run_one(serial, point, total=total, setup=setup)
            try:
                assert rp.crashed == rs.crashed, \
                    f"crash divergence: par={rp.crashed} ser={rs.crashed}"
                assert (rp.dev._data.tobytes() == rs.dev._data.tobytes()), \
                    "parallel drain produced a different device image"
                for rec in (rp, rs):
                    chk(rec)
                    for i in range(4):
                        assert rec.view.read_file(f"/r{i}") == seed, \
                            f"/r{i} damaged by a concurrent-domain drain"
            except AssertionError as e:
                raise AssertionError(
                    f"parallel-drain invariant violated at crash point "
                    f"{point}/{total}: {e}") from e
    finally:
        pool.shutdown(wait=False)
    return len(list(points))


# --- lazy-materialization + overlay tortures (repro.fs.blockdev / .overlay) ------


def _golden_image(kind: str, populate, *, n_blocks: int = 2048,
                  ninodes: int = 256, nlog: int = 32) -> MemBlockDevice:
    """A formatted, populated, CLEANLY unmounted image at the CrashSim
    geometry — the provider a ``LazyBlockDevice`` fetches from. The clean
    unmount matters: a provider image must never need recovery writes."""
    dev = MemBlockDevice(n_blocks)
    ks = kernel_binding(dev)
    mkfs(ks, ninodes=ninodes, nlog=nlog)
    fs = _fs_factory(kind)()
    fs.init(ks.superblock(), ks)
    m = DirectMount(fs)
    populate(PosixView(m))
    m.unmount()
    return dev


def torture_lazy(kind: str = "xv6", *, quick: bool = False) -> int:
    """Sweep a read-then-mutate workload on an fs mounted directly ON a
    ``LazyBlockDevice`` over a golden image, with power loss at every
    LOCAL device write — which includes both halves of the 2-step
    materialization protocol (data landing, valid-bit commit), so crash
    points land BETWEEN them. The invariant: a half-materialized block is
    NEVER visible — after remounting the SAME device (local store and
    valid bitmap survive, like a disk), base content reads back exactly
    (invalid blocks re-fetch from the provider), the mutation chain stays
    all-or-nothing, and the provider image is never written."""
    base_payload = b"G" * (3 * 4096 + 41)

    def populate(view: PosixView) -> None:
        view.write_file("/base", base_payload)

    image = _golden_image(kind, populate)
    image_writes0 = image.writes
    image_bytes0 = image._data.tobytes()

    new_payload = b"L" * (2 * 4096 + 17)
    run_chain = chain_workload(new_payload)

    def workload(ctx: CrashCtx) -> None:
        # the read MATERIALIZES /base's blocks: counted local writes, so
        # the sweep enumerates power loss inside the fetch protocol
        got = ctx.view.read_file("/base")
        assert got == base_payload, "golden read failed without a crash"
        run_chain(ctx)

    chk = all_or_nothing(new_payload)

    def invariant(rec: Recovered) -> None:
        assert isinstance(rec.dev, LazyBlockDevice)
        got = rec.view.read_file("/base")
        assert got == base_payload, (
            f"half-materialized base content visible: /base has "
            f"{len(got)}B, {sum(a != b for a, b in zip(got, base_payload))}"
            f" bytes differ")
        chk(rec)
        assert image.writes == image_writes0, \
            "the provider image took a write"

    sim = CrashSim(
        _fs_factory(kind), format_device=False,
        device_factory=lambda: LazyBlockDevice(
            image, n_blocks=image.n_blocks, device_id="lazy-torture"))
    n = sim.sweep(workload, invariant, quick=quick)
    assert image._data.tobytes() == image_bytes0, \
        "the provider image was dirtied during the sweep"
    return n


def torture_overlay(kind: str = "xv6", *, quick: bool = False) -> int:
    """Sweep the overlay-specific multi-step mutations — whiteout,
    create-over-whiteout, copy-up overwrite, copy-up + rename — on a CoW
    tenant (writable upper, lazy immutable base) with power loss at every
    UPPER device write. At every point the merged view must show each
    name old-XOR-new (a deleted base name never resurrects half-way, a
    copied-up file is never torn between base and upper content, a
    renamed name never exists on both sides), no copy-up temp file is
    ever visible, and the shared base image stays byte-identical."""
    from repro.fs.mounts import build_base_image
    from repro.fs.overlay import COWTMP_PREFIX, OverlayFilesystem, \
        OverlayOptions

    image = build_base_image(kind, n_blocks=2048)
    image_writes0 = image.writes
    image_bytes0 = image._data.tobytes()

    BASE_MOTD = b"welcome to the base image\n"
    BASE_HOST = b"golden\n"
    BASE_README = b"base readme\n"
    BASE_WORDS = b"alpha beta gamma delta\n" * 64
    NEW_MOTD = b"tenant motd, reborn over the whiteout\n"
    NEW_HOST = b"tenant-hostname-longer-than-the-golden-one\n"

    def factory():
        lazy = LazyBlockDevice(image, n_blocks=image.n_blocks,
                               device_id="lazy-base", immutable_base=True)
        return OverlayFilesystem(OverlayOptions(kind=kind, base_dev=lazy))

    def workload(ctx: CrashCtx) -> None:
        v = ctx.view
        v.unlink("/etc/motd")                   # whiteout over a base name
        v.write_file("/etc/motd", NEW_MOTD)     # create over the whiteout
        v.write_file("/etc/hostname", NEW_HOST)  # copy-up overwrite
        v.rename("/readme", "/readme2")         # copy-up + move + whiteout
        ctx.fs.flush()

    def invariant(rec: Recovered) -> None:
        v = rec.view
        # unlink → recreate: base content XOR gone XOR empty-new XOR new
        # (write_file is create-then-write, so the fresh empty file is a
        # legal intermediate; a torn HYBRID of base and new is not)
        if v.exists("/etc/motd"):
            motd = v.read_file("/etc/motd")
            assert motd in (BASE_MOTD, b"", NEW_MOTD), \
                f"torn whiteout/recreate: /etc/motd = {motd!r}"
        else:
            assert rec.crashed, "no crash, yet /etc/motd is missing"
        # copy-up overwrite is ONE transaction: old XOR new content
        host = v.read_file("/etc/hostname")
        assert host in (BASE_HOST, NEW_HOST), \
            f"half-copied-up file visible: /etc/hostname = {host!r}"
        # copy-up + rename + source whiteout is ONE transaction: exactly
        # one of the two names resolves, with the COMPLETE content
        src, dst = v.exists("/readme"), v.exists("/readme2")
        assert src != dst, (
            "rename not atomic: /readme and /readme2 " +
            ("both visible" if src else "both missing"))
        assert v.read_file("/readme2" if dst else "/readme") == BASE_README
        if not rec.crashed:  # control: every step must be durable
            assert dst and motd == NEW_MOTD and host == NEW_HOST, \
                "no crash, yet the workload's end state is not visible"
        # a half-copied-up temp name must never appear in any listing
        for d in ("/", "/etc"):
            tmp = [n for n in v.listdir(d) if n.startswith(COWTMP_PREFIX)]
            assert not tmp, f"copy-up temp visible in {d}: {tmp}"
        # untouched base names still merge intact (re-fetch path)
        assert v.read_file("/usr/share/words") == BASE_WORDS
        # the shared base image is immutable — never even one write
        assert image.writes == image_writes0, "base image took a write"
        assert image._data.tobytes() == image_bytes0, "base image dirtied"
        v.statfs()

    sim = CrashSim(factory, nlog=64)
    return sim.sweep(workload, invariant, quick=quick)


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="bounded crash-point subset (CI smoke)")
    ap.add_argument("--kind", default="both",
                    choices=["xv6", "ext4like", "both"])
    ap.add_argument("--payload-blocks", type=int, default=2)
    ap.add_argument("--fuse", action="store_true",
                    help="also torture the FUSE daemon's file-backed "
                         "device (subprocess per point — slower)")
    ap.add_argument("--torn-bytes", type=int, default=-1,
                    help="with --fuse: tear the dying write after this "
                         "many bytes instead of losing it whole")
    ap.add_argument("--dedup", action="store_true",
                    help="also torture the content-addressed dedup plane "
                         "(refcount-exact index audit at every point) and "
                         "the index compaction/remat path under churn")
    ap.add_argument("--no-parallel", action="store_true",
                    help="skip the parallel-drain differential sweep")
    ap.add_argument("--lazy", action="store_true",
                    help="also torture the lazy-materialization protocol "
                         "(power loss inside the 2-step block fetch)")
    ap.add_argument("--overlay", action="store_true",
                    help="also torture CoW overlay tenants (whiteouts, "
                         "copy-up, rename — old-XOR-new at every point)")
    ap.add_argument("--ckpt", action="store_true",
                    help="also torture the v2 sharded checkpoint re-save "
                         "(old XOR complete-new at every point, shard "
                         "files and checksums included)")
    args = ap.parse_args()
    kinds = ["xv6", "ext4like"] if args.kind == "both" else [args.kind]
    mode = "quick subset" if args.quick else "exhaustive"
    for kind in kinds:
        n = torture_chain(kind, payload_blocks=args.payload_blocks,
                          quick=args.quick)
        print(f"crashsim {kind}: create→write(PrevResult)→fsync chain "
              f"all-or-nothing at {n} crash points ({mode}) — OK")
        n = torture_rename(kind, quick=args.quick)
        print(f"crashsim {kind}: rename-overwrite old-XOR-new (+blocks "
              f"freed) at {n} crash points ({mode}) — OK")
        n = torture_prov(kind, quick=args.quick)
        print(f"crashsim {kind}: provenance log explains the recovered fs "
              f"at {n} crash points ({mode}) — OK")
        n = torture_prov_chain(kind, quick=args.quick)
        print(f"crashsim {kind}: chain txn spans data + provenance records "
              f"at {n} crash points ({mode}) — OK")
        if not args.no_parallel:
            n = torture_parallel(kind, quick=args.quick)
            print(f"crashsim {kind}: parallel drain byte-identical to "
                  f"serial at {n} crash points ({mode}) — OK")
        if args.lazy:
            n = torture_lazy(kind, quick=args.quick)
            print(f"crashsim {kind}: no half-materialized block visible, "
                  f"provider untouched at {n} crash points ({mode}) — OK")
        if args.overlay:
            n = torture_overlay(kind, quick=args.quick)
            print(f"crashsim {kind}: overlay whiteout/copy-up/rename "
                  f"old-XOR-new at {n} crash points ({mode}) — OK")
        if args.ckpt:
            n = torture_ckpt_shards(kind, quick=args.quick)
            print(f"crashsim {kind}: sharded checkpoint re-save old-XOR-"
                  f"complete-new at {n} crash points ({mode}) — OK")
        if args.dedup:
            n = torture_dedup(kind, quick=args.quick)
            print(f"crashsim {kind}: dedup index refcount-exact (+no "
                  f"leaks, hashes fresh) at {n} crash points ({mode}) — OK")
            n = torture_dedup_churn(kind, quick=args.quick)
            print(f"crashsim {kind}: index compaction punch + remat under "
                  f"churn at {n} crash points ({mode}) — OK")
            n = torture_parallel(kind, quick=args.quick, dedup=True)
            print(f"crashsim {kind}: dedup-mount parallel drain matches "
                  f"serial at {n} crash points ({mode}) — OK")
    if args.fuse:
        n = torture_fuse(quick=True, torn_bytes=args.torn_bytes)
        torn = (f", torn at {args.torn_bytes}B" if args.torn_bytes >= 0
                else "")
        print(f"crashsim fuse: daemon-side chain all-or-nothing at {n} "
              f"crash points (quick subset{torn}) — OK")


if __name__ == "__main__":
    main()
