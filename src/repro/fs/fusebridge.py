"""FUSE baseline: the file system in a separate *daemon process*
("userspace"), every operation marshalled over a unix socket — a real
address-space crossing with real serialization cost, not a simulated sleep.

Mirrors the paper's FUSE setup: the same fs code, userspace services
binding (file-backed block device, whole-file fsync — the paper's "no way
to sync parts of a file" penalty), and per-operation request/response
messages through the kernel boundary (here: a unix socket with
length-prefixed pickle frames + a context switch per op).

The daemon is a plain ``subprocess`` running ``python -m
repro.fs.fusebridge`` — no multiprocessing fork/spawn games, so it is safe
to start from a multithreaded JAX parent.

Multi-submitter: each client THREAD gets its own channel (socket
connection), so submissions from many threads are in flight at once, and
the daemon drains every channel with a readable ``submit_batch`` request
per service round into ONE ``execute_multi_batch`` call — the SQPOLL-style
drain of ``repro.core.registry``, carried across the address-space
boundary. Chains stay within their channel's submission; unchained runs
coalesce across channels into the fs's vectorized paths. Scalar ops ride
the same per-thread channels (multi-queue /dev/fuse): a service round
collects every readable channel's scalar request, so N scalar callers
no longer serialize behind one connection's request/response turn.

Crash torture: a ``__ctl__`` side-channel arms write-stream fault
injection in the daemon's FileBlockDevice (power loss after the Nth
device write, optionally tearing the dying write mid-block), and
``FuseMount.kill()`` is the power-cut analogue — SIGKILL, no flush, the
backing file left exactly as the last completed write left it. Remounting
with ``reuse=True`` skips mkfs so daemon-side journal recovery runs
against the survived image (see ``repro.fs.crashsim.FuseCrashSim``).
"""

from __future__ import annotations

import os
import pickle
import selectors
import socket
import struct
import subprocess
import sys
import tempfile
import threading
import time
from typing import Any, List, Optional

from repro.core.interface import (Errno, FS_OPS as _FS_OPS, FsError,
                                  execute_multi_batch)


def _send(sock: socket.socket, obj: Any) -> None:
    raw = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    sock.sendall(struct.pack("<I", len(raw)) + raw)


def _recv(sock: socket.socket) -> Any:
    hdr = _recv_exact(sock, 4)
    (n,) = struct.unpack("<I", hdr)
    return pickle.loads(_recv_exact(sock, n))


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise EOFError("fuse daemon connection closed")
        buf += chunk
    return buf


def _send_quiet(sock: socket.socket, obj: Any) -> None:
    """Best-effort reply: a channel whose client vanished mid-drain must
    not take the daemon (and every other channel) down with it. A reply
    that won't SERIALIZE (an op returning an unpicklable object) is a
    programming error on the daemon side — before this guard it
    propagated out of the service loop and killed every channel; now the
    client gets an ``error`` frame naming the failure instead of EOF."""
    try:
        _send(sock, obj)
    except OSError:
        pass
    except Exception as e:  # noqa: BLE001 — pickle/struct failures
        _log_exc(f"unserializable reply ({type(obj).__name__})")
        try:
            _send(sock, ("error", f"unserializable daemon reply: "
                                  f"{type(e).__name__}: {e}"))
        except Exception:  # noqa: BLE001 — client gone too: nothing owed
            pass


def _log_exc(context: str) -> None:
    """Daemon-side error log: programming errors are NEVER swallowed
    silently — the traceback lands on stderr (the client holds the pipe),
    and the offending channel is failed, not the whole daemon."""
    import traceback

    print(f"fusebridge: {context}", file=sys.stderr)
    traceback.print_exc(file=sys.stderr)
    sys.stderr.flush()


def _make_fs(fs_kind: str, opts):
    """Module factory for the daemon's mount matrix. ``prov-<kind>``
    wraps the base fs in the provenance layer at mount time (the
    re-mount/crash-recovery path; live swaps go through the ``wrap_prov``
    ctl instead); ``dedup-<kind>`` enables the content-addressed
    blockstore (prefixes compose: ``prov-dedup-xv6``)."""
    import dataclasses as _dc

    from repro.fs.ext4like import Ext4LikeFileSystem
    from repro.fs.prov import ProvFilesystem
    from repro.fs.xv6 import Xv6FileSystem

    base_kind = fs_kind[len("prov-"):] if fs_kind.startswith("prov-") \
        else fs_kind
    if base_kind.startswith("dedup-"):
        base_kind = base_kind[len("dedup-"):]
        opts = _dc.replace(opts, dedup=True)
    fs = (Ext4LikeFileSystem(opts) if base_kind == "ext4like"
          else Xv6FileSystem(opts))
    return ProvFilesystem(fs) if fs_kind.startswith("prov-") else fs


def _swap_module(ks, state, new_fs) -> dict:
    """Daemon-side hot swap: the single-threaded service loop IS the op
    gate (a ctl request is never concurrent with a drain), so the swap is
    extract → init → restore → install, same protocol as
    ``repro.core.upgrade`` behind the real gate. Returns the measured
    pause — the daemon's analogue of the upgrade timing stats."""
    import time as _time

    from repro.core.upgrade import _extracted_state

    old = state["fs"]
    t0 = _time.perf_counter()
    st = _extracted_state(old, new_fs, None, True)
    new_fs.init(ks.superblock(), ks)
    new_fs.restore_state(st, old.VERSION)
    state["fs"] = new_fs
    state["generation"] += 1
    old.destroy()
    return {"pause_s": _time.perf_counter() - t0,
            "generation": state["generation"],
            "module": type(new_fs).__name__}


def _handle_ctl(dev, stats, ks, state, args) -> Any:
    """The daemon side-channel: crash-torture fault injection, drain
    counters, and the live provenance wrap/unwrap (values only — the
    client never touches daemon objects)."""
    from repro.core.upgrade import _fresh_like
    from repro.fs.prov import ProvFilesystem

    cmd = args[0]
    if cmd == "fail_after_writes":
        dev.fail_after_writes = int(args[1])
        dev.fail_torn_bytes = int(args[2]) if len(args) > 2 else -1
        dev._writes_seen = 0
        return None
    if cmd == "writes_seen":
        return dev._writes_seen
    if cmd == "stats":
        return dict(stats, generation=state["generation"],
                    module=type(state["fs"]).__name__)
    if cmd == "generation":
        return state["generation"]
    if cmd == "wrap_prov":
        old = state["fs"]
        if isinstance(old, ProvFilesystem):
            raise FsError(Errno.EEXIST, "provenance layer already mounted")
        return _swap_module(ks, state, ProvFilesystem(_fresh_like(old)))
    if cmd == "unwrap_prov":
        old = state["fs"]
        if getattr(old, "inner", None) is None:
            raise FsError(Errno.EINVAL, "no layer to unwrap")
        return _swap_module(ks, state, _fresh_like(old.inner))
    raise FsError(Errno.EINVAL, f"unknown ctl {cmd!r}")


def serve(sock_path: str, backing_path: str, n_blocks: int, fs_kind: str,
          do_mkfs: bool = True) -> None:
    """Daemon main: userspace binding + the same fs code, serving any
    number of client channels. ``do_mkfs=False`` remounts an existing
    image (journal recovery runs in the fs's init)."""
    from repro.core.services import userspace_binding
    from repro.fs.blockdev import FileBlockDevice
    from repro.fs.xv6 import Xv6Options, mkfs

    dev = FileBlockDevice(backing_path, n_blocks)
    ks = userspace_binding(dev)
    if do_mkfs:
        mkfs(ks)
    # userspace policy: synchronous installs, whole-file fsync
    opts = Xv6Options(group_commit=True, batched_install=False)
    fs = _make_fs(fs_kind, opts)
    fs.init(ks.superblock(), ks)
    # the live module rides in a holder so the wrap/unwrap ctl can swap it
    # between service rounds (the loop is the gate: no request in flight)
    state = {"fs": fs, "generation": 1}

    # drain observability (read via __ctl__ "stats"): drains counts service
    # rounds that executed submit_batch traffic, batch_requests the client
    # submissions they carried — requests ≫ drains is the multi-channel win.
    # scalar_requests counts one-op calls the same way (they ride per-thread
    # channels too), multi_channel_scalar_rounds the service rounds that
    # collected scalars from more than one channel at once.
    stats = {"drains": 0, "batch_requests": 0, "multi_channel_drains": 0,
             "scalar_requests": 0, "multi_channel_scalar_rounds": 0}

    srv = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    srv.bind(sock_path)
    srv.listen(64)
    sel = selectors.DefaultSelector()
    sel.register(srv, selectors.EVENT_READ)
    channels: List[socket.socket] = []
    shutdown = False

    def drop(conn):
        try:
            sel.unregister(conn)
        except (KeyError, ValueError):
            pass  # already failed earlier this round
        conn.close()
        if conn in channels:
            channels.remove(conn)

    try:
        while not shutdown:
            events = sel.select(timeout=1.0)
            batch_reqs = []   # (conn, entries): drained together this round
            scalar_reqs = []  # (conn, op, args, kw): served one at a time
            for key, _ in events:
                if key.fileobj is srv:
                    conn, _ = srv.accept()
                    sel.register(conn, selectors.EVENT_READ)
                    channels.append(conn)
                    continue
                conn = key.fileobj
                try:
                    msg = _recv(conn)
                except (EOFError, OSError):
                    drop(conn)
                    continue
                except Exception:  # noqa: BLE001 — poisoned frame
                    # an undecodable frame used to propagate OUT of the
                    # service loop and kill the daemon — every other
                    # channel died with an unexplained EOF. Fail only the
                    # channel that sent the poison.
                    _log_exc("undecodable frame — failing the channel")
                    _send_quiet(conn, ("error", "undecodable request "
                                                "frame — channel failed"))
                    drop(conn)
                    continue
                if msg is None:
                    shutdown = True
                    break
                try:
                    op, args, kw = msg
                except (TypeError, ValueError):
                    _log_exc(f"malformed request {type(msg).__name__} — "
                             "failing the channel")
                    _send_quiet(conn, ("error", "malformed request (want "
                                                "(op, args, kw)) — "
                                                "channel failed"))
                    drop(conn)
                    continue
                if op == "submit_batch":
                    batch_reqs.append((conn, args[0]))
                else:
                    scalar_reqs.append((conn, op, args, kw))
            if batch_reqs:
                # ONE boundary crossing for every channel's pending
                # submission: chains grouped per channel, cancellation and
                # PrevResult substitution daemon-side, so a chained batch
                # still costs its channel one round trip.
                stats["drains"] += 1
                stats["batch_requests"] += len(batch_reqs)
                if len(batch_reqs) > 1:
                    stats["multi_channel_drains"] += 1
                try:
                    segs = execute_multi_batch(
                        state["fs"].submit_batch,
                        [ents for _, ents in batch_reqs])
                except FsError as e:
                    # whole-drain refusal (reservation/validation): a real
                    # errno every submitter understands — channels live on
                    for conn, _ in batch_reqs:
                        _send_quiet(conn, ("fs_error", int(e.errno)))
                except Exception as e:  # noqa: BLE001 — programming error
                    # NOT an fs refusal: daemon-side state may be torn
                    # mid-drain. Log it, surface it to every involved
                    # client, then FAIL those channels — continuing to
                    # serve them would pretend the drain half-happened.
                    _log_exc("programming error in multi-batch drain — "
                             "failing the involved channels")
                    for conn, _ in batch_reqs:
                        _send_quiet(conn, ("error",
                                           f"{type(e).__name__}: {e}"))
                        drop(conn)
                else:
                    if any(e.op in ("fsync", "flush")
                           for _, ents in batch_reqs for e in ents):
                        dev.sync()  # whole-file sync penalty, once per drain
                    for (conn, _), comps in zip(batch_reqs, segs):
                        _send_quiet(conn, ("ok", comps))
            if scalar_reqs:
                stats["scalar_requests"] += len(scalar_reqs)
                if len({id(c) for c, _, _, _ in scalar_reqs}) > 1:
                    stats["multi_channel_scalar_rounds"] += 1
            for conn, op, args, kw in scalar_reqs:
                try:
                    if op == "__ctl__":
                        _send_quiet(conn, ("ok", _handle_ctl(dev, stats, ks,
                                                             state, args)))
                        continue
                    if op == "fsync":
                        # paper: the file interface can't sync parts of a
                        # file — the whole backing file syncs per fsync.
                        state["fs"].journal.commit()
                        dev.sync()
                        _send_quiet(conn, ("ok", None))
                        continue
                    res = getattr(state["fs"], op)(*args, **kw)
                    _send_quiet(conn, ("ok", res))
                except FsError as e:
                    _send_quiet(conn, ("fs_error", int(e.errno)))
                except Exception as e:  # noqa: BLE001 — programming error
                    # narrow contract: FsError -> errno above; anything
                    # else is a bug (unknown op, bad arg types, daemon
                    # state corruption). Log the traceback, surface it to
                    # the caller, and fail the channel — the old handler
                    # replied "error" and kept serving a connection whose
                    # op may have half-applied.
                    _log_exc(f"programming error in scalar op {op!r} — "
                             "failing the channel")
                    _send_quiet(conn, ("error", f"{type(e).__name__}: {e}"))
                    drop(conn)
    finally:
        try:
            state["fs"].destroy()
            dev.close()
        except Exception:  # noqa: BLE001 — teardown after injected crash
            pass
        for conn in channels:
            conn.close()
        srv.close()


class FuseMount:
    """Client-side mount handle: same call surface as core.registry.Mount.

    Scalar calls AND ``submit`` both ride a per-THREAD channel (the
    multi-queue /dev/fuse clone of the multi-submitter design), so
    concurrent scalar callers stop funneling through one connection:
    each thread has one request in flight on its own socket and the
    daemon collects every readable channel per service round
    (``mq_submissions`` counts this client's submissions — daemon-side
    drain/scalar counts come back via ``ctl("stats")``). The primary
    socket opened at mount is reserved for the shutdown sentinel."""

    def __init__(self, n_blocks: int = 16384, fs_kind: str = "xv6",
                 backing_path: Optional[str] = None, reuse: bool = False):
        self._tmpdir = tempfile.mkdtemp(prefix="fusebridge_")
        if backing_path is None:
            backing_path = os.path.join(self._tmpdir, "disk.img")
        self.backing_path = backing_path
        sock_path = os.path.join(self._tmpdir, "fuse.sock")
        self._sock_path = sock_path
        env = dict(os.environ)
        src_root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
        self._proc = subprocess.Popen(
            [sys.executable, "-m", "repro.fs.fusebridge", sock_path,
             backing_path, str(n_blocks), fs_kind,
             "reuse" if reuse else "mkfs"],
            env=env, stdout=subprocess.DEVNULL, stderr=subprocess.PIPE)
        self._sock = self._connect(deadline_s=30)
        self.generation = 1
        self.name = f"fuse-{fs_kind}"
        self._tls = threading.local()
        self._channels: List[socket.socket] = [self._sock]
        self._chan_lock = threading.Lock()
        self.mq_submissions = 0

    def _connect(self, deadline_s: float) -> socket.socket:
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        deadline = time.time() + deadline_s
        while True:
            try:
                sock.connect(self._sock_path)
                return sock
            except (FileNotFoundError, ConnectionRefusedError):
                if self._proc.poll() is not None:
                    err = self._proc.stderr.read().decode()[-2000:]
                    raise RuntimeError(f"fuse daemon died at startup: {err}")
                if time.time() > deadline:
                    raise TimeoutError("fuse daemon did not come up")
                time.sleep(0.02)

    def _channel(self) -> socket.socket:
        """This thread's private daemon connection (created on first
        use): the per-thread SQ of the multi-submitter design, carried
        over the address-space boundary. Scalar ops and submissions
        share it — one in-flight request per thread by construction, so
        no lock is needed."""
        ch = getattr(self._tls, "ch", None)
        if ch is None:
            ch = self._connect(deadline_s=10)
            with self._chan_lock:
                self._channels.append(ch)
            self._tls.ch = ch
        return ch

    def call(self, op: str, *args, **kw) -> Any:
        ch = self._channel()
        _send(ch, (op, args, kw))
        status, payload = _recv(ch)
        if status == "ok":
            return payload
        if status == "fs_error":
            raise FsError(Errno(payload))
        raise RuntimeError(payload)

    def ctl(self, *args) -> Any:
        """Crash-torture side-channel (see ``_handle_ctl``): e.g.
        ``ctl("fail_after_writes", n, torn_bytes)`` / ``ctl("stats")``."""
        return self.call("__ctl__", *args)

    def wrap_prov(self) -> Any:
        """Hot-swap the provenance layer onto the daemon's live fs — the
        paper's §6 demo carried across the address-space boundary. The
        swap lands between two service rounds (never mid-drain) and the
        returned dict reports the daemon-side pause. Bumps
        ``generation`` like the in-process upgrade does."""
        res = self.ctl("wrap_prov")
        self.generation = res["generation"]
        return res

    def unwrap_prov(self) -> Any:
        """Strip the daemon's provenance layer (the reverse demo)."""
        res = self.ctl("unwrap_prov")
        self.generation = res["generation"]
        return res

    def submit(self, entries):
        # The batched boundary is where FUSE hurts least: one socket
        # round-trip (two context switches) per submission — and when many
        # threads submit at once, the daemon serves all their channels in
        # one drain. Per-entry errors ride inside the completions, so the
        # daemon's fs_error path is never taken for a batch.
        ch = self._channel()
        self.mq_submissions += 1
        _send(ch, ("submit_batch", (list(entries),), {}))
        status, payload = _recv(ch)
        if status == "ok":
            return payload
        if status == "fs_error":
            raise FsError(Errno(payload))
        raise RuntimeError(payload)

    def __getattr__(self, op: str):
        if op in _FS_OPS:
            return lambda *a, **k: self.call(op, *a, **k)
        raise AttributeError(op)

    def _close_channels(self) -> None:
        with self._chan_lock:
            for ch in self._channels:
                try:
                    ch.close()
                except OSError:
                    pass
            self._channels.clear()

    def _cleanup_tmpdir(self, keep_backing: bool = False) -> None:
        for f in ("disk.img", "fuse.sock"):
            p = os.path.join(self._tmpdir, f)
            if os.path.exists(p) and not (keep_backing and f == "disk.img"):
                os.unlink(p)
        try:
            os.rmdir(self._tmpdir)
        except OSError:
            pass  # backing file kept inside: leave the dir for its owner

    def unmount(self) -> None:
        try:
            self.call("flush")
            _send(self._sock, None)
        except (BrokenPipeError, EOFError, OSError):
            pass
        self._close_channels()
        try:
            self._proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            self._proc.terminate()
        self._cleanup_tmpdir()

    def kill(self) -> None:
        """Power-cut analogue: SIGKILL the daemon — no flush, no graceful
        shutdown — leaving the backing file exactly as the last completed
        device write left it. The socket tempdir is cleaned; the backing
        file survives for a ``reuse=True`` remount (crash torture)."""
        self._proc.kill()
        try:
            self._proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            pass
        self._close_channels()
        self._cleanup_tmpdir(keep_backing=True)


if __name__ == "__main__":
    serve(sys.argv[1], sys.argv[2], int(sys.argv[3]), sys.argv[4],
          do_mkfs=(len(sys.argv) < 6 or sys.argv[5] != "reuse"))
