"""FUSE baseline: the file system in a separate *daemon process*
("userspace"), every operation marshalled over a unix socket — a real
address-space crossing with real serialization cost, not a simulated sleep.

Mirrors the paper's FUSE setup: the same fs code, userspace services
binding (file-backed block device, whole-file fsync — the paper's "no way
to sync parts of a file" penalty), and per-operation request/response
messages through the kernel boundary (here: a unix socket with
length-prefixed pickle frames + a context switch per op).

The daemon is a plain ``subprocess`` running ``python -m
repro.fs.fusebridge`` — no multiprocessing fork/spawn games, so it is safe
to start from a multithreaded JAX parent.
"""

from __future__ import annotations

import os
import pickle
import socket
import struct
import subprocess
import sys
import tempfile
import threading
import time
from typing import Any, Optional

from repro.core.interface import Errno, FsError, execute_batch

_FS_OPS = ("getattr", "lookup", "create", "mkdir", "unlink", "rmdir", "rename",
           "readdir", "read", "write", "truncate", "fsync", "flush", "statfs")


def _send(sock: socket.socket, obj: Any) -> None:
    raw = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    sock.sendall(struct.pack("<I", len(raw)) + raw)


def _recv(sock: socket.socket) -> Any:
    hdr = _recv_exact(sock, 4)
    (n,) = struct.unpack("<I", hdr)
    return pickle.loads(_recv_exact(sock, n))


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise EOFError("fuse daemon connection closed")
        buf += chunk
    return buf


def serve(sock_path: str, backing_path: str, n_blocks: int, fs_kind: str) -> None:
    """Daemon main: userspace binding + the same fs code."""
    from repro.core.services import userspace_binding
    from repro.fs.blockdev import FileBlockDevice
    from repro.fs.ext4like import Ext4LikeFileSystem
    from repro.fs.xv6 import Xv6FileSystem, Xv6Options, mkfs

    dev = FileBlockDevice(backing_path, n_blocks)
    ks = userspace_binding(dev)
    mkfs(ks)
    # userspace policy: synchronous installs, whole-file fsync
    opts = Xv6Options(group_commit=True, batched_install=False)
    fs = (Ext4LikeFileSystem(opts) if fs_kind == "ext4like"
          else Xv6FileSystem(opts))
    fs.init(ks.superblock(), ks)

    srv = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    srv.bind(sock_path)
    srv.listen(1)
    conn, _ = srv.accept()
    try:
        while True:
            try:
                msg = _recv(conn)
            except EOFError:
                break
            if msg is None:
                break
            op, args, kw = msg
            try:
                if op == "fsync":
                    # paper: the file interface can't sync parts of a file —
                    # the whole backing file is synced per fsync.
                    fs.journal.commit()
                    dev.sync()
                    _send(conn, ("ok", None))
                    continue
                if op == "submit_batch":
                    # chains (SQE_LINK) execute daemon-side: grouping,
                    # cancellation and PrevResult substitution all happen
                    # here, so a chained batch still costs ONE round trip.
                    res = execute_batch(fs.submit_batch, args[0])
                else:
                    res = getattr(fs, op)(*args, **kw)
                if op == "submit_batch" and any(
                        e.op in ("fsync", "flush") for e in args[0]):
                    dev.sync()  # same whole-file sync penalty, once per batch
                _send(conn, ("ok", res))
            except FsError as e:
                _send(conn, ("fs_error", int(e.errno)))
            except Exception as e:  # noqa: BLE001
                _send(conn, ("error", f"{type(e).__name__}: {e}"))
    finally:
        fs.destroy()
        dev.close()
        conn.close()
        srv.close()


class FuseMount:
    """Client-side mount handle: same call surface as core.registry.Mount."""

    def __init__(self, n_blocks: int = 16384, fs_kind: str = "xv6",
                 backing_path: Optional[str] = None):
        self._tmpdir = tempfile.mkdtemp(prefix="fusebridge_")
        if backing_path is None:
            backing_path = os.path.join(self._tmpdir, "disk.img")
        self.backing_path = backing_path
        sock_path = os.path.join(self._tmpdir, "fuse.sock")
        env = dict(os.environ)
        src_root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
        self._proc = subprocess.Popen(
            [sys.executable, "-m", "repro.fs.fusebridge", sock_path,
             backing_path, str(n_blocks), fs_kind],
            env=env, stdout=subprocess.DEVNULL, stderr=subprocess.PIPE)
        self._sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        deadline = time.time() + 30
        while True:
            try:
                self._sock.connect(sock_path)
                break
            except (FileNotFoundError, ConnectionRefusedError):
                if self._proc.poll() is not None:
                    err = self._proc.stderr.read().decode()[-2000:]
                    raise RuntimeError(f"fuse daemon died at startup: {err}")
                if time.time() > deadline:
                    raise TimeoutError("fuse daemon did not come up")
                time.sleep(0.02)
        self.generation = 1
        self.name = f"fuse-{fs_kind}"
        self._lock = threading.Lock()  # one in-flight request per channel

    def call(self, op: str, *args, **kw) -> Any:
        with self._lock:
            _send(self._sock, (op, args, kw))
            status, payload = _recv(self._sock)
        if status == "ok":
            return payload
        if status == "fs_error":
            raise FsError(Errno(payload))
        raise RuntimeError(payload)

    def submit(self, entries):
        # The batched boundary is where FUSE hurts least: one socket
        # round-trip (two context switches) per batch instead of per op.
        # Per-entry errors ride inside the completions, so the daemon's
        # fs_error path is never taken for a batch.
        return self.call("submit_batch", list(entries))

    def __getattr__(self, op: str):
        if op in _FS_OPS:
            return lambda *a, **k: self.call(op, *a, **k)
        raise AttributeError(op)

    def unmount(self) -> None:
        try:
            self.call("flush")
            _send(self._sock, None)
        except (BrokenPipeError, EOFError, OSError):
            pass
        self._sock.close()
        try:
            self._proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            self._proc.terminate()
        for f in ("disk.img", "fuse.sock"):
            p = os.path.join(self._tmpdir, f)
            if os.path.exists(p):
                os.unlink(p)
        os.rmdir(self._tmpdir)


if __name__ == "__main__":
    serve(sys.argv[1], sys.argv[2], int(sys.argv[3]), sys.argv[4])
