"""On-disk layout for the xv6-style file system (4 KiB blocks).

    [ 0 | superblock ]
    [ logstart .. logstart+nlog )        write-ahead journal
    [ inodestart .. bmapstart )          inode table
    [ bmapstart .. datastart )           block bitmap
    [ datastart .. size )                data blocks

Inodes carry 12 direct, 1 indirect and 1 double-indirect pointer (the
paper's 4 GB-file extension of stock xv6). Directory entries are fixed
64-byte records.
"""

from __future__ import annotations

import dataclasses
import struct
from typing import List

BSIZE = 4096
FSMAGIC = 0x10203040
NDIRECT = 12
NINDIRECT = BSIZE // 4  # 1024 u32 pointers per block
MAXFILE_BLOCKS = NDIRECT + NINDIRECT + NINDIRECT * NINDIRECT  # ~4.2 GB

# inode: type u16, nlink u16, pad u32, size u64, addrs (NDIRECT+2) u32
_INODE_FMT = "<HHIQ" + "I" * (NDIRECT + 2)
INODE_SIZE = struct.calcsize(_INODE_FMT)  # 72 bytes
IPB = BSIZE // INODE_SIZE  # inodes per block

T_FREE, T_FILE, T_DIR = 0, 1, 2

DIRENT_SIZE = 64
NAME_MAX = DIRENT_SIZE - 4 - 1  # u32 ino + NUL

# Whiteout sentinel for overlay mounts (fs/overlay.py): a dirent whose ino
# field is this value records "NAME IS DELETED HERE" in a writable upper
# directory, masking a same-named entry in the immutable base below. Plain
# (non-overlay) mounts never create one; their namespace ops skip it like
# a hole but never REUSE its slot for a different name (the overlay's
# delete marker must not be silently evicted by an unrelated create).
WHITEOUT_INO = 0xFFFFFFFF  # u32 max — can never collide with a real ino


@dataclasses.dataclass
class SuperBlock:
    magic: int
    size: int  # total blocks
    nlog: int
    logstart: int
    ninodes: int
    inodestart: int
    bmapstart: int
    datastart: int

    _FMT = "<8I"

    def pack(self) -> bytes:
        raw = struct.pack(self._FMT, self.magic, self.size, self.nlog,
                          self.logstart, self.ninodes, self.inodestart,
                          self.bmapstart, self.datastart)
        return raw + b"\0" * (BSIZE - len(raw))

    @classmethod
    def unpack(cls, raw: bytes) -> "SuperBlock":
        vals = struct.unpack_from(cls._FMT, raw)
        return cls(*vals)


@dataclasses.dataclass
class DiskInode:
    type: int = T_FREE
    nlink: int = 0
    size: int = 0
    addrs: List[int] = dataclasses.field(
        default_factory=lambda: [0] * (NDIRECT + 2))

    def pack(self) -> bytes:
        return struct.pack(_INODE_FMT, self.type, self.nlink, 0, self.size,
                           *self.addrs)

    @classmethod
    def unpack(cls, raw: bytes, off: int = 0) -> "DiskInode":
        vals = struct.unpack_from(_INODE_FMT, raw, off)
        return cls(type=vals[0], nlink=vals[1], size=vals[3],
                   addrs=list(vals[4:]))


def pack_dirent(ino: int, name: str) -> bytes:
    nb = name.encode()
    assert 0 < len(nb) <= NAME_MAX, name
    return struct.pack("<I", ino) + nb + b"\0" * (DIRENT_SIZE - 4 - len(nb))


def unpack_dirent(raw: bytes, off: int):
    (ino,) = struct.unpack_from("<I", raw, off)
    name = raw[off + 4: off + DIRENT_SIZE].split(b"\0", 1)[0].decode()
    return ino, name


def geometry(n_blocks: int, ninodes: int = 4096, nlog: int = 64) -> SuperBlock:
    logstart = 1
    inodestart = logstart + nlog
    ninodeblocks = (ninodes + IPB - 1) // IPB
    bmapstart = inodestart + ninodeblocks
    nbmap = (n_blocks + BSIZE * 8 - 1) // (BSIZE * 8)
    datastart = bmapstart + nbmap
    return SuperBlock(FSMAGIC, n_blocks, nlog, logstart, ninodes,
                      inodestart, bmapstart, datastart)
