"""The benchmark mount matrix — paper Table 2 made executable.

  bento    xv6 through the Bento typed boundary, kernel binding,
           group commit + writepages-batched install (inherits the FUSE
           kernel module's optimizations, like the paper's Bento).
  vfs      the same xv6 logic called directly (no capability checks, no op
           gate), write-through cache, per-operation commit — the
           "just written for this evaluation" C baseline.
  fuse     xv6 in a subprocess behind full serialization (userspace).
  ext4like the optimized commercial-grade baseline.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

from repro.core.interface import FS_OPS as _FS_OPS, execute_batch
from repro.core.registry import Mount, mount as bento_mount
from repro.core.services import kernel_binding, userspace_binding
from repro.fs.blockdev import LazyBlockDevice, MemBlockDevice
from repro.fs.ext4like import Ext4LikeFileSystem
from repro.fs.fusebridge import FuseMount
from repro.fs.overlay import OverlayFilesystem, OverlayOptions
from repro.fs.posix import PosixView
from repro.fs.xv6 import Xv6FileSystem, Xv6Options, mkfs



class DirectMount:
    """VFS-direct baseline: raw calls into the fs object — no dispatch table,
    no gate, no capability discipline (the unsafe fast path). Also no
    multi-submitter drain: every ``submit`` is its own dispatch, which is
    exactly what "4 threads sharing the scalar path" means in the
    benchmark matrix."""

    def __init__(self, fs):
        self.module = fs
        self.generation = 1
        self.name = "vfs-direct"
        for op in _FS_OPS:
            setattr(self, op, getattr(fs, op))

    def call(self, op, *a, **k):
        return getattr(self.module, op)(*a, **k)

    def submit(self, entries):
        # Same batched surface as Mount.submit, minus the gate (this is the
        # no-discipline baseline): the fs still gets its vectorized paths
        # and chains (SQE_LINK) keep their cancel-on-failure semantics.
        return execute_batch(self.module.submit_batch, list(entries))

    def unmount(self) -> None:
        self.module.flush()
        self.module.destroy()


@dataclasses.dataclass
class MountedFs:
    kind: str
    mount: Any
    view: PosixView
    services: Any = None
    dev: Any = None  # the backing device (in-process kinds; fault injection)

    def close(self) -> None:
        self.mount.unmount()


def make_mount(kind: str, n_blocks: int = 16384, *,
               backing_path: str = None, reuse: bool = False,
               prov: bool = False) -> MountedFs:
    """Build one matrix entry. ``backing_path``/``reuse`` apply to the
    fuse kind only: an explicit backing file location, and whether to
    remount it as-is (skip mkfs; daemon-side journal recovery runs) — the
    FUSE crash-torture path (repro.fs.crashsim.FuseCrashSim).
    ``prov=True`` mounts the module wrapped in the provenance layer from
    the start (the torture/benchmark baseline; the live-swap path goes
    through ``repro.core.upgrade.wrap_layer`` instead).

    ``dedup-bento`` / ``dedup-ext4like`` mount the same modules with the
    content-addressed blockstore enabled (repro.fs.blockstore) — plain
    kinds stay bit-identical to the pre-blockstore format.

    ``overlay-bento`` / ``overlay-ext4like`` mount a CoW overlay tenant
    (repro.fs.overlay): a small writable upper over a freshly built,
    default-populated base image. Sharing ONE image across many tenants
    (the provisioning story) goes through ``build_base_image`` +
    ``overlay_tenant`` instead."""
    def _wrap(fs):
        if not prov:
            return fs
        from repro.fs.prov import ProvFilesystem
        return ProvFilesystem(fs)

    if kind.startswith("overlay-"):
        fs_kind = {"bento": "xv6", "ext4like": "ext4like"}[
            kind[len("overlay-"):]]
        image = build_base_image(fs_kind)
        return overlay_tenant(image, fs_kind, kind=kind,
                              n_blocks=n_blocks, prov=prov)

    dedup = kind.startswith("dedup-")
    base_kind = kind[len("dedup-"):] if dedup else kind

    if base_kind == "bento":
        dev = MemBlockDevice(n_blocks)
        ks = kernel_binding(dev)
        mkfs(ks)
        fs = _wrap(Xv6FileSystem(Xv6Options(group_commit=True,
                                            batched_install=True,
                                            dedup=dedup)))
        m = bento_mount("xv6", ks, module=fs)
        return MountedFs(kind, m, PosixView(m), ks, dev)
    if base_kind == "vfs" and not dedup:
        dev = MemBlockDevice(n_blocks)
        ks = kernel_binding(dev, writeback="through")
        mkfs(ks)
        fs = _wrap(Xv6FileSystem(Xv6Options(group_commit=False,
                                            batched_install=False)))
        fs.init(ks.superblock(), ks)
        m = DirectMount(fs)
        return MountedFs(kind, m, PosixView(m), ks, dev)
    if base_kind == "fuse" and not dedup:
        m = FuseMount(n_blocks=n_blocks,
                      fs_kind="prov-xv6" if prov else "xv6",
                      backing_path=backing_path, reuse=reuse)
        return MountedFs(kind, m, PosixView(m))
    if base_kind == "ext4like":
        dev = MemBlockDevice(n_blocks)
        ks = kernel_binding(dev)
        mkfs(ks)
        opts = Xv6Options(group_commit=True, batched_install=True,
                          dedup=dedup)
        fs = _wrap(Ext4LikeFileSystem(opts))
        m = bento_mount("ext4like", ks, module=fs)
        return MountedFs(kind, m, PosixView(m), ks, dev)
    raise KeyError(kind)


# --- CoW overlay provisioning (repro.fs.overlay) ----------------------------------


def default_base_populate(view: PosixView) -> None:
    """The deterministic tree the default base image carries: a few dirs
    and files with recognizable content, enough to exercise every merge
    rule (lookup-through, copy-up, whiteouts, nested dirs)."""
    view.mkdir("/etc")
    view.mkdir("/usr")
    view.mkdir("/usr/share")
    view.write_file("/etc/hostname", b"golden\n")
    view.write_file("/etc/motd", b"welcome to the base image\n")
    view.write_file("/usr/share/words", b"alpha beta gamma delta\n" * 64)
    view.write_file("/readme", b"base readme\n")


def build_base_image(fs_kind: str = "xv6", n_blocks: int = 8192,
                     populate=None) -> MemBlockDevice:
    """Build ONE golden base image: mkfs, run ``populate(view)`` (default
    tree when None), unmount cleanly. The returned device is the shared
    read-only artifact every tenant's ``LazyBlockDevice`` fetches from —
    the clean unmount matters, because an immutable base may never need
    journal recovery writes."""
    dev = MemBlockDevice(n_blocks)
    ks = kernel_binding(dev)
    mkfs(ks)
    cls = Ext4LikeFileSystem if fs_kind == "ext4like" else Xv6FileSystem
    fs = cls(Xv6Options(group_commit=True, batched_install=True))
    m = bento_mount("base-image", ks, module=fs)
    (populate or default_base_populate)(PosixView(m))
    m.unmount()
    return dev


def overlay_tenant(image: MemBlockDevice, fs_kind: str = "xv6", *,
                   kind: str = None, n_blocks: int = 4096,
                   ninodes: int = 1024, prov: bool = False) -> MountedFs:
    """Provision ONE tenant over a shared base image: a fresh small
    upper device (mkfs'd) plus a per-tenant lazy immutable view of the
    image — O(metadata), never a data copy. ``MountedFs.dev`` is the
    UPPER device (the writable side fault injection arms)."""
    upper_dev = MemBlockDevice(n_blocks)
    ks = kernel_binding(upper_dev)
    # a tenant upper holds deltas, not a whole tree: a smaller inode table
    # keeps provisioning (per-tenant mkfs) O(small metadata)
    mkfs(ks, ninodes=ninodes, nlog=64)
    lazy = LazyBlockDevice(image, n_blocks=image.n_blocks,
                           device_id="lazy-base", immutable_base=True)
    fs = OverlayFilesystem(OverlayOptions(kind=fs_kind, base_dev=lazy))
    if prov:
        from repro.fs.prov import ProvFilesystem
        fs = ProvFilesystem(fs)
    m = bento_mount(kind or f"overlay-{fs_kind}", ks, module=fs)
    return MountedFs(kind or f"overlay-{fs_kind}", m, PosixView(m), ks,
                     upper_dev)


ALL_KINDS = ("bento", "vfs", "fuse", "ext4like")
DEDUP_KINDS = ("dedup-bento", "dedup-ext4like")
OVERLAY_KINDS = ("overlay-bento", "overlay-ext4like")
