"""BlockStore: a content-addressed data plane for the Bento file systems.

Every file data block written through a ``dedup`` mount is hashed with the
``kernels/blockhash`` Pallas kernel — ONE batched launch per flushed write
batch, threaded through the same chain/batch scope hooks the submission
queues established — and recorded in an on-device hash→(block, refcount)
index. The index buys three production features on top of the paper's
"fast kernel-quality fs" claim:

* **Dedup (copy-on-write sharing).** A write whose final block content
  already exists on disk takes a *reference* to the existing block instead
  of keeping its own copy; the duplicate block is freed in the same
  journal transaction that rewrites the map. Tenants sharing
  mostly-identical data (checkpoints, container bases) pay for one copy.
* **CoW break-before-mutate.** A write that lands on a block with
  ``refcount > 1`` first allocates a private copy, carries the old
  content over, and repoints only the writing file — the other references
  never observe the mutation.
* **Verified reads.** ``read_many`` re-hashes every device-fetched block
  in one batched launch and compares against the index; a mismatch
  surfaces as an ``EIO``-carrying ``FsError`` on exactly the affected
  entries, turning silent device corruption (torn writes, bit rot) into a
  detected error instead of returned garbage.

On-disk index and crash safety
------------------------------

The index lives in a reserved root file (``.bento-dedup``, hidden from
``readdir`` and guarded against unlink/rename): one 8-byte record per
data-region block — ``<IHH`` = (hash u32, refcount u16, flags u16, flag
bit0 = hash-valid). Records are mutated through the fs's ``_bread`` /
``_log`` primitives, so every index mutation is STAGED INTO THE JOURNAL
and commits with the operation that caused it. The invariants, proven at
every power-loss point by ``crashsim.torture_dedup``:

* **Refcount-in-txn.** Refcount changes (take a reference, drop one,
  break sharing) stage in the same journal transaction as the block-map
  change they describe. A crash at any device write recovers to an index
  whose refcounts EXACTLY equal the number of file-map references — no
  leaked blocks, no double frees, ever.
* **Hash-valid-in-txn.** A write *invalidates* the target block's stored
  hash in the same transaction as the data, and *revalidates* it only in
  (or after) the transaction that made the new content durable. A valid
  hash therefore always matches the durable content — verified reads can
  never false-positive across a crash.
* **Sharing rewrites are atomic.** The dedup pass (map repoint + refcount
  increment + duplicate free) stages as one transaction: inside the chain
  transaction for chained writes, the trailing transaction of the batch
  otherwise. A crash between the data transaction and a deferred dedup
  pass simply leaves the blocks unshared (and their hashes invalid) —
  consistent, just not yet deduplicated.

Hash collisions never corrupt: the 32-bit polynomial hash only nominates
dedup *candidates*; sharing happens after a byte compare (ZFS
``dedup=verify`` discipline). The in-memory maps (refcounts, hash index)
are a cache of the on-device table, reloaded from the (rolled-back)
device state after any journal rollback, and carried across live
upgrades via ``extract_state``/``restore_state`` under the optional
``"dedup"`` key.

Index compaction under churn
----------------------------

The table is direct-mapped (one record slot per data-region block), so
sustained create/delete churn leaves *tombstones*: table blocks whose
records are all dead (``refcount == 0``) but which still hold device
blocks. When the ratio of fully-dead table blocks to materialized ones
crosses ``_COMPACT_TOMBSTONE_RATIO``, the batch-end pass PUNCHES them:
the logical→device mapping is cleared and the block returned to the
allocator, staged in the same journal transaction as the churn that
exposed them (crash at any point recovers to either state, proven by
``crashsim.torture_dedup``'s churn sweep). A record landing on a punched
slot later *rematerializes* the table block (fresh zeroed allocation, in
that record's transaction). ``reload`` re-derives the table-block map
from the index inode, so rollbacks and crash recovery see holes exactly
as the device does.
"""

from __future__ import annotations

import struct
import threading
from typing import Dict, List, Optional, Set, Tuple

from repro.core.interface import Errno, FsError, ROOT_INO
from repro.fs import layout as L

DEDUP_TABLE_NAME = ".bento-dedup"

_REC_FMT = "<IHH"  # hash, refcount, flags
_REC_SIZE = 8
_F_VALID = 0x1
_MAX_REFS = 0xFFFF
# journal blocks one dedup-pass item may stage (table + inode + indirect +
# bitmap); the pass defers items when the open transaction has less room
_ITEM_MARGIN = 8
# punch fully-dead table blocks once they exceed this fraction of the
# materialized table (see "Index compaction under churn" above)
_COMPACT_TOMBSTONE_RATIO = 0.25


class BlockStore:
    """Content-addressed index attached to one fs instance.

    All mutating entry points run under the owning fs's op lock with an
    open journal reservation — the store itself takes no locks beyond a
    thread-local batch-depth counter.
    """

    def __init__(self, fs):
        self.fs = fs
        self.table_ino: Optional[int] = None
        self._table_blocks: List[int] = []  # lbn -> device block (0 = punched)
        self._live_per_lbn: List[int] = []  # live (rc>0) records per table block
        # table blocks whose last live record DIED (vs never-populated
        # ones): only these are tombstones — punching the preallocated
        # but never-used tail of the table would just churn remats
        self._dead_churned: Set[int] = set()
        self._n_live_blocks = 0  # table blocks with any live record
        # in-memory cache of the on-device table
        self.refcnt: Dict[int, int] = {}
        self.hashval: Dict[int, int] = {}      # blockno -> hash (valid only)
        self._by_hash: Dict[int, Set[int]] = {}
        # blocks written this batch, awaiting the dedup pass:
        # blockno -> (ino, lbn, submitter)
        self.pending: Dict[int, Tuple[int, int, object]] = {}
        self._tls = threading.local()
        self.stats = {
            "hash_launches": 0, "hashed_blocks": 0, "dedup_hits": 0,
            "cow_breaks": 0, "dedup_deferred": 0, "verify_launches": 0,
            "verified_blocks": 0, "corruptions_detected": 0,
            "compactions": 0, "remats": 0,
            "by_submitter": {},
        }

    # --- batch scope (threaded through submit_batch / chain hooks) ------------------
    @property
    def batch_depth(self) -> int:
        return getattr(self._tls, "depth", 0)

    def batch_begin(self) -> None:
        self._tls.depth = self.batch_depth + 1

    def batch_dec(self) -> int:
        d = max(self.batch_depth - 1, 0)
        self._tls.depth = d
        return d

    # --- attach / bootstrap ----------------------------------------------------------
    def _n_entries(self) -> int:
        geo = self.fs.geo
        return geo.size - geo.datastart

    def attach(self) -> None:
        """Find or create the on-device table, then load it. Called at
        mount (after journal recovery): the create+zero bootstrap goes
        through the ordinary journaled write path, chunked into sub-op
        transactions, so a crash mid-bootstrap recovers to either a
        complete table or a retryable shorter one."""
        fs = self.fs
        table_bytes = self._n_entries() * _REC_SIZE
        root_di = fs._iget(ROOT_INO)
        hit = fs._dirlookup(ROOT_INO, root_di, DEDUP_TABLE_NAME)
        if hit is None:
            attr = fs._create_common(ROOT_INO, DEDUP_TABLE_NAME, L.T_FILE,
                                     _internal=True)
            self.table_ino = attr.ino
            fs.write(self.table_ino, 0, bytes(table_bytes))
        else:
            self.table_ino = hit[2]
            di = fs._iget(self.table_ino)
            if di.size < table_bytes:  # crash mid-bootstrap: finish the zero
                fs.write(self.table_ino, di.size, bytes(table_bytes - di.size))
        fs.journal.commit()
        self.reload()

    def reload(self) -> None:
        """Rebuild the in-memory maps from the on-device table (through
        the journal overlay). Also the rollback path: after an aborted
        chain member / op the overlay shows pre-transaction state, so a
        reload drops exactly the rolled-back index mutations. The
        table-block map is RE-DERIVED from the index inode each time —
        compaction punches holes into it (and rematerialization fills
        them), and both may be the thing that just rolled back."""
        fs = self.fs
        di = fs._iget(self.table_ino)
        nlbn = (self._n_entries() * _REC_SIZE + L.BSIZE - 1) // L.BSIZE
        cache: Dict[int, bytes] = {}
        self._table_blocks = [fs._bmap_ro(di, i, cache) for i in range(nlbn)]
        refcnt: Dict[int, int] = {}
        hashval: Dict[int, int] = {}
        by_hash: Dict[int, Set[int]] = {}
        datastart = fs.geo.datastart
        per_blk = L.BSIZE // _REC_SIZE
        live = [0] * nlbn
        for lbn, tb in enumerate(self._table_blocks):
            if tb == 0:
                continue  # punched: every record in range is dead
            with fs._bread(tb) as bh:
                raw = bytes(bh.data())
            base = datastart + lbn * per_blk
            for i, (h, rc, fl) in enumerate(struct.iter_unpack(_REC_FMT, raw)):
                if rc == 0:
                    continue
                b = base + i
                if b >= fs.geo.size:
                    break
                refcnt[b] = rc
                live[lbn] += 1
                if fl & _F_VALID:
                    hashval[b] = h
                    by_hash.setdefault(h, set()).add(b)
        self.refcnt = refcnt
        self.hashval = hashval
        self._by_hash = by_hash
        self._live_per_lbn = live
        self._n_live_blocks = sum(1 for n in live if n > 0)
        # churn history is transition-derived; the device can't tell a
        # churned-dead block from a never-used one, so pressure restarts
        self._dead_churned = set()
        self.pending.clear()

    # --- on-device record mutation (journaled: same txn as the caller's op) ----------
    def _entry_write(self, b: int, h: int, rc: int, valid: bool) -> None:
        fs = self.fs
        idx = b - fs.geo.datastart
        lbn, off = divmod(idx * _REC_SIZE, L.BSIZE)
        tb = self._table_blocks[lbn]
        if tb == 0:
            if rc == 0:
                return  # dead record on a punched block: already gone
            tb = self._remat_table_block(lbn)
        with fs._bread(tb) as bh:
            buf = bh.data()
            struct.pack_into(_REC_FMT, buf, off, h & 0xFFFFFFFF, rc,
                             _F_VALID if valid else 0)
            fs._log(tb, bytes(buf))
        # mirror into the in-memory cache (and the per-block live counts
        # compaction keys off)
        was_live = b in self.refcnt
        old_h = self.hashval.pop(b, None)
        if old_h is not None:
            peers = self._by_hash.get(old_h)
            if peers is not None:
                peers.discard(b)
                if not peers:
                    self._by_hash.pop(old_h, None)
        if rc == 0:
            self.refcnt.pop(b, None)
        else:
            self.refcnt[b] = rc
            if valid:
                self.hashval[b] = h
                self._by_hash.setdefault(h, set()).add(b)
        if was_live != (rc > 0) and self._live_per_lbn:
            if rc > 0:
                if self._live_per_lbn[lbn] == 0:
                    self._n_live_blocks += 1
                self._live_per_lbn[lbn] += 1
                self._dead_churned.discard(lbn)
            else:
                self._live_per_lbn[lbn] -= 1
                if self._live_per_lbn[lbn] == 0:
                    self._n_live_blocks -= 1
                    self._dead_churned.add(lbn)

    def _remat_table_block(self, lbn: int) -> int:
        """A record is landing on a punched (compacted-away) table block:
        materialize a fresh zeroed block for it, journaled in the current
        transaction like any other index mutation."""
        fs = self.fs
        nb = fs._balloc()  # stages the bitmap bit AND zeroed content
        di = fs._iget(self.table_ino)
        fs._bmap_install(self.table_ino, di, lbn, nb)
        self._table_blocks[lbn] = nb
        self.stats["remats"] += 1
        return nb

    # --- write-path hook --------------------------------------------------------------
    def note_write(self, ino: int, di, bn: int, b: int) -> int:
        """Called by the fs for every file data block about to be
        (re)written, inside the op's journal scope. Breaks CoW sharing,
        invalidates the stored hash (same txn as the data — the
        hash-valid-in-txn invariant), and registers the block for the
        batch-end dedup pass. Returns the block the write must target."""
        if ino == self.table_ino:
            return b  # the index never indexes itself
        fs = self.fs
        rc = self.refcnt.get(b)
        if rc is not None and rc > 1:
            # CoW break: private copy first, mutate the copy
            nb = fs._balloc()
            old = self._content(b)
            fs._log(nb, old)
            h = self.hashval.get(b)
            self._entry_write(b, h if h is not None else 0, rc - 1,
                              h is not None)
            self._entry_write(nb, 0, 1, False)
            fs._bmap_install(ino, di, bn, nb)
            self.stats["cow_breaks"] += 1
            b = nb
        elif rc is None:
            self._entry_write(b, 0, 1, False)  # start tracking
        elif b in self.hashval:
            self._entry_write(b, 0, 1, False)  # content changing: invalidate
        self.pending[b] = (ino, bn, self._submitter())
        return b

    def _submitter(self):
        sub = getattr(self.fs, "_current_submitter", None)
        return sub if sub is not None else f"tid:{threading.get_ident()}"

    def _content(self, b: int) -> bytes:
        pend = self.fs.journal.pending_get(b)
        if pend is not None:
            return pend
        with self.fs._bread(b) as bh:
            return bytes(bh.data())

    # --- free-path hook ---------------------------------------------------------------
    def release(self, b: int) -> bool:
        """Drop one reference; returns True when the caller should really
        free the block (last reference, or untracked metadata block)."""
        rc = self.refcnt.get(b)
        if rc is None:
            return True
        if rc > 1:
            h = self.hashval.get(b)
            self._entry_write(b, h if h is not None else 0, rc - 1,
                              h is not None)
            return False
        self._entry_write(b, 0, 0, False)
        self.pending.pop(b, None)
        return True

    # --- the batch-end dedup pass -------------------------------------------------------
    def flush_pending(self) -> None:
        """Hash every block the batch wrote in ONE Pallas launch, then
        share duplicates copy-on-write style. Runs under the fs lock with
        an open journal scope (the chain transaction for chained writes,
        a trailing reservation otherwise); items that would overflow the
        open transaction stay pending for the next pass. Piggybacks the
        tombstone compaction check: churn that killed whole table blocks
        gets them punched in this same transaction."""
        self._dedup_pass()
        self._maybe_compact()

    def _dedup_pass(self) -> None:
        if not self.pending:
            return
        fs = self.fs
        items = []
        for b, (ino, bn, sub) in list(self.pending.items()):
            # staleness: the batch may have re-freed / re-targeted the block
            if self.refcnt.get(b) != 1:
                self.pending.pop(b, None)
                continue
            try:
                di = fs._iget(ino)
            except FsError:
                self.pending.pop(b, None)
                continue
            if di.type != L.T_FILE or fs._bmap_ro(di, bn, {}) != b:
                self.pending.pop(b, None)
                continue
            items.append((b, ino, bn, sub, self._content(b)))
        if not items:
            self.pending.clear()
            return
        sums = fs.ks.checksum_batch([it[4] for it in items])
        self.stats["hash_launches"] += 1
        self.stats["hashed_blocks"] += len(items)
        journal = fs.journal
        for i, ((b, ino, bn, sub, content), h) in enumerate(zip(items, sums)):
            if journal.room < _ITEM_MARGIN:
                # transaction nearly full: leave the tail pending (counted)
                self.stats["dedup_deferred"] += len(items) - i
                return
            self.pending.pop(b, None)
            target = None
            for c in self._by_hash.get(h, ()):
                if (c != b and self.refcnt.get(c, 0) > 0
                        and self.refcnt[c] < _MAX_REFS
                        and self._content(c) == content):
                    target = c
                    break
            if target is not None:
                di = fs._iget(ino)
                fs._bmap_install(ino, di, bn, target)
                self._entry_write(target, h, self.refcnt[target] + 1, True)
                self._entry_write(b, 0, 0, False)
                fs._bfree_raw(b)
                self.stats["dedup_hits"] += 1
                per = self.stats["by_submitter"].setdefault(
                    str(sub), {"blocks": 0, "dedup_hits": 0})
                per["dedup_hits"] += 1
            else:
                self._entry_write(b, h, 1, True)
            per = self.stats["by_submitter"].setdefault(
                str(sub), {"blocks": 0, "dedup_hits": 0})
            per["blocks"] += 1

    # --- index compaction under churn ----------------------------------------------------
    def compaction_due(self) -> bool:
        """Tombstone pressure: CHURNED fully-dead table blocks (blocks
        whose last live record died — never-populated preallocated blocks
        don't count) as a fraction of the USED index (dead + still-live
        blocks) crossed the punch threshold. O(1): the counts are
        maintained incrementally by ``_entry_write`` — this runs on every
        mutating op's epilogue."""
        dead = len(self._dead_churned)
        if dead == 0:
            return False
        return dead / (dead + self._n_live_blocks) > _COMPACT_TOMBSTONE_RATIO

    def _maybe_compact(self) -> None:
        """Punch every fully-dead table block back to the allocator,
        journaled in the caller's open transaction: clear the index
        inode's mapping, free the device block, leave a hole sentinel in
        the in-memory map. Stops early when the open transaction runs
        low on room — the rest punch on a later pass (``compaction_due``
        stays true until they do)."""
        if not self.compaction_due():
            return
        fs = self.fs
        di = fs._iget(self.table_ino)
        for lbn in sorted(self._dead_churned):
            tb = self._table_blocks[lbn]
            if tb == 0 or self._live_per_lbn[lbn] != 0:
                self._dead_churned.discard(lbn)
                continue
            if fs.journal.room < _ITEM_MARGIN:
                return
            fs._bmap_clear(self.table_ino, di, lbn)
            fs._bfree_raw(tb)
            self._table_blocks[lbn] = 0
            self._dead_churned.discard(lbn)
            self.stats["compactions"] += 1

    # --- verified reads ------------------------------------------------------------------
    def verify_fetched(self, bufs: Dict[int, bytes], fetched) -> Set[int]:
        """Bulk-verify device-fetched blocks against stored hashes (one
        batched launch); returns the set of corrupt block numbers."""
        cand = [b for b in fetched if b in self.hashval]
        if not cand:
            return set()
        sums = self.fs.ks.checksum_batch([bytes(bufs[b]) for b in cand])
        self.stats["verify_launches"] += 1
        self.stats["verified_blocks"] += len(cand)
        bad = {b for b, got in zip(cand, sums) if got != self.hashval[b]}
        if bad:
            self.stats["corruptions_detected"] += len(bad)
            self.fs.ks.log_warn(
                f"blockstore: checksum mismatch on blocks {sorted(bad)}")
        return bad

    # --- observability / state transfer ---------------------------------------------------
    def shared_refs(self) -> int:
        return sum(rc - 1 for rc in self.refcnt.values() if rc > 1)

    def statfs_extras(self) -> Dict[str, int]:
        return {
            "dedup_tracked_blocks": len(self.refcnt),
            "dedup_shared_refs": self.shared_refs(),
            # statfs accounting (the free-block estimate folds these in):
            # device blocks the index itself occupies, and data blocks
            # CoW sharing saves (rc-1 per shared block) — what free space
            # would gain if every share were broken
            "dedup_index_blocks": sum(1 for tb in self._table_blocks if tb),
            "dedup_saved_blocks": self.shared_refs(),
            "dedup_hits": self.stats["dedup_hits"],
            "dedup_cow_breaks": self.stats["cow_breaks"],
            "dedup_hash_launches": self.stats["hash_launches"],
            "dedup_verify_launches": self.stats["verify_launches"],
            "dedup_corruptions_detected": self.stats["corruptions_detected"],
            "dedup_compactions": self.stats["compactions"],
            "dedup_remats": self.stats["remats"],
        }

    def extract_state(self) -> Dict:
        return {
            "table_ino": self.table_ino,
            "table_blocks": list(self._table_blocks),
            "refcnt": dict(self.refcnt),
            "hashval": dict(self.hashval),
            "stats": {k: (dict(v) if isinstance(v, dict) else v)
                      for k, v in self.stats.items()},
        }

    def restore_state(self, state: Dict) -> None:
        self.table_ino = state.get("table_ino", self.table_ino)
        blocks = state.get("table_blocks")
        if blocks:
            self._table_blocks = [int(b) for b in blocks]
        self.refcnt = {int(k): int(v)
                       for k, v in state.get("refcnt", {}).items()}
        self.hashval = {int(k): int(v)
                        for k, v in state.get("hashval", {}).items()}
        self._by_hash = {}
        for b, h in self.hashval.items():
            self._by_hash.setdefault(h, set()).add(b)
        # recompute the compaction live counts from the restored refcounts
        per_blk = L.BSIZE // _REC_SIZE
        datastart = self.fs.geo.datastart
        live = [0] * len(self._table_blocks)
        for b in self.refcnt:
            live[(b - datastart) // per_blk] += 1
        self._live_per_lbn = live
        self._n_live_blocks = sum(1 for n in live if n > 0)
        self._dead_churned = set()
        st = state.get("stats")
        if st:
            self.stats.update({k: (dict(v) if isinstance(v, dict) else v)
                               for k, v in st.items()})
        self.pending.clear()
