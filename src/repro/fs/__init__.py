from repro.fs.blockdev import (BLOCK_SIZE, BlockDevice, FileBlockDevice,
                               JaxBlockDevice, MemBlockDevice)
from repro.fs.buffercache import BufferCache, BufferHead, BufferLeak
from repro.fs.posix import PosixView

__all__ = [
    "BLOCK_SIZE", "BlockDevice", "BufferCache", "BufferHead", "BufferLeak",
    "FileBlockDevice", "JaxBlockDevice", "MemBlockDevice", "PosixView",
]
