"""Buffer cache: the ``sb_bread``/``brelse`` kernel service (paper §4.5/4.7).

``BufferHead`` is the wrapping abstraction from §4.7: the raw (pointer, size)
pair becomes a sized, bounds-checked memory region; release is attached to
scope exit (Rust ``drop`` -> our context manager / refcount), so "buffer
management has the same properties as memory management in Rust: leaks are
possible but difficult". A leak detector fires at unmount.

Writeback policies:
  * write-through per block (the VFS-direct baseline's behaviour), or
  * delayed writeback with batched flush (`writepages`-style — the paper's
    explanation for Bento beating the VFS C version on large writes).
"""

from __future__ import annotations

import collections
import threading
from typing import Dict, List, Optional

from repro.fs.blockdev import BlockDevice


class BufferLeak(Exception):
    pass


class BufferHead:
    """Sized view of one cached block. Mutation only via ``data()`` while
    held; ``mark_dirty`` schedules writeback; release via context manager or
    explicit ``brelse`` (drop semantics)."""

    __slots__ = ("blockno", "_buf", "_cache", "_held", "dirty")

    def __init__(self, blockno: int, buf: bytearray, cache: "BufferCache"):
        self.blockno = blockno
        self._buf = buf
        self._cache = cache
        self._held = True
        self.dirty = False

    def data(self) -> bytearray:
        if not self._held:
            raise BufferLeak(f"buffer {self.blockno} used after brelse")
        return self._buf

    def mark_dirty(self) -> None:
        if not self._held:
            raise BufferLeak(f"buffer {self.blockno} dirtied after brelse")
        self.dirty = True

    def brelse(self) -> None:
        # idempotence lives in the cache: the held-flag test-and-clear
        # happens under the cache lock (_release), so an explicit brelse
        # racing the GC finalizer can never double-decrement a refcount
        cache = getattr(self, "_cache", None)
        if cache is not None and self._held:
            cache._release(self)

    def __enter__(self) -> "BufferHead":
        return self

    def __exit__(self, *exc) -> None:
        self.brelse()

    def __del__(self):
        # drop -> brelse (paper §4.7): prevents accidental leaks. At
        # interpreter shutdown the finalizer can run AFTER the cache (or
        # its lock, or the threading module) is torn down — a raise here
        # would just spew "Exception ignored in __del__" noise, so any
        # failure means the process is dying and the unpin is moot.
        try:
            if getattr(self, "_held", False):
                self.brelse()
        except Exception:  # noqa: BLE001 — shutdown-ordering teardown
            pass


class BufferCache:
    """LRU cache of device blocks with refcounts and writeback."""

    def __init__(self, dev: BlockDevice, capacity: int = 1024,
                 writeback: str = "through"):
        assert writeback in ("through", "delayed")
        self.dev = dev
        self.capacity = capacity
        self.writeback = writeback
        self._lock = threading.RLock()
        self._blocks: "collections.OrderedDict[int, bytearray]" = collections.OrderedDict()
        self._dirty: Dict[int, bytearray] = {}
        self._refs: Dict[int, int] = collections.defaultdict(int)
        self.hits = 0
        self.misses = 0

    # --- sb_bread / getblk -------------------------------------------------------
    def bread(self, blockno: int) -> BufferHead:
        with self._lock:
            buf = self._blocks.get(blockno)
            if buf is None:
                self.misses += 1
                buf = bytearray(self.dev.read_block(blockno))
                self._insert(blockno, buf)
            else:
                self.hits += 1
                self._blocks.move_to_end(blockno)
            self._refs[blockno] += 1
            return BufferHead(blockno, buf, self)

    def bread_many(self, blocknos, fetched=None) -> List[BufferHead]:
        """Read many blocks under ONE lock acquisition (the batched-boundary
        analogue of plugging a bio list): same semantics as bread per block,
        heads returned in the order requested. All-or-nothing: the miss
        run hits the device BEFORE any ref is taken, so a failed bulk read
        can never strand pinned buffers.

        ``fetched`` (optional list) collects the blocknos that actually hit
        the DEVICE this call — the verified-read path (repro.fs.blockstore)
        re-hashes exactly those, never cache hits it already vouched for."""
        if not isinstance(blocknos, list):
            blocknos = list(blocknos)
        out: List[BufferHead] = []
        with self._lock:
            # warm fast path: serve hits with exactly bread's per-block
            # cost until the first miss — the all-cached case (the steady
            # state of every benchmark loop) never pays for miss plumbing
            for blockno in blocknos:
                buf = self._blocks.get(blockno)
                if buf is None:
                    break
                self.hits += 1
                self._blocks.move_to_end(blockno)
                self._refs[blockno] += 1
                out.append(BufferHead(blockno, buf, self))
            else:
                return out
            # cold suffix: the remaining miss run hits the device as ONE
            # call, so a lazy device materializes the whole run in a
            # single provider round-trip instead of one fetch per block
            rest = blocknos[len(out):]
            missing = [b for b in dict.fromkeys(rest)
                       if b not in self._blocks]
            try:
                prefetched = dict(zip(missing, self.dev.read_many(missing)))
            except BaseException:
                for bh in out:  # clean (never dirtied) — just unpin
                    self._release_locked(bh)
                raise
            for blockno in rest:
                buf = self._blocks.get(blockno)
                if buf is None:
                    self.misses += 1
                    buf = bytearray(prefetched[blockno])
                    self._insert(blockno, buf)
                    if fetched is not None:
                        fetched.append(blockno)
                else:
                    self.hits += 1
                    self._blocks.move_to_end(blockno)
                self._refs[blockno] += 1
                out.append(BufferHead(blockno, buf, self))
        return out

    def getblk_zero(self, blockno: int) -> BufferHead:
        """Get a block without reading it (about to be fully overwritten)."""
        with self._lock:
            buf = self._blocks.get(blockno)
            if buf is None:
                buf = bytearray(self.dev.block_size)
                self._insert(blockno, buf)
            else:
                buf[:] = bytes(self.dev.block_size)
                self._blocks.move_to_end(blockno)
            self._refs[blockno] += 1
            return BufferHead(blockno, buf, self)

    def _insert(self, blockno: int, buf: bytearray) -> None:
        self._blocks[blockno] = buf
        while len(self._blocks) > self.capacity:
            old, obuf = next(iter(self._blocks.items()))
            if self._refs.get(old, 0) > 0 or old in self._dirty:
                self._blocks.move_to_end(old)  # pinned/dirty: skip
                if all(self._refs.get(b, 0) > 0 or b in self._dirty
                       for b in self._blocks):
                    break  # everything pinned — grow past capacity
                continue
            self._blocks.popitem(last=False)
            self._refs.pop(old, None)

    # --- release / writeback -------------------------------------------------------
    def _release(self, bh: BufferHead) -> None:
        with self._lock:
            self._release_locked(bh)

    def _release_locked(self, bh: BufferHead) -> None:
        """Idempotent unpin: the held-flag test-and-clear AND the ref
        decrement happen together under the cache lock, so brelse, the
        ``__del__`` finalizer and ``brelse_many`` can all race on one head
        without double-releasing. A head whose refs entry is already gone
        (``invalidate`` ran between bread and release) unpins to nothing
        instead of minting a negative refcount that would silently cancel
        a real leak in ``assert_no_leaks``."""
        if not bh._held:
            return
        bh._held = False
        live = self._refs.get(bh.blockno, 0)
        if live > 1:
            self._refs[bh.blockno] = live - 1
        else:
            # drop zero entries so the refs dict IS the held-set
            self._refs.pop(bh.blockno, None)
        if bh.dirty:
            if self.writeback == "through":
                self.dev.write_block(bh.blockno, bytes(bh._buf))
            else:
                self._dirty[bh.blockno] = bh._buf

    def brelse_many(self, heads: List[BufferHead]) -> None:
        """Release many heads under ONE lock acquisition — the unpin
        counterpart of ``bread_many`` (per-head ``brelse`` pays a cache-lock
        round trip per block, which dominates large vectorized reads).
        Already-released heads are skipped, same as ``brelse``."""
        with self._lock:
            for bh in heads:
                self._release_locked(bh)

    def write_now(self, bh: BufferHead) -> None:
        """Synchronous write of a held buffer (journal commit path)."""
        with self._lock:
            self.dev.write_block(bh.blockno, bytes(bh.data()))
            self._dirty.pop(bh.blockno, None)
            bh.dirty = False

    def flush(self, blocknos: Optional[List[int]] = None) -> int:
        """Batched writeback (`writepages`): contiguous runs written in order."""
        with self._lock:
            targets = sorted(self._dirty if blocknos is None
                             else [b for b in blocknos if b in self._dirty])
            for b in targets:
                self.dev.write_block(b, bytes(self._dirty[b]))
            for b in targets:
                del self._dirty[b]
            self.dev.sync()
            return len(targets)

    @property
    def n_dirty(self) -> int:
        return len(self._dirty)

    def assert_no_leaks(self) -> None:
        # any NONZERO entry is a bug: positive = a head never released,
        # negative = a double release slipped past the idempotence guard
        # (pre-fix, a stray __del__ after invalidate() minted -1 entries
        # that could mask a real +1 leak on the same block)
        with self._lock:
            leaked = {b: r for b, r in self._refs.items() if r != 0}
            if leaked:
                raise BufferLeak(f"buffers still held at teardown: {leaked}")

    def invalidate(self) -> None:
        with self._lock:
            self.flush()
            self._blocks.clear()
            self._refs.clear()

    def invalidate_blocks(self, blocknos) -> None:
        """Discard specific blocks' cached MUTATIONS — the journal's
        rollback path uses this to undo cache buffers an aborted op/chain
        member mutated in place. Unpinned blocks are dropped (next bread
        re-reads the device); a pinned block (the failing op may still
        hold the buffer it was mutating when the journal refused its
        log_write) is refreshed in place from the device, so every holder
        sees pre-op content."""
        with self._lock:
            for b in blocknos:
                if self._refs.get(b, 0) > 0:
                    buf = self._blocks.get(b)
                    if buf is not None:
                        buf[:] = self.dev.read_block(b)
                    self._dirty.pop(b, None)
                else:
                    self._blocks.pop(b, None)
                    self._dirty.pop(b, None)
                    self._refs.pop(b, None)
