"""Block devices.

Three backends with one interface:

* ``MemBlockDevice`` — host-memory numpy array ("kernel mode" binding; the
  disk is hardware, not compute, so host memory is the honest stand-in).
* ``FileBlockDevice`` — file-backed ("userspace mode" binding, used by the
  FUSE bridge subprocess; O_DIRECT-style full-block transfers only).
* ``JaxBlockDevice`` — pure-jnp immutable device (``.at[]`` updates), used
  by property tests to keep the substrate expressible in JAX end-to-end and
  by the Pallas crc32c checksum path.

All I/O is whole blocks; partial writes are the caller's read-modify-write
(exactly the buffer-cache contract).
"""

from __future__ import annotations

import os
import threading
from typing import Callable, Optional

import numpy as np

BLOCK_SIZE = 4096


class BlockDeviceError(Exception):
    pass


class BlockDevice:
    """Interface + common checks."""

    block_size: int
    n_blocks: int
    device_id: str

    def read_block(self, blockno: int) -> bytes:
        raise NotImplementedError

    def write_block(self, blockno: int, data: bytes) -> None:
        raise NotImplementedError

    def sync(self) -> None:
        pass

    def _check(self, blockno: int, data: Optional[bytes] = None) -> None:
        if not (0 <= blockno < self.n_blocks):
            raise BlockDeviceError(f"block {blockno} out of range 0..{self.n_blocks}")
        if data is not None and len(data) != self.block_size:
            raise BlockDeviceError(
                f"partial write ({len(data)} != {self.block_size}) — "
                "read-modify-write through the buffer cache")

    # --- fault injection (crash-recovery property tests) --------------------------
    fail_after_writes: int = -1  # -1 disabled; else raise after N writes
    fail_torn_bytes: int = -1    # >= 0: the DYING write lands this many
    #   bytes before power dies (a torn block — what a real power cut does
    #   to an in-flight sector transfer; the journal's per-block checksums
    #   must catch it at recovery). Backends that pass a torn_writer to
    #   _maybe_fail honour it (MemBlockDevice and FileBlockDevice both do).
    _writes_seen: int = 0

    def _maybe_fail(self, torn_writer: Optional[Callable[[int], None]]
                    = None) -> None:
        """Write-stream fault injection: count down to the armed crash
        point, then die. ``torn_writer(nbytes)``, when the backend
        provides one and ``fail_torn_bytes`` is armed, lands a partial
        block before the power-loss exception — the torn-write case."""
        if self.fail_after_writes >= 0:
            if self._writes_seen >= self.fail_after_writes:
                if torn_writer is not None and self.fail_torn_bytes >= 0:
                    torn_writer(min(self.fail_torn_bytes, self.block_size))
                raise BlockDeviceError("injected crash: device lost power")
            self._writes_seen += 1


class MemBlockDevice(BlockDevice):
    def __init__(self, n_blocks: int, block_size: int = BLOCK_SIZE,
                 device_id: str = "mem0"):
        self.block_size = block_size
        self.n_blocks = n_blocks
        self.device_id = device_id
        self._data = np.zeros((n_blocks, block_size), dtype=np.uint8)
        self._lock = threading.Lock()
        self.reads = 0
        self.writes = 0

    def read_block(self, blockno: int) -> bytes:
        self._check(blockno)
        with self._lock:
            self.reads += 1
            return self._data[blockno].tobytes()

    def write_block(self, blockno: int, data: bytes) -> None:
        self._check(blockno, data)
        with self._lock:

            def torn(nbytes: int) -> None:
                # the dying write lands a prefix of the block — what a real
                # power cut does to an in-flight sector transfer
                self._data[blockno, :nbytes] = np.frombuffer(
                    data[:nbytes], dtype=np.uint8)

            self._maybe_fail(torn)
            self.writes += 1
            self._data[blockno] = np.frombuffer(data, dtype=np.uint8)

    def snapshot(self) -> "MemBlockDevice":
        """Copy-on-crash snapshot for recovery tests."""
        dev = MemBlockDevice(self.n_blocks, self.block_size, self.device_id)
        dev._data = self._data.copy()
        return dev


class FileBlockDevice(BlockDevice):
    """File-backed device (userspace binding). Whole-block pread/pwrite."""

    def __init__(self, path: str, n_blocks: int, block_size: int = BLOCK_SIZE,
                 device_id: str = "file0"):
        self.block_size = block_size
        self.n_blocks = n_blocks
        self.device_id = device_id
        self.path = path
        flags = os.O_RDWR | os.O_CREAT
        self._fd = os.open(path, flags, 0o644)
        os.ftruncate(self._fd, n_blocks * block_size)
        self._lock = threading.Lock()
        self.reads = 0
        self.writes = 0

    def read_block(self, blockno: int) -> bytes:
        self._check(blockno)
        with self._lock:
            self.reads += 1
            return os.pread(self._fd, self.block_size, blockno * self.block_size)

    def write_block(self, blockno: int, data: bytes) -> None:
        self._check(blockno, data)
        with self._lock:
            # the dying write may TEAR: a prefix of the block lands, the
            # rest never does (fail_torn_bytes) — the FUSE daemon's
            # crash-torture path proves recovery detects this via the
            # journal's per-block checksums
            self._maybe_fail(lambda n: os.pwrite(
                self._fd, data[:n], blockno * self.block_size))
            self.writes += 1
            os.pwrite(self._fd, data, blockno * self.block_size)

    def sync(self) -> None:
        os.fsync(self._fd)

    def close(self) -> None:
        os.close(self._fd)


class JaxBlockDevice(BlockDevice):
    """Immutable jnp-backed device: functional `.at[]` updates.

    Slow by design; exists so the whole storage substrate is expressible in
    JAX (property tests + the Pallas checksum path run against it).
    """

    def __init__(self, n_blocks: int, block_size: int = BLOCK_SIZE,
                 device_id: str = "jax0"):
        import jax.numpy as jnp

        self.block_size = block_size
        self.n_blocks = n_blocks
        self.device_id = device_id
        self._data = jnp.zeros((n_blocks, block_size), dtype=jnp.uint8)
        self.reads = 0
        self.writes = 0

    def read_block(self, blockno: int) -> bytes:
        self._check(blockno)
        self.reads += 1
        return bytes(np.asarray(self._data[blockno]))

    def write_block(self, blockno: int, data: bytes) -> None:
        self._check(blockno, data)
        self._maybe_fail()
        self.writes += 1
        import jax.numpy as jnp

        arr = jnp.frombuffer(bytearray(data), dtype=jnp.uint8)
        self._data = self._data.at[blockno].set(arr)
