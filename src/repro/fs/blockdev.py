"""Block devices.

Four backends with one interface:

* ``MemBlockDevice`` — host-memory numpy array ("kernel mode" binding; the
  disk is hardware, not compute, so host memory is the honest stand-in).
* ``FileBlockDevice`` — file-backed ("userspace mode" binding, used by the
  FUSE bridge subprocess; O_DIRECT-style full-block transfers only).
* ``JaxBlockDevice`` — pure-jnp immutable device (``.at[]`` updates), used
  by property tests to keep the substrate expressible in JAX end-to-end and
  by the Pallas crc32c checksum path.
* ``LazyBlockDevice`` — sparse local store over a remote *provider*:
  blocks are fetched on first read (container cold-start / overlay base
  images — see the materialization protocol below).

All I/O is whole blocks; partial writes are the caller's read-modify-write
(exactly the buffer-cache contract).

Materialization protocol (``LazyBlockDevice``)
----------------------------------------------
A lazy device's local store starts empty except a per-block *valid* bitmap
(all clear). The bitmap is LOCAL DISK STATE — it survives remounts exactly
like data does, and every transition is a counted device write so the
crash-injection harness can lose power between any two steps:

1. ``read_block``/``read_many`` on an invalid block fetches the content
   from the provider (``read_many`` fetches the whole miss run in ONE
   provider round-trip — ``provider_round_trips`` counts interface
   crossings, the cold-start currency).
2. The fetched bytes land in the local store — a counted, torn-capable
   device write. If power dies here (or mid-transfer, leaving a torn
   prefix), the valid bit is still clear: the half-materialized block is
   NEVER visible, and a cold remount simply re-fetches from the provider.
3. The valid bit is set — a second counted write. Only after this commit
   point does the local copy shadow the provider.

``write_block`` always lands locally (the provider is never written) and
sets the valid bit with the data in one counted write, so a local write
permanently shadows the base content. A torn local write to a
still-invalid block leaves the bit clear — the torn prefix is unreachable
and the next read re-fetches, which is "the write never happened": the
same all-or-nothing story the journal gives torn metadata.

Blocks at or beyond ``base_blocks`` have no provider backing: they read
as zeros until written (a sparse local extension — the tenant's own
territory). ``immutable_base=True`` additionally rejects every write
inside the base range, which is how an overlay mount enforces that the
shared base image can never be dirtied by a tenant.
"""

from __future__ import annotations

import os
import threading
from typing import Callable, Optional

import numpy as np

BLOCK_SIZE = 4096


class BlockDeviceError(Exception):
    pass


class BlockDevice:
    """Interface + common checks."""

    block_size: int
    n_blocks: int
    device_id: str

    def read_block(self, blockno: int) -> bytes:
        raise NotImplementedError

    def read_many(self, blocknos) -> "list[bytes]":
        """Vectorized read. The base implementation is a loop; devices
        with a real batch path (``LazyBlockDevice``) override it to serve
        the whole run in one provider round-trip. The buffer cache routes
        its miss runs here."""
        return [self.read_block(b) for b in blocknos]

    def write_block(self, blockno: int, data: bytes) -> None:
        raise NotImplementedError

    def sync(self) -> None:
        pass

    def _check(self, blockno: int, data: Optional[bytes] = None) -> None:
        if not (0 <= blockno < self.n_blocks):
            raise BlockDeviceError(f"block {blockno} out of range 0..{self.n_blocks}")
        if data is not None and len(data) != self.block_size:
            raise BlockDeviceError(
                f"partial write ({len(data)} != {self.block_size}) — "
                "read-modify-write through the buffer cache")

    # --- fault injection (crash-recovery property tests) --------------------------
    fail_after_writes: int = -1  # -1 disabled; else raise after N writes
    fail_torn_bytes: int = -1    # >= 0: the DYING write lands this many
    #   bytes before power dies (a torn block — what a real power cut does
    #   to an in-flight sector transfer; the journal's per-block checksums
    #   must catch it at recovery). Backends that pass a torn_writer to
    #   _maybe_fail honour it (MemBlockDevice and FileBlockDevice both do).
    _writes_seen: int = 0

    def _maybe_fail(self, torn_writer: Optional[Callable[[int], None]]
                    = None) -> None:
        """Write-stream fault injection: count down to the armed crash
        point, then die. ``torn_writer(nbytes)``, when the backend
        provides one and ``fail_torn_bytes`` is armed, lands a partial
        block before the power-loss exception — the torn-write case."""
        if self.fail_after_writes >= 0:
            if self._writes_seen >= self.fail_after_writes:
                if torn_writer is not None and self.fail_torn_bytes >= 0:
                    torn_writer(min(self.fail_torn_bytes, self.block_size))
                raise BlockDeviceError("injected crash: device lost power")
            self._writes_seen += 1


class MemBlockDevice(BlockDevice):
    def __init__(self, n_blocks: int, block_size: int = BLOCK_SIZE,
                 device_id: str = "mem0"):
        self.block_size = block_size
        self.n_blocks = n_blocks
        self.device_id = device_id
        self._data = np.zeros((n_blocks, block_size), dtype=np.uint8)
        self._lock = threading.Lock()
        self.reads = 0
        self.writes = 0

    def read_block(self, blockno: int) -> bytes:
        self._check(blockno)
        with self._lock:
            self.reads += 1
            return self._data[blockno].tobytes()

    def write_block(self, blockno: int, data: bytes) -> None:
        self._check(blockno, data)
        with self._lock:

            def torn(nbytes: int) -> None:
                # the dying write lands a prefix of the block — what a real
                # power cut does to an in-flight sector transfer
                self._data[blockno, :nbytes] = np.frombuffer(
                    data[:nbytes], dtype=np.uint8)

            self._maybe_fail(torn)
            self.writes += 1
            self._data[blockno] = np.frombuffer(data, dtype=np.uint8)

    def snapshot(self) -> "MemBlockDevice":
        """Copy-on-crash snapshot for recovery tests."""
        dev = MemBlockDevice(self.n_blocks, self.block_size, self.device_id)
        dev._data = self._data.copy()
        return dev


class LazyBlockDevice(BlockDevice):
    """Sparse local store over a remote provider (lazy materialization).

    ``provider`` is one of:

    * another ``BlockDevice`` (its ``read_many`` is the batch fetch path),
    * a callable ``fn(blockno) -> bytes`` (generator-style provider; give
      it a ``fetch_many(blocknos) -> list[bytes]`` attribute to batch), or
    * a content map via :meth:`content_provider` — blockno -> BlockStore
      hash, resolved through a content-addressed index.

    See the module docstring for the crash-ordered materialization
    protocol. ``provider_round_trips`` / ``provider_blocks_fetched`` are
    the cold-start counters ``benchmarks/fs_coldstart.py`` asserts on.
    """

    def __init__(self, provider, n_blocks: int,
                 block_size: int = BLOCK_SIZE, device_id: str = "lazy0",
                 base_blocks: Optional[int] = None,
                 immutable_base: bool = False):
        self.block_size = block_size
        self.n_blocks = n_blocks
        self.device_id = device_id
        if isinstance(provider, BlockDevice):
            if provider.block_size != block_size:
                raise BlockDeviceError("provider block size mismatch")
            if base_blocks is None:
                base_blocks = min(provider.n_blocks, n_blocks)
            self._fetch_batch = provider.read_many
        else:
            if base_blocks is None:
                base_blocks = n_blocks
            batch = getattr(provider, "fetch_many", None)
            self._fetch_batch = (batch if batch is not None
                                 else lambda bs: [provider(b) for b in bs])
        if base_blocks > n_blocks:
            raise BlockDeviceError("base range exceeds device size")
        self.provider = provider
        self.base_blocks = base_blocks
        self.immutable_base = immutable_base
        self._data = np.zeros((n_blocks, block_size), dtype=np.uint8)
        self._valid = np.zeros(n_blocks, dtype=bool)
        self._lock = threading.RLock()
        self.reads = 0
        self.writes = 0
        self.provider_round_trips = 0
        self.provider_blocks_fetched = 0

    @classmethod
    def content_provider(cls, store, src_dev, hashes):
        """Provider resolving blocks through a BlockStore content index:
        ``hashes`` maps blockno -> content hash; each fetch reads ANY
        source block carrying that hash (content-addressed, so they are
        all the same bytes)."""
        def fetch(blockno: int) -> bytes:
            h = hashes[blockno]
            owners = store._by_hash.get(h)
            if not owners:
                raise BlockDeviceError(f"content hash {h:#x} not in store")
            return src_dev.read_block(next(iter(owners)))
        return fetch

    def materialized(self, blockno: int) -> bool:
        return bool(self._valid[blockno])

    @property
    def n_materialized(self) -> int:
        return int(self._valid.sum())

    def _fetch(self, blocknos) -> None:
        """One provider round-trip for ``blocknos``, then the two-step
        local commit per block: data write (torn-capable), then valid-bit
        set — each a counted device write, so power loss can land between
        them and must leave the block invisible (protocol steps 2–3)."""
        datas = self._fetch_batch(blocknos)
        self.provider_round_trips += 1
        self.provider_blocks_fetched += len(blocknos)
        for blockno, data in zip(blocknos, datas):
            if len(data) != self.block_size:
                raise BlockDeviceError(
                    f"provider returned {len(data)} bytes for block {blockno}")

            def torn(nbytes: int, _b=blockno, _d=data) -> None:
                self._data[_b, :nbytes] = np.frombuffer(_d[:nbytes],
                                                        dtype=np.uint8)

            self._maybe_fail(torn)  # step 2: data lands locally
            self.writes += 1
            self._data[blockno] = np.frombuffer(data, dtype=np.uint8)
            self._maybe_fail()      # step 3: valid-bit commit point
            self.writes += 1
            self._valid[blockno] = True

    def read_block(self, blockno: int) -> bytes:
        self._check(blockno)
        with self._lock:
            self.reads += 1
            if not self._valid[blockno] and blockno < self.base_blocks:
                self._fetch([blockno])
            return self._data[blockno].tobytes()

    def read_many(self, blocknos) -> "list[bytes]":
        if not isinstance(blocknos, list):
            blocknos = list(blocknos)
        for b in blocknos:
            self._check(b)
        with self._lock:
            self.reads += len(blocknos)
            missing = [b for b in dict.fromkeys(blocknos)
                       if not self._valid[b] and b < self.base_blocks]
            if missing:
                self._fetch(missing)
            return [self._data[b].tobytes() for b in blocknos]

    def prefetch(self, blocknos) -> int:
        """Materialize ``blocknos`` (one provider round-trip) without
        returning data; returns how many blocks were actually fetched."""
        with self._lock:
            missing = [b for b in dict.fromkeys(blocknos)
                       if not self._valid[b] and b < self.base_blocks]
            if missing:
                self._fetch(missing)
            return len(missing)

    def write_block(self, blockno: int, data: bytes) -> None:
        self._check(blockno, data)
        if self.immutable_base and blockno < self.base_blocks:
            raise BlockDeviceError(
                f"block {blockno} is in the immutable base range")
        with self._lock:

            def torn(nbytes: int) -> None:
                # torn prefix lands; the valid bit is NOT set here, so a
                # torn write to a never-materialized block stays invisible
                # (the next read re-fetches the base content)
                self._data[blockno, :nbytes] = np.frombuffer(
                    data[:nbytes], dtype=np.uint8)

            self._maybe_fail(torn)
            self.writes += 1
            self._data[blockno] = np.frombuffer(data, dtype=np.uint8)
            self._valid[blockno] = True

    def snapshot(self) -> "LazyBlockDevice":
        """Copy-on-crash snapshot: local store + valid bitmap copied, the
        provider (immutable by contract) shared."""
        dev = LazyBlockDevice(self.provider, self.n_blocks, self.block_size,
                              self.device_id, base_blocks=self.base_blocks,
                              immutable_base=self.immutable_base)
        dev._fetch_batch = self._fetch_batch
        dev._data = self._data.copy()
        dev._valid = self._valid.copy()
        return dev


class FileBlockDevice(BlockDevice):
    """File-backed device (userspace binding). Whole-block pread/pwrite."""

    def __init__(self, path: str, n_blocks: int, block_size: int = BLOCK_SIZE,
                 device_id: str = "file0"):
        self.block_size = block_size
        self.n_blocks = n_blocks
        self.device_id = device_id
        self.path = path
        flags = os.O_RDWR | os.O_CREAT
        self._fd = os.open(path, flags, 0o644)
        os.ftruncate(self._fd, n_blocks * block_size)
        self._lock = threading.Lock()
        self.reads = 0
        self.writes = 0

    def read_block(self, blockno: int) -> bytes:
        self._check(blockno)
        with self._lock:
            self.reads += 1
            return os.pread(self._fd, self.block_size, blockno * self.block_size)

    def write_block(self, blockno: int, data: bytes) -> None:
        self._check(blockno, data)
        with self._lock:
            # the dying write may TEAR: a prefix of the block lands, the
            # rest never does (fail_torn_bytes) — the FUSE daemon's
            # crash-torture path proves recovery detects this via the
            # journal's per-block checksums
            self._maybe_fail(lambda n: os.pwrite(
                self._fd, data[:n], blockno * self.block_size))
            self.writes += 1
            os.pwrite(self._fd, data, blockno * self.block_size)

    def sync(self) -> None:
        os.fsync(self._fd)

    def close(self) -> None:
        os.close(self._fd)


class JaxBlockDevice(BlockDevice):
    """Immutable jnp-backed device: functional `.at[]` updates.

    Slow by design; exists so the whole storage substrate is expressible in
    JAX (property tests + the Pallas checksum path run against it).
    """

    def __init__(self, n_blocks: int, block_size: int = BLOCK_SIZE,
                 device_id: str = "jax0"):
        import jax.numpy as jnp

        self.block_size = block_size
        self.n_blocks = n_blocks
        self.device_id = device_id
        self._data = jnp.zeros((n_blocks, block_size), dtype=jnp.uint8)
        self.reads = 0
        self.writes = 0

    def read_block(self, blockno: int) -> bytes:
        self._check(blockno)
        self.reads += 1
        return bytes(np.asarray(self._data[blockno]))

    def write_block(self, blockno: int, data: bytes) -> None:
        self._check(blockno, data)
        self._maybe_fail()
        self.writes += 1
        import jax.numpy as jnp

        arr = jnp.frombuffer(bytearray(data), dtype=jnp.uint8)
        self._data = self._data.at[blockno].set(arr)
