"""CoW overlay mounts: one immutable base image, many writable tenants.

``OverlayFilesystem`` merges TWO complete file systems into one POSIX
view, overlayfs-style:

* the **base** — a read-only image every tenant shares, mounted over a
  ``LazyBlockDevice`` with ``immutable_base=True`` (blocks materialize
  from the golden image on first read, writes to the base range are
  refused at the device — see ``repro.fs.blockdev``);
* the **upper** — a small writable fs private to the tenant, holding
  every mutation: new files, copied-up base files, and *whiteouts*
  (``layout.WHITEOUT_INO`` dirents) recording "this base name is
  deleted here".

Provisioning a tenant therefore costs O(metadata): mkfs of the tiny
upper plus a lazy view of the base — never a copy of the base data
(``benchmarks/fs_coldstart.py`` asserts the ratio).

Merge rules (the overlayfs classics):

* lookup is upper-first: a live upper entry wins, a whiteout masks the
  base name (ENOENT), otherwise the base entry shows through with its
  ino tagged ``BASE_BIT`` so data ops know which layer to read;
* readdir is the union minus whiteouted names; an *opaque* upper dir
  (one carrying a whiteout named ``OPAQUE_MARK`` — set when a deleted
  base dir's name is recreated) hides the base dir wholesale;
* deleting a base-backed name writes a whiteout; deleting an upper name
  that also exists in base does both IN ONE journal transaction, so no
  crash point can resurrect the base version under a deleted name;
* writing a base file copies it up first: content is streamed into a
  hidden ``COWTMP_PREFIX`` name (invisible to the merged view; leftovers
  are reaped at mount), then ONE transaction renames it over the real
  name and applies the triggering op — at every crash point the name
  shows either the base bytes or the complete copy, never a torn blend;
* renaming a base-backed DIRECTORY (or displacing one) refuses with
  ``EXDEV``, exactly like kernel overlayfs — directories move by copy
  at a higher layer, not by the fs.

All upper mutations ride the upper's journal; multi-step overlay ops
(unlink+whiteout, mkdir+opaque, copy-up rename+write) reuse the chain
reservation machinery (``journal.begin_chain``) so each is one
crash-atomic transaction — ``repro.fs.crashsim.torture_overlay``
enumerates every device write to prove it. The base journal recovers
write-free on a clean image, so an immutable base mounts repeatedly.

The overlay is itself a ``BentoFilesystem``: it mounts through the
registry, speaks the batched boundary, transfers state across live
upgrades (§4.8) and — because it leaves ``inner`` unset — can be
wrapped by the provenance layer (``repro.fs.prov``) like any plain
module, with the provenance log landing in the tenant's upper.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.core.interface import (Attr, BentoFilesystem, Errno, FileKind,
                                  FsError, ROOT_INO)
from repro.fs import layout as L
from repro.fs.xv6 import Xv6FileSystem, Xv6Options

# Tag bit for inos served from the base layer: upper inos are bounded by
# ninodes (thousands), so bit 30 can never collide, and the tagged value
# still fits the u32 dirent field with room below WHITEOUT_INO.
BASE_BIT = 1 << 30

# Reserved upper names. OPAQUE_MARK is stored AS A WHITEOUT inside a
# directory (never a live entry), so plain readdir/lookup already hide
# it; COWTMP_PREFIX names are live upper files mid-copy-up, filtered
# from the merged view and reaped at mount.
OPAQUE_MARK = ".bento-opq"
COWTMP_PREFIX = ".bento-cowtmp."

# journal-blocks slack added to every chain reservation for the overlay's
# piggybacked mutations (a whiteout slot, an opacity marker)
_CHAIN_SLACK = 8


@dataclasses.dataclass
class OverlayOptions:
    """One tenant's recipe: which fs flavour runs the layers, and the
    (lazy, immutable) device holding the shared base image."""

    kind: str = "xv6"  # "xv6" | "ext4like" — class of BOTH layers
    base_dev: Any = None  # BlockDevice of the base image (per-tenant lazy view)
    upper_options: Optional[Xv6Options] = None


def _fs_class(kind: str):
    if kind == "xv6":
        return Xv6FileSystem
    if kind == "ext4like":
        from repro.fs.ext4like import Ext4LikeFileSystem
        return Ext4LikeFileSystem
    raise KeyError(kind)


class OverlayFilesystem(BentoFilesystem):
    """Merged view of a writable upper fs over an immutable base fs.

    ``inner`` stays None on purpose: to the upgrade machinery this is a
    PLAIN module (wrap_layer may stack provenance on top); ``upper`` and
    ``base`` are composition, not layering — neither alone presents the
    merged namespace.
    """

    NAME = "overlay"
    VERSION = 1

    def __init__(self, opts: OverlayOptions = OverlayOptions()):
        self.opts = opts
        self.upper: Optional[Xv6FileSystem] = None
        self.base: Optional[Xv6FileSystem] = None
        self.ks = None
        # merge maps — SESSION state (rebuilt from disk at init, carried
        # across live upgrades via extract_state, lost on cold remount):
        self._mirror: Dict[int, int] = {}    # upper dir ino -> base dir ino
        self._rmirror: Dict[int, int] = {}   # base dir ino -> upper dir ino
        self._base_parent: Dict[int, Tuple[int, str]] = {}  # bino -> (bdino, name)
        self._redirect: Dict[int, int] = {}  # tagged base ino -> upper ino
        self.ov_stats = {"copy_ups": 0, "copy_up_bytes": 0,
                         "mirror_dirs": 0, "whiteouts": 0}

    # --- lifecycle -------------------------------------------------------------
    def init(self, sb, services) -> None:
        if self.opts.base_dev is None:
            raise FsError(Errno.EINVAL, "overlay needs a base device")
        cls = _fs_class(self.opts.kind)
        self.ks = services
        self.upper = cls(self.opts.upper_options
                         or Xv6Options(group_commit=True,
                                       batched_install=True))
        self.upper.init(sb, services)
        # the base gets its own internal binding over the (immutable,
        # lazily materialized) image device; recovery on a clean image is
        # write-free, so mounting never violates immutability
        from repro.core.services import kernel_binding
        bks = kernel_binding(self.opts.base_dev)
        self.base = cls(Xv6Options(group_commit=True, batched_install=True))
        self.base.init(bks.superblock(), bks)
        self._mirror.clear()
        self._rmirror.clear()
        self._base_parent.clear()
        self._redirect.clear()
        self._rebuild_mirrors()
        self._cleanup_tmp()

    def destroy(self) -> None:
        if self.upper is not None:
            self.upper.destroy()
        # base: nothing to destroy — no mutation ever reached it, and a
        # flush against the immutable device would be refused anyway

    # --- §4.8 state transfer ------------------------------------------------------
    def extract_state(self) -> Dict:
        state = self.upper.extract_state()
        state["overlay"] = {
            "mirror": dict(self._mirror),
            "base_parent": {k: list(v) for k, v in self._base_parent.items()},
            "redirect": dict(self._redirect),
            "ov_stats": dict(self.ov_stats),
        }
        return state

    def restore_state(self, state: Dict, from_version: int) -> None:
        ov = state.get("overlay")
        self.upper.restore_state(
            {k: v for k, v in state.items() if k != "overlay"}, from_version)
        if ov is not None:
            self._mirror = {int(k): v for k, v in ov["mirror"].items()}
            self._rmirror = {v: k for k, v in self._mirror.items()}
            self._base_parent = {int(k): (v[0], v[1])
                                 for k, v in ov["base_parent"].items()}
            self._redirect = {int(k): v for k, v in ov["redirect"].items()}
            self.ov_stats.update(ov.get("ov_stats", {}))
        else:  # plain predecessor: bootstrap the merge maps from disk
            self._rebuild_mirrors()

    def _schema_upper(self):
        # wrap_layer probes the schema on a FRESH (un-init'd) instance;
        # xv6/ext4like schemas depend only on options, so a throwaway
        # built from opts answers identically to the mounted upper
        if self.upper is not None:
            return self.upper
        return _fs_class(self.opts.kind)(
            self.opts.upper_options or Xv6Options(group_commit=True,
                                                  batched_install=True))

    def state_schema(self) -> Tuple[str, ...]:
        return self._schema_upper().state_schema() + ("overlay",)

    def optional_state_keys(self) -> Tuple[str, ...]:
        return self._schema_upper().optional_state_keys() + ("overlay",)

    # --- forwarding the stacked-layer contract (prov wraps THIS module) ----------
    @property
    def journal(self):
        return getattr(self.upper, "journal", None)

    @property
    def stats(self):
        return getattr(self.upper, "stats", {})

    @property
    def _oplock(self):
        return self.upper._oplock

    @property
    def _CHAIN_OP_BLOCKS(self):
        return self.upper._CHAIN_OP_BLOCKS

    @property
    def _current_submitter(self):
        return getattr(self.upper, "_current_submitter", None)

    def estimate_append_blocks(self, nbytes: int) -> int:
        return self.upper.estimate_append_blocks(nbytes)

    def chain_begin(self, entries, extra_blocks: int = 0):
        """Reserve the chain on the UPPER journal, widened by the
        overlay's piggybacked mutations: whiteout/opacity slots, plus a
        full copy-up (create + content + rename) for every chained
        write/truncate that targets a not-yet-copied base file — those
        all land inside the chain's one transaction."""
        extra = extra_blocks + _CHAIN_SLACK
        for e in entries:
            if e.op in ("write", "truncate"):
                kw = e.kwargs or {}
                ino = e.args[0] if e.args else kw.get("ino")
                if isinstance(ino, int) and (ino & BASE_BIT) \
                        and ino not in self._redirect:
                    try:
                        sz = self.base.getattr(ino & ~BASE_BIT).size
                    except FsError:
                        sz = 0
                    extra += (self.upper.estimate_append_blocks(sz)
                              + self.upper._CHAIN_OP_BLOCKS.get("create", 6)
                              + self.upper._CHAIN_OP_BLOCKS.get("rename", 12))
        return self.upper.chain_begin(entries, extra_blocks=extra)

    def chain_end(self) -> None:
        self.upper.chain_end()

    # --- one-transaction scope for multi-step overlay mutations -------------------
    @contextlib.contextmanager
    def _txn(self, op: str, extra_blocks: int = 0):
        """Everything inside runs as ONE upper-journal transaction (the
        prov idiom): no-ops when this thread already holds a chain scope
        (the chain IS the transaction); degrades to per-op commits when
        the reservation can never fit — multi-step ops then lose their
        crash atomicity only on journals too small to ever hold them."""
        up = self.upper
        j = up.journal
        up._oplock.acquire()
        opened = False
        try:
            if j is not None and not j.in_chain_here:
                est = (up._CHAIN_OP_BLOCKS.get(op, 16)
                       + _CHAIN_SLACK + extra_blocks)
                try:
                    j.begin_chain(est)
                    opened = True
                except FsError:
                    pass
            yield
        finally:
            if opened:
                j.end_chain()
            up._oplock.release()

    # --- ino namespace ------------------------------------------------------------
    def _resolve(self, ino: int) -> Tuple[str, int]:
        """Map a caller-visible ino to its layer: copied-up/mirrored
        tagged inos follow the redirect to their upper twin."""
        if ino & BASE_BIT:
            up = self._redirect.get(ino)
            if up is not None:
                return "upper", up
            return "base", ino & ~BASE_BIT
        return "upper", ino

    @staticmethod
    def _tag(a: Attr) -> Attr:
        return dataclasses.replace(a, ino=a.ino | BASE_BIT)

    def _dir_pair(self, dino: int) -> Tuple[Optional[int], Optional[int]]:
        """(upper dino | None, base dino | None) for a merged directory."""
        layer, real = self._resolve(dino)
        if layer == "upper":
            return real, self._mirror.get(real)
        return None, real

    def _opaque(self, u: int) -> bool:
        return OPAQUE_MARK in self.upper.dir_whiteouts(u)

    def _base_entry(self, u: Optional[int], b: Optional[int],
                    name: str) -> Optional[int]:
        """Base ino contributing ``name`` to this merged dir, or None
        (no base side, name decided by an upper slot, or opaque dir)."""
        if b is None:
            return None
        if u is not None:
            if self.upper.dir_entry_state(u, name) is not None:
                return None  # live upper entry masks; whiteout deletes
            if self._opaque(u):
                return None
        st = self.base.dir_entry_state(b, name)
        return st[1] if st is not None and st[0] == "present" else None

    @staticmethod
    def _hidden(name: str) -> bool:
        return name == OPAQUE_MARK or name.startswith(COWTMP_PREFIX)

    def _check_overlay_name(self, name, creating: bool) -> None:
        if isinstance(name, str) and self._hidden(name):
            raise FsError(Errno.EPERM if creating else Errno.ENOENT, name)

    # --- mount-time reconstruction -------------------------------------------------
    def _rebuild_mirrors(self) -> None:
        """Re-derive the upper-dir <-> base-dir pairing from disk: walk
        upper dirs from the root, pairing each with the same-named base
        dir, stopping at opaque dirs (their base twin is dead). The
        pairing is pure convention — same path, both dirs — so a cold
        remount always reconstructs the same merge the live maps held."""
        stack = [(ROOT_INO, ROOT_INO)]
        while stack:
            u, b = stack.pop()
            if self._opaque(u):
                continue  # recreated-after-delete: base side stays hidden
            self._mirror[u] = b
            self._rmirror[b] = u
            bkids = {name: (ino, kind)
                     for name, ino, kind in self.base.readdir(b)}
            for name, uino, kind in self.upper.readdir(u):
                hit = bkids.get(name)
                if kind == FileKind.DIR and hit is not None \
                        and hit[1] == FileKind.DIR:
                    stack.append((uino, hit[0]))

    def _cleanup_tmp(self) -> None:
        """Reap copy-up temporaries a crash stranded (they were never
        visible — the merged view filters the prefix)."""
        stack = [ROOT_INO]
        while stack:
            u = stack.pop()
            for name, ino, kind in self.upper.readdir(u):
                if kind == FileKind.DIR:
                    stack.append(ino)
                elif name.startswith(COWTMP_PREFIX):
                    self.upper.unlink(u, name)

    # --- copy-up machinery ----------------------------------------------------------
    def _ensure_dir_mirror(self, b: int) -> int:
        """Writable twin of base dir ``b``: mkdir the ancestor chain in
        the upper as needed. Each mkdir is its own (journaled) op —
        a crash mid-chain leaves empty mirror dirs whose names the merge
        resolves identically, so the view never changes half-way."""
        u = self._rmirror.get(b)
        if u is not None:
            return u
        loc = self._base_parent.get(b)
        if loc is None:
            raise FsError(Errno.ESTALE, f"unknown base dir {b}")
        bparent, name = loc
        up = self._ensure_dir_mirror(bparent)
        a = self.upper.mkdir(up, name)
        self._mirror[a.ino] = b
        self._rmirror[b] = a.ino
        self._redirect[b | BASE_BIT] = a.ino
        self.ov_stats["mirror_dirs"] += 1
        return a.ino

    def _copy_up(self, tagged: int, limit: Optional[int] = None) -> int:
        """Materialize a base FILE into the upper under its own name and
        return the upper ino. Content streams into a hidden temp name in
        per-chunk transactions (crash: invisible leftover, reaped at
        mount); the final rename is left to the CALLER's transaction so
        it commits atomically with the op that forced the copy-up."""
        bino = tagged & ~BASE_BIT
        loc = self._base_parent.get(bino)
        if loc is None:
            raise FsError(Errno.ESTALE, f"unknown base file {bino}")
        bparent, name = loc
        a = self.base.getattr(bino)
        if a.is_dir:
            raise FsError(Errno.EISDIR, name)
        u = self._ensure_dir_mirror(bparent)
        tmp = f"{COWTMP_PREFIX}{bino}"
        if self.upper.dir_entry_state(u, tmp) is not None:
            self.upper.unlink(u, tmp)  # stale leftover from a crashed try
        ta = self.upper.create(u, tmp)
        nbytes = a.size if limit is None else min(a.size, limit)
        chunk = 16 * L.BSIZE
        for off in range(0, nbytes, chunk):
            n = min(chunk, nbytes - off)
            self.upper.write(ta.ino, off, self.base.read(bino, off, n))
        # caller's txn: flip the name from base-backed to the full copy
        self.upper.rename(u, tmp, u, name)
        self._redirect[tagged] = ta.ino
        self.ov_stats["copy_ups"] += 1
        self.ov_stats["copy_up_bytes"] += nbytes
        return ta.ino

    # --- namespace ops ---------------------------------------------------------------
    def getattr(self, ino: int) -> Attr:
        layer, real = self._resolve(ino)
        if layer == "upper":
            return self.upper.getattr(real)
        return self._tag(self.base.getattr(real))

    def lookup(self, parent: int, name: str) -> Attr:
        self._check_overlay_name(name, creating=False)
        with self.upper._oplock:
            u, b = self._dir_pair(parent)
            if u is not None:
                st = self.upper.dir_entry_state(u, name)
                if st is not None:
                    if st[0] == "whiteout":
                        raise FsError(Errno.ENOENT, name)
                    return self.upper.getattr(st[1])
                if b is not None and self._opaque(u):
                    b = None
            if b is not None:
                a = self.base.lookup(b, name)  # ENOENT/ENOTDIR propagate
                self._base_parent[a.ino] = (b, name)
                return self._tag(a)
            if u is None:
                # pure-base parent without a base side cannot happen; a
                # FILE parent must still errno like the plain fs
                raise FsError(Errno.ENOENT, name)
            # parent may be a file: dir_entry_state above raised ENOTDIR
            raise FsError(Errno.ENOENT, name)

    def readdir(self, ino: int) -> List[Tuple[str, int, FileKind]]:
        with self.upper._oplock:
            u, b = self._dir_pair(ino)
            out: List[Tuple[str, int, FileKind]] = []
            names = set()
            masked = set()
            if u is not None:
                for name, e_ino, kind in self.upper.readdir(u):
                    if self._hidden(name):
                        continue
                    names.add(name)
                    out.append((name, e_ino, kind))
                masked = set(self.upper.dir_whiteouts(u))
                if b is not None and self._opaque(u):
                    b = None
            if b is not None:
                for name, bino, kind in self.base.readdir(b):
                    if name in names or name in masked or self._hidden(name):
                        continue
                    self._base_parent[bino] = (b, name)
                    out.append((name, bino | BASE_BIT, kind))
            return out

    def _upper_parent_for(self, parent: int) -> Tuple[int, Optional[int]]:
        """Writable dino for a mutation under ``parent`` (mirroring a
        pure-base dir on demand) plus the base twin."""
        u, b = self._dir_pair(parent)
        if u is None:
            # raises ENOTDIR via base if parent is a file, ESTALE if unknown
            bdi = self.base.getattr(b)
            if not bdi.is_dir:
                raise FsError(Errno.ENOTDIR, str(parent))
            u = self._ensure_dir_mirror(b)
        return u, self._mirror.get(u)

    def _create_common(self, parent: int, name: str, mkdir: bool) -> Attr:
        self._check_overlay_name(name, creating=True)
        with self.upper._oplock:
            u, b = self._dir_pair(parent)
            st = (self.upper.dir_entry_state(u, name)
                  if u is not None else None)
            if st is not None and st[0] == "present":
                raise FsError(Errno.EEXIST, name)
            if st is None and self._base_entry(u, b, name) is not None:
                raise FsError(Errno.EEXIST, name)
            u, b = self._upper_parent_for(parent)
            was_whiteout = st is not None  # st can only be a whiteout here
            base_dir_under = False
            if was_whiteout and mkdir and b is not None:
                bst = self.base.dir_entry_state(b, name)
                base_dir_under = (bst is not None and bst[0] == "present"
                                  and self.base.getattr(bst[1]).is_dir)
            with self._txn("mkdir" if mkdir else "create"):
                a = (self.upper.mkdir if mkdir else self.upper.create)(u, name)
                if base_dir_under:
                    # recreating a deleted base dir's name: the new dir
                    # must NOT merge with the dead base dir after a
                    # remount — mark it opaque in the same transaction
                    self.upper.dir_set_whiteout(a.ino, OPAQUE_MARK)
            return a

    def create(self, parent: int, name: str) -> Attr:
        return self._create_common(parent, name, mkdir=False)

    def mkdir(self, parent: int, name: str) -> Attr:
        return self._create_common(parent, name, mkdir=True)

    def unlink(self, parent: int, name: str) -> None:
        self._check_overlay_name(name, creating=False)
        with self.upper._oplock:
            u, b = self._dir_pair(parent)
            st = (self.upper.dir_entry_state(u, name)
                  if u is not None else None)
            if st is not None:
                if st[0] == "whiteout":
                    raise FsError(Errno.ENOENT, name)
                shadowed = self._base_shadow(u, b, name)
                with self._txn("unlink"):
                    self.upper.unlink(u, name)  # EISDIR on dirs, like plain
                    if shadowed is not None:
                        # base still has the name: mask it in the SAME
                        # transaction or a crash between the two writes
                        # would resurrect the base version
                        self.upper.dir_set_whiteout(u, name)
                        self.ov_stats["whiteouts"] += 1
                self._drop_redirects(st[1])
                return
            bino = self._base_entry(u, b, name)
            if bino is None:
                raise FsError(Errno.ENOENT, name)
            if self.base.getattr(bino).is_dir:
                raise FsError(Errno.EISDIR, name)
            u2, _ = self._upper_parent_for(parent)
            with self._txn("unlink"):
                self.upper.dir_set_whiteout(u2, name)
            self.ov_stats["whiteouts"] += 1
            self._redirect.pop(bino | BASE_BIT, None)

    def _base_shadow(self, u, b, name) -> Optional[int]:
        """Base ino that would SHOW THROUGH if the upper entry vanished
        (ignores the live upper slot, honours opacity)."""
        if b is None:
            return None
        if u is not None and self._opaque(u):
            return None
        st = self.base.dir_entry_state(b, name)
        return st[1] if st is not None and st[0] == "present" else None

    def _drop_redirects(self, upper_ino: int) -> None:
        for t, up in list(self._redirect.items()):
            if up == upper_ino:
                del self._redirect[t]

    def rmdir(self, parent: int, name: str) -> None:
        self._check_overlay_name(name, creating=False)
        with self.upper._oplock:
            u, b = self._dir_pair(parent)
            st = (self.upper.dir_entry_state(u, name)
                  if u is not None else None)
            if st is not None:
                if st[0] == "whiteout":
                    raise FsError(Errno.ENOENT, name)
                child = st[1]
                cdi = self.upper.getattr(child)
                if not cdi.is_dir:
                    raise FsError(Errno.ENOTDIR, name)
                cb = self._mirror.get(child)
                wh = [n for n in self.upper.dir_whiteouts(child)]
                if self.upper.readdir(child):
                    raise FsError(Errno.ENOTEMPTY, name)
                if cb is not None:
                    live = {n for n, _, _ in self.base.readdir(cb)}
                    if live - set(wh):
                        raise FsError(Errno.ENOTEMPTY, name)
                shadowed = self._base_shadow(u, b, name)
                with self._txn("rmdir", extra_blocks=2 * len(wh) + 2):
                    for n in wh:  # purge markers so the plain rmdir sees empty
                        self.upper.dir_clear_whiteout(child, n)
                    self.upper.rmdir(u, name)
                    if shadowed is not None:
                        self.upper.dir_set_whiteout(u, name)
                        self.ov_stats["whiteouts"] += 1
                if cb is not None:
                    self._mirror.pop(child, None)
                    self._rmirror.pop(cb, None)
                    self._redirect.pop(cb | BASE_BIT, None)
                return
            bino = self._base_entry(u, b, name)
            if bino is None:
                raise FsError(Errno.ENOENT, name)
            ba = self.base.getattr(bino)
            if not ba.is_dir:
                raise FsError(Errno.ENOTDIR, name)
            if self.base.readdir(bino):
                raise FsError(Errno.ENOTEMPTY, name)
            u2, _ = self._upper_parent_for(parent)
            with self._txn("rmdir"):
                self.upper.dir_set_whiteout(u2, name)
            self.ov_stats["whiteouts"] += 1

    def rename(self, parent: int, name: str,
               newparent: int, newname: str) -> None:
        self._check_overlay_name(name, creating=False)
        self._check_overlay_name(newname, creating=True)
        with self.upper._oplock:
            su, sb_ = self._dir_pair(parent)
            sst = (self.upper.dir_entry_state(su, name)
                   if su is not None else None)
            if sst is not None and sst[0] == "whiteout":
                raise FsError(Errno.ENOENT, name)
            src_base = None
            src_is_dir = False
            if sst is None:
                src_base = self._base_entry(su, sb_, name)
                if src_base is None:
                    raise FsError(Errno.ENOENT, name)
                self._base_parent[src_base] = (sb_, name)
                if self.base.getattr(src_base).is_dir:
                    # a base-backed directory cannot move: its children
                    # live below, in the read-only layer (overlayfs EXDEV)
                    raise FsError(Errno.EXDEV, name)
            else:
                src_is_dir = self.upper.getattr(sst[1]).is_dir
                if src_is_dir and sst[1] in self._mirror:
                    raise FsError(Errno.EXDEV, name)  # merged dir: same rule
            du, db = self._dir_pair(newparent)
            dst_upper = (self.upper.dir_entry_state(du, newname)
                         if du is not None else None)
            base_dir_under_dst = False
            if dst_upper is not None and dst_upper[0] == "present":
                ddi = self.upper.getattr(dst_upper[1])
                if ddi.is_dir and dst_upper[1] in self._mirror:
                    raise FsError(Errno.EXDEV, newname)  # displacing merged
            else:
                dst_base = self._base_entry(du, db, newname)
                if dst_base is not None \
                        and self.base.getattr(dst_base).is_dir:
                    raise FsError(Errno.EXDEV, newname)  # displacing base dir
                if dst_upper is not None and db is not None:
                    # destination is a whiteout masking the base: if the
                    # dead base name was a DIR and a DIR is moving in, the
                    # newcomer must go opaque or a remount's mirror walk
                    # would pair it with the deleted base dir
                    bst = self.base.dir_entry_state(db, newname)
                    base_dir_under_dst = (
                        bst is not None and bst[0] == "present"
                        and self.base.getattr(bst[1]).is_dir)
            # below here everything is upper-resolvable: copy up a base
            # file source, mirror the destination parent, then ONE plain
            # upper rename (overwrite semantics included) plus the
            # overlay's masking writes, all in one transaction
            du2, db2 = self._upper_parent_for(newparent)
            if src_base is not None:
                su, sb_ = self._upper_parent_for(parent)
            src_shadow = self._base_shadow(su, sb_, name)
            dst_shadow_file = None
            if dst_upper is not None and dst_upper[0] == "present":
                dst_shadow_file = dst_upper[1]
            extra = 0
            if src_base is not None:
                extra = (self.upper.estimate_append_blocks(
                             self.base.getattr(src_base).size)
                         + self.upper._CHAIN_OP_BLOCKS.get("create", 6))
            moved_in_place = (su == du2 and name == newname)
            with self._txn("rename", extra_blocks=extra):
                if src_base is not None:
                    self._copy_up(src_base | BASE_BIT)
                self.upper.rename(su, name, du2, newname)
                if src_shadow is not None and not moved_in_place:
                    # the source name vanished from the upper but still
                    # exists below: mask it in the same transaction
                    self.upper.dir_set_whiteout(su, name)
                    self.ov_stats["whiteouts"] += 1
                if base_dir_under_dst and src_is_dir \
                        and not moved_in_place:
                    moved = self.upper.dir_entry_state(du2, newname)
                    self.upper.dir_set_whiteout(moved[1], OPAQUE_MARK)
            if dst_shadow_file is not None and not moved_in_place:
                self._drop_redirects(dst_shadow_file)

    # --- data ops ----------------------------------------------------------------------
    def read(self, ino: int, off: int, size: int) -> bytes:
        layer, real = self._resolve(ino)
        if layer == "upper":
            return self.upper.read(real, off, size)
        return self.base.read(real, off, size)

    def write(self, ino: int, off: int, data: bytes) -> int:
        layer, real = self._resolve(ino)
        if layer == "upper":
            return self.upper.write(real, off, data)
        with self.upper._oplock:
            with self._txn("write",
                           extra_blocks=self.upper.estimate_append_blocks(
                               self.base.getattr(real).size + len(data))):
                up = self._copy_up(ino)
                return self.upper.write(up, off, data)

    def truncate(self, ino: int, size: int) -> None:
        layer, real = self._resolve(ino)
        if layer == "upper":
            return self.upper.truncate(real, size)
        with self.upper._oplock:
            with self._txn("write",
                           extra_blocks=self.upper.estimate_append_blocks(
                               min(self.base.getattr(real).size, size))):
                # only the surviving prefix is worth copying
                up = self._copy_up(ino, limit=size)
                return self.upper.truncate(up, size)

    def fsync(self, ino: int) -> None:
        layer, real = self._resolve(ino)
        if layer == "upper":
            self.upper.fsync(real)
        # base inos: immutable and already durable — nothing to sync

    def flush(self) -> None:
        self.upper.flush()

    def statfs(self) -> Dict[str, int]:
        return self.upper.statfs()

    def read_provenance(self, since: int = 0, offset: int = 0,
                        limit: Optional[int] = None):
        return self.upper.read_provenance(since, offset, limit)
