"""POSIX-style path API over a mounted Bento file system.

This is the application-facing layer the benchmarks, the checkpoint store
and the examples use; it performs path walking + dentry caching on top of
the inode-granular file-operations API (like the kernel side of VFS does).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.interface import Attr, Errno, FsError, ROOT_INO


class PosixView:
    def __init__(self, mount, dentry_cache: bool = True):
        self.m = mount
        self._dcache: Dict[Tuple[int, str], int] = {}
        self._use_dcache = dentry_cache

    # --- path walking -------------------------------------------------------------
    def _walk(self, path: str) -> int:
        ino = ROOT_INO
        for part in self._parts(path):
            key = (ino, part)
            hit = self._dcache.get(key) if self._use_dcache else None
            if hit is not None:
                ino = hit
                continue
            attr = self.m.lookup(ino, part)
            if self._use_dcache:
                self._dcache[key] = attr.ino
            ino = attr.ino
        return ino

    @staticmethod
    def _parts(path: str) -> List[str]:
        return [p for p in path.split("/") if p]

    def _split(self, path: str) -> Tuple[int, str]:
        parts = self._parts(path)
        if not parts:
            raise FsError(Errno.EINVAL, path)
        parent = ROOT_INO
        for p in parts[:-1]:
            parent = self._walk_one(parent, p)
        return parent, parts[-1]

    def _walk_one(self, parent: int, name: str) -> int:
        key = (parent, name)
        hit = self._dcache.get(key) if self._use_dcache else None
        if hit is not None:
            return hit
        ino = self.m.lookup(parent, name).ino
        if self._use_dcache:
            self._dcache[key] = ino
        return ino

    def _invalidate(self, parent: int, name: str) -> None:
        self._dcache.pop((parent, name), None)

    # --- API ------------------------------------------------------------------------
    def create(self, path: str) -> Attr:
        parent, name = self._split(path)
        attr = self.m.create(parent, name)
        if self._use_dcache:
            self._dcache[(parent, name)] = attr.ino
        return attr

    def mkdir(self, path: str) -> Attr:
        parent, name = self._split(path)
        attr = self.m.mkdir(parent, name)
        if self._use_dcache:
            self._dcache[(parent, name)] = attr.ino
        return attr

    def makedirs(self, path: str) -> None:
        parts = self._parts(path)
        cur = ""
        for p in parts:
            cur += "/" + p
            try:
                self.mkdir(cur)
            except FsError as e:
                if e.errno != Errno.EEXIST:
                    raise

    def unlink(self, path: str) -> None:
        parent, name = self._split(path)
        self.m.unlink(parent, name)
        self._invalidate(parent, name)

    def rmdir(self, path: str) -> None:
        parent, name = self._split(path)
        self.m.rmdir(parent, name)
        self._invalidate(parent, name)

    def rename(self, old: str, new: str) -> None:
        p1, n1 = self._split(old)
        p2, n2 = self._split(new)
        self.m.rename(p1, n1, p2, n2)
        self._invalidate(p1, n1)
        self._invalidate(p2, n2)

    def listdir(self, path: str) -> List[str]:
        ino = self._walk(path)
        return [name for name, _, _ in self.m.readdir(ino)]

    def stat(self, path: str) -> Attr:
        return self.m.getattr(self._walk(path))

    def exists(self, path: str) -> bool:
        try:
            self._walk(path)
            return True
        except FsError:
            return False

    def write_file(self, path: str, data: bytes, *, off: int = 0,
                   create: bool = True) -> int:
        try:
            ino = self._walk(path)
        except FsError as e:
            if e.errno != Errno.ENOENT or not create:
                raise
            ino = self.create(path).ino
        return self.m.write(ino, off, data)

    def append(self, path: str, data: bytes) -> int:
        try:
            ino = self._walk(path)
            size = self.m.getattr(ino).size
        except FsError:
            ino = self.create(path).ino
            size = 0
        return self.m.write(ino, size, data)

    def read_file(self, path: str, off: int = 0, size: int = -1) -> bytes:
        ino = self._walk(path)
        if size < 0:
            size = self.m.getattr(ino).size - off
        return self.m.read(ino, off, max(size, 0))

    def truncate(self, path: str, size: int) -> None:
        self.m.truncate(self._walk(path), size)

    def fsync(self, path: str) -> None:
        self.m.fsync(self._walk(path))

    def statfs(self) -> Dict[str, int]:
        return self.m.statfs()
