"""POSIX-style path API over a mounted Bento file system.

This is the application-facing layer the benchmarks, the checkpoint store
and the examples use; it performs path walking + dentry caching on top of
the inode-granular file-operations API (like the kernel side of VFS does).

Two call surfaces share the dentry cache:

* scalar calls (``read_file``, ``write_file``, ``stat``, …) — unchanged:
  one gate-crossing and one dispatch per operation;
* plural forms (``read_many`` / ``write_many`` / ``stat_many``) — resolve
  paths through the dentry cache, then cross the module boundary ONCE per
  batch via ``mount.submit`` (preadv/pwritev over io_uring). Per-entry
  failures come back as in-list ``FsError`` values when ``strict=False``;
  by default the first failure raises, matching the scalar API.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.interface import (Attr, Errno, FsError, ROOT_INO,
                                  SubmissionEntry)


class PosixView:
    def __init__(self, mount, dentry_cache: bool = True):
        self.m = mount
        self._dcache: Dict[Tuple[int, str], int] = {}
        self._use_dcache = dentry_cache

    # --- path walking -------------------------------------------------------------
    def _walk(self, path: str) -> int:
        ino = ROOT_INO
        for part in self._parts(path):
            key = (ino, part)
            hit = self._dcache.get(key) if self._use_dcache else None
            if hit is not None:
                ino = hit
                continue
            attr = self.m.lookup(ino, part)
            if self._use_dcache:
                self._dcache[key] = attr.ino
            ino = attr.ino
        return ino

    @staticmethod
    def _parts(path: str) -> List[str]:
        return [p for p in path.split("/") if p]

    def _split(self, path: str) -> Tuple[int, str]:
        parts = self._parts(path)
        if not parts:
            raise FsError(Errno.EINVAL, path)
        parent = ROOT_INO
        for p in parts[:-1]:
            parent = self._walk_one(parent, p)
        return parent, parts[-1]

    def _walk_one(self, parent: int, name: str) -> int:
        key = (parent, name)
        hit = self._dcache.get(key) if self._use_dcache else None
        if hit is not None:
            return hit
        ino = self.m.lookup(parent, name).ino
        if self._use_dcache:
            self._dcache[key] = ino
        return ino

    def _invalidate(self, parent: int, name: str) -> None:
        self._dcache.pop((parent, name), None)

    # --- API ------------------------------------------------------------------------
    def create(self, path: str) -> Attr:
        parent, name = self._split(path)
        attr = self.m.create(parent, name)
        if self._use_dcache:
            self._dcache[(parent, name)] = attr.ino
        return attr

    def mkdir(self, path: str) -> Attr:
        parent, name = self._split(path)
        attr = self.m.mkdir(parent, name)
        if self._use_dcache:
            self._dcache[(parent, name)] = attr.ino
        return attr

    def makedirs(self, path: str) -> None:
        parts = self._parts(path)
        cur = ""
        for p in parts:
            cur += "/" + p
            try:
                self.mkdir(cur)
            except FsError as e:
                if e.errno != Errno.EEXIST:
                    raise

    def unlink(self, path: str) -> None:
        parent, name = self._split(path)
        self.m.unlink(parent, name)
        self._invalidate(parent, name)

    def rmdir(self, path: str) -> None:
        parent, name = self._split(path)
        self.m.rmdir(parent, name)
        self._invalidate(parent, name)

    def rename(self, old: str, new: str) -> None:
        p1, n1 = self._split(old)
        p2, n2 = self._split(new)
        self.m.rename(p1, n1, p2, n2)
        self._invalidate(p1, n1)
        self._invalidate(p2, n2)

    def listdir(self, path: str) -> List[str]:
        ino = self._walk(path)
        return [name for name, _, _ in self.m.readdir(ino)]

    def stat(self, path: str) -> Attr:
        return self.m.getattr(self._walk(path))

    def exists(self, path: str) -> bool:
        try:
            self._walk(path)
            return True
        except FsError:
            return False

    def write_file(self, path: str, data: bytes, *, off: int = 0,
                   create: bool = True) -> int:
        try:
            ino = self._walk(path)
        except FsError as e:
            if e.errno != Errno.ENOENT or not create:
                raise
            ino = self.create(path).ino
        return self.m.write(ino, off, data)

    def append(self, path: str, data: bytes) -> int:
        try:
            ino = self._walk(path)
            size = self.m.getattr(ino).size
        except FsError:
            ino = self.create(path).ino
            size = 0
        return self.m.write(ino, size, data)

    def read_file(self, path: str, off: int = 0, size: int = -1) -> bytes:
        ino = self._walk(path)
        if size < 0:
            size = self.m.getattr(ino).size - off
        return self.m.read(ino, off, max(size, 0))

    def truncate(self, path: str, size: int) -> None:
        self.m.truncate(self._walk(path), size)

    def fsync(self, path: str) -> None:
        self.m.fsync(self._walk(path))

    def statfs(self) -> Dict[str, int]:
        return self.m.statfs()

    # --- batched API (one boundary crossing per batch) ----------------------------
    @staticmethod
    def _unwrap(comps, strict: bool):
        if strict:
            return [c.unwrap() for c in comps]
        return [c.result if c.ok else FsError(c.errno, str(c.user_data))
                for c in comps]

    def _walk_many(self, paths: Sequence[str], *, strict: bool,
                   create: bool = False) -> List:
        """Resolve each path to an ino, walking repeats once. In strict
        mode walk failures raise (matching the scalar API); otherwise the
        failing slot holds its FsError and the rest proceed."""
        walked: Dict[str, Union[int, FsError]] = {}
        out: List = []
        for p in paths:
            r = walked.get(p)
            if r is None:
                try:
                    r = self._walk(p)
                except FsError as e:
                    if e.errno == Errno.ENOENT and create:
                        try:
                            r = self.create(p).ino
                        except FsError as e2:
                            if strict:
                                raise
                            r = e2
                    elif strict:
                        raise
                    else:
                        r = e
                walked[p] = r
            out.append(r)
        return out

    def _submit_sparse(self, resolved: List, entry_for, strict: bool) -> List:
        """Submit entries for the slots that resolved; failed slots keep
        their FsError in place (per-entry isolation end to end)."""
        idxs = [i for i, r in enumerate(resolved)
                if not isinstance(r, FsError)]
        results = self._unwrap(self.m.submit([entry_for(i) for i in idxs]),
                               strict)
        out = list(resolved)
        for i, res in zip(idxs, results):
            out[i] = res
        return out

    def read_many(self, specs: Sequence[Union[str, Tuple[str, int, int]]],
                  *, strict: bool = True) -> List:
        """Read many (path | (path, off, size)) specs in one submission.

        A bare path (or size < 0) means "the rest of the file": sizes for
        those are resolved with one batched getattr round first, so a full-
        file batch costs two boundary crossings total, not 2N.
        """
        norm: List[Tuple[str, int, int]] = [
            (s, 0, -1) if isinstance(s, str) else (s[0], s[1], s[2])
            for s in specs]
        resolved = self._walk_many([p for p, _, _ in norm], strict=strict)
        sized = sorted({r for (_, _, sz), r in zip(norm, resolved)
                        if sz < 0 and not isinstance(r, FsError)})
        if sized:
            attrs = self.m.submit([SubmissionEntry("getattr", (ino,),
                                                   user_data=ino)
                                   for ino in sized])
            size_of = {}
            for c in attrs:
                if c.ok:
                    size_of[c.user_data] = c.result.size
                elif strict:
                    c.unwrap()
                else:
                    size_of[c.user_data] = FsError(c.errno, "getattr")
            for i, ((p, off, sz), r) in enumerate(zip(norm, resolved)):
                if sz < 0 and not isinstance(r, FsError):
                    s = size_of[r]
                    if isinstance(s, FsError):
                        resolved[i] = s
                    else:
                        norm[i] = (p, off, max(s - off, 0))
        return self._submit_sparse(
            resolved,
            lambda i: SubmissionEntry("read",
                                      (resolved[i], norm[i][1], norm[i][2]),
                                      user_data=norm[i][0]),
            strict)

    def write_many(self, items: Sequence[Union[Tuple[str, bytes],
                                               Tuple[str, int, bytes]]],
                   *, create: bool = True, fsync: bool = False,
                   strict: bool = True) -> List:
        """Write many (path, data) / (path, off, data) items in one
        submission; with ``fsync=True`` a trailing flush entry commits the
        whole batch as one journal transaction (one checksum launch)."""
        norm = [(it[0], 0, it[1]) if len(it) == 2 else it for it in items]
        resolved = self._walk_many([p for p, _, _ in norm], strict=strict,
                                   create=create)
        idxs = [i for i, r in enumerate(resolved)
                if not isinstance(r, FsError)]
        entries = [SubmissionEntry("write",
                                   (resolved[i], norm[i][1], norm[i][2]),
                                   user_data=norm[i][0]) for i in idxs]
        if fsync:
            entries.append(SubmissionEntry("flush", (), user_data="<flush>"))
        comps = self.m.submit(entries)
        if fsync:
            comps[-1].unwrap()  # a failed commit is never ignorable
            comps = comps[:-1]
        results = self._unwrap(comps, strict)
        out = list(resolved)
        for i, res in zip(idxs, results):
            out[i] = res
        return out

    def stat_many(self, paths: Sequence[str], *, strict: bool = True) -> List:
        resolved = self._walk_many(paths, strict=strict)
        return self._submit_sparse(
            resolved,
            lambda i: SubmissionEntry("getattr", (resolved[i],),
                                      user_data=paths[i]),
            strict)
