"""POSIX-style path API over a mounted Bento file system.

This is the application-facing layer the benchmarks, the checkpoint store
and the examples use; it performs path walking + dentry caching on top of
the inode-granular file-operations API (like the kernel side of VFS does).

Two call surfaces share the dentry cache:

* scalar calls (``read_file``, ``write_file``, ``stat``, …) — unchanged:
  one gate-crossing and one dispatch per operation;
* plural forms (``read_many`` / ``write_many`` / ``stat_many`` /
  ``create_many`` / ``unlink_many`` / ``create_and_write_many``) — resolve
  paths through the dentry cache, then cross the module boundary ONCE per
  batch via ``mount.submit`` (preadv/pwritev over io_uring). Per-entry
  failures come back as in-list ``FsError`` values when ``strict=False``;
  by default a failure raises, matching the scalar API (after the whole
  batch ran — the batched forms never stop halfway through a submission).

Path walking in the plural forms is batched too: every path advances one
component per round, and each round's dentry-cache MISSES are resolved
with a single ``lookup`` submission (one gate crossing per dcache-miss
level, instead of one per missing component per path). Cache hits never
cross the boundary, so a warm walk still costs zero submissions.

Submissions ride a THREAD-LOCAL ``SubmitterQueue``: N threads sharing one
PosixView (or N views over one mount) stage into N per-thread SQs, and the
mount's drainer carries every queue pending at drain time across the
boundary in one gate crossing (io_uring SQPOLL-style — see
``repro.core.registry``). One thread sees exactly the old behaviour; many
threads see crossings ≪ submissions.
"""

from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.interface import (Attr, Errno, FsError, PrevResult, ROOT_INO,
                                  SQE_DRAIN, SQE_LINK, SubmissionEntry)


class PosixView:
    def __init__(self, mount, dentry_cache: bool = True):
        self.m = mount
        self._dcache: Dict[Tuple[int, str], int] = {}
        self._use_dcache = dentry_cache
        self._tls = threading.local()

    def _submit(self, entries: List[SubmissionEntry]):
        """Cross the boundary once for ``entries`` via this thread's
        SubmitterQueue (created on first use). The queue is drained to
        empty every call, so the completions returned are exactly this
        batch's, in submission order."""
        q = getattr(self._tls, "sq", None)
        if q is None:
            from repro.core.registry import SubmitterQueue
            q = self._tls.sq = SubmitterQueue(self.m)
        q.stage(entries)
        q.submit()
        return q.drain()

    # --- path walking -------------------------------------------------------------
    def _walk(self, path: str) -> int:
        ino = ROOT_INO
        for part in self._parts(path):
            key = (ino, part)
            hit = self._dcache.get(key) if self._use_dcache else None
            if hit is not None:
                ino = hit
                continue
            attr = self.m.lookup(ino, part)
            if self._use_dcache:
                self._dcache[key] = attr.ino
            ino = attr.ino
        return ino

    @staticmethod
    def _parts(path: str) -> List[str]:
        return [p for p in path.split("/") if p]

    def _split(self, path: str) -> Tuple[int, str]:
        parts = self._parts(path)
        if not parts:
            raise FsError(Errno.EINVAL, path)
        parent = ROOT_INO
        for p in parts[:-1]:
            parent = self._walk_one(parent, p)
        return parent, parts[-1]

    def _walk_one(self, parent: int, name: str) -> int:
        key = (parent, name)
        hit = self._dcache.get(key) if self._use_dcache else None
        if hit is not None:
            return hit
        ino = self.m.lookup(parent, name).ino
        if self._use_dcache:
            self._dcache[key] = ino
        return ino

    def _invalidate(self, parent: int, name: str) -> None:
        self._dcache.pop((parent, name), None)

    # --- API ------------------------------------------------------------------------
    def create(self, path: str) -> Attr:
        parent, name = self._split(path)
        attr = self.m.create(parent, name)
        if self._use_dcache:
            self._dcache[(parent, name)] = attr.ino
        return attr

    def mkdir(self, path: str) -> Attr:
        parent, name = self._split(path)
        attr = self.m.mkdir(parent, name)
        if self._use_dcache:
            self._dcache[(parent, name)] = attr.ino
        return attr

    def makedirs(self, path: str) -> None:
        parts = self._parts(path)
        cur = ""
        for p in parts:
            cur += "/" + p
            try:
                self.mkdir(cur)
            except FsError as e:
                if e.errno != Errno.EEXIST:
                    raise

    def unlink(self, path: str) -> None:
        parent, name = self._split(path)
        self.m.unlink(parent, name)
        self._invalidate(parent, name)

    def rmdir(self, path: str) -> None:
        parent, name = self._split(path)
        self.m.rmdir(parent, name)
        self._invalidate(parent, name)

    def rename(self, old: str, new: str) -> None:
        p1, n1 = self._split(old)
        p2, n2 = self._split(new)
        self.m.rename(p1, n1, p2, n2)
        self._invalidate(p1, n1)
        self._invalidate(p2, n2)

    def listdir(self, path: str) -> List[str]:
        ino = self._walk(path)
        return [name for name, _, _ in self.m.readdir(ino)]

    def stat(self, path: str) -> Attr:
        return self.m.getattr(self._walk(path))

    def exists(self, path: str) -> bool:
        try:
            self._walk(path)
            return True
        except FsError:
            return False

    def write_file(self, path: str, data: bytes, *, off: int = 0,
                   create: bool = True) -> int:
        try:
            ino = self._walk(path)
        except FsError as e:
            if e.errno != Errno.ENOENT or not create:
                raise
            ino = self.create(path).ino
        return self.m.write(ino, off, data)

    def append(self, path: str, data: bytes) -> int:
        try:
            ino = self._walk(path)
            size = self.m.getattr(ino).size
        except FsError:
            ino = self.create(path).ino
            size = 0
        return self.m.write(ino, size, data)

    def read_file(self, path: str, off: int = 0, size: int = -1) -> bytes:
        ino = self._walk(path)
        if size < 0:
            size = self.m.getattr(ino).size - off
        return self.m.read(ino, off, max(size, 0))

    def truncate(self, path: str, size: int) -> None:
        self.m.truncate(self._walk(path), size)

    def fsync(self, path: str) -> None:
        self.m.fsync(self._walk(path))

    def statfs(self) -> Dict[str, int]:
        return self.m.statfs()

    def read_provenance(self, since: int = 0, offset: int = 0,
                        limit: Optional[int] = None) -> List[Dict]:
        """Query the mounted provenance layer (paper §6): plain-value
        records for every mutation with ``seq >= since``, in execution
        order. ``offset``/``limit`` paginate within that selection (the
        whole triple rides the submission payload, so batched and FUSE
        dispatch paginate identically). Raises ``FsError(EINVAL)`` when no
        provenance layer is mounted — feature-probe with a try/except,
        like an ioctl."""
        return self.m.read_provenance(since, offset, limit)

    # --- batched API (one boundary crossing per batch) ----------------------------
    @staticmethod
    def _unwrap(comps, strict: bool):
        if strict:
            return [c.unwrap() for c in comps]
        return [c.result if c.ok else FsError(c.errno, str(c.user_data))
                for c in comps]

    def _walk_many(self, paths: Sequence[str], *, strict: bool,
                   create: bool = False) -> List:
        """Resolve each path to an ino with a *batched* walk, repeats
        walked once. All paths advance one component per round; a round's
        dcache misses become ONE ``lookup`` submission (scalar fallback
        never happens — a cold walk of N paths costs one submission per
        tree level, not one per component). With ``create=True``, final-
        component ENOENT misses become one trailing ``create`` batch,
        riding the fs's vectorized create path. In strict mode the first
        failing path's error raises — after the batch's walk and creates
        completed (the batched forms never stop mid-submission); otherwise
        the failing slot holds its FsError and the rest proceed."""
        uniq = list(dict.fromkeys(paths))
        parts = {p: self._parts(p) for p in uniq}
        res: Dict[str, Union[int, FsError]] = {}
        cur = {p: ROOT_INO for p in uniq}
        pending = list(uniq)
        level = 0
        while pending:
            nxt = []
            for p in pending:
                if len(parts[p]) == level:
                    res[p] = cur[p]
                else:
                    nxt.append(p)
            pending = nxt
            if not pending:
                break
            # dcache pass for this level; misses grouped by (parent, name)
            need: Dict[Tuple[int, str], List[str]] = {}
            for p in pending:
                key = (cur[p], parts[p][level])
                hit = self._dcache.get(key) if self._use_dcache else None
                if hit is not None:
                    cur[p] = hit
                else:
                    need.setdefault(key, []).append(p)
            if need:
                comps = self._submit(
                    [SubmissionEntry("lookup", k, user_data=k) for k in need])
                to_create: Dict[Tuple[int, str], List[str]] = {}
                for c in comps:
                    key = c.user_data
                    if c.ok:
                        ino = c.result.ino
                        if self._use_dcache:
                            self._dcache[key] = ino
                        for p in need[key]:
                            cur[p] = ino
                        continue
                    for p in need[key]:
                        if (create and c.errno == Errno.ENOENT
                                and len(parts[p]) == level + 1):
                            to_create.setdefault(key, []).append(p)
                        else:
                            res[p] = FsError(c.errno, key[1])
                if to_create:
                    ccomps = self._submit(
                        [SubmissionEntry("create", k, user_data=k)
                         for k in to_create])
                    for c in ccomps:
                        key = c.user_data
                        if c.ok:
                            ino = c.result.ino
                            if self._use_dcache:
                                self._dcache[key] = ino
                            for p in to_create[key]:
                                cur[p] = ino
                        else:
                            for p in to_create[key]:
                                res[p] = FsError(c.errno, key[1])
                pending = [p for p in pending if p not in res]
            level += 1
        if strict:
            for p in paths:
                if isinstance(res[p], FsError):
                    raise res[p]
        return [res[p] for p in paths]

    def _split_many(self, paths: Sequence[str], *, strict: bool) -> List:
        """Batched ``_split``: resolve every path's parent directory with
        one batched walk. Returns (parent_ino | FsError, name) per path."""
        pairs: List = [None] * len(paths)
        walk_idx: List[int] = []
        walk_paths: List[str] = []
        for i, p in enumerate(paths):
            parts = self._parts(p)
            if not parts:
                err = FsError(Errno.EINVAL, p)
                if strict:
                    raise err
                pairs[i] = (err, None)
            else:
                walk_idx.append(i)
                walk_paths.append("/".join(parts[:-1]))
                pairs[i] = (None, parts[-1])
        resolved = self._walk_many(walk_paths, strict=strict)
        for i, r in zip(walk_idx, resolved):
            pairs[i] = (r, pairs[i][1])
        return pairs

    def _submit_sparse(self, resolved: List, entry_for, strict: bool) -> List:
        """Submit entries for the slots that resolved; failed slots keep
        their FsError in place (per-entry isolation end to end)."""
        idxs = [i for i, r in enumerate(resolved)
                if not isinstance(r, FsError)]
        results = self._unwrap(self._submit([entry_for(i) for i in idxs]),
                               strict)
        out = list(resolved)
        for i, res in zip(idxs, results):
            out[i] = res
        return out

    def read_many(self, specs: Sequence[Union[str, Tuple[str, int, int]]],
                  *, strict: bool = True) -> List:
        """Read many (path | (path, off, size)) specs in one submission.

        A bare path (or size < 0) means "the rest of the file": sizes for
        those are resolved with one batched getattr round first, so a full-
        file batch costs two boundary crossings total, not 2N.
        """
        norm: List[Tuple[str, int, int]] = [
            (s, 0, -1) if isinstance(s, str) else (s[0], s[1], s[2])
            for s in specs]
        resolved = self._walk_many([p for p, _, _ in norm], strict=strict)
        sized = sorted({r for (_, _, sz), r in zip(norm, resolved)
                        if sz < 0 and not isinstance(r, FsError)})
        if sized:
            attrs = self._submit([SubmissionEntry("getattr", (ino,),
                                                   user_data=ino)
                                   for ino in sized])
            size_of = {}
            for c in attrs:
                if c.ok:
                    size_of[c.user_data] = c.result.size
                elif strict:
                    c.unwrap()
                else:
                    size_of[c.user_data] = FsError(c.errno, "getattr")
            for i, ((p, off, sz), r) in enumerate(zip(norm, resolved)):
                if sz < 0 and not isinstance(r, FsError):
                    s = size_of[r]
                    if isinstance(s, FsError):
                        resolved[i] = s
                    else:
                        norm[i] = (p, off, max(s - off, 0))
        if not any(isinstance(r, FsError) for r in resolved):
            # common case: everything resolved — build the entries in one
            # comprehension instead of a per-slot closure call
            return self._unwrap(
                self._submit([SubmissionEntry("read", (r, off, sz),
                                              user_data=p)
                              for r, (p, off, sz) in zip(resolved, norm)]),
                strict)
        return self._submit_sparse(
            resolved,
            lambda i: SubmissionEntry("read",
                                      (resolved[i], norm[i][1], norm[i][2]),
                                      user_data=norm[i][0]),
            strict)

    def write_many(self, items: Sequence[Union[Tuple[str, bytes],
                                               Tuple[str, int, bytes]]],
                   *, create: bool = True, fsync: bool = False,
                   strict: bool = True, chain: bool = False) -> List:
        """Write many (path, data) / (path, off, data) items in one
        submission; with ``fsync=True`` a trailing flush entry commits the
        whole batch as one journal transaction (one checksum launch).

        ``chain=True`` links every entry (SQE_LINK): writes execute in
        order and stop at the first failure — the rest complete
        ``ECANCELED``, and the trailing flush (when ``fsync``) is the chain
        tail, so nothing commits unless EVERY write succeeded (the
        checkpoint store's manifest-commit ordering). A chain is also ONE
        journal transaction (crash-atomic: after a crash either every
        write is installed or none — see ``repro.fs.journal``), which
        bounds it by journal capacity: a chain whose estimated footprint
        can never fit completes ENOSPC-first/ECANCELED-rest, so keep
        chained batches small (they are an atomicity unit, not a bulk-data
        path). A cancelled flush raises the first failing member's real
        errno in strict mode; with ``strict=False`` the per-entry slots
        tell the story (FsError / ECANCELED values) and nothing raises.
        Chained execution is member-by-member, so it trades the coalescing
        fast path for the ordering + atomicity guarantees."""
        norm = [(it[0], 0, it[1]) if len(it) == 2 else it for it in items]
        resolved = self._walk_many([p for p, _, _ in norm], strict=strict,
                                   create=create)
        idxs = [i for i, r in enumerate(resolved)
                if not isinstance(r, FsError)]
        flags = SQE_LINK if chain else 0
        entries = [SubmissionEntry("write",
                                   (resolved[i], norm[i][1], norm[i][2]),
                                   user_data=norm[i][0], flags=flags)
                   for i in idxs]
        if fsync:
            # chained: the flush is the chain TAIL (cancelled if any write
            # failed); unchained: SQE_DRAIN documents the barrier — the
            # flush runs only after every write completed
            entries.append(SubmissionEntry("flush", (), user_data="<flush>",
                                           flags=0 if chain else SQE_DRAIN))
        comps = self._submit(entries)
        if fsync:
            flush = comps[-1]
            comps = comps[:-1]
            if flush.errno == Errno.ECANCELED:
                # the chain stopped before the commit — that is requested
                # behaviour, not a commit failure. strict: surface the ROOT
                # cause (the first failing member), never the cancellation;
                # strict=False: the per-entry results carry the story.
                if strict:
                    for c in comps:
                        if c.errno not in (None, Errno.ECANCELED):
                            raise FsError(c.errno, str(c.user_data))
            else:
                flush.unwrap()  # a genuinely failed commit is never ignorable
        results = self._unwrap(comps, strict)
        out = list(resolved)
        for i, res in zip(idxs, results):
            out[i] = res
        return out

    def _meta_many(self, op: str, paths: Sequence[str], strict: bool,
                   on_success) -> List:
        """Shared body of the batched metadata forms: batched parent walk,
        ONE ``op`` submission, per-success dcache action, merged results."""
        pairs = self._split_many(paths, strict=strict)
        idxs = [i for i, (parent, _) in enumerate(pairs)
                if not isinstance(parent, FsError)]
        comps = self._submit(
            [SubmissionEntry(op, (pairs[i][0], pairs[i][1]),
                             user_data=paths[i]) for i in idxs]) \
            if idxs else []
        for i, c in zip(idxs, comps):
            if c.ok:
                on_success(pairs[i][0], pairs[i][1], c.result)
        results = self._unwrap(comps, strict)
        out = [p if isinstance(p, FsError) else None for p, _ in pairs]
        for i, r in zip(idxs, results):
            out[i] = r
        return out

    def create_many(self, paths: Sequence[str], *, strict: bool = True) -> List:
        """Create many files: one batched parent walk, then ONE ``create``
        submission riding the fs's vectorized create path (one gate
        crossing, one directory scan per touched parent). Returns the new
        Attr per slot."""
        def cache(parent, name, attr):
            if self._use_dcache:
                self._dcache[(parent, name)] = attr.ino
        return self._meta_many("create", paths, strict, cache)

    def unlink_many(self, paths: Sequence[str], *, strict: bool = True) -> List:
        """Unlink many paths: one batched parent walk, then ONE ``unlink``
        submission (the fs scans each touched directory once for the whole
        batch). Slots hold None on success."""
        return self._meta_many(
            "unlink", paths, strict,
            lambda parent, name, _res: self._invalidate(parent, name))

    def create_and_write_many(self, items: Sequence[Tuple[str, bytes]],
                              *, fsync: bool = False,
                              strict: bool = True) -> List:
        """Chained create→write per (path, data) item, all in ONE
        submission: each item's write is linked onto its create (SQE_LINK)
        and consumes the fresh ino via ``PrevResult("ino")`` — if the
        create fails, the write completes ECANCELED instead of running.
        With ``fsync=True`` one trailing (unchained) flush entry commits
        every item as ONE journal transaction — one checksum_batch launch
        for the whole batch, the batched analogue of per-file
        create+write+fsync. Returns bytes-written per item; a failed
        item's slot holds its first failing member's FsError."""
        paths = [p for p, _ in items]
        pairs = self._split_many(paths, strict=strict)
        idxs = [i for i, (parent, _) in enumerate(pairs)
                if not isinstance(parent, FsError)]
        entries: List[SubmissionEntry] = []
        for i in idxs:
            parent, name = pairs[i]
            entries.append(SubmissionEntry("create", (parent, name),
                                           user_data=(i, "create"),
                                           flags=SQE_LINK))
            entries.append(SubmissionEntry("write",
                                           (PrevResult("ino"), 0,
                                            items[i][1]),
                                           user_data=(i, "write")))
        if fsync and entries:
            # drain barrier: the commit waits for every chain in the batch
            entries.append(SubmissionEntry("flush", (), user_data="<flush>",
                                           flags=SQE_DRAIN))
        comps = self._submit(entries) if entries else []
        if fsync and entries:
            comps[-1].unwrap()
            comps = comps[:-1]
        out: List = [p if isinstance(p, FsError) else None
                     for p, _ in pairs]
        for c in comps:
            i, stage = c.user_data
            if stage == "create":
                if c.ok:
                    if self._use_dcache:
                        self._dcache[(pairs[i][0], pairs[i][1])] = \
                            c.result.ino
                else:
                    out[i] = FsError(c.errno, paths[i])
            elif c.ok:  # write
                out[i] = c.result
            elif not isinstance(out[i], FsError):
                out[i] = FsError(c.errno, paths[i])
        if strict:
            for r in out:
                if isinstance(r, FsError):
                    raise r
        return out

    def stat_many(self, paths: Sequence[str], *, strict: bool = True) -> List:
        resolved = self._walk_many(paths, strict=strict)
        return self._submit_sparse(
            resolved,
            lambda i: SubmissionEntry("getattr", (resolved[i],),
                                      user_data=paths[i]),
            strict)
