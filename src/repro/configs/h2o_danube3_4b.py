"""h2o-danube-3-4b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; unverified].

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000.
SWA makes decode state O(window) — runs long_500k.
"""

from repro.configs.base import ArchBundle, ModelConfig, RunConfig

MODEL = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    sliding_window=4096,
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="h2o-danube-3-4b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=160,
    vocab_size=256,
    sliding_window=32,
    rope_theta=10_000.0,
)

BUNDLE = ArchBundle(
    arch_id="h2o-danube-3-4b",
    model=MODEL,
    smoke=SMOKE,
    run=RunConfig(microbatch_per_data_shard=4, scan_group=6),
)
