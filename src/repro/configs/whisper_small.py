"""whisper-small — encoder-decoder audio model, conv frontend stubbed
[arXiv:2212.04356; unverified].

12L(enc)+12L(dec) d_model=768 12H (kv=12, i.e. MHA) d_ff=3072 vocab=51865.
``input_specs()`` provides precomputed frame embeddings (B, 1500, d_model)
per the stub-frontend rule. Decode shapes lower the decoder (self-attn KV
cache + fixed cross-attn KV over the 1500 encoder frames).

Note: 32k/500k decode shapes exceed Whisper's real 448-token context; the
32k cell is lowered as a shape exercise (EXPERIMENTS §Dry-run notes this),
while long_500k is skipped (full attention).
"""

from repro.configs.base import ArchBundle, ModelConfig, RunConfig

MODEL = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,  # decoder layers
    encoder_layers=12,
    encoder_seq=1500,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    rope_theta=10_000.0,  # we use RoPE in place of learned positions (noted in DESIGN)
)

SMOKE = ModelConfig(
    name="whisper-small-smoke",
    family="audio",
    num_layers=2,
    encoder_layers=2,
    encoder_seq=32,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    rope_theta=10_000.0,
)

BUNDLE = ArchBundle(
    arch_id="whisper-small",
    model=MODEL,
    smoke=SMOKE,
    run=RunConfig(),
    skip_shapes=(("long_500k", "full-attention enc-dec — skipped per spec"),),
)
