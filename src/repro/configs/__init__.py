from repro.configs.base import (
    ArchBundle,
    LM_SHAPES,
    ModelConfig,
    RunConfig,
    SHAPES_BY_NAME,
    ShapeSpec,
)
from repro.configs.registry import arch_ids, get

__all__ = [
    "ArchBundle",
    "LM_SHAPES",
    "ModelConfig",
    "RunConfig",
    "SHAPES_BY_NAME",
    "ShapeSpec",
    "arch_ids",
    "get",
]
