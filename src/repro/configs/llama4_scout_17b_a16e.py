"""llama4-scout-17b-a16e — MoE 16e top-1 with shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192(per expert) vocab=202048.
Early-fusion multimodality is a frontend stub (text path lowered here).
"""

from repro.configs.base import ArchBundle, ModelConfig, RunConfig

MODEL = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    num_experts=16,
    experts_per_token=1,
    shared_expert=True,
    rope_theta=500_000.0,
)

SMOKE = ModelConfig(
    name="llama4-scout-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=96,
    vocab_size=256,
    num_experts=4,
    experts_per_token=1,
    shared_expert=True,
    rope_theta=500_000.0,
)

BUNDLE = ArchBundle(
    arch_id="llama4-scout-17b-a16e",
    model=MODEL,
    smoke=SMOKE,
    run=RunConfig(moment_dtype="bfloat16", microbatch_per_data_shard=2, scan_group=8),
    skip_shapes=(("long_500k", "global-attention layers are quadratic — skipped per spec"),),
)
