"""olmoe-1b-7b — MoE, 64 experts top-8 [arXiv:2409.02060; hf].

16L d_model=2048 16H (GQA kv=16) d_ff=1024(per expert) vocab=50304.
"""

from repro.configs.base import ArchBundle, ModelConfig, RunConfig

MODEL = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    num_experts=64,
    experts_per_token=8,
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="olmoe-1b-7b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=64,
    vocab_size=256,
    num_experts=8,
    experts_per_token=2,
    rope_theta=10_000.0,
)

BUNDLE = ArchBundle(
    arch_id="olmoe-1b-7b",
    model=MODEL,
    smoke=SMOKE,
    run=RunConfig(microbatch_per_data_shard=8),
    skip_shapes=(("long_500k", "full-attention MoE — skipped per spec"),),
)
