"""smollm-135m — llama-arch small [hf:HuggingFaceTB/SmolLM-135M; hf].

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
"""

from repro.configs.base import ArchBundle, ModelConfig, RunConfig

MODEL = ModelConfig(
    name="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    rope_theta=10_000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="smollm-135m-smoke",
    family="dense",
    num_layers=2,
    d_model=48,
    num_heads=3,
    num_kv_heads=1,
    d_ff=128,
    vocab_size=256,
    rope_theta=10_000.0,
    tie_embeddings=True,
)

BUNDLE = ArchBundle(
    arch_id="smollm-135m",
    model=MODEL,
    smoke=SMOKE,
    # 9 heads don't shard over model=16 -> attention runs unsharded per data
    # shard; microbatching keeps its activation temps bounded.
    run=RunConfig(microbatch_per_data_shard=4),
    skip_shapes=(("long_500k", "pure full-attention arch — skipped per spec"),),
)
