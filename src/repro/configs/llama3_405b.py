"""llama3-405b — dense GQA, 128k vocab [arXiv:2407.21783; unverified].

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
"""

from repro.configs.base import ArchBundle, ModelConfig, RunConfig

MODEL = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=500_000.0,
)

SMOKE = ModelConfig(
    name="llama3-405b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    rope_theta=500_000.0,
)

# 405B does not fit fp32 Adam (+fp32 master) on 256 x 16GB chips: bf16
# weights (TPU MXU accumulates fp32 internally; cross-shard reduces in bf16
# like Megatron), bf16 first moment, factored second moment, microbatch=1
# with accumulation. An fp32-master-in-optstate option exists
# (RunConfig.master_weights) and is exercised in tests; it pushes this cell
# past 16 GB on a single pod, so the flagship cell runs pure-bf16 — see
# EXPERIMENTS.md §Dry-run for the accounting.
_RUN = RunConfig(
    param_dtype="bfloat16",
    moment_dtype="bfloat16",
    factored_second_moment=True,
    microbatch_per_data_shard=1,
    grad_accum_dtype="bfloat16",
    scan_group=6,  # 126 = 21x6: balances remat slices vs per-group gathered weights
)

BUNDLE = ArchBundle(
    arch_id="llama3-405b",
    model=MODEL,
    smoke=SMOKE,
    run=_RUN,
    skip_shapes=(("long_500k", "pure full-attention arch; 500k decode is quadratic-cache — skipped per spec"),),
)
