"""Config system: architecture, shape, mesh and run configuration.

Every assigned architecture is a ``ModelConfig`` built in its own
``src/repro/configs/<id>.py`` file; the registry maps ``--arch <id>`` to the
bundle (full config + reduced smoke config + shape set).

Design notes
------------
* Configs are frozen dataclasses — hashable, printable, and safe to close
  over in jitted functions.
* ``ShapeSpec.kind`` selects which program is lowered: ``train`` lowers
  ``train_step``; ``prefill``/``decode`` lower serving programs (one new
  token against a KV cache of ``seq_len`` for decode).
* Divisibility-aware sharding decisions live in ``repro.distributed.sharding``,
  not here; configs only carry declarative facts.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Declarative architecture description (one per assigned arch)."""

    name: str
    family: str  # dense | moe | vlm | ssm | audio | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention options -------------------------------------------------
    qkv_bias: bool = False  # qwen1.5 style
    sliding_window: int = 0  # 0 = full attention; >0 = SWA window (danube)
    rope_theta: float = 500_000.0
    attn_logit_softcap: float = 0.0

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1  # 1 = every layer is MoE (olmoe/scout)
    shared_expert: bool = False  # llama4 shared expert
    router_aux_loss: float = 0.01

    # --- VLM (llama-3.2-vision) ---------------------------------------------
    cross_attn_every: int = 0  # >0: every Nth layer is a gated cross-attn layer
    num_image_tokens: int = 0  # stub frontend supplies (B, T_img, d_model)

    # --- audio enc-dec (whisper) ---------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0  # stub conv frontend supplies (B, T_enc, d_model)

    # --- SSM / linear attention ----------------------------------------------
    ssm_state: int = 0  # mamba2 state size per head
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    wkv_head_dim: int = 64  # rwkv6 head size
    scan_chunk: int = 128  # chunked-scan block length for ssm/wkv

    # --- hybrid (zamba2) ------------------------------------------------------
    shared_attn_every: int = 0  # >0: weight-tied attn block applied every Nth layer

    # --- misc ----------------------------------------------------------------
    act: str = "silu"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    causal: bool = True

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # Convenience -------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm" and self.num_heads == 0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline cross-checks)."""
        from repro.models import registry as model_registry

        return model_registry.param_count(self)

    def active_param_count(self) -> int:
        from repro.models import registry as model_registry

        return model_registry.param_count(self, active_only=True)


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One benchmark cell's input shape."""

    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_serving(self) -> bool:
        return self.kind in ("prefill", "decode")


TRAIN_4K = ShapeSpec("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeSpec("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeSpec("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeSpec("long_500k", "decode", 524288, 1)

LM_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in LM_SHAPES}


# ---------------------------------------------------------------------------
# Run config (training/serving knobs; the hillclimb edits these, not models)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Execution knobs for a (arch × shape × mesh) cell."""

    # dtypes
    param_dtype: str = "float32"  # master copy
    compute_dtype: str = "bfloat16"
    moment_dtype: str = "float32"  # adam m/v; bf16 for very large archs
    factored_second_moment: bool = False  # adafactor-style v for 405B
    master_weights: bool = False  # fp32 master copy kept in optimizer state

    # batching
    microbatch_per_data_shard: int = 0  # 0 = no gradient accumulation
    grad_accum_dtype: str = "float32"  # bf16 for archs that cannot fit fp32 accum

    # memory policy
    remat: str = "block"  # none | block (remat each scanned layer)
    scan_layers: bool = True
    scan_group: int = 0  # >1: two-level grouped scan (O(L/G + G) remat memory)

    # sharding strategy name -> repro.distributed.sharding.RULESETS
    sharding_rules: str = "baseline"

    # optimizer
    optimizer: str = "adamw"
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    # distributed extras
    gradient_compression: str = "none"  # none | int8_ef | topk_ef
    pod_axis_mode: str = "dp"  # dp | pipeline
    moe_impl: str = "dense"  # dense (GShard einsum) | a2a (shard_map EP)

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Bundle: what `--arch <id>` resolves to
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchBundle:
    arch_id: str
    model: ModelConfig
    smoke: ModelConfig  # reduced same-family config for CPU tests
    shapes: Tuple[ShapeSpec, ...] = LM_SHAPES
    run: RunConfig = RunConfig()
    run_overrides: Tuple[Tuple[str, RunConfig], ...] = ()  # per-shape RunConfig
    skip_shapes: Tuple[Tuple[str, str], ...] = ()  # (shape_name, reason)

    def run_for(self, shape_name: str) -> RunConfig:
        for name, rc in self.run_overrides:
            if name == shape_name:
                return rc
        return self.run

    def skip_reason(self, shape_name: str) -> Optional[str]:
        for name, reason in self.skip_shapes:
            if name == shape_name:
                return reason
        return None
