"""``--arch <id>`` registry over the 10 assigned architectures."""

from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ArchBundle


def _load() -> Dict[str, ArchBundle]:
    from repro.configs import (
        h2o_danube3_4b,
        llama3_405b,
        llama4_scout_17b_a16e,
        llama32_vision_11b,
        olmoe_1b_7b,
        qwen15_110b,
        rwkv6_7b,
        smollm_135m,
        whisper_small,
        zamba2_7b,
    )

    bundles = [
        llama3_405b.BUNDLE,
        smollm_135m.BUNDLE,
        qwen15_110b.BUNDLE,
        h2o_danube3_4b.BUNDLE,
        olmoe_1b_7b.BUNDLE,
        llama4_scout_17b_a16e.BUNDLE,
        llama32_vision_11b.BUNDLE,
        rwkv6_7b.BUNDLE,
        whisper_small.BUNDLE,
        zamba2_7b.BUNDLE,
    ]
    return {b.arch_id: b for b in bundles}


_REGISTRY: Dict[str, ArchBundle] = {}


def get(arch_id: str) -> ArchBundle:
    global _REGISTRY
    if not _REGISTRY:
        _REGISTRY = _load()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def arch_ids() -> List[str]:
    global _REGISTRY
    if not _REGISTRY:
        _REGISTRY = _load()
    return sorted(_REGISTRY)
