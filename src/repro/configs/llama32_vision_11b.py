"""llama-3.2-vision-11b — VLM with gated cross-attention image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256; every 5th layer is
a gated cross-attention layer attending to stub-provided patch embeddings.
"""

from repro.configs.base import ArchBundle, ModelConfig, RunConfig

MODEL = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    cross_attn_every=5,
    num_image_tokens=6404,  # 4 tiles x 1601 patch embeddings (stub frontend)
    rope_theta=500_000.0,
)

SMOKE = ModelConfig(
    name="llama-3.2-vision-smoke",
    family="vlm",
    num_layers=4,  # one cross-attn super-block of period 2 x 2
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    cross_attn_every=2,
    num_image_tokens=16,
    rope_theta=500_000.0,
)

BUNDLE = ArchBundle(
    arch_id="llama-3.2-vision-11b",
    model=MODEL,
    smoke=SMOKE,
    run=RunConfig(microbatch_per_data_shard=4),
    skip_shapes=(("long_500k", "full-attention VLM — skipped per spec"),),
)
