"""rwkv6-7b (Finch) — attention-free, data-dependent decay linear attention
[arXiv:2404.05892; hf].

32L d_model=4096 d_ff=14336 vocab=65536. wkv head dim 64 -> 64 heads.
Attention-free: decode state is O(1) in sequence length — runs long_500k.
"""

from repro.configs.base import ArchBundle, ModelConfig, RunConfig

MODEL = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=0,  # attention-free
    num_kv_heads=0,
    head_dim=64,  # wkv head size
    d_ff=14336,
    vocab_size=65536,
    wkv_head_dim=64,
    # chunk=32 keeps the exact per-channel decay tensor (B,C,C,H,K) bounded
    scan_chunk=32,
)

SMOKE = ModelConfig(
    name="rwkv6-7b-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    num_heads=0,
    num_kv_heads=0,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    wkv_head_dim=16,
    scan_chunk=16,
)

BUNDLE = ArchBundle(
    arch_id="rwkv6-7b",
    model=MODEL,
    smoke=SMOKE,
    run=RunConfig(microbatch_per_data_shard=4, scan_group=8),
)
