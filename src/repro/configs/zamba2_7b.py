"""zamba2-7b — hybrid Mamba2 backbone + weight-tied shared attention blocks
[arXiv:2411.15242; unverified].

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000 ssm_state=64.
A single weight-tied transformer block is applied every 6th layer (13
applications over 81 layers), mirroring Zamba2's shared-block design.
SSM decode state is O(1) in sequence length — runs long_500k (the shared
attention blocks keep a KV cache; with 32 kv heads it shards cleanly).
"""

from repro.configs.base import ArchBundle, ModelConfig, RunConfig

MODEL = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    shared_attn_every=6,
    scan_chunk=128,
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="zamba2-7b-smoke",
    family="hybrid",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_expand=2,
    ssm_conv_width=4,
    shared_attn_every=2,
    scan_chunk=16,
    rope_theta=10_000.0,
)

BUNDLE = ArchBundle(
    arch_id="zamba2-7b",
    model=MODEL,
    smoke=SMOKE,
    run=RunConfig(microbatch_per_data_shard=4, scan_group=9),
)
