"""Expert-parallel MoE via shard_map + all_to_all (the MoE hillclimb).

The baseline dense-dispatch einsum under auto-SPMD reshards the (g, E, C)
combine tensor on every group step (~16x the useful routing volume measured
in the olmoe baseline HLO). This path controls the bytes explicitly:

  1. each model-rank routes its 1/16 slice of the local tokens (routing is
     replicated work otherwise),
  2. sort-based packing (no one-hot matmuls): assignments sorted by expert,
     packed into per-expert capacity buckets (E, C_e, d),
  3. one all_to_all over the model axis delivers each shard its 4 experts'
     buckets; expert FFNs run as local grouped matmuls,
  4. reverse all_to_all + scatter-add combine; one all_gather rejoins the
     per-rank token slices.

Wire bytes per call per chip ~= 2 x (E x C_e x d) [a2a] + T_loc x d [gather]
— measured 50x below the baseline's resharding traffic (EXPERIMENTS §Perf).
Capacity semantics (drops beyond C_e) match the dense baseline.
"""

from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as PS

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingCtx

CAPACITY_FACTOR = 1.25


def _pair_capacity(t_m: int, cfg: ModelConfig) -> int:
    c = math.ceil(t_m * cfg.experts_per_token * CAPACITY_FACTOR / cfg.num_experts)
    return max(8, ((c + 7) // 8) * 8)


def moe_a2a_apply(cfg: ModelConfig, ctx: ShardingCtx, w, x: jax.Array):
    """x: (B, S, d) with B sharded over the batch axes. Returns (y, aux)."""
    mesh = ctx.mesh
    assert mesh is not None and "model" in mesh.axis_names
    n_exp_shards = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    assert cfg.num_experts % n_exp_shards == 0
    e_loc = cfg.num_experts // n_exp_shards
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def local_moe(xb, router, w_gate, w_up, w_down):
        # xb: (B_loc, S, d); experts weights: (e_loc, ...) local shard
        dt = xb.dtype
        B_loc, S, d = xb.shape
        T = B_loc * S
        m = jax.lax.axis_index("model")
        t_m = T // n_exp_shards
        C = _pair_capacity(t_m, cfg)
        E, k = cfg.num_experts, cfg.experts_per_token

        xt = xb.reshape(T, d)
        x_m = jax.lax.dynamic_slice_in_dim(xt, m * t_m, t_m, axis=0)  # (t_m, d)

        # 1) route
        logits = jnp.matmul(x_m, router.astype(dt),
                            preferred_element_type=jnp.float32)
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        wk, ids = jax.lax.top_k(probs, k)  # (t_m, k)
        wk = wk / jnp.maximum(jnp.sum(wk, axis=-1, keepdims=True), 1e-9)

        # 2) sort-based packing into (E, C, d)
        flat_e = ids.reshape(-1)                      # (t_m*k,)
        flat_t = jnp.repeat(jnp.arange(t_m), k)
        flat_w = wk.reshape(-1).astype(jnp.float32)
        order = jnp.argsort(flat_e, stable=True)
        se, st_, sw = flat_e[order], flat_t[order], flat_w[order]
        # position within expert = index - start offset of that expert
        counts = jnp.bincount(se, length=E)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(t_m * k) - starts[se]
        keep = pos < C
        slot = jnp.where(keep, pos, C)  # C = spill row (dropped)
        send = jnp.zeros((E, C + 1, d), dt).at[se, slot].set(xt[st_ + m * t_m])
        send = send[:, :C]  # (E, C, d)

        # 3) a2a: (E, C, d) -> shard e_loc experts per rank
        recv = jax.lax.all_to_all(
            send.reshape(n_exp_shards, e_loc, C, d), "model",
            split_axis=0, concat_axis=0, tiled=False)
        # recv: (n_shards_src, e_loc, C, d) -> (e_loc, n_src*C, d)
        recv = jnp.moveaxis(recv, 0, 1).reshape(e_loc, n_exp_shards * C, d)

        # expert FFNs: grouped matmuls, fully local
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", recv, w_gate.astype(dt),
                                   preferred_element_type=dt))
        h = h * jnp.einsum("ecd,edf->ecf", recv, w_up.astype(dt),
                           preferred_element_type=dt)
        ye = jnp.einsum("ecf,efd->ecd", h, w_down.astype(dt),
                        preferred_element_type=dt)

        # 4) reverse a2a + combine
        ye = jnp.moveaxis(ye.reshape(e_loc, n_exp_shards, C, d), 1, 0)
        back = jax.lax.all_to_all(ye, "model", split_axis=0, concat_axis=0,
                                  tiled=False)
        back = back.reshape(E, C, d)  # my tokens' expert outputs
        picked = back[se, jnp.clip(slot, 0, C - 1)]
        picked = jnp.where((keep & True)[:, None], picked, 0)
        contrib = picked.astype(jnp.float32) * sw[:, None]
        y_m = jnp.zeros((t_m, d), jnp.float32).at[st_].add(contrib)

        # rejoin rank slices
        y = jax.lax.all_gather(y_m.astype(dt), "model", axis=0, tiled=True)
        # aux load-balance loss (Switch), averaged over shards
        frac = counts.astype(jnp.float32) / jnp.maximum(jnp.sum(counts), 1)
        mean_prob = jnp.mean(probs, axis=0)
        aux = E * jnp.sum(frac * mean_prob)
        aux = jax.lax.pmean(aux, "model")
        return y.reshape(B_loc, S, d), aux

    bspec = PS(batch_axes if batch_axes else None)
    fn = shard_map(
        local_moe, mesh=mesh,
        in_specs=(PS(batch_axes if batch_axes else None, None, None),
                  PS(None, None),
                  PS("model", None, None), PS("model", None, None),
                  PS("model", None, None)),
        out_specs=(PS(batch_axes if batch_axes else None, None, None), PS()),
        check_rep=False,
    )
    y, aux = fn(x, w["router"], w["w_gate"], w["w_up"], w["w_down"])
    if cfg.shared_expert:
        from repro.models.common import mlp_apply
        y = y + mlp_apply(w["shared"], x, ctx, cfg.act)
    return y, aux * cfg.router_aux_loss
