"""Decoder-only transformer stacks (dense / MoE / VLM families).

All stacks scan over layers with stacked parameters (compile-time O(1) in
depth); the VLM family scans over super-blocks of ``cross_attn_every`` layers
([p-1 self layers, 1 gated cross-attn layer] x groups, llama-3.2 style).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.distributed.sharding import ShardingCtx
from repro.models import params as P
from repro.models import attention as A
from repro.models import moe as M
from repro.models.common import matmul, mlp_apply, mlp_specs, rms_norm, rms_norm_specs


# --- single blocks ---------------------------------------------------------------


def block_specs(cfg: ModelConfig, *, moe: bool) -> Dict:
    s = {
        "ln1": rms_norm_specs(cfg.d_model),
        "attn": A.attn_specs(cfg),
        "ln2": rms_norm_specs(cfg.d_model),
    }
    if moe:
        s["moe"] = M.moe_specs(cfg)
    else:
        s["mlp"] = mlp_specs(cfg)
    return s


def block_apply(cfg: ModelConfig, run: RunConfig, ctx: ShardingCtx, w, x, positions,
                *, q_chunk: int = 1024):
    B, S, _ = x.shape
    h = rms_norm(x, w["ln1"], cfg.norm_eps)
    q = A.project_q(cfg, w["attn"], h, positions, ctx)
    k, v = A.project_kv(cfg, w["attn"], h, positions, ctx)
    o = A.attention_auto(q, k, v, causal=cfg.causal, window=cfg.sliding_window,
                         softcap=cfg.attn_logit_softcap, q_chunk=q_chunk, ctx=ctx)
    o = matmul(o.reshape(B, S, cfg.q_dim), w["attn"]["wo"])
    x = x + ctx.constrain(o, ("batch", "seq", "embed"))
    h2 = rms_norm(x, w["ln2"], cfg.norm_eps)
    if "moe" in w:
        y, aux = M.moe_apply(cfg, ctx, w["moe"], h2, impl=run.moe_impl)
    else:
        y, aux = mlp_apply(w["mlp"], h2, ctx, cfg.act), jnp.float32(0.0)
    return x + y, aux


def block_decode(cfg: ModelConfig, run: RunConfig, ctx: ShardingCtx, w, x, ck, cv,
                 pos, *, use_flash: bool = False):
    """One-token decode through one block. x: (B,1,d); ck/cv: (B,Sc,Hkv,D)."""
    B = x.shape[0]
    posv = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    h = rms_norm(x, w["ln1"], cfg.norm_eps)
    q = A.project_q(cfg, w["attn"], h, posv, ctx)
    k, v = A.project_kv(cfg, w["attn"], h, posv, ctx)
    ck, cv = A.cache_update(ck, cv, k, v, pos, window=cfg.sliding_window)
    if use_flash and ctx.mesh is not None:
        o = A.flash_decode(q, ck, cv, pos, ctx.mesh, softcap=cfg.attn_logit_softcap,
                           window=cfg.sliding_window)
    else:
        o = A.decode_attention(q, ck, cv, pos, window=cfg.sliding_window,
                               softcap=cfg.attn_logit_softcap)
    o = matmul(o.reshape(B, 1, cfg.q_dim), w["attn"]["wo"])
    x = x + o
    h2 = rms_norm(x, w["ln2"], cfg.norm_eps)
    if "moe" in w:
        y, _ = M.moe_apply(cfg, ctx, w["moe"], h2, impl=run.moe_impl)
    else:
        y = mlp_apply(w["mlp"], h2, ctx, cfg.act)
    return x + y, ck, cv


def block_prefill(cfg, run, ctx, w, x, positions, *, q_chunk=1024):
    """Like block_apply but also returns this layer's (k, v) for the cache."""
    B, S, _ = x.shape
    h = rms_norm(x, w["ln1"], cfg.norm_eps)
    q = A.project_q(cfg, w["attn"], h, positions, ctx)
    k, v = A.project_kv(cfg, w["attn"], h, positions, ctx)
    o = A.attention_auto(q, k, v, causal=cfg.causal, window=cfg.sliding_window,
                         softcap=cfg.attn_logit_softcap, q_chunk=q_chunk, ctx=ctx)
    o = matmul(o.reshape(B, S, cfg.q_dim), w["attn"]["wo"])
    x = x + o
    h2 = rms_norm(x, w["ln2"], cfg.norm_eps)
    if "moe" in w:
        y, _ = M.moe_apply(cfg, ctx, w["moe"], h2, impl=run.moe_impl)
    else:
        y = mlp_apply(w["mlp"], h2, ctx, cfg.act)
    return x + y, k, v


# --- cross-attention block (VLM) ---------------------------------------------------


def cross_block_specs(cfg: ModelConfig) -> Dict:
    return {
        "ln1": rms_norm_specs(cfg.d_model),
        "xattn": A.attn_specs(cfg, cross=True),
        "gate_attn": P.dense((), (), init="zeros"),
        "ln2": rms_norm_specs(cfg.d_model),
        "mlp": mlp_specs(cfg),
        "gate_mlp": P.dense((), (), init="zeros"),
    }


def cross_block_apply(cfg, ctx, w, x, img):
    h = rms_norm(x, w["ln1"], cfg.norm_eps)
    o = A.cross_attention(cfg, w["xattn"], h, img, ctx)
    x = x + jnp.tanh(w["gate_attn"]).astype(x.dtype) * o
    h2 = rms_norm(x, w["ln2"], cfg.norm_eps)
    x = x + jnp.tanh(w["gate_mlp"]).astype(x.dtype) * mlp_apply(w["mlp"], h2, ctx, cfg.act)
    return x


def cross_block_decode(cfg, ctx, w, x, img_k, img_v):
    h = rms_norm(x, w["ln1"], cfg.norm_eps)
    o = A.cross_decode(cfg, w["xattn"], h, img_k, img_v)
    x = x + jnp.tanh(w["gate_attn"]).astype(x.dtype) * o
    h2 = rms_norm(x, w["ln2"], cfg.norm_eps)
    x = x + jnp.tanh(w["gate_mlp"]).astype(x.dtype) * mlp_apply(w["mlp"], h2, ctx, cfg.act)
    return x


# --- stacks -----------------------------------------------------------------------


def _vlm_groups(cfg: ModelConfig) -> Tuple[int, int]:
    p = cfg.cross_attn_every
    assert p > 1 and cfg.num_layers % p == 0, (cfg.num_layers, p)
    return cfg.num_layers // p, p - 1  # (groups, self layers per group)


def stack_specs(cfg: ModelConfig) -> Dict:
    moe = cfg.is_moe
    if cfg.family == "vlm":
        g, s = _vlm_groups(cfg)
        self_specs = P.stack_tree(s, block_specs(cfg, moe=False))
        return {
            "self": P.map_specs(lambda sp: P.stacked(g, sp), self_specs),
            "cross": P.stack_tree(g, cross_block_specs(cfg)),
        }
    return {"layers": P.stack_tree(cfg.num_layers, block_specs(cfg, moe=moe))}


def stack_apply(cfg: ModelConfig, run: RunConfig, ctx: ShardingCtx, w, x,
                positions, *, img: Optional[jax.Array] = None, q_chunk=1024):
    """Full-sequence forward. Returns (x, aux_loss)."""
    from repro.models.scan_utils import grouped_scan

    remat = run.remat == "block"

    def one_layer(carry, wl):
        x, aux = carry
        x, a = block_apply(cfg, run, ctx, wl, x, positions, q_chunk=q_chunk)
        return (x, aux + a.astype(jnp.float32)), None

    if cfg.family == "vlm":
        one_layer_ck = jax.checkpoint(one_layer) if remat else one_layer

        def one_group(carry, wg):
            (x, aux) = carry
            (x, aux), _ = jax.lax.scan(one_layer_ck, (x, aux), wg["self"])
            x = cross_block_apply(cfg, ctx, wg["cross"], x, img)
            return (x, aux), None

        if remat:
            one_group = jax.checkpoint(one_group)
        (x, aux), _ = jax.lax.scan(one_group, (x, jnp.float32(0.0)), w)
        return x, aux

    (x, aux), _ = grouped_scan(one_layer, (x, jnp.float32(0.0)), w["layers"],
                               cfg.num_layers, run.scan_group, remat)
    return x, aux


def stack_cache_specs(cfg: ModelConfig, batch: int, cache_len: int) -> Dict:
    base = A.cache_specs(cfg, batch, A.effective_cache_len(cfg, cache_len))
    if cfg.family == "vlm":
        g, s = _vlm_groups(cfg)
        self_cache = P.map_specs(lambda sp: P.stacked(s, sp), base)
        self_cache = P.map_specs(lambda sp: P.stacked(g, sp), self_cache)
        img_kv = (batch, cfg.num_image_tokens, cfg.num_kv_heads, cfg.head_dim)
        cross = {
            "img_k": P.dense(img_kv, ("batch", "img_seq", "cache_heads", "head_dim"),
                             init="zeros", dtype="bfloat16"),
            "img_v": P.dense(img_kv, ("batch", "img_seq", "cache_heads", "head_dim"),
                             init="zeros", dtype="bfloat16"),
        }
        return {"self": self_cache, "cross": P.stack_tree(g, cross)}
    return P.stack_tree(cfg.num_layers, base)


def stack_decode(cfg: ModelConfig, run: RunConfig, ctx: ShardingCtx, w, cache, x,
                 pos, *, use_flash=False):
    """One-token decode. Returns (x, new_cache)."""

    def one_layer(x, inp):
        wl, ck, cv = inp
        x, ck, cv = block_decode(cfg, run, ctx, wl, x, ck, cv, pos, use_flash=use_flash)
        return x, (ck, cv)

    if cfg.family == "vlm":
        def one_group(x, inp):
            wg, cg, cross_kv = inp

            def inner(x, i2):
                wl, ck, cv = i2
                x, ck, cv = block_decode(cfg, run, ctx, wl, x, ck, cv, pos,
                                         use_flash=use_flash)
                return x, (ck, cv)

            x, (ks, vs) = jax.lax.scan(inner, x, (wg["self"], cg["k"], cg["v"]))
            x = cross_block_decode(cfg, ctx, wg["cross"], x,
                                   cross_kv["img_k"], cross_kv["img_v"])
            return x, {"k": ks, "v": vs}

        x, new_self = jax.lax.scan(one_group, x, (w, cache["self"], cache["cross"]))
        return x, {"self": new_self, "cross": cache["cross"]}

    x, (ks, vs) = jax.lax.scan(one_layer, x, (w["layers"], cache["k"], cache["v"]))
    return x, {"k": ks, "v": vs}


def stack_prefill(cfg: ModelConfig, run: RunConfig, ctx: ShardingCtx, w, x,
                  positions, *, img=None, q_chunk=1024):
    """Full-sequence forward that also builds the KV cache. Returns (x, cache)."""
    eff = A.effective_cache_len(cfg, x.shape[1])

    def trim(k):
        if cfg.sliding_window > 0:
            return A.ring_layout(k, cfg.sliding_window)
        return k[:, -eff:] if eff < k.shape[1] else k

    def one_layer(x, wl):
        x, k, v = block_prefill(cfg, run, ctx, wl, x, positions, q_chunk=q_chunk)
        return x, (trim(k).astype(jnp.bfloat16), trim(v).astype(jnp.bfloat16))

    if cfg.family == "vlm":
        def one_group(x, wg):
            x, kv = jax.lax.scan(one_layer, x, wg["self"])
            ks, vs = kv
            x = cross_block_apply(cfg, ctx, wg["cross"], x, img)
            dt = x.dtype
            B, T = img.shape[:2]
            ik = (img @ wg["cross"]["xattn"]["wk"].astype(dt)).reshape(
                B, T, cfg.num_kv_heads, cfg.head_dim)
            iv = (img @ wg["cross"]["xattn"]["wv"].astype(dt)).reshape(
                B, T, cfg.num_kv_heads, cfg.head_dim)
            return x, ({"k": ks, "v": vs},
                       {"img_k": ik.astype(jnp.bfloat16), "img_v": iv.astype(jnp.bfloat16)})

        x, (self_c, cross_c) = jax.lax.scan(one_group, x, w)
        return x, {"self": self_c, "cross": cross_c}

    x, (ks, vs) = jax.lax.scan(one_layer, x, w["layers"])
    return x, {"k": ks, "v": vs}
