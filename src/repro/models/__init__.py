from repro.models import lm, params

__all__ = ["lm", "params"]
