"""Whisper-style encoder-decoder (audio family).

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, encoder_seq, d_model). The transformer
backbone (bidirectional encoder + causal decoder with cross-attention) is
fully implemented.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.distributed.sharding import ShardingCtx
from repro.models import params as P
from repro.models import attention as A
from repro.models.common import mlp_apply, mlp_specs, rms_norm, rms_norm_specs


def enc_layer_specs(cfg: ModelConfig) -> Dict:
    return {
        "ln1": rms_norm_specs(cfg.d_model),
        "attn": A.attn_specs(cfg),
        "ln2": rms_norm_specs(cfg.d_model),
        "mlp": mlp_specs(cfg),
    }


def dec_layer_specs(cfg: ModelConfig) -> Dict:
    return {
        "ln1": rms_norm_specs(cfg.d_model),
        "attn": A.attn_specs(cfg),
        "lnx": rms_norm_specs(cfg.d_model),
        "xattn": A.attn_specs(cfg, cross=True),
        "ln2": rms_norm_specs(cfg.d_model),
        "mlp": mlp_specs(cfg),
    }


def stack_specs(cfg: ModelConfig) -> Dict:
    return {
        "encoder": P.stack_tree(cfg.encoder_layers, enc_layer_specs(cfg)),
        "enc_ln": rms_norm_specs(cfg.d_model),
        "decoder": P.stack_tree(cfg.num_layers, dec_layer_specs(cfg)),
    }


def _enc_layer(cfg, run, ctx, w, x, positions):
    B, S, _ = x.shape
    h = rms_norm(x, w["ln1"], cfg.norm_eps)
    q = A.project_q(cfg, w["attn"], h, positions, ctx)
    k, v = A.project_kv(cfg, w["attn"], h, positions, ctx)
    o = A.attention_dense(q, k, v, causal=False)
    x = x + o.reshape(B, S, cfg.q_dim) @ w["attn"]["wo"].astype(x.dtype)
    h2 = rms_norm(x, w["ln2"], cfg.norm_eps)
    return x + mlp_apply(w["mlp"], h2, ctx, act="gelu")


def encode(cfg, run, ctx, w, frames):
    """frames: (B, T_enc, d) stub embeddings -> encoder states."""
    B, S, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(x, wl):
        return _enc_layer(cfg, run, ctx, wl, x, positions), None

    if run.remat == "block":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, frames, w["encoder"])
    return rms_norm(x, w["enc_ln"], cfg.norm_eps)


def _dec_layer(cfg, run, ctx, w, x, enc, positions, *, q_chunk=1024):
    B, S, _ = x.shape
    h = rms_norm(x, w["ln1"], cfg.norm_eps)
    q = A.project_q(cfg, w["attn"], h, positions, ctx)
    k, v = A.project_kv(cfg, w["attn"], h, positions, ctx)
    o = A.attention_auto(q, k, v, causal=True, q_chunk=q_chunk, ctx=ctx)
    x = x + o.reshape(B, S, cfg.q_dim) @ w["attn"]["wo"].astype(x.dtype)
    hx = rms_norm(x, w["lnx"], cfg.norm_eps)
    x = x + A.cross_attention(cfg, w["xattn"], hx, enc, ctx)
    h2 = rms_norm(x, w["ln2"], cfg.norm_eps)
    return x + mlp_apply(w["mlp"], h2, ctx, act="gelu")


def decode_train(cfg, run, ctx, w, x, enc, positions, *, q_chunk=1024):
    def body(x, wl):
        return _dec_layer(cfg, run, ctx, wl, x, enc, positions, q_chunk=q_chunk), None

    if run.remat == "block":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, w["decoder"])
    return x


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int) -> Dict:
    self_kv = A.cache_specs(cfg, batch, cache_len)
    cross_shape = (batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim)
    cross = {
        "ck": P.dense(cross_shape, ("batch", "img_seq", "cache_heads", "head_dim"),
                      init="zeros", dtype="bfloat16"),
        "cv": P.dense(cross_shape, ("batch", "img_seq", "cache_heads", "head_dim"),
                      init="zeros", dtype="bfloat16"),
    }
    per_layer = {**self_kv, **cross}
    return P.stack_tree(cfg.num_layers, per_layer)


def prefill(cfg, run, ctx, w, tokens_x, frames, positions, *, q_chunk=1024):
    """Returns (x, cache) with self KV + precomputed cross KV per layer."""
    enc = encode(cfg, run, ctx, w, frames)
    B = tokens_x.shape[0]
    T = enc.shape[1]
    dt = tokens_x.dtype

    def body(x, wl):
        h = rms_norm(x, wl["ln1"], cfg.norm_eps)
        q = A.project_q(cfg, wl["attn"], h, positions, ctx)
        k, v = A.project_kv(cfg, wl["attn"], h, positions, ctx)
        o = A.attention_auto(q, k, v, causal=True, q_chunk=q_chunk, ctx=ctx)
        x = x + o.reshape(B, x.shape[1], cfg.q_dim) @ wl["attn"]["wo"].astype(dt)
        hx = rms_norm(x, wl["lnx"], cfg.norm_eps)
        x = x + A.cross_attention(cfg, wl["xattn"], hx, enc, ctx)
        h2 = rms_norm(x, wl["ln2"], cfg.norm_eps)
        x = x + mlp_apply(wl["mlp"], h2, ctx, act="gelu")
        ck = (enc @ wl["xattn"]["wk"].astype(dt)).reshape(B, T, cfg.num_kv_heads,
                                                          cfg.head_dim)
        cv = (enc @ wl["xattn"]["wv"].astype(dt)).reshape(B, T, cfg.num_kv_heads,
                                                          cfg.head_dim)
        return x, {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16),
                   "ck": ck.astype(jnp.bfloat16), "cv": cv.astype(jnp.bfloat16)}

    x, cache = jax.lax.scan(body, tokens_x, w["decoder"])
    return x, cache


def decode_step(cfg, run, ctx, w, cache, x, pos, *, use_flash=False):
    def body(x, inp):
        wl, cl = inp
        B = x.shape[0]
        posv = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
        h = rms_norm(x, wl["ln1"], cfg.norm_eps)
        q = A.project_q(cfg, wl["attn"], h, posv, ctx)
        k, v = A.project_kv(cfg, wl["attn"], h, posv, ctx)
        ck, cv = A.cache_update(cl["k"], cl["v"], k, v, pos)
        if use_flash and ctx.mesh is not None:
            o = A.flash_decode(q, ck, cv, pos, ctx.mesh)
        else:
            o = A.decode_attention(q, ck, cv, pos)
        x = x + o.reshape(B, 1, cfg.q_dim) @ wl["attn"]["wo"].astype(x.dtype)
        hx = rms_norm(x, wl["lnx"], cfg.norm_eps)
        x = x + A.cross_decode(cfg, wl["xattn"], hx, cl["ck"], cl["cv"])
        h2 = rms_norm(x, wl["ln2"], cfg.norm_eps)
        x = x + mlp_apply(wl["mlp"], h2, ctx, act="gelu")
        return x, {"k": ck, "v": cv, "ck": cl["ck"], "cv": cl["cv"]}

    x, cache = jax.lax.scan(body, x, (w["decoder"], cache))
    return x, cache
