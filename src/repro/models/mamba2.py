"""Mamba2 (SSD) blocks and the Zamba2 hybrid stack.

SSD recurrence per head (state h in R^{P x N}, scalar decay per head/step):
    a_t = exp(A * dt_t)            A = -exp(A_log) < 0
    h_t = a_t h_{t-1} + dt_t * x_t B_t^T
    y_t = h_t C_t + D * x_t

Training/prefill use the chunked SSD form: within a chunk the decay matrix
M[t,s] = (C_t . B_s) * exp(Li[t]-Li[s]) * dt_s (s<=t) is a plain per-head
(C x C) matmul operand — MXU-shaped; across chunks state is carried by scan.
Zamba2 = Mamba2 backbone + one weight-tied transformer block applied every
``shared_attn_every`` layers (lax.cond inside the layer scan).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.distributed.sharding import ShardingCtx
from repro.models import params as P
from repro.models import transformer as T
from repro.models.common import rms_norm, rms_norm_specs


def dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    return d_inner, H, cfg.ssm_head_dim, cfg.ssm_state


# --- SSD core -----------------------------------------------------------------------


def ssd_chunked(x, dt, B, C, A_log, D, state, *, chunk: int):
    """x: (b,S,H,P); dt: (b,S,H); B,C: (b,S,N); state: (b,H,P,N).

    Returns (y (b,S,H,P), state_out). On TPU this dispatches to the Pallas
    kernel (repro.kernels.ssd); the body below is the jnp reference path.
    """
    import jax as _jax
    if _jax.default_backend() == "tpu":
        from repro.kernels.ssd import ops as _ssd_ops
        y, st = _ssd_ops.ssd(x, dt, B, C, A_log, D, state, chunk=chunk)
        return y, st
    b, S, H, Pd = x.shape
    N = B.shape[-1]
    if S % chunk:
        pad = chunk - S % chunk
        p3 = lambda z: jnp.pad(z, ((0, 0), (0, pad)) + ((0, 0),) * (z.ndim - 2))
        y, st = ssd_chunked(p3(x), p3(dt), p3(B), p3(C), A_log, D, state,
                            chunk=chunk)
        return y[:, :S], st
    n = S // chunk
    f32 = jnp.float32

    A = -jnp.exp(A_log.astype(f32))  # (H,)

    def resh(z):
        return jnp.moveaxis(z.reshape(b, n, chunk, *z.shape[2:]), 1, 0)

    xc, dtc, Bc, Cc = map(resh, (x, dt, B, C))
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))  # s <= t

    def one_chunk(h_in, inp):
        xx, dd, BB, CC = inp
        xx, dd, BB, CC = (z.astype(f32) for z in (xx, dd, BB, CC))
        la = dd * A[None, None, :]  # (b,C,H) log decay per step
        Li = jnp.cumsum(la, axis=1)  # inclusive
        # M[t,s] per head: (C_t.B_s) exp(Li[t]-Li[s]) dt_s,  s<=t
        cb = jnp.einsum("btn,bsn->bts", CC, BB)
        G = jnp.exp(jnp.clip(Li[:, :, None, :] - Li[:, None, :, :], -60.0, 0.0))
        M = cb[..., None] * G * dd[:, None, :, :]  # (b,t,s,H)
        M = jnp.where(mask[None, :, :, None], M, 0.0)
        y = jnp.einsum("btsh,bshp->bthp", M, xx)
        # contribution from incoming state
        y += jnp.einsum("btn,bhpn,bth->bthp", CC, h_in, jnp.exp(Li))
        # state update
        decay_all = jnp.exp(Li[:, -1])  # (b,H)
        w = jnp.exp(Li[:, -1, None, :] - Li) * dd  # (b,C,H)
        h_out = decay_all[:, :, None, None] * h_in + jnp.einsum(
            "bth,bthp,btn->bhpn", w, xx, BB)
        return h_out, y

    state, ys = jax.lax.scan(one_chunk, state.astype(f32), (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, S, H, Pd)
    y = y + x.astype(f32) * D.astype(f32)[None, None, :, None]
    return y, state


def ssd_step(x, dt, B, C, A_log, D, state):
    """One token. x: (b,H,P); dt: (b,H); B,C: (b,N); state: (b,H,P,N)."""
    f32 = jnp.float32
    x, dt, B, C = (z.astype(f32) for z in (x, dt, B, C))
    a = jnp.exp(dt * (-jnp.exp(A_log.astype(f32)))[None, :])  # (b,H)
    upd = (dt[..., None] * x)[..., None] * B[:, None, None, :]  # (b,H,P,N)
    state = a[..., None, None] * state + upd
    y = jnp.einsum("bhpn,bn->bhp", state, C) + x * D.astype(f32)[None, :, None]
    return y, state


# --- Mamba2 block ---------------------------------------------------------------------


def mamba_specs(cfg: ModelConfig) -> Dict:
    d_inner, H, Pd, N = dims(cfg)
    K = cfg.ssm_conv_width
    conv_ch = d_inner + 2 * N
    return {
        "ln": rms_norm_specs(cfg.d_model),
        "w_in": P.dense((cfg.d_model, 2 * d_inner + 2 * N + H), ("fsdp", "mlp")),
        "conv_w": P.dense((K, conv_ch), ("conv_k", None), scale=0.5),
        "conv_b": P.dense((conv_ch,), (None,), init="zeros"),
        "A_log": P.dense((H,), (None,), init="zeros"),
        "D": P.dense((H,), (None,), init="ones"),
        "dt_bias": P.dense((H,), (None,), init="zeros"),
        "norm_gate": rms_norm_specs(d_inner),
        "w_out": P.dense((d_inner, cfg.d_model), ("mlp", "fsdp")),
    }


def _split_proj(cfg: ModelConfig, z):
    d_inner, H, Pd, N = dims(cfg)
    zs = jnp.split(z, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N],
                   axis=-1)
    gate, xin, B, C, dt = zs
    return gate, xin, B, C, dt


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (b,S,ch); w: (K,ch)."""
    K = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(K):
        shift = K - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]] if shift else x
        out = out + xi * w[i][None, None, :]
    return out + b[None, None, :]


def _conv_step(x_t, conv_state, w, b):
    """x_t: (b,ch); conv_state: (b,K-1,ch) holding previous inputs."""
    K = w.shape[0]
    full = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (b,K,ch)
    out = jnp.einsum("bkc,kc->bc", full, w) + b[None, :]
    return out, full[:, 1:]


def mamba_apply(cfg, ctx: ShardingCtx, w, x, *, chunk):
    b, S, _ = x.shape
    d_inner, H, Pd, N = dims(cfg)
    dt_comp = x.dtype
    h = rms_norm(x, w["ln"], cfg.norm_eps)
    z = h @ w["w_in"].astype(dt_comp)
    z = ctx.constrain(z, ("batch", "seq_inner", "mlp"))
    gate, xin, B, C, dtr = _split_proj(cfg, z)
    conv_in = jnp.concatenate([xin, B, C], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, w["conv_w"].astype(dt_comp),
                                        w["conv_b"].astype(dt_comp)))
    xin, B, C = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + w["dt_bias"].astype(jnp.float32))
    y, _ = ssd_chunked(xin.reshape(b, S, H, Pd), dt, B, C, w["A_log"], w["D"],
                       jnp.zeros((b, H, Pd, N), jnp.float32), chunk=chunk)
    y = y.reshape(b, S, d_inner).astype(dt_comp)
    y = rms_norm(y * jax.nn.silu(gate), w["norm_gate"], cfg.norm_eps)
    out = y @ w["w_out"].astype(dt_comp)
    return ctx.constrain(out, ("batch", "seq", "embed"))


def mamba_prefill(cfg, ctx, w, x, *, chunk):
    b, S, _ = x.shape
    d_inner, H, Pd, N = dims(cfg)
    dt_comp = x.dtype
    h = rms_norm(x, w["ln"], cfg.norm_eps)
    z = h @ w["w_in"].astype(dt_comp)
    gate, xin, B, C, dtr = _split_proj(cfg, z)
    conv_in = jnp.concatenate([xin, B, C], axis=-1)
    K = cfg.ssm_conv_width
    conv_state = jnp.pad(conv_in, ((0, 0), (K - 1, 0), (0, 0)))[:, -(K - 1):] \
        if S >= K - 1 else jnp.pad(conv_in, ((0, 0), (K - 1 - S, 0), (0, 0)))
    conv_out = jax.nn.silu(_causal_conv(conv_in, w["conv_w"].astype(dt_comp),
                                        w["conv_b"].astype(dt_comp)))
    xin, B, C = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + w["dt_bias"].astype(jnp.float32))
    y, ssm = ssd_chunked(xin.reshape(b, S, H, Pd), dt, B, C, w["A_log"], w["D"],
                         jnp.zeros((b, H, Pd, N), jnp.float32), chunk=chunk)
    y = y.reshape(b, S, d_inner).astype(dt_comp)
    y = rms_norm(y * jax.nn.silu(gate), w["norm_gate"], cfg.norm_eps)
    out = y @ w["w_out"].astype(dt_comp)
    state = {"ssm": ssm, "conv": conv_state.astype(jnp.bfloat16)}
    return ctx.constrain(out, ("batch", "seq", "embed")), state


def mamba_decode(cfg, ctx, w, x, state):
    """x: (b,1,d); state: {ssm (b,H,P,N), conv (b,K-1,ch)}."""
    b = x.shape[0]
    d_inner, H, Pd, N = dims(cfg)
    dt_comp = x.dtype
    h = rms_norm(x, w["ln"], cfg.norm_eps)[:, 0]
    z = h @ w["w_in"].astype(dt_comp)
    gate, xin, B, C, dtr = _split_proj(cfg, z)
    conv_in = jnp.concatenate([xin, B, C], axis=-1)
    conv_out, conv_state = _conv_step(conv_in, state["conv"].astype(dt_comp),
                                      w["conv_w"].astype(dt_comp),
                                      w["conv_b"].astype(dt_comp))
    conv_out = jax.nn.silu(conv_out)
    xin, B, C = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + w["dt_bias"].astype(jnp.float32))
    y, ssm = ssd_step(xin.reshape(b, H, Pd), dt, B, C, w["A_log"], w["D"],
                      state["ssm"])
    y = y.reshape(b, d_inner).astype(dt_comp)
    y = rms_norm(y * jax.nn.silu(gate), w["norm_gate"], cfg.norm_eps)
    out = (y @ w["w_out"].astype(dt_comp))[:, None, :]
    return out, {"ssm": ssm, "conv": conv_state.astype(jnp.bfloat16)}


def mamba_state_specs(cfg: ModelConfig, batch: int) -> Dict:
    d_inner, H, Pd, N = dims(cfg)
    K = cfg.ssm_conv_width
    return {
        "ssm": P.dense((batch, H, Pd, N), ("batch", "heads", None, None),
                       init="zeros", dtype="float32"),
        "conv": P.dense((batch, K - 1, d_inner + 2 * N), ("batch", None, "mlp"),
                        init="zeros", dtype="bfloat16"),
    }


# --- Zamba2 hybrid stack ----------------------------------------------------------------


def n_shared_applications(cfg: ModelConfig) -> int:
    e = cfg.shared_attn_every
    return 0 if e <= 0 else sum(1 for i in range(cfg.num_layers) if i % e == e - 1)


def stack_specs(cfg: ModelConfig) -> Dict:
    specs = {"layers": P.stack_tree(cfg.num_layers, mamba_specs(cfg))}
    if cfg.shared_attn_every > 0:
        specs["shared"] = T.block_specs(cfg, moe=False)  # weight-tied, NOT stacked
    return specs


def _is_attn_layer(cfg: ModelConfig, i):
    e = cfg.shared_attn_every
    return (i % e) == (e - 1)


def stack_apply(cfg, run: RunConfig, ctx, w, x, positions, *, chunk):
    from repro.models.scan_utils import grouped_scan

    shared = w.get("shared")

    def body(x, inp):
        i, wl = inp
        x = x + mamba_apply(cfg, ctx, wl, x, chunk=chunk)
        if shared is not None:
            def with_attn(x):
                y, _ = T.block_apply(cfg, run, ctx, shared, x, positions)
                return y

            x = jax.lax.cond(_is_attn_layer(cfg, i), with_attn, lambda x: x, x)
        return x, None

    x, _ = grouped_scan(body, x, (jnp.arange(cfg.num_layers), w["layers"]),
                        cfg.num_layers, run.scan_group, run.remat == "block")
    return x, jnp.float32(0.0)


def hybrid_cache_specs(cfg: ModelConfig, batch: int, cache_len: int) -> Dict:
    from repro.models import attention as A

    specs = {"mamba": P.stack_tree(cfg.num_layers, mamba_state_specs(cfg, batch))}
    napp = n_shared_applications(cfg)
    if napp:
        att = A.cache_specs(cfg, batch, A.effective_cache_len(cfg, cache_len))
        specs["attn"] = P.stack_tree(napp, att)
    return specs


def stack_prefill(cfg, run: RunConfig, ctx, w, x, positions, *, chunk):
    shared = w.get("shared")
    napp = n_shared_applications(cfg)
    B, S = x.shape[:2]

    attn_cache = None
    if napp:
        from repro.models import attention as A
        eff = A.effective_cache_len(cfg, S)
        kshape = (napp, B, eff, cfg.num_kv_heads, cfg.head_dim)
        attn_cache = {"k": jnp.zeros(kshape, jnp.bfloat16),
                      "v": jnp.zeros(kshape, jnp.bfloat16)}

    def body2(carry, inp):
        x, cache = carry
        i, wl = inp
        dx, st = mamba_prefill(cfg, ctx, wl, x, chunk=chunk)
        x = x + dx
        if shared is not None:
            def with_attn(args):
                xx, cc = args
                xo, k, v = T.block_prefill(cfg, run, ctx, shared, xx, positions)
                app = i // cfg.shared_attn_every
                cc = {
                    "k": jax.lax.dynamic_update_index_in_dim(
                        cc["k"], k[:, -cc["k"].shape[2]:].astype(jnp.bfloat16), app, 0),
                    "v": jax.lax.dynamic_update_index_in_dim(
                        cc["v"], v[:, -cc["v"].shape[2]:].astype(jnp.bfloat16), app, 0),
                }
                return xo, cc

            x, cache = jax.lax.cond(_is_attn_layer(cfg, i), with_attn,
                                    lambda a: a, (x, cache))
        return (x, cache), st

    (x, attn_cache), mamba_states = jax.lax.scan(
        body2, (x, attn_cache), (jnp.arange(cfg.num_layers), w["layers"]))
    cache = {"mamba": mamba_states}
    if napp:
        cache["attn"] = attn_cache
    return x, cache


def stack_decode(cfg, run: RunConfig, ctx, w, cache, x, pos, *, use_flash=False):
    shared = w.get("shared")
    napp = n_shared_applications(cfg)
    attn_cache = cache.get("attn")

    def body(carry, inp):
        x, acache = carry
        i, wl, mstate = inp
        dx, mstate = mamba_decode(cfg, ctx, wl, x, mstate)
        x = x + dx
        if shared is not None:
            def with_attn(args):
                xx, cc = args
                app = i // cfg.shared_attn_every
                ck = cc["k"][app]
                cv = cc["v"][app]
                xo, ck, cv = T.block_decode(cfg, run, ctx, shared, xx, ck, cv, pos,
                                            use_flash=use_flash)
                cc = {"k": jax.lax.dynamic_update_index_in_dim(cc["k"], ck, app, 0),
                      "v": jax.lax.dynamic_update_index_in_dim(cc["v"], cv, app, 0)}
                return xo, cc

            x, acache = jax.lax.cond(_is_attn_layer(cfg, i), with_attn,
                                     lambda a: a, (x, acache))
        return (x, acache), mstate

    (x, attn_cache), mamba_states = jax.lax.scan(
        body, (x, attn_cache), (jnp.arange(cfg.num_layers), w["layers"], cache["mamba"]))
    out = {"mamba": mamba_states}
    if napp:
        out["attn"] = attn_cache
    return x, out
