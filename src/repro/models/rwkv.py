"""RWKV6 (Finch): attention-free LM with data-dependent decay linear attention.

WKV6 recurrence per head (state S in R^{dk x dv}):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)

Training/prefill use an exact chunked scan: within a chunk the per-channel
decay matrix A[t,s,c] = exp(L_excl[t,c] - L_incl[s,c]) (always <= 1, so
numerically safe) is contracted with r/k/v; across chunks the state is
carried by ``lax.scan``. The TPU fast path is ``repro.kernels.wkv6``.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.distributed.sharding import ShardingCtx
from repro.models import params as P
from repro.models.common import rms_norm, rms_norm_specs

LORA_MIX = 32
LORA_DECAY = 64


def _num_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.wkv_head_dim


# --- WKV6 core ---------------------------------------------------------------------


def wkv6_chunked(r, k, v, w, u, state, *, chunk: int):
    """r,k,w: (B,S,H,K); v: (B,S,H,V); u: (H,K); state: (B,H,K,V).

    Returns (y (B,S,H,V), state_out). Exact (non-approximate) chunked form.
    On TPU this dispatches to the Pallas kernel (repro.kernels.wkv6); the
    body below is the jnp reference/XLA path.
    """
    import jax as _jax
    if _jax.default_backend() == "tpu":
        from repro.kernels.wkv6 import ops as _wkv_ops
        return _wkv_ops.wkv6(r, k, v, w, u, state, chunk=chunk)
    B, S, H, K = r.shape
    V = v.shape[-1]
    if S % chunk:
        # zero-pad to a chunk multiple: k=0 contributes nothing to y or the
        # kv sum; the returned state is only exact when S %% chunk == 0
        # (prefill callers guarantee that).
        pad = chunk - S % chunk
        padf = lambda z: jnp.pad(z, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, st = wkv6_chunked(padf(r), padf(k), padf(v), padf(w), u, state,
                             chunk=chunk)
        return y[:, :S], st
    n = S // chunk
    f32 = jnp.float32

    def reshape(x):
        return jnp.moveaxis(x.reshape(B, n, chunk, H, x.shape[-1]), 1, 0)

    rc, kc, vc, wc = map(reshape, (r, k, v, w))  # (n, B, chunk, H, *)

    mask = jnp.tril(jnp.ones((chunk, chunk), bool), -1)  # strict lower: s < t

    def one_chunk(S_in, inp):
        rr, kk, vv, ww = [x.astype(f32) for x in inp]  # (B, C, H, *)
        logw = -jnp.exp(ww)  # RWKV6 parameterization: w = exp(-exp(ww)) -> log w
        Li = jnp.cumsum(logw, axis=1)  # inclusive
        Le = Li - logw  # exclusive
        # intra-chunk: A[t,s,c] = exp(Le[t]-Li[s]) for s<t
        A = jnp.exp(jnp.clip(Le[:, :, None] - Li[:, None, :], -60.0, 0.0))
        A = jnp.where(mask[None, :, :, None, None], A, 0.0)  # (B,t,s,H,K)
        tmp = jnp.einsum("bthk,btshk,bshk->btsh", rr, A, kk)
        y = jnp.einsum("btsh,bshv->bthv", tmp, vv)
        # diagonal (s == t) with the u bonus
        y += jnp.einsum("bthk,hk,bthk,bthv->bthv", rr, u.astype(f32), kk, vv)
        # state contribution
        y += jnp.einsum("bthk,bthk,bhkv->bthv", rr, jnp.exp(Le), S_in)
        # state update: S_out = exp(Li[-1]) * S_in + sum_s exp(Li[-1]-Li[s]) k_s v_s^T
        decay_all = jnp.exp(Li[:, -1])  # (B,H,K)
        kd = kk * jnp.exp(Li[:, -1, None] - Li)  # (B,C,H,K)
        S_out = decay_all[..., None] * S_in + jnp.einsum("bshk,bshv->bhkv", kd, vv)
        return S_out, y

    state, ys = jax.lax.scan(one_chunk, state.astype(f32), (rc, kc, vc, wc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, V)
    return y, state


def wkv6_step(r, k, v, w, u, state):
    """Single-token recurrence. r,k,w: (B,H,K); v: (B,H,V); state: (B,H,K,V)."""
    f32 = jnp.float32
    r, k, v, w = (x.astype(f32) for x in (r, k, v, w))
    decay = jnp.exp(-jnp.exp(w))
    kv = k[..., :, None] * v[..., None, :]  # (B,H,K,V)
    y = jnp.einsum("bhk,bhkv->bhv", r, state + u.astype(f32)[None, :, :, None] * kv)
    state = decay[..., None] * state + kv
    return y, state


# --- blocks -------------------------------------------------------------------------


def time_mix_specs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    H = _num_heads(cfg)
    K = cfg.wkv_head_dim
    return {
        "ln": rms_norm_specs(d),
        "mu_base": P.dense((d,), (None,), init="zeros"),
        "mu_rkvwg": P.dense((5, d), (None, None), init="zeros"),
        "lora_A": P.dense((d, 5 * LORA_MIX), ("fsdp", None), scale=0.1),
        "lora_B": P.dense((5, LORA_MIX, d), (None, None, "fsdp"), scale=0.1),
        "wr": P.dense((d, d), ("fsdp", "heads")),
        "wk": P.dense((d, d), ("fsdp", "heads")),
        "wv": P.dense((d, d), ("fsdp", "heads")),
        "wg": P.dense((d, d), ("fsdp", "heads")),
        "w0": P.dense((d,), (None,), init="zeros"),
        "wlora_A": P.dense((d, LORA_DECAY), ("fsdp", None), scale=0.1),
        "wlora_B": P.dense((LORA_DECAY, d), (None, "fsdp"), scale=0.1),
        "u": P.dense((H, K), (None, None), init="zeros"),
        "ln_x": rms_norm_specs(d),
        "wo": P.dense((d, d), ("heads", "fsdp")),
    }


def channel_mix_specs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    return {
        "ln": rms_norm_specs(d),
        "mu_k": P.dense((d,), (None,), init="zeros"),
        "mu_r": P.dense((d,), (None,), init="zeros"),
        "wk": P.dense((d, cfg.d_ff), ("fsdp", "mlp")),
        "wv": P.dense((cfg.d_ff, d), ("mlp", "fsdp")),
        "wr": P.dense((d, d), ("fsdp", None)),
    }


def layer_specs(cfg: ModelConfig) -> Dict:
    return {"tmix": time_mix_specs(cfg), "cmix": channel_mix_specs(cfg)}


def _ddlerp(w, x, xx):
    """Data-dependent token-shift interpolation -> 5 mixed streams (r,k,v,w,g)."""
    dt = x.dtype
    dx = xx - x
    base = x + dx * w["mu_base"].astype(dt)
    lora = jnp.tanh(base @ w["lora_A"].astype(dt))
    lora = lora.reshape(lora.shape[:-1] + (5, LORA_MIX))
    delta = jnp.einsum("...lk,lkd->...ld", lora, w["lora_B"].astype(dt))
    mixed = x[..., None, :] + dx[..., None, :] * (w["mu_rkvwg"].astype(dt) + delta)
    return [mixed[..., i, :] for i in range(5)]


def _decay(w, xw):
    dt = xw.dtype
    lora = jnp.tanh(xw @ w["wlora_A"].astype(dt)) @ w["wlora_B"].astype(dt)
    return w["w0"].astype(dt) + lora  # ww; decay = exp(-exp(ww))


def _split_heads(x, H, K):
    return x.reshape(x.shape[:-1] + (H, K))


def time_mix_apply(cfg: ModelConfig, ctx: ShardingCtx, w, x, xx, state, *, chunk):
    """x: (B,S,d); xx: token-shifted x; state: (B,H,K,V) or None (train from 0)."""
    B, S, d = x.shape
    H, K = _num_heads(cfg), cfg.wkv_head_dim
    h = rms_norm(x, w["ln"], cfg.norm_eps)
    hh = rms_norm(xx, w["ln"], cfg.norm_eps)
    xr, xk, xv, xw, xg = _ddlerp(w, h, hh)
    dt = x.dtype
    r = _split_heads(xr @ w["wr"].astype(dt), H, K)
    k = _split_heads(xk @ w["wk"].astype(dt), H, K)
    v = _split_heads(xv @ w["wv"].astype(dt), H, K)
    g = jax.nn.silu(xg @ w["wg"].astype(dt))
    ww = _split_heads(_decay(w, xw), H, K)
    r = ctx.constrain(r, ("batch", "seq_inner", "heads", "head_dim"))
    k = ctx.constrain(k, ("batch", "seq_inner", "heads", "head_dim"))
    if state is None:
        state = jnp.zeros((B, H, K, K), jnp.float32)
    y, state = wkv6_chunked(r, k, v, ww, w["u"], state, chunk=chunk)
    y = y.reshape(B, S, d).astype(dt)
    y = rms_norm(y, w["ln_x"], cfg.norm_eps)  # stand-in for per-head groupnorm
    out = (y * g) @ w["wo"].astype(dt)
    return ctx.constrain(out, ("batch", "seq", "embed")), state


def channel_mix_apply(cfg: ModelConfig, ctx: ShardingCtx, w, x, xx):
    dt = x.dtype
    h = rms_norm(x, w["ln"], cfg.norm_eps)
    hh = rms_norm(xx, w["ln"], cfg.norm_eps)
    dx = hh - h
    xk = h + dx * w["mu_k"].astype(dt)
    xr = h + dx * w["mu_r"].astype(dt)
    k = jnp.square(jax.nn.relu(xk @ w["wk"].astype(dt)))
    k = ctx.constrain(k, ("batch", "seq_inner", "mlp"))
    v = k @ w["wv"].astype(dt)
    rgate = jax.nn.sigmoid(xr @ w["wr"].astype(dt))
    return ctx.constrain(rgate * v, ("batch", "seq", "embed"))


def _shift(x):
    """xx_t = x_{t-1} (zeros at t=0)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def layer_apply(cfg, run, ctx, w, x, *, chunk):
    xx = _shift(x)
    y, _ = time_mix_apply(cfg, ctx, w["tmix"], x, xx, None, chunk=chunk)
    x = x + y
    xx2 = _shift(x)
    x = x + channel_mix_apply(cfg, ctx, w["cmix"], x, xx2)
    return x


def layer_prefill(cfg, run, ctx, w, x, *, chunk):
    """Like layer_apply but returns decode state (wkv state + last-token xs)."""
    B, S, d = x.shape
    H, K = _num_heads(cfg), cfg.wkv_head_dim
    xx = _shift(x)
    y, wkv_state = time_mix_apply(cfg, ctx, w["tmix"], x, xx, None, chunk=chunk)
    last_tmix = x[:, -1]
    x = x + y
    xx2 = _shift(x)
    last_cmix = x[:, -1]
    x = x + channel_mix_apply(cfg, ctx, w["cmix"], x, xx2)
    state = {"wkv": wkv_state, "last_tmix": last_tmix, "last_cmix": last_cmix}
    return x, state


def layer_decode(cfg, run, ctx, w, x, state):
    """x: (B,1,d); state: {wkv (B,H,K,V), last_tmix (B,d), last_cmix (B,d)}."""
    B, _, d = x.shape
    H, K = _num_heads(cfg), cfg.wkv_head_dim
    xt = x[:, 0]
    xx = state["last_tmix"][:, None, :].astype(x.dtype)
    wt = w["tmix"]
    h = rms_norm(x, wt["ln"], cfg.norm_eps)
    hh = rms_norm(xx, wt["ln"], cfg.norm_eps)
    xr, xk, xv, xw, xg = _ddlerp(wt, h, hh)
    dt = x.dtype
    r = _split_heads(xr @ wt["wr"].astype(dt), H, K)[:, 0]
    k = _split_heads(xk @ wt["wk"].astype(dt), H, K)[:, 0]
    v = _split_heads(xv @ wt["wv"].astype(dt), H, K)[:, 0]
    g = jax.nn.silu(xg @ wt["wg"].astype(dt))
    ww = _split_heads(_decay(wt, xw), H, K)[:, 0]
    y, wkv = wkv6_step(r, k, v, ww, wt["u"], state["wkv"])
    y = y.reshape(B, 1, d).astype(dt)
    y = rms_norm(y, wt["ln_x"], cfg.norm_eps)
    x = x + (y * g) @ wt["wo"].astype(dt)
    # channel mix
    xx2 = state["last_cmix"][:, None, :].astype(x.dtype)
    new_last_cmix = x[:, 0]
    x = x + channel_mix_apply(cfg, ctx, w["cmix"], x, xx2)
    return x, {"wkv": wkv, "last_tmix": xt, "last_cmix": new_last_cmix}


# --- stacked -------------------------------------------------------------------------


def stack_specs(cfg: ModelConfig) -> Dict:
    return {"layers": P.stack_tree(cfg.num_layers, layer_specs(cfg))}


def state_specs(cfg: ModelConfig, batch: int) -> Dict:
    H, K = _num_heads(cfg), cfg.wkv_head_dim
    per_layer = {
        "wkv": P.dense((batch, H, K, K), ("batch", "heads", None, None),
                       init="zeros", dtype="float32"),
        "last_tmix": P.dense((batch, cfg.d_model), ("batch", "embed"),
                             init="zeros", dtype="bfloat16"),
        "last_cmix": P.dense((batch, cfg.d_model), ("batch", "embed"),
                             init="zeros", dtype="bfloat16"),
    }
    return P.stack_tree(cfg.num_layers, per_layer)


def stack_apply(cfg, run, ctx, w, x, *, chunk):
    from repro.models.scan_utils import grouped_scan

    def body(x, wl):
        return layer_apply(cfg, run, ctx, wl, x, chunk=chunk), None

    x, _ = grouped_scan(body, x, w["layers"], cfg.num_layers, run.scan_group,
                        run.remat == "block")
    return x, jnp.float32(0.0)


def stack_prefill(cfg, run, ctx, w, x, *, chunk):
    def body(x, wl):
        return layer_prefill(cfg, run, ctx, wl, x, chunk=chunk)

    x, states = jax.lax.scan(body, x, w["layers"])
    return x, states


def stack_decode(cfg, run, ctx, w, state, x):
    def body(x, inp):
        wl, sl = inp
        return layer_decode(cfg, run, ctx, wl, x, sl)

    x, states = jax.lax.scan(body, x, (w["layers"], state))
    return x, states
