"""Model registry helpers: exact parameter counts from the declarative specs."""

from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig
from repro.models import params as P


def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact count from specs; ``active_only`` scales MoE experts by k/E."""
    from repro.models import lm

    specs = lm.param_specs(cfg)
    total = P.count_params(specs)
    if not active_only or not cfg.is_moe:
        return total
    # Identify expert weights (w_gate/w_up/w_down with leading E axis).
    expert = 0
    # jax.tree_util spelling: jax.tree.flatten_with_path only exists on
    # newer jax lines
    from jax.tree_util import tree_flatten_with_path
    flat, _ = tree_flatten_with_path(specs, is_leaf=P.is_spec)
    for path, spec in flat:
        keys = [getattr(p, "key", None) for p in path]
        if "moe" in keys and any(k in ("w_gate", "w_up", "w_down") for k in keys):
            expert += int(np.prod(spec.shape))
    active = total - expert + expert * cfg.experts_per_token // cfg.num_experts
    return active
