"""Shared model components: norms, RoPE, MLP, embedding, losses."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.distributed.sharding import ShardingCtx
from repro.models import params as P


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def rms_norm_specs(d: int) -> P.TensorSpec:
    return P.dense((d,), (None,), init="ones")


# --- RoPE -------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --- MLP ----------------------------------------------------------------------


def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    ff = d_ff or cfg.d_ff
    return {
        "w_gate": P.dense((cfg.d_model, ff), ("fsdp", "mlp")),
        "w_up": P.dense((cfg.d_model, ff), ("fsdp", "mlp")),
        "w_down": P.dense((ff, cfg.d_model), ("mlp", "fsdp")),
    }


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Matmul that keeps cross-shard partial sums in the compute dtype.

    bf16 x bf16 otherwise accumulates to f32 under SPMD *before* the
    tensor-parallel all-reduce, doubling wire bytes; pinning the dot output
    dtype reduces in bf16 (Megatron behaviour — MXU still accumulates fp32
    within a shard).
    """
    return jnp.matmul(x, w.astype(x.dtype), preferred_element_type=x.dtype)


def mlp_apply(w: dict, x: jax.Array, ctx: ShardingCtx, act: str = "silu") -> jax.Array:
    gate = matmul(x, w["w_gate"])
    up = matmul(x, w["w_up"])
    gate = ctx.constrain(gate, ("batch", "seq_inner", "mlp")[: gate.ndim])
    h = (jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate)) * up
    out = matmul(h, w["w_down"])
    return ctx.constrain(out, ("batch", "seq", "embed")[: out.ndim])


# --- Embedding / logits / loss -------------------------------------------------


def embed_specs(cfg: ModelConfig) -> dict:
    d = {"embedding": P.dense((cfg.vocab_size, cfg.d_model), ("vocab", "fsdp"),
                              init="embed")}
    if not cfg.tie_embeddings:
        d["unembed"] = P.dense((cfg.d_model, cfg.vocab_size), ("fsdp", "vocab"))
    return d


def embed_tokens(w: dict, tokens: jax.Array, ctx: ShardingCtx, dtype) -> jax.Array:
    x = jnp.take(w["embedding"].astype(dtype), tokens, axis=0)
    return ctx.constrain(x, ("batch", "seq", "embed"))


def logits_fn(w: dict, x: jax.Array, ctx: ShardingCtx) -> jax.Array:
    if "unembed" in w:
        logits = matmul(x, w["unembed"])
    else:
        logits = matmul(x, w["embedding"].T)
    return ctx.constrain(logits, ("batch", "seq", "vocab")[: logits.ndim])


def xent_loss(logits: jax.Array, labels: jax.Array, z_loss: float = 1e-4):
    """Cross-entropy over (B, S, V) vs labels (B, S); fp32 reduction.

    Returns (mean_loss, aux) where aux carries the z-loss for logging.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    label_logit = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - label_logit
    zl = z_loss * jnp.square(lse)
    loss = jnp.mean(nll + zl)
    return loss, {"nll": jnp.mean(nll), "z_loss": jnp.mean(zl)}


def compute_dtype(run: RunConfig):
    return jnp.dtype(run.compute_dtype)
