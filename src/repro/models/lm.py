"""Unified model API over all families.

Every architecture exposes the same five entry points, which is what the
launcher, trainer, server and dry-run lower:

  param_specs(cfg)                   declarative parameter pytree
  input_specs(cfg, shape)            batch stand-ins per ShapeSpec
  loss_fn(cfg, run, ctx, params, batch)      -> (loss, metrics)
  cache_specs(cfg, shape)            decode-state pytree
  prefill_fn(...) / decode_fn(...)   serving programs
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeSpec
from repro.distributed.sharding import ShardingCtx
from repro.models import params as P
from repro.models import attention as A
from repro.models import audio as AU
from repro.models import mamba2 as MB
from repro.models import rwkv as RW
from repro.models import transformer as T
from repro.models.common import (compute_dtype, embed_specs, embed_tokens,
                                 logits_fn, rms_norm, rms_norm_specs, xent_loss)

Q_CHUNK = 1024


# --- parameter specs ----------------------------------------------------------------


def param_specs(cfg: ModelConfig) -> Dict:
    if cfg.family in ("dense", "moe", "vlm"):
        stack = T.stack_specs(cfg)
    elif cfg.family == "ssm":
        stack = RW.stack_specs(cfg)
    elif cfg.family == "hybrid":
        stack = MB.stack_specs(cfg)
    elif cfg.family == "audio":
        stack = AU.stack_specs(cfg)
    else:
        raise ValueError(cfg.family)
    return {"embed": embed_specs(cfg), "stack": stack,
            "final_ln": rms_norm_specs(cfg.d_model)}


# --- batch stand-ins -----------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, P.TensorSpec]:
    B, S = shape.global_batch, shape.seq_len
    tok = lambda s: P.dense(s, ("batch", "seq")[: len(s)], init="zeros", dtype="int32")
    if shape.kind == "train":
        batch = {"tokens": tok((B, S)), "labels": tok((B, S))}
    elif shape.kind == "prefill":
        batch = {"tokens": tok((B, S))}
    else:  # decode
        batch = {"tokens": tok((B, 1)),
                 "pos": P.dense((), (), init="zeros", dtype="int32")}
    if cfg.family == "vlm" and shape.kind != "decode":
        batch["image_embeds"] = P.dense(
            (B, cfg.num_image_tokens, cfg.d_model),
            ("batch", "img_seq", "embed"), dtype="bfloat16")
    if cfg.family == "audio" and shape.kind != "decode":
        batch["frame_embeds"] = P.dense(
            (B, cfg.encoder_seq, cfg.d_model),
            ("batch", "img_seq", "embed"), dtype="bfloat16")
    return batch


# --- train loss ------------------------------------------------------------------------


def _positions(tokens: jax.Array) -> jax.Array:
    B, S = tokens.shape
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))


def _backbone(cfg: ModelConfig, run: RunConfig, ctx: ShardingCtx, params, batch,
              tokens):
    dt = compute_dtype(run)
    x = embed_tokens(params["embed"], tokens, ctx, dt)
    positions = _positions(tokens)
    w = params["stack"]
    if cfg.family in ("dense", "moe"):
        x, aux = T.stack_apply(cfg, run, ctx, w, x, positions, q_chunk=Q_CHUNK)
    elif cfg.family == "vlm":
        img = batch["image_embeds"].astype(dt)
        x, aux = T.stack_apply(cfg, run, ctx, w, x, positions, img=img,
                               q_chunk=Q_CHUNK)
    elif cfg.family == "ssm":
        x, aux = RW.stack_apply(cfg, run, ctx, w, x, chunk=cfg.scan_chunk)
    elif cfg.family == "hybrid":
        x, aux = MB.stack_apply(cfg, run, ctx, w, x, positions, chunk=cfg.scan_chunk)
    elif cfg.family == "audio":
        enc = AU.encode(cfg, run, ctx, w, batch["frame_embeds"].astype(dt))
        x = AU.decode_train(cfg, run, ctx, w, x, enc, positions, q_chunk=Q_CHUNK)
        aux = jnp.float32(0.0)
    else:
        raise ValueError(cfg.family)
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    return x, aux


def loss_fn(cfg: ModelConfig, run: RunConfig, ctx: ShardingCtx, params, batch):
    x, aux = _backbone(cfg, run, ctx, params, batch, batch["tokens"])
    logits = logits_fn(params["embed"], x, ctx)
    loss, metrics = xent_loss(logits, batch["labels"])
    loss = loss + aux
    metrics["aux_loss"] = aux
    metrics["loss"] = loss
    return loss, metrics


# --- serving ------------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family in ("dense", "moe", "vlm"):
        return T.stack_cache_specs(cfg, B, S)
    if cfg.family == "ssm":
        return RW.state_specs(cfg, B)
    if cfg.family == "hybrid":
        return MB.hybrid_cache_specs(cfg, B, S)
    if cfg.family == "audio":
        return AU.cache_specs(cfg, B, S)
    raise ValueError(cfg.family)


def prefill_fn(cfg: ModelConfig, run: RunConfig, ctx: ShardingCtx, params, batch):
    """Full-sequence prefill. Returns (last_token_logits (B, V), cache)."""
    dt = compute_dtype(run)
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, ctx, dt)
    positions = _positions(tokens)
    w = params["stack"]
    if cfg.family in ("dense", "moe"):
        x, cache = T.stack_prefill(cfg, run, ctx, w, x, positions, q_chunk=Q_CHUNK)
    elif cfg.family == "vlm":
        img = batch["image_embeds"].astype(dt)
        x, cache = T.stack_prefill(cfg, run, ctx, w, x, positions, img=img,
                                   q_chunk=Q_CHUNK)
    elif cfg.family == "ssm":
        x, cache = RW.stack_prefill(cfg, run, ctx, w, x, chunk=cfg.scan_chunk)
    elif cfg.family == "hybrid":
        x, cache = MB.stack_prefill(cfg, run, ctx, w, x, positions,
                                    chunk=cfg.scan_chunk)
    elif cfg.family == "audio":
        x, cache = AU.prefill(cfg, run, ctx, w, x, batch["frame_embeds"].astype(dt),
                              positions, q_chunk=Q_CHUNK)
    else:
        raise ValueError(cfg.family)
    x = rms_norm(x[:, -1:], params["final_ln"], cfg.norm_eps)
    logits = logits_fn(params["embed"], x, ctx)[:, 0]
    return logits, cache


def decode_fn(cfg: ModelConfig, run: RunConfig, ctx: ShardingCtx, params, cache,
              batch):
    """One decode step. batch: {tokens (B,1), pos ()}. Returns (logits, cache)."""
    dt = compute_dtype(run)
    tokens, pos = batch["tokens"], batch["pos"]
    use_flash = run.sharding_rules == "decode_flash"
    x = embed_tokens(params["embed"], tokens, ctx, dt)
    w = params["stack"]
    if cfg.family in ("dense", "moe", "vlm"):
        x, cache = T.stack_decode(cfg, run, ctx, w, cache, x, pos,
                                  use_flash=use_flash)
    elif cfg.family == "ssm":
        x, cache = RW.stack_decode(cfg, run, ctx, w, cache, x)
    elif cfg.family == "hybrid":
        x, cache = MB.stack_decode(cfg, run, ctx, w, cache, x, pos,
                                   use_flash=use_flash)
    elif cfg.family == "audio":
        x, cache = AU.decode_step(cfg, run, ctx, w, cache, x, pos,
                                  use_flash=use_flash)
    else:
        raise ValueError(cfg.family)
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = logits_fn(params["embed"], x, ctx)[:, 0]
    return logits, cache
