"""Grouped scan-over-layers with two-level rematerialization.

Flat scan + per-layer remat stores one residual-stream slice per layer
(O(L) activation memory). Grouping into sqrt(L)-ish chunks with checkpoints
at both levels stores O(L/G + G) slices — the standard deep-stack memory
policy (selected per arch via RunConfig.scan_group).
"""

from __future__ import annotations

import jax


def grouped_scan(body, carry, xs_tree, n: int, group: int, remat: bool):
    """scan(body) over leading axis n, optionally in groups of ``group``.

    body: (carry, x_slice) -> (carry, y_slice | None)
    """
    if group <= 1 or n % group != 0:
        f = jax.checkpoint(body) if remat else body
        return jax.lax.scan(f, carry, xs_tree)
    n_outer = n // group
    xs2 = jax.tree.map(lambda a: a.reshape((n_outer, group) + a.shape[1:]), xs_tree)
    inner = jax.checkpoint(body) if remat else body

    def outer(c, xg):
        return jax.lax.scan(inner, c, xg)

    outer_f = jax.checkpoint(outer) if remat else outer
    carry, ys = jax.lax.scan(outer_f, carry, xs2)
    if ys is not None:
        ys = jax.tree.map(lambda a: a.reshape((n,) + a.shape[2:]), ys)
    return carry, ys
