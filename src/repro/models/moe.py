"""Mixture-of-Experts layer: top-k router + capacity-based matmul dispatch
(GShard-style), expert-parallel over the ``model`` mesh axis.

Baseline uses the dense dispatch/combine einsum (TPU-friendly, MXU-shaped);
its extra dispatch FLOPs are visible in the roofline MODEL/HLO ratio and are
the target of the MoE hillclimb (§Perf), which switches to a sort-based
dispatch. Tokens are processed in groups of ``moe_group`` so the (g, E, C)
combine tensor stays bounded regardless of sequence length.
"""

from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingCtx
from repro.models import params as P
from repro.models.common import mlp_apply, mlp_specs

MOE_GROUP = 4096
CAPACITY_FACTOR = 1.25


def moe_specs(cfg: ModelConfig) -> Dict[str, P.TensorSpec]:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    specs = {
        "router": P.dense((d, E), ("fsdp", "experts"), scale=0.1),
        "w_gate": P.dense((E, d, ff), ("experts", "fsdp", "expert_mlp")),
        "w_up": P.dense((E, d, ff), ("experts", "fsdp", "expert_mlp")),
        "w_down": P.dense((E, ff, d), ("experts", "expert_mlp", "fsdp")),
    }
    if cfg.shared_expert:
        specs["shared"] = mlp_specs(cfg, d_ff=cfg.d_ff)
    return specs


def capacity(group: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(group * cfg.experts_per_token * CAPACITY_FACTOR / cfg.num_experts))
    return max(8, ((c + 127) // 128) * 128) if c > 8 else max(c, 4)


def _route(cfg: ModelConfig, logits: jax.Array):
    """logits (g, E) -> weights (g, k), ids (g, k), router probs (g, E)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, ids = jax.lax.top_k(probs, cfg.experts_per_token)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)  # olmoe renorm
    return w, ids, probs


def _combine_tensor(cfg: ModelConfig, w, ids, C: int):
    """Build (g, E, C) combine weights via per-k accumulation (GShard)."""
    g, k = ids.shape
    E = cfg.num_experts
    onehot = jax.nn.one_hot(ids, E, dtype=jnp.float32)  # (g, k, E)
    # Priority order: all k=0 choices first, then k=1, ... (GShard semantics).
    flat = jnp.moveaxis(onehot, 1, 0).reshape(k * g, E)
    pos = jnp.cumsum(flat, axis=0) - flat  # (k*g, E) slot index per assignment
    pos = pos.reshape(k, g, E)
    combine = jnp.zeros((g, E, C), jnp.float32)
    for j in range(k):
        slot = jnp.sum(pos[j] * onehot[:, j], axis=-1)  # (g,)
        keep = slot < C
        slot_oh = jax.nn.one_hot(slot, C, dtype=jnp.float32)  # (g, C)
        contrib = (w[:, j] * keep)[:, None, None] * onehot[:, j][:, :, None] * slot_oh[:, None, :]
        combine = combine + contrib
    return combine


def _moe_group_apply(cfg: ModelConfig, ctx: ShardingCtx, wts, xg: jax.Array):
    """xg: (g, d) one token group -> (y (g, d), aux)."""
    dt = xg.dtype
    g = xg.shape[0]
    C = capacity(g, cfg)
    logits = xg @ wts["router"].astype(dt)  # (g, E)
    w, ids, probs = _route(cfg, logits)
    combine = _combine_tensor(cfg, w, ids, C)  # (g, E, C) f32
    combine = ctx.constrain(combine, ("batch", "experts", "capacity"))
    dispatch = (combine > 0).astype(dt)
    xe = jnp.einsum("gec,gd->ecd", dispatch, xg)
    xe = ctx.constrain(xe, ("experts", "capacity", "embed"))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wts["w_gate"].astype(dt)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, wts["w_up"].astype(dt))
    h = ctx.constrain(h, ("experts", "capacity", "expert_mlp"))
    ye = jnp.einsum("ecf,efd->ecd", h, wts["w_down"].astype(dt))
    ye = ctx.constrain(ye, ("experts", "capacity", "embed"))
    y = jnp.einsum("gec,ecd->gd", combine.astype(dt), ye)
    # Load-balancing aux loss (Switch): E * mean_e(frac_tokens_e * mean_prob_e)
    assign = jnp.sum((combine > 0), axis=2).astype(jnp.float32)  # (g, E)
    frac = jnp.mean(assign, axis=0) / cfg.experts_per_token
    mean_prob = jnp.mean(probs, axis=0)
    aux = cfg.num_experts * jnp.sum(frac * mean_prob)
    return y, aux


def moe_apply(cfg: ModelConfig, ctx: ShardingCtx, wts, x: jax.Array,
              impl: str = "dense"):
    """x: (B, S, d) -> (y, aux_loss). impl="a2a" uses the shard_map
    expert-parallel path (requires a mesh with a model axis)."""
    if impl == "a2a" and ctx.mesh is not None and "model" in ctx.mesh.axis_names:
        from repro.models.moe_a2a import moe_a2a_apply
        return moe_a2a_apply(cfg, ctx, wts, x)
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    group = min(MOE_GROUP, T)
    if T % group != 0:
        group = T
    n_groups = T // group
    if n_groups == 1:
        y, aux = _moe_group_apply(cfg, ctx, wts, xf)
    else:
        xg = xf.reshape(n_groups, group, d)

        def body(_, xc):
            return None, _moe_group_apply(cfg, ctx, wts, xc)

        _, (ys, auxs) = jax.lax.scan(body, None, xg)
        y, aux = ys.reshape(T, d), jnp.mean(auxs)
    y = y.reshape(B, S, d)
    if cfg.shared_expert:
        y = y + mlp_apply(wts["shared"], x, ctx, cfg.act)
    return y, aux * cfg.router_aux_loss
