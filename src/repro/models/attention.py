"""GQA attention: train (dense + chunked online-softmax), decode (KV cache,
ring-buffer SWA, shard_map flash-decoding), and cross-attention.

The chunked path is the pure-JAX flash attention used for large lowerings
(bounded temp memory); the Pallas kernel in ``repro.kernels.flash_attention``
is the TPU fast path with the same oracle semantics.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingCtx
from repro.models import params as P
from repro.models.common import apply_rope, matmul

NEG_INF = -1e30


# --- parameter specs -----------------------------------------------------------


def attn_specs(cfg: ModelConfig, *, cross: bool = False) -> Dict[str, P.TensorSpec]:
    d = cfg.d_model
    specs = {
        "wq": P.dense((d, cfg.q_dim), ("fsdp", "heads")),
        "wk": P.dense((d, cfg.kv_dim), ("fsdp", "kv_heads")),
        "wv": P.dense((d, cfg.kv_dim), ("fsdp", "kv_heads")),
        "wo": P.dense((cfg.q_dim, d), ("heads", "fsdp")),
    }
    if cfg.qkv_bias and not cross:
        specs["bq"] = P.dense((cfg.q_dim,), ("heads",), init="zeros")
        specs["bk"] = P.dense((cfg.kv_dim,), ("kv_heads",), init="zeros")
        specs["bv"] = P.dense((cfg.kv_dim,), ("kv_heads",), init="zeros")
    return specs


def project_q(cfg: ModelConfig, w, x, positions, ctx: ShardingCtx, *, rope=True):
    dt = x.dtype
    q = matmul(x, w["wq"])
    if "bq" in w:
        q = q + w["bq"].astype(dt)
    B, S = x.shape[:2]
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
    return ctx.constrain(q, ("batch", "seq_inner", "heads", "head_dim"))


def project_kv(cfg: ModelConfig, w, x, positions, ctx: ShardingCtx, *, rope=True):
    dt = x.dtype
    k = matmul(x, w["wk"])
    v = matmul(x, w["wv"])
    if "bk" in w:
        k = k + w["bk"].astype(dt)
        v = v + w["bv"].astype(dt)
    B, S = x.shape[:2]
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if rope:
        k = apply_rope(k, positions, cfg.rope_theta)
    k = ctx.constrain(k, ("batch", "seq_inner", "kv_heads", "head_dim"))
    v = ctx.constrain(v, ("batch", "seq_inner", "kv_heads", "head_dim"))
    return k, v


# --- core attention math ---------------------------------------------------------


def _split_groups(q: jax.Array, num_kv: int) -> jax.Array:
    """(B,S,Hq,D) -> (B,S,Hkv,G,D)."""
    B, S, Hq, D = q.shape
    return q.reshape(B, S, num_kv, Hq // num_kv, D)


def _mask(sq: int, skv: int, q_offset, *, causal: bool, window: int) -> jax.Array:
    """(sq, skv) boolean mask of allowed positions."""
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    m = jnp.ones((sq, skv), bool)
    if causal:
        m &= kpos <= qpos
    if window > 0:
        m &= kpos > (qpos - window)
    return m


def attention_dense(q, k, v, *, causal=True, window=0, softcap=0.0, q_offset=0):
    """Reference full-materialization GQA attention. q:(B,Sq,Hq,D) k/v:(B,Skv,Hkv,D)."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    qg = _split_groups(q, Hkv)  # (B,Sq,Hkv,G,D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(D).astype(jnp.float32)
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    m = _mask(Sq, k.shape[1], q_offset, causal=causal, window=window)
    scores = jnp.where(m[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return out.reshape(B, Sq, Hq, D)


def attention_chunked(q, k, v, *, causal=True, window=0, softcap=0.0,
                      q_chunk=1024, ctx: Optional[ShardingCtx] = None):
    """Online-softmax attention, scanning over query chunks.

    Temp memory is O(q_chunk x Skv) instead of O(Sq x Skv). For SWA the kv
    range per chunk is statically sliced to [chunk_start - window, chunk_end],
    so HLO FLOPs scale with the window, not the full sequence.
    """
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    if Sq % q_chunk != 0:
        return attention_dense(q, k, v, causal=causal, window=window, softcap=softcap)
    n_chunks = Sq // q_chunk
    qg = _split_groups(q, Hkv).reshape(B, n_chunks, q_chunk, Hkv, Hq // Hkv, D)
    qg = jnp.moveaxis(qg, 1, 0)  # (n_chunks, B, qc, Hkv, G, D)
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)

    # Static kv slicing for SWA: chunk i sees kv [max(0, i*qc + qc - window - qc), ...]
    use_window_slice = causal and window > 0 and window % q_chunk == 0

    def one_chunk(i, qc_block):
        if use_window_slice:
            span = window + q_chunk
            start = jnp.maximum(i * q_chunk + q_chunk - span, 0)
            kc = jax.lax.dynamic_slice_in_dim(k, start, min(span, k.shape[1]), axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, min(span, k.shape[1]), axis=1)
            kv_off = start
        else:
            kc, vc, kv_off = k, v, 0
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qc_block, kc).astype(jnp.float32) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        qpos = jnp.arange(q_chunk)[:, None] + i * q_chunk
        kpos = jnp.arange(kc.shape[1])[None, :] + kv_off
        m = jnp.ones(s.shape[-2:], bool)
        if causal:
            m &= kpos <= qpos
        if window > 0:
            m &= kpos > (qpos - window)
        s = jnp.where(m[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vc)
        return o

    def body(carry, inp):
        i, qc = inp
        return carry, one_chunk(i, qc)

    _, outs = jax.lax.scan(body, None, (jnp.arange(n_chunks), qg))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Hq, D)
    return out


def _flash_blocks(n: int) -> int:
    for b in (512, 256, 128):
        if n % b == 0:
            return b
    return 0


def attention_auto(q, k, v, *, causal=True, window=0, softcap=0.0, q_chunk=1024,
                   ctx: Optional[ShardingCtx] = None):
    """Backend dispatch: Pallas flash kernel on TPU (or forced interpret via
    REPRO_ATTN=pallas_interpret for integration tests); otherwise the pure-
    jnp paths — chunked online-softmax at/beyond 2k tokens (bounds the
    scores temp at q_chunk x Skv), dense below."""
    import os
    force = os.environ.get("REPRO_ATTN", "")
    on_tpu = jax.default_backend() == "tpu"
    if (on_tpu or force == "pallas_interpret") and force != "ref":
        bq, bk = _flash_blocks(q.shape[1]), _flash_blocks(k.shape[1])
        if bq and bk:
            from repro.kernels.flash_attention import ops as fa
            return fa.flash_attention(q, k, v, causal, window, softcap,
                                      None if on_tpu else True)
    if q.shape[1] >= 2048 and q.shape[1] % q_chunk == 0:
        return attention_chunked(q, k, v, causal=causal, window=window,
                                 softcap=softcap, q_chunk=q_chunk, ctx=ctx)
    return attention_dense(q, k, v, causal=causal, window=window, softcap=softcap)


# --- KV cache / decode -------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int) -> Dict[str, P.TensorSpec]:
    shp = (batch, cache_len, cfg.num_kv_heads, cfg.head_dim)
    logical = ("cache_batch", "cache_seq", "cache_heads", "head_dim")
    return {
        "k": P.dense(shp, logical, init="zeros", dtype="bfloat16"),
        "v": P.dense(shp, logical, init="zeros", dtype="bfloat16"),
    }


def effective_cache_len(cfg: ModelConfig, seq_len: int) -> int:
    # SWA caches are always window-sized ring buffers (decode continues past
    # the prefill length; index = pos %% window).
    if cfg.sliding_window > 0:
        return cfg.sliding_window
    return seq_len


def ring_layout(kv: jax.Array, window: int) -> jax.Array:
    """(B, S, H, D) full-prefill kv -> (B, window, H, D) ring-buffer layout
    where position p sits at index p %% window (zero-padded when S < window)."""
    S = kv.shape[1]
    if window <= 0:
        return kv
    if S < window:
        pad = [(0, 0)] * kv.ndim
        pad[1] = (0, window - S)
        return jnp.pad(kv, pad)
    tail = kv[:, -window:]
    return jnp.roll(tail, shift=S % window, axis=1)


def cache_update(cache_k, cache_v, k_new, v_new, pos, *, window=0):
    """Insert one token at pos (ring-buffer for SWA). k_new: (B,1,Hkv,D)."""
    cache_len = cache_k.shape[1]
    idx = jnp.where(window > 0, pos % cache_len, pos).astype(jnp.int32)
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new.astype(cache_k.dtype), idx, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new.astype(cache_v.dtype), idx, axis=1)
    return ck, cv


def decode_attention(q, cache_k, cache_v, pos, *, window=0, softcap=0.0):
    """One-token attention against the cache. q: (B,1,Hq,D)."""
    B, _, Hq, D = q.shape
    Hkv = cache_k.shape[2]
    S = cache_k.shape[1]
    qg = _split_groups(q, Hkv)[:, 0]  # (B,Hkv,G,D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, cache_k.astype(q.dtype)).astype(jnp.float32)
    s = s / jnp.sqrt(D).astype(jnp.float32)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    kpos = jnp.arange(S)
    if window > 0:
        valid = kpos < jnp.minimum(pos + 1, S)  # ring buffer: all slots valid once full
    else:
        valid = kpos <= pos
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, cache_v.astype(q.dtype))
    return out.reshape(B, 1, Hq, D)


def flash_decode(q, cache_k, cache_v, pos, mesh, *, axis="model", softcap=0.0,
                 window=0, q_replicated=True):
    """Sequence-sharded decode attention (flash-decoding on TPU).

    The KV cache is batch-sharded over data and seq-sharded over ``axis``;
    each shard computes a partial (out, lse) and the results combine with
    the log-sum-exp trick via psum — one small collective instead of
    gathering the cache.

    ``q_replicated=True`` (the decode_flash ruleset): single-token
    activations are replicated over the data axis, so each shard slices the
    batch rows matching its cache shard, attends locally, and a tiny
    all_gather re-replicates the output.
    """
    from jax.experimental.shard_map import shard_map

    B, _, Hq, D = q.shape
    S = cache_k.shape[1]
    n_shards = mesh.devices.shape[list(mesh.axis_names).index(axis)]
    shard_len = S // n_shards
    Hkv = cache_k.shape[2]
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def per_shard(q_, ck_, cv_, pos_):
        if q_replicated and batch_axes:
            b_loc = ck_.shape[0]
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            bidx = jax.lax.axis_index(batch_axes[0])
            for a in batch_axes[1:]:  # row-major over the joint batch axes
                bidx = bidx * sizes[a] + jax.lax.axis_index(a)
            q_ = jax.lax.dynamic_slice_in_dim(q_, bidx * b_loc, b_loc, axis=0)
        B_loc, _, Hq_, D_ = q_.shape  # per-shard shapes (batch is sharded)
        shard_id = jax.lax.axis_index(axis)
        base = shard_id * shard_len
        qg = _split_groups(q_, Hkv)[:, 0]
        s = jnp.einsum("bhgd,bkhd->bhgk", qg, ck_.astype(q_.dtype)).astype(jnp.float32)
        s = s / jnp.sqrt(D).astype(jnp.float32)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        kpos = jnp.arange(shard_len) + base
        if window > 0:
            valid = jnp.arange(shard_len) + base < jnp.minimum(pos_[0] + 1, S)
        else:
            valid = kpos <= pos_[0]
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        # guard all-masked shards
        m_safe = jnp.maximum(m, NEG_INF / 2)
        e = jnp.exp(s - m_safe)
        denom = jnp.sum(e, axis=-1, keepdims=True)
        o = jnp.einsum("bhgk,bkhd->bhgd", e.astype(q_.dtype), cv_.astype(q_.dtype))
        # LSE-combine across shards.
        lse = m_safe[..., 0] + jnp.log(jnp.maximum(denom[..., 0], 1e-30))
        g_max = jax.lax.pmax(lse, axis)
        w = jnp.exp(lse - g_max)  # (B,Hkv,G)
        o = o * (w / jnp.maximum(denom[..., 0], 1e-30))[..., None].astype(q_.dtype)
        o = jax.lax.psum(o.astype(jnp.float32), axis)
        z = jax.lax.psum(w, axis)
        o = (o / z[..., None]).astype(q_.dtype)
        o = o.reshape(B_loc, 1, Hq_, D_)
        if q_replicated and batch_axes:
            for a in reversed(batch_axes):  # tiny: (B,1,Hq,D) bf16
                o = jax.lax.all_gather(o, a, axis=0, tiled=True)
        return o

    spec_q = PS(None) if q_replicated or not batch_axes else PS(batch_axes)
    spec_kv = PS(batch_axes if batch_axes else None, axis)
    fn = shard_map(
        per_shard, mesh=mesh,
        in_specs=(spec_q, spec_kv, spec_kv, PS()),
        out_specs=spec_q,
        check_rep=False,
    )
    return fn(q, cache_k, cache_v, jnp.broadcast_to(pos, (1,)))


# --- cross attention ------------------------------------------------------------


def cross_attention(cfg: ModelConfig, w, x, enc, ctx: ShardingCtx):
    """q from x (B,S,d); kv from enc (B,T,d). No causal mask, no rope."""
    dt = x.dtype
    B, S = x.shape[:2]
    T = enc.shape[1]
    q = matmul(x, w["wq"]).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = matmul(enc, w["wk"]).reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
    v = matmul(enc, w["wv"]).reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
    q = ctx.constrain(q, ("batch", "seq", "heads", "head_dim"))
    out = attention_dense(q, k, v, causal=False)
    return matmul(out.reshape(B, S, cfg.q_dim), w["wo"])


def cross_decode(cfg: ModelConfig, w, x, ck, cv):
    """Decode-time cross attention against precomputed encoder KV."""
    dt = x.dtype
    B = x.shape[0]
    q = matmul(x, w["wq"]).reshape(B, 1, cfg.num_heads, cfg.head_dim)
    out = attention_dense(q, ck.astype(dt), cv.astype(dt), causal=False)
    return matmul(out.reshape(B, 1, cfg.q_dim), w["wo"])
