"""Declarative parameter specs.

Each model family declares its parameters once, as a pytree of ``TensorSpec``
(shape + logical axes + initializer). From that single source of truth we
derive:

  * materialized parameters for CPU smoke tests / real small-scale training,
  * ``jax.ShapeDtypeStruct`` stand-ins with ``NamedSharding`` for the
    multi-pod dry-run (no allocation),
  * ``PartitionSpec`` trees for jit in/out shardings,
  * analytic parameter counts for 6ND roofline cross-checks.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ShardingCtx


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | embed | small
    scale: float = 1.0
    dtype: Optional[str] = None  # None -> run param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_spec(x: Any) -> bool:
    return isinstance(x, TensorSpec)


def _leaf_dtype(spec: TensorSpec, default_dtype) -> Any:
    return jnp.dtype(spec.dtype) if spec.dtype else jnp.dtype(default_dtype)


def materialize(tree, rng: jax.Array, dtype="float32"):
    """Instantiate real parameters (used by smoke tests and real training)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for spec, key in zip(leaves, keys):
        dt = _leaf_dtype(spec, dtype)
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, dt)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, dt)
        else:
            fan_in = spec.shape[-1] if spec.init == "embed" else (
                spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1])
            std = spec.scale / np.sqrt(max(fan_in, 1))
            arr = (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dt)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def shape_dtype_tree(tree, ctx: ShardingCtx, dtype="float32"):
    """ShapeDtypeStructs with shardings — the dry-run stand-ins."""

    def f(spec: TensorSpec):
        dt = _leaf_dtype(spec, dtype)
        if ctx.mesh is None:
            return jax.ShapeDtypeStruct(spec.shape, dt)
        return jax.ShapeDtypeStruct(spec.shape, dt, sharding=ctx.sharding(spec.logical, spec.shape))

    return jax.tree.map(f, tree, is_leaf=is_spec)


def partition_specs(tree, ctx: ShardingCtx):
    return jax.tree.map(lambda s: ctx.spec(s.logical, s.shape), tree, is_leaf=is_spec)


def count_params(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_spec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))


def map_specs(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def spec_like(spec: TensorSpec, **overrides) -> TensorSpec:
    return dataclasses.replace(spec, **overrides)


# --- helpers used by model definitions -------------------------------------


def dense(shape: Sequence[int], logical: Sequence[Optional[str]], *, scale=1.0,
          dtype: Optional[str] = None, init="normal") -> TensorSpec:
    return TensorSpec(tuple(shape), tuple(logical), init=init, scale=scale, dtype=dtype)


def stacked(n_layers: int, spec: TensorSpec) -> TensorSpec:
    """Prepend a scanned ``layers`` axis."""
    return TensorSpec((n_layers,) + spec.shape, ("layers",) + spec.logical,
                      init=spec.init, scale=spec.scale, dtype=spec.dtype)


def stack_tree(n_layers: int, tree):
    return map_specs(lambda s: stacked(n_layers, s), tree)
