"""Scalar vs batched submission through the Bento boundary.

Run:  PYTHONPATH=src python examples/batched_io_demo.py

Shows the three ways to talk to a mounted Bento file system:

1. scalar ops        — one gate-crossing, one dispatch per call (§4.3);
2. ``Mount.submit``  — a list of SubmissionEntry records crosses the
   boundary once; per-entry errors come back as errno values;
3. ``BentoQueue``    — the io_uring-style SQ/CQ wrapper: ``prep`` stages,
   ``submit`` crosses, ``drain`` collects completions in order.

The printed counters make the batching visible: gate crossings, bulk
buffer-cache passes, and journal checksum launches per flushed batch.
"""

import time

from repro.core.interface import SubmissionEntry
from repro.core.registry import BentoQueue
from repro.fs.mounts import make_mount

N = 2048
SIZE = 4096


def main() -> None:
    mf = make_mount("bento", n_blocks=16384)
    v, m, ks = mf.view, mf.mount, mf.services

    data = bytes(range(256)) * (SIZE // 256)
    v.write_file("/demo", data * 1024)   # 4 MiB: larger than trivially warm
    v.fsync("/demo")
    ino = v.stat("/demo").ino
    n_off = 1024

    # --- 1. scalar: one boundary crossing per op ----------------------------
    g0 = m.gate.crossings
    t0 = time.perf_counter()
    for i in range(N):
        v.read_file("/demo", off=(i % n_off) * SIZE, size=SIZE)
    scalar_s = time.perf_counter() - t0
    print(f"scalar : {N} reads, {m.gate.crossings - g0} gate crossings, "
          f"{N / scalar_s:,.0f} ops/s")

    # --- 2. submission batches (depth 256: batches bigger than the working
    # set stop paying — let the queue's auto-submit pick the cadence) -------
    BATCH = 256
    g0, b0 = m.gate.crossings, ks.counters["bread_many_calls"]
    t0 = time.perf_counter()
    n_ok = 0
    for b in range(N // BATCH):
        comps = m.submit([
            SubmissionEntry("read", (ino, ((b * BATCH + i) % n_off) * SIZE,
                                     SIZE), user_data=b * BATCH + i)
            for i in range(BATCH)])
        # tally and drop: hoarding every CompletionEntry across batches
        # costs ~40% in GC survivor pressure (why io_uring's CQ is a ring)
        n_ok += sum(1 for c in comps if c.ok)
    batched_s = time.perf_counter() - t0
    assert n_ok == N
    print(f"batched: {N} reads, {m.gate.crossings - g0} gate crossings, "
          f"{ks.counters['bread_many_calls'] - b0} bulk cache passes, "
          f"{N / batched_s:,.0f} ops/s  "
          f"({scalar_s / batched_s:.2f}x)")

    # --- errno isolation: a bad entry doesn't poison its neighbours ---------
    comps = m.submit([
        SubmissionEntry("read", (ino, 0, 8), user_data="good"),
        SubmissionEntry("read", (999999, 0, 8), user_data="bad"),
        SubmissionEntry("read", (ino, 8, 8), user_data="also-good"),
    ])
    print("mixed  :", [(c.user_data, "ok" if c.ok else c.errno.name)
                       for c in comps])

    # --- 3. BentoQueue + one checksum launch per flushed write batch --------
    q = BentoQueue(m, depth=32)
    c0 = ks.counters["checksum_batch_calls"]
    for i in range(16):
        q.prep("write", ino, i * SIZE, b"Q" * SIZE, user_data=i)
    q.prep("flush", user_data="flush")   # commits the whole batch
    q.submit()
    done = q.drain()
    print(f"queue  : {len(done)} completions, "
          f"{ks.counters['checksum_batch_calls'] - c0} journal checksum "
          f"launch(es) for the whole write batch")

    mf.close()


if __name__ == "__main__":
    main()
