"""The paper's headline feature, live: upgrade the file system under a
running workload AND hot-swap a trainer module mid-run (§4.8) — the same
quiesce -> extract -> migrate -> restore protocol both times.

    PYTHONPATH=src python examples/online_upgrade_demo.py
"""

import threading
import time

from repro.configs import registry
from repro.core.upgrade import transfer_state, upgrade
from repro.fs.ext4like import Ext4LikeFileSystem
from repro.fs.mounts import make_mount
from repro.fs.xv6 import Xv6FileSystem, Xv6Options
from repro.train.trainer import Trainer


def fs_upgrade_under_load():
    print("== 1. file system hot-upgrade under load ==")
    mf = make_mount("bento", n_blocks=16384)
    v = mf.view
    v.makedirs("/w")
    stop = threading.Event()
    ops = {"n": 0, "errors": 0}

    def workload():
        i = 0
        while not stop.is_set():
            try:
                v.write_file(f"/w/f{i % 32}", b"payload" * 512)
                v.read_file(f"/w/f{i % 32}")
                ops["n"] += 2
            except Exception:  # noqa: BLE001
                ops["errors"] += 1
            i += 1

    t = threading.Thread(target=workload, daemon=True)
    t.start()
    time.sleep(0.5)
    for gen, new_fs in ((2, Xv6FileSystem(Xv6Options())),
                        (3, Ext4LikeFileSystem())):
        migrate = (lambda s, o, n: {**s, "dirindex": {}}) \
            if isinstance(new_fs, Ext4LikeFileSystem) else None
        stats = upgrade(mf.mount, new_fs, migrate=migrate)
        print(f"  upgrade -> gen {mf.mount.generation} "
              f"({type(new_fs).__name__}): pause "
              f"{stats['total_s']*1e3:.2f} ms (quiesce "
              f"{stats['quiesce_s']*1e3:.2f} ms)")
        time.sleep(0.3)
    stop.set()
    t.join(5)
    print(f"  {ops['n']} ops during upgrades, {ops['errors']} failures")
    assert ops["errors"] == 0
    mf.close()


def trainer_module_upgrade():
    print("== 2. trainer hot-swap (optimizer hyper-upgrade mid-run) ==")
    b = registry.get("smollm-135m")
    run_v1 = b.run.replace(microbatch_per_data_shard=0, learning_rate=3e-4)
    t1 = Trainer(b.smoke, run_v1, global_batch=4, seq_len=32)
    t1.train(5)
    print(f"  v1 @ step {t1.step_idx}: loss {t1.metrics_log[-1]['loss']:.4f}")

    # "new release": higher LR schedule — new Trainer, transferred state
    run_v2 = run_v1.replace(learning_rate=1e-3)
    t2 = Trainer(b.smoke, run_v2, global_batch=4, seq_len=32)
    t2.VERSION = 2
    transfer_state(t1, t2)  # quiesce/extract/restore — moments preserved
    assert t2.step_idx == 5
    t2.train(10)
    print(f"  v2 @ step {t2.step_idx}: loss {t2.metrics_log[-1]['loss']:.4f} "
          "(optimizer moments survived the swap)")


if __name__ == "__main__":
    fs_upgrade_under_load()
    trainer_module_upgrade()
    print("OK")
